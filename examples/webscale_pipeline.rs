//! **End-to-end driver** (DESIGN.md §4): stream a multi-million-edge
//! synthetic webgraph through the full three-layer stack —
//!
//!   1. the *generator* streams edges in chunks through bounded channels;
//!   2. *shard workers* (Layer 3) perform local contractions of their
//!      partitions with streaming union-find, under real backpressure;
//!   3. the *summary graph* (one spanning edge per local merge) is solved
//!      by the paper's LocalContraction on the MPC simulator, with the
//!      per-phase labels computed by the **compiled XLA artifact** (the
//!      Layer-1 Pallas kernel lowered through the Layer-2 JAX graph) once
//!      the contracted graph fits a shard;
//!   4. the final labels are cross-checked against the sequential oracle.
//!
//! Run with `make artifacts` done first to exercise the XLA path:
//!
//!     cargo run --release --example webscale_pipeline [n] [avg_deg] [machines] [spill_budget]
//!
//! `machines` sweeps the simulator shard count the summary graph is
//! re-partitioned onto for the global merge (default 16).  `spill_budget`
//! (bytes) caps resident edge memory: the workers' summary shards and
//! every contracted generation of the merge spill to disk once they
//! exceed it — the same run, out-of-core (default: unbounded).

use lcc::coordinator::{pipeline, Driver, PipelineConfig, RunConfig};
use lcc::graph::generators::presets;
use lcc::util::rng::Rng;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let avg_deg: f64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(7.6); // webpages row of Table 1
    let machines: usize = std::env::args()
        .nth(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let spill_budget: Option<u64> = std::env::args().nth(4).and_then(|s| s.parse().ok());

    // The "webpages" shape of Table 1: heavily fragmented similarity graph
    // (largest CC ~0.8% of n).  Generated streaming-style below.
    println!("generating webpages-analogue: n={n}, avg_deg={avg_deg}");
    let mut rng = Rng::new(2026);
    let g = presets::component_mixture(n, 0.008, avg_deg, &mut rng);
    println!("graph ready: n={} m={}", g.num_vertices(), g.num_edges());

    // ---- stage 1+2: streaming shard-local contraction --------------------
    let cfg = PipelineConfig {
        num_workers: 6,
        chunk_size: 64 * 1024,
        channel_capacity: 4,
        spill_budget,
    };
    let t0 = std::time::Instant::now();
    let res = pipeline::run(g.num_vertices(), g.edges().iter().copied(), &cfg);
    println!(
        "pipeline: {} edges in {} chunks over {} workers, {} backpressure stalls",
        res.stats.edges_streamed, res.stats.chunks, cfg.num_workers, res.stats.backpressure_stalls
    );
    println!(
        "summary graph: {} edges ({:.1}x contraction) in {:.0} ms",
        res.stats.summary_edges,
        res.stats.edges_streamed as f64 / res.stats.summary_edges.max(1) as f64,
        res.stats.generate_ms + res.stats.merge_ms,
    );
    if res.summary.is_spilled() {
        println!(
            "summary is disk-backed under the {}-byte budget ({})",
            spill_budget.unwrap_or(0),
            res.summary.spill_dir().unwrap().display(),
        );
    }

    // ---- stage 3: LocalContraction (+XLA dense finisher) on the summary --
    // The workers' shards flow straight into the finisher: re-partitioned
    // shard-to-shard onto the simulator's machines, never concatenated.
    let driver = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines,
        use_xla: true, // compiled artifact path; falls back with a warning
        finisher_threshold: 0,
        spill_budget,
        verify: false,
        ..Default::default()
    });
    let merge = driver.run_named_sharded(&res.summary, "summary");
    println!("global merge: {}", merge.summary());
    println!("  edges per phase: {:?}", merge.edges_per_phase);
    if merge.xla_calls > 0 {
        println!("  XLA dense-backend executions: {}", merge.xla_calls);
    } else {
        println!("  (XLA artifacts unavailable — ran on the pure-MPC path)");
    }

    // ---- stage 4: verify against the oracle ------------------------------
    let labels = pipeline::merge_summary(&res.summary);
    lcc::cc::oracle::verify(&g, &labels).expect("pipeline labels disagree with oracle");
    let wall = t0.elapsed().as_secs_f64();
    let comps = {
        let mut ls = labels;
        ls.sort_unstable();
        ls.dedup();
        ls.len()
    };
    println!(
        "END-TO-END OK: {} components of {} vertices / {} edges in {:.2}s \
         ({:.2} Medges/s), oracle-verified",
        comps,
        g.num_vertices(),
        g.num_edges(),
        wall,
        g.num_edges() as f64 / wall / 1e6
    );
}
