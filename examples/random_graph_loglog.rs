//! Theorem 5.5 demo: on `G(n, c·ln n / n)` random graphs, LocalContraction
//! with the MergeToLarge step converges in `O(log log n)` phases — the
//! phase count stays essentially flat while `n` grows by two orders of
//! magnitude, even though the graph's diameter is `Θ(log n / log log n)`.
//!
//!     cargo run --release --example random_graph_loglog [machines]

use lcc::coordinator::{Driver, RunConfig};
use lcc::graph::{generators, stats};
use lcc::util::rng::Rng;
use lcc::util::stats::AsciiTable;

fn main() {
    let machines: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let mut t = AsciiTable::new(&[
        "n",
        "diameter~",
        "log2 n",
        "loglog2 n",
        "lc phases",
        "lc-mtl phases",
    ]);
    for exp in [10u32, 12, 14, 16, 18] {
        let n = 1usize << exp;
        let g = generators::gnp_log_regime(n, 2.0, &mut Rng::new(7 + exp as u64));
        let phases = |algo: &str| {
            let driver = Driver::new(RunConfig {
                algorithm: algo.into(),
                machines,
                finisher_threshold: 0, // measure the raw phase count
                verify: true,
                ..Default::default()
            });
            let r = driver.run(&g);
            assert_eq!(r.verified, Some(true), "{algo} wrong on n={n}");
            r.phases
        };
        t.row(vec![
            n.to_string(),
            stats::diameter_estimate(&g).to_string(),
            exp.to_string(),
            format!("{:.1}", (exp as f64).log2()),
            phases("lc").to_string(),
            phases("lc-mtl").to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Expected shape (Thm 5.5): the lc-mtl column grows like log log n\n\
         (roughly +1 when log2 n doubles), while the diameter column grows\n\
         linearly in log n."
    );
}
