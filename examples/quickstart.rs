//! Quickstart: find connected components of a random graph with
//! LocalContraction and check the answer against the sequential oracle.
//!
//!     cargo run --release --example quickstart [machines]
//!
//! `machines` is the simulated machine count = the shard count of the
//! resident edge store (default 16).

use lcc::cc::{oracle, CcAlgorithm};
use lcc::coordinator::{Driver, RunConfig};
use lcc::graph::generators;
use lcc::util::rng::Rng;

fn main() {
    let machines: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);

    // A sparse random graph: 100k vertices, average degree ~6.
    let n = 100_000;
    let g = generators::gnp(n, 6.0 / n as f64, &mut Rng::new(42));
    println!(
        "graph: n={} m={} (sharded over {machines} machines)",
        g.num_vertices(),
        g.num_edges()
    );

    // LocalContraction (§3 of the paper) on the MPC simulator with the §6
    // optimizations: isolated-node pruning + the small-graph finisher.
    let driver = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines,
        finisher_threshold: 10_000,
        verify: false, // we verify explicitly below
        ..Default::default()
    });
    let report = driver.run_named(&g, "quickstart");

    println!("{}", report.summary());
    println!("edges at the start of each phase: {:?}", report.edges_per_phase);
    println!(
        "total shuffle: {:.1} MB over {} rounds",
        report.total_shuffle_bytes as f64 / 1e6,
        report.rounds
    );

    // Cross-check against streaming union-find.
    let algo = lcc::cc::by_name("lc");
    let mut sim = lcc::mpc::Simulator::new(lcc::mpc::MpcConfig {
        machines,
        ..Default::default()
    });
    let mut rng = Rng::new(42);
    let res = algo.run(&g, &mut sim, &mut rng, &lcc::cc::RunOptions::default());
    oracle::verify(&g, &res.labels).expect("labels disagree with the oracle");
    println!("oracle check: OK ({} components)", report.num_components);
}
