//! Client for the `lcc serve` incremental connectivity daemon.
//!
//! Start the daemon in one terminal (port 0 = ephemeral, announced on
//! stdout):
//!
//!     cargo run --release -- serve --graph gnp --n 100000 --avg-deg 2 \
//!         --machines 8 --port 7171 --recontract-threshold 5000
//!
//! then talk to it:
//!
//!     cargo run --release --example serve_client 7171
//!
//! The example issues each protocol op once — point queries, a size
//! listing, a streamed insertion batch, a flush barrier — and prints the
//! raw newline-JSON exchange, so it doubles as protocol documentation.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn main() {
    let port: u16 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(7171);

    let stream = TcpStream::connect(("127.0.0.1", port))
        .unwrap_or_else(|e| panic!("cannot connect to lcc serve on port {port}: {e}"));
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;

    let mut request = |line: &str| -> String {
        writeln!(writer, "{line}").expect("send request");
        writer.flush().expect("flush request");
        let mut reply = String::new();
        reader.read_line(&mut reply).expect("read reply");
        println!("-> {line}");
        println!("<- {}", reply.trim_end());
        reply
    };

    // Point queries answer out of the current lock-free snapshot.
    request(r#"{"op":"component-of","u":0}"#);
    request(r#"{"op":"same-component","u":0,"v":1}"#);
    request(r#"{"op":"component-sizes","top":5}"#);

    // Stream an insertion batch; the daemon applies it incrementally
    // (union-find over the contracted core) and recontracts in the
    // background once enough core edges accumulate.
    request(r#"{"op":"insert","edges":[[0,1],[1,2],[2,3]]}"#);

    // flush is the read-your-writes barrier: everything queued before it
    // is applied before the ack.
    let ack = request(r#"{"op":"flush"}"#);
    assert!(ack.contains("\"ok\":true"), "flush failed: {ack}");

    // The inserted chain must now be connected.
    let reply = request(r#"{"op":"same-component","u":0,"v":3}"#);
    assert!(
        reply.contains("\"same\":true"),
        "0 and 3 should be connected after the insert: {reply}"
    );

    request(r#"{"op":"stats"}"#);
    println!("serve_client: OK");
}
