//! §7 lower bounds demo: on a path every considered algorithm needs
//! Ω(log n) phases — LocalContraction shortens the path at most 5x per
//! phase (Thm 7.1), TreeContraction survives w.h.p. for log_26 n rounds
//! (Thm 7.2), and Hash-Min pays the full Θ(n) diameter.
//!
//!     cargo run --release --example path_worst_case [machines]

use lcc::coordinator::{Driver, RunConfig};
use lcc::graph::generators;
use lcc::util::stats::AsciiTable;

fn main() {
    let machines: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let algos = ["lc", "tc-dht", "cracker", "htm", "hash-min"];
    let mut t = AsciiTable::new(&["n", "log5 n", "lc", "tc-dht", "cracker", "htm", "hash-min"]);
    for exp in [8u32, 10, 12, 14] {
        let n = 1usize << exp;
        let g = generators::path(n);
        let mut cells = vec![
            n.to_string(),
            format!("{:.1}", (n as f64).ln() / 5f64.ln()),
        ];
        for algo in algos {
            // hash-min needs Θ(n) rounds on a path and Hash-To-Min's
            // cluster state is Θ(n·2^round) — cap both to small sizes so
            // the example stays interactive (the paper's "X" entries).
            if (algo == "hash-min" && exp > 10) || (algo == "htm" && exp > 11) {
                cells.push("(skipped)".into());
                continue;
            }
            let driver = Driver::new(RunConfig {
                algorithm: algo.to_string(),
                machines,
                finisher_threshold: 0,
                max_phases: 20_000,
                verify: true,
                ..Default::default()
            });
            let r = driver.run(&g);
            assert_eq!(r.verified, Some(true), "{algo} wrong on path({n})");
            cells.push(r.phases.to_string());
        }
        t.row(cells);
    }
    println!("{}", t.render());
    println!(
        "Expected shape (§7): the contraction algorithms track log n (each\n\
         phase shortens the path by a constant factor; ~log5 n for lc), while\n\
         hash-min pays the full diameter n."
    );
}
