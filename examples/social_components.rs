//! Social-network workload: the Table 2/3 comparison on the Orkut and
//! Friendster analogues — all five paper algorithms, phases and relative
//! running times.
//!
//!     cargo run --release --example social_components [n] [machines]

use lcc::cc::PAPER_ALGORITHMS;
use lcc::coordinator::{Driver, RunConfig};
use lcc::graph::generators::presets;
use lcc::util::stats::AsciiTable;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30_000);
    let machines: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);

    for dataset in ["orkut", "friendster"] {
        let g = presets::generate(dataset, Some(n), 42);
        println!(
            "\n=== {dataset} analogue: n={} m={} ===",
            g.num_vertices(),
            g.num_edges()
        );
        let mut t = AsciiTable::new(&["algorithm", "phases", "rounds", "rel. time", "verified"]);
        let mut rows = Vec::new();
        for algo in PAPER_ALGORITHMS {
            let driver = Driver::new(RunConfig {
                algorithm: algo.to_string(),
                machines,
                finisher_threshold: g.num_edges() / 100,
                state_cap: 20 * g.num_edges() as u64,
                verify: true,
                ..Default::default()
            });
            let r = driver.run_median(&g, dataset, 3);
            rows.push(r);
        }
        let best = rows
            .iter()
            .filter(|r| r.completed)
            .map(|r| r.wall_ms)
            .fold(f64::INFINITY, f64::min);
        for r in &rows {
            t.row(vec![
                r.algorithm.clone(),
                if r.completed {
                    r.phases.to_string()
                } else {
                    "X".into()
                },
                r.rounds.to_string(),
                if r.completed {
                    format!("{:.2}", r.wall_ms / best)
                } else {
                    "X".into()
                },
                format!("{:?}", r.verified == Some(true)),
            ]);
        }
        println!("{}", t.render());
    }
    println!("(compare with Tables 2 and 3 of the paper: LocalContraction wins or ties,\n Hash-To-Min needs the most phases and blows up first)");
}
