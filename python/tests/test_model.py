"""Layer-2 correctness: phase graphs vs oracles + semantic invariants.

Beyond numeric agreement with ref.py, these tests check the *algorithmic*
meaning of the phase computation: labels never leave the connected component,
label values only decrease with more hops, one phase on a clique collapses it,
and `tree_roots` resolves pointer forests to canonical roots.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

INF = ref.INF


def random_graph(rng, n, density):
    mask = (rng.random((n, n)) < density).astype(np.int32)
    mask = np.maximum(mask, mask.T)
    np.fill_diagonal(mask, 1)
    return mask


def components(mask):
    """Union-find oracle over the mask (diag ignored)."""
    n = mask.shape[0]
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for v in range(n):
        for u in range(v + 1, n):
            if mask[v, u]:
                rv, ru = find(v), find(u)
                if rv != ru:
                    parent[rv] = ru
    return [find(v) for v in range(n)]


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([128, 256]),
    density=st.floats(0.0, 0.1),
    seed=st.integers(0, 2**31 - 1),
)
def test_local_labels_matches_ref(n, density, seed):
    rng = np.random.default_rng(seed)
    mask = random_graph(rng, n, density)
    prio = rng.permutation(n).astype(np.int32)
    (got,) = model.local_labels(jnp.array(mask), jnp.array(prio))
    want = ref.local_labels_ref(mask, prio)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([128, 256]), seed=st.integers(0, 2**31 - 1))
def test_local_labels_stay_within_component(n, seed):
    """l(v) is the priority of some vertex in v's component (merge soundness)."""
    rng = np.random.default_rng(seed)
    mask = random_graph(rng, n, 0.02)
    prio = rng.permutation(n).astype(np.int32)
    (labels,) = model.local_labels(jnp.array(mask), jnp.array(prio))
    labels = np.asarray(labels)
    comp = components(mask)
    owner = {int(p): v for v, p in enumerate(prio)}
    for v in range(n):
        assert comp[owner[int(labels[v])]] == comp[v]


def test_two_hops_dominate_one_hop():
    """min over N(N(v)) <= min over N(v): hop-2 labels can't exceed hop-1."""
    rng = np.random.default_rng(7)
    n = 128
    mask = random_graph(rng, n, 0.03)
    prio = rng.permutation(n).astype(np.int32)
    (h1,) = model.hash_min_step(jnp.array(mask), jnp.array(prio))
    (h2,) = model.local_labels(jnp.array(mask), jnp.array(prio))
    assert (np.asarray(h2) <= np.asarray(h1)).all()


def test_clique_collapses_in_one_phase():
    n = 128
    mask = np.ones((n, n), np.int32)
    prio = np.random.default_rng(8).permutation(n).astype(np.int32)
    (labels,) = model.local_labels(jnp.array(mask), jnp.array(prio))
    assert len(np.unique(np.asarray(labels))) == 1


def test_padding_slots_decay_to_inf():
    """Rust packer convention: zero rows + INF priority stay inert."""
    n, live = 256, 100
    rng = np.random.default_rng(9)
    mask = np.zeros((n, n), np.int32)
    sub = random_graph(rng, live, 0.05)
    mask[:live, :live] = sub
    prio = np.full(n, INF, np.int32)
    prio[:live] = rng.permutation(live).astype(np.int32)
    (labels,) = model.local_labels(jnp.array(mask), jnp.array(prio))
    labels = np.asarray(labels)
    assert (labels[live:] == INF).all()
    want = np.asarray(ref.local_labels_ref(sub, prio[:live]))
    np.testing.assert_array_equal(labels[:live], want)


def test_tree_roots_resolves_forest():
    """Random f_rho-style forest: tree_roots returns the canonical 2-cycle min."""
    rng = np.random.default_rng(10)
    n = 256
    # Build a pointer array whose terminal structure is 2-cycles (like f_rho):
    # pair up roots, then hang random chains below them.
    f = np.zeros(n, np.int32)
    f[0], f[1] = 1, 0  # one 2-cycle
    for v in range(2, n):
        f[v] = rng.integers(0, v)  # points to an earlier vertex -> same tree
    (roots,) = model.tree_roots(jnp.array(f), steps=8)
    roots = np.asarray(roots)
    assert (roots == 0).all()  # canonical min of the {0,1} 2-cycle


def test_tree_roots_two_forests():
    n = 256
    half = n // 2
    f = np.zeros(n, np.int32)
    f[0], f[1] = 1, 0
    f[half], f[half + 1] = half + 1, half
    rng = np.random.default_rng(11)
    for v in range(2, half):
        f[v] = rng.integers(0, v)
    for v in range(half + 2, n):
        f[v] = rng.integers(half, v)
    (roots,) = model.tree_roots(jnp.array(f), steps=8)
    roots = np.asarray(roots)
    assert (roots[:half] == 0).all()
    assert (roots[half:] == half).all()


def test_phase_shrink_stats_counts_distinct_labels():
    rng = np.random.default_rng(12)
    n = 256
    mask = random_graph(rng, n, 0.01)
    prio = rng.permutation(n).astype(np.int32)
    labels, cnt = model.phase_shrink_stats(jnp.array(mask), jnp.array(prio))
    assert int(cnt) == len(np.unique(np.asarray(labels)))


def test_phase_shrink_lemma41_on_gnp():
    """Lemma 4.1: E[#labels after one phase] <= 3n/4 — check with margin."""
    rng = np.random.default_rng(13)
    n = 256
    counts = []
    for seed in range(10):
        r = np.random.default_rng(seed)
        mask = random_graph(r, n, 4.0 / n)
        prio = r.permutation(n).astype(np.int32)
        _, cnt = model.phase_shrink_stats(jnp.array(mask), jnp.array(prio))
        counts.append(int(cnt))
    assert np.mean(counts) <= 0.75 * n
