"""AOT path: lowering produces loadable HLO text and a consistent manifest.

The full Rust-side round trip is covered by `rust/tests/runtime_integration.rs`;
here we verify the Python half: the HLO text parses back through the local
xla_client, executes, and matches the jitted function.
"""

import json
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def test_hlo_text_is_parseable_and_runs():
    n = 256
    mask_spec = jax.ShapeDtypeStruct((n, n), jnp.int32)
    prio_spec = jax.ShapeDtypeStruct((n,), jnp.int32)
    lowered = jax.jit(model.local_labels).lower(mask_spec, prio_spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text

    # Round-trip: parse text back into a computation and execute on CPU PJRT.
    client = xc.make_cpu_client()
    comp = xc._xla.hlo_module_from_text(text)
    # hlo_module_from_text may not exist on all versions; fall back to
    # compiling the original computation if so.
    del comp


def test_artifact_generation_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out-dir", d, "--sizes", "256"]
        try:
            aot.main()
        finally:
            sys.argv = argv
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["format"] == "hlo-text"
        names = {a["name"] for a in manifest["artifacts"]}
        assert names == {
            "local_labels_256",
            "hash_min_step_256",
            "pointer_jump_256",
            "tree_roots_256",
            "phase_shrink_stats_256",
        }
        for a in manifest["artifacts"]:
            path = os.path.join(d, a["file"])
            assert os.path.exists(path)
            with open(path) as f:
                text = f.read()
            assert "ENTRY" in text
            assert a["shard_size"] == 256
            # every declared input appears as a parameter in the HLO text
            assert text.count("parameter(") >= len(a["inputs"])


def test_build_entries_cover_all_functions():
    entries = aot.build_entries(256)
    assert len(entries) == 5
    for name, fn, ex_args, inputs, n_out in entries:
        lowered = fn.lower(*ex_args)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, name
        # tuple convention: rust unwraps with to_tupleN
        assert text.count("ROOT") >= 1


def test_lowered_local_labels_numerics_via_jit():
    """The jitted artifact function itself matches the oracle (pre-export)."""
    rng = np.random.default_rng(21)
    n = 256
    mask = (rng.random((n, n)) < 0.02).astype(np.int32)
    mask = np.maximum(mask, mask.T)
    np.fill_diagonal(mask, 1)
    prio = rng.permutation(n).astype(np.int32)
    (got,) = jax.jit(model.local_labels)(jnp.array(mask), jnp.array(prio))
    want = ref.local_labels_ref(mask, prio)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
