"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compiled hot path: everything the
Rust runtime executes lowers through these kernels.  hypothesis sweeps
shapes, block sizes, densities, and priority ranges.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import minprop as k
from compile.kernels import ref

INF = ref.INF

SIZES = [128, 256, 384]
BLOCKS = [32, 64, 128]


def random_mask(rng, n, density, symmetric=True, diag=True):
    mask = (rng.random((n, n)) < density).astype(np.int32)
    if symmetric:
        mask = np.maximum(mask, mask.T)
    if diag:
        np.fill_diagonal(mask, 1)
    return mask


# ---------------------------------------------------------------------------
# minprop
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    n=st.sampled_from(SIZES),
    bv=st.sampled_from(BLOCKS),
    bn=st.sampled_from(BLOCKS),
    density=st.floats(0.0, 0.3),
    seed=st.integers(0, 2**31 - 1),
)
def test_minprop_matches_ref(n, bv, bn, density, seed):
    rng = np.random.default_rng(seed)
    mask = random_mask(rng, n, density, diag=bool(seed % 2))
    prio = rng.integers(-(2**31) + 1, 2**31 - 2, size=n, dtype=np.int32)
    got = np.asarray(k.minprop(jnp.array(mask), jnp.array(prio), block_v=bv, block_n=bn))
    want = np.asarray(ref.minprop_ref(mask, prio))
    np.testing.assert_array_equal(got, want)


def test_minprop_empty_rows_yield_inf():
    n = 128
    mask = np.zeros((n, n), np.int32)
    prio = np.arange(n, dtype=np.int32)
    out = np.asarray(k.minprop(jnp.array(mask), jnp.array(prio)))
    assert (out == INF).all()


def test_minprop_identity_mask_is_identity():
    n = 256
    mask = np.eye(n, dtype=np.int32)
    prio = np.random.default_rng(1).permutation(n).astype(np.int32)
    out = np.asarray(k.minprop(jnp.array(mask), jnp.array(prio)))
    np.testing.assert_array_equal(out, prio)


def test_minprop_full_mask_is_global_min():
    n = 128
    mask = np.ones((n, n), np.int32)
    prio = np.random.default_rng(2).integers(-1000, 1000, n).astype(np.int32)
    out = np.asarray(k.minprop(jnp.array(mask), jnp.array(prio)))
    assert (out == prio.min()).all()


def test_minprop_accepts_bool_mask_and_casts():
    n = 128
    rng = np.random.default_rng(3)
    mask = random_mask(rng, n, 0.05).astype(bool)
    prio = rng.permutation(n).astype(np.int32)
    got = np.asarray(k.minprop(jnp.array(mask), jnp.array(prio)))
    want = np.asarray(ref.minprop_ref(mask.astype(np.int32), prio))
    np.testing.assert_array_equal(got, want)


def test_minprop_monotone_in_mask():
    """Adding edges can only lower the per-vertex min (tropical monotonicity)."""
    n = 128
    rng = np.random.default_rng(4)
    m1 = random_mask(rng, n, 0.02)
    extra = random_mask(rng, n, 0.02, diag=False)
    m2 = np.maximum(m1, extra)
    prio = rng.permutation(n).astype(np.int32)
    o1 = np.asarray(k.minprop(jnp.array(m1), jnp.array(prio)))
    o2 = np.asarray(k.minprop(jnp.array(m2), jnp.array(prio)))
    assert (o2 <= o1).all()


@pytest.mark.parametrize("n,bv,bn", [(100, 128, 128), (256, 100, 128), (256, 128, 100)])
def test_minprop_rejects_bad_blocking(n, bv, bn):
    mask = jnp.zeros((n, n), jnp.int32)
    prio = jnp.zeros((n,), jnp.int32)
    with pytest.raises(ValueError):
        k.minprop(mask, prio, block_v=bv, block_n=bn)


def test_minprop_rejects_bad_shapes():
    with pytest.raises(ValueError):
        k.minprop(jnp.zeros((128, 256), jnp.int32), jnp.zeros((128,), jnp.int32))
    with pytest.raises(ValueError):
        k.minprop(jnp.zeros((128, 128), jnp.int32), jnp.zeros((256,), jnp.int32))


# ---------------------------------------------------------------------------
# gather
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    n=st.sampled_from(SIZES),
    bv=st.sampled_from(BLOCKS),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_matches_ref(n, bv, seed):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=n, dtype=np.int32)
    src = rng.integers(-(2**20), 2**20, size=n, dtype=np.int32)
    got = np.asarray(k.gather(jnp.array(idx), jnp.array(src), block_v=bv))
    np.testing.assert_array_equal(got, np.asarray(ref.gather_ref(idx, src)))


def test_gather_identity():
    n = 256
    idx = np.arange(n, dtype=np.int32)
    src = np.random.default_rng(5).permutation(n).astype(np.int32)
    got = np.asarray(k.gather(jnp.array(idx), jnp.array(src)))
    np.testing.assert_array_equal(got, src)


def test_gather_pointer_jump_converges_on_chain():
    """f(v) = v-1 chain: repeated squaring converges to all-zeros in log steps."""
    n = 256
    f = np.maximum(np.arange(n) - 1, 0).astype(np.int32)
    steps = 0
    cur = jnp.array(f)
    while not (np.asarray(cur) == 0).all():
        cur = k.gather(cur, cur)
        steps += 1
        assert steps <= 10, "pointer jumping failed to converge in log2(n) steps"
    assert steps <= 8  # ceil(log2(255))
