"""Layer-1 Pallas kernels for contraction-based connected components.

The per-phase hot spot of every algorithm in the paper (LocalContraction,
Cracker's label step, Hash-Min) is *neighborhood min aggregation*: for each
vertex ``v`` compute the minimum priority over a masked set of columns,

    out[v] = min_{j : mask[v, j] != 0} prio[j]           (INF if row empty)

i.e. a matrix-vector product over the tropical (min, +) semiring with a 0/1
matrix.  On a MapReduce worker this is a key-grouped reducer fold; on TPU we
re-think it as a *blocked masked VPU min-reduction*: the adjacency mask is
streamed HBM -> VMEM tile by tile via BlockSpec, priorities are broadcast
along rows, and a per-vertex-block accumulator folds the min across neighbor
blocks (see DESIGN.md `§Hardware-Adaptation`).

Priorities are int32 (exact min semantics, sentinel ``INF = iinfo(int32).max``).
The mask is int32 on the interchange boundary because the Rust `xla` crate
exposes {i,u}{32,64} / f{32,64} literals only.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the only lowering that round-trips
through HLO text into the Rust runtime (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = jnp.iinfo(jnp.int32).max

# Default tile sizes.  128 matches the TPU VPU lane width; a (128, 128) int32
# mask tile is 64 KiB, far under VMEM, and lets the compiler double-buffer the
# HBM -> VMEM stream of neighbor blocks.
BLOCK_V = 128
BLOCK_N = 128


def _minprop_kernel(mask_ref, prio_ref, out_ref):
    """One (vertex-block, neighbor-block) grid step of the tropical SpMV.

    Grid is (num_vertex_blocks, num_neighbor_blocks); the second axis is the
    reduction axis, so ``out_ref`` maps to the same block for every ``j`` and
    is initialized on the first reduction step.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, INF)

    mask = mask_ref[...]  # [BLOCK_V, BLOCK_N] int32 (0/1)
    prio = prio_ref[...]  # [BLOCK_N] int32
    # Masked broadcast + row min: the VPU-friendly form of the reducer fold.
    vals = jnp.where(mask != 0, prio[None, :], INF)  # [BLOCK_V, BLOCK_N]
    out_ref[...] = jnp.minimum(out_ref[...], jnp.min(vals, axis=1))


@functools.partial(jax.jit, static_argnames=("block_v", "block_n"))
def minprop(mask, prio, *, block_v=BLOCK_V, block_n=BLOCK_N):
    """Tropical SpMV: ``out[v] = min_{j: mask[v,j]!=0} prio[j]`` (INF if none).

    Args:
      mask: ``[n, n]`` int32 0/1 adjacency mask.  Callers that want the
        paper's self-inclusive ``N(v)`` semantics must set the diagonal.
      prio: ``[n]`` int32 priorities; ``INF`` is reserved as the identity.
      block_v / block_n: tile sizes; ``n`` must be divisible by both
        (the Rust packer always pads shards to the artifact size).

    Returns:
      ``[n]`` int32 per-vertex masked minimum.
    """
    n = mask.shape[0]
    if mask.shape != (n, n):
        raise ValueError(f"mask must be square, got {mask.shape}")
    if prio.shape != (n,):
        raise ValueError(f"prio must be [{n}], got {prio.shape}")
    if n % block_v or n % block_n:
        raise ValueError(f"n={n} not divisible by blocks ({block_v},{block_n})")

    grid = (n // block_v, n // block_n)
    return pl.pallas_call(
        _minprop_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_v,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(mask.astype(jnp.int32), prio.astype(jnp.int32))


def _gather_kernel(idx_ref, src_ref, out_ref):
    """Per-vertex-block gather: ``out[v] = src[idx[v]]``.

    ``src`` is mapped as a single full-width block (it is the pointer array
    of the *whole* shard and must be addressable from every vertex block);
    indices and output are tiled over the vertex axis.
    """
    out_ref[...] = src_ref[...][idx_ref[...]]


@functools.partial(jax.jit, static_argnames=("block_v",))
def gather(idx, src, *, block_v=BLOCK_V):
    """Pointer-jump gather ``out[v] = src[idx[v]]`` (TreeContraction, Thm 4.7).

    Args:
      idx: ``[n]`` int32 indices into ``src`` (each in ``[0, n)``).
      src: ``[n]`` int32 values.
    """
    n = idx.shape[0]
    if src.shape != (n,):
        raise ValueError(f"src must be [{n}], got {src.shape}")
    if n % block_v:
        raise ValueError(f"n={n} not divisible by block_v={block_v}")

    return pl.pallas_call(
        _gather_kernel,
        grid=(n // block_v,),
        in_specs=[
            pl.BlockSpec((block_v,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),  # whole pointer array in VMEM
        ],
        out_specs=pl.BlockSpec((block_v,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(idx.astype(jnp.int32), src.astype(jnp.int32))
