"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

These are the ground truth the pytest/hypothesis suite compares against:
straight-line jnp with no Pallas, no blocking, no tricks.
"""

from __future__ import annotations

import jax.numpy as jnp

INF = jnp.iinfo(jnp.int32).max


def minprop_ref(mask, prio):
    """``out[v] = min_{j: mask[v,j]!=0} prio[j]``, INF where the row is empty."""
    mask = jnp.asarray(mask, jnp.int32)
    prio = jnp.asarray(prio, jnp.int32)
    vals = jnp.where(mask != 0, prio[None, :], INF)
    return jnp.min(vals, axis=1)


def gather_ref(idx, src):
    """``out[v] = src[idx[v]]``."""
    return jnp.asarray(src, jnp.int32)[jnp.asarray(idx, jnp.int32)]


def local_labels_ref(mask, prio):
    """LocalContraction phase label: min priority over N(N(v)).

    ``mask`` must already include the diagonal (self-inclusive N(v)).
    Two tropical SpMV hops: h1[v] = min_{u in N(v)} prio[u], then
    label[v] = min_{u in N(v)} h1[u] = min_{w in N(N(v))} prio[w].
    """
    h1 = minprop_ref(mask, prio)
    return minprop_ref(mask, h1)


def hash_min_step_ref(mask, prio):
    """One Hash-Min / Cracker label hop: min priority over N(v) (diag set)."""
    return minprop_ref(mask, prio)


def pointer_jump_ref(f):
    """One pointer-jumping step: ``f2[v] = f[f[v]]`` (Thm 4.7 subroutine)."""
    f = jnp.asarray(f, jnp.int32)
    return f[f]
