"""Layer-2 JAX phase graphs for contraction-based connected components.

Each function here is one *phase-level* computation of the paper, expressed
over the Layer-1 Pallas kernels so that everything lowers into a single HLO
module per artifact.  ``aot.py`` lowers these once per shard size; the Rust
coordinator executes the resulting artifacts on its hot path — Python never
runs at request time.

Shard convention (shared with ``rust/src/runtime/shard.rs``):
  * a shard is a padded dense graph of exactly ``n`` slots (artifact shape);
  * ``mask[v, u] = 1`` iff ``{v, u}`` is an edge; the diagonal is set for
    every *live* slot (self-inclusive ``N(v)``, §3 of the paper);
  * padding slots have an all-zero row/column and priority ``INF``, so they
    decay to label ``INF`` and are dropped by the unpacker.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import minprop as k

INF = k.INF


def _blocks(mask, block_v, block_n):
    """Resolve per-artifact tile sizes.

    Defaults to the kernel's 128-lane TPU tiles.  The CPU AOT artifacts
    override to an (n/2, n/2) 2x2 grid: interpret-mode Pallas executes the
    grid as a python-level loop lowered into an HLO while-loop, so on the
    CPU plugin fewer/wider steps win by ~8x (§Perf, EXPERIMENTS.md) while
    the accumulate-across-neighbor-blocks structure stays exercised.  On a
    real TPU build the (128, 128) default is the VMEM-sized choice.
    """
    n = mask.shape[0]
    return block_v or min(k.BLOCK_V, n), block_n or min(k.BLOCK_N, n)


def local_labels(mask, prio, block_v=None, block_n=None):
    """LocalContraction phase labels: ``l(v) = min_{w in N(N(v))} rho(w)``.

    Two hops of the tropical SpMV over the self-inclusive adjacency mask
    (§3, "LocalContraction").  Returns int32 labels; vertices sharing a
    label merge into one node of the contracted graph.
    """
    bv, bn = _blocks(mask, block_v, block_n)
    h1 = k.minprop(mask, prio, block_v=bv, block_n=bn)
    # Padding rows came back INF; re-injecting them through `where` is not
    # needed because their mask row is all-zero in hop 2 as well.
    return (k.minprop(mask, h1, block_v=bv, block_n=bn),)


def hash_min_step(mask, prio, block_v=None, block_n=None):
    """One Hash-Min hop / the Cracker label step: min over N(v) (diag set)."""
    bv, bn = _blocks(mask, block_v, block_n)
    return (k.minprop(mask, prio, block_v=bv, block_n=bn),)


def pointer_jump(f):
    """One pointer-jumping squaring step ``f <- f o f`` (Thm 4.7).

    Used by TreeContraction to resolve ``f_rho`` forests in
    ``O(log max d(v)) = O(log log n)`` steps w.h.p. (Lemma 4.5).
    """
    return (k.gather(f, f),)


def tree_roots(f, steps: int):
    """``steps`` pointer-jump squarings fused into one module.

    After ``ceil(log2(max d(v)))`` squarings every vertex points into its
    terminal 2-cycle (Lemma 4.4).  Squared powers all share one parity, so
    to see *both* cycle elements we take one extra step of the **original**
    pointer array: ``min(f0^(2^s)(v), f0^(2^s + 1)(v))`` is the canonical
    (minimum) element of the 2-cycle — the root label Lemma 4.6 merges on.
    """
    f0 = f
    for _ in range(steps):
        f = k.gather(f, f)
    fnext = k.gather(f, f0)  # f0[f^(2^steps)(v)] — the opposite-parity element
    return (jnp.minimum(f, fnext),)


def phase_shrink_stats(mask, prio):
    """Diagnostics variant: labels plus the number of distinct live labels.

    Exercised by the ablation bench (Lemma 4.1: E[#labels] <= 3n/4).

    Requires the Rust packer's priority convention: live priorities are a
    permutation of ``[0, live)`` and padding slots carry ``INF``.  Distinct
    labels are then counted with a scatter-max into an ``n``-slot table;
    out-of-range (``INF``, i.e. padding) labels drop out of the scatter.
    """
    n2 = mask.shape[0] // 2
    (labels,) = local_labels(mask, prio, block_v=n2, block_n=n2)
    n = labels.shape[0]
    hits = jnp.zeros((n,), jnp.int32).at[labels].max(
        jnp.ones_like(labels), mode="drop"
    )
    return labels, jnp.sum(hits)
