"""AOT lowering: Layer-2 phase graphs -> HLO text artifacts for the Rust runtime.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile does
this).  Emits one artifact per (function, shard size) plus a
``manifest.json`` the Rust artifact registry reads.

This is the ONLY place Python touches the system: artifacts are built once;
the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shard sizes the Rust runtime can pack.  Must be multiples of the kernel
# block sizes (128).
SHARD_SIZES = (256, 1024)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(n: int):
    mask = jax.ShapeDtypeStruct((n, n), jnp.int32)
    prio = jax.ShapeDtypeStruct((n,), jnp.int32)
    return mask, prio


def build_entries(n: int):
    """(name, jitted fn, example args, input descs, n_outputs) per artifact.

    CPU artifacts are lowered with (n/2, n/2) tiles — a 2x2 grid.  The
    interpret-mode Pallas grid becomes an HLO while-loop, so on the CPU
    PJRT plugin fewer, wider steps are ~8x faster at identical numerics
    (§Perf); a TPU build would keep the kernel's (128, 128) VMEM tiles.
    """
    mask, prio = _specs(n)
    jump_steps = max(1, math.ceil(math.log2(n)))
    bv = bn = n // 2
    return [
        (
            f"local_labels_{n}",
            jax.jit(lambda m, p: model.local_labels(m, p, block_v=bv, block_n=bn)),
            (mask, prio),
            [["mask", "i32", [n, n]], ["prio", "i32", [n]]],
            1,
        ),
        (
            f"hash_min_step_{n}",
            jax.jit(lambda m, p: model.hash_min_step(m, p, block_v=bv, block_n=bn)),
            (mask, prio),
            [["mask", "i32", [n, n]], ["prio", "i32", [n]]],
            1,
        ),
        (
            f"pointer_jump_{n}",
            jax.jit(model.pointer_jump),
            (prio,),
            [["f", "i32", [n]]],
            1,
        ),
        (
            f"tree_roots_{n}",
            jax.jit(lambda f: model.tree_roots(f, jump_steps)),
            (prio,),
            [["f", "i32", [n]]],
            1,
        ),
        (
            f"phase_shrink_stats_{n}",
            jax.jit(model.phase_shrink_stats),
            (mask, prio),
            [["mask", "i32", [n, n]], ["prio", "i32", [n]]],
            2,
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes", type=int, nargs="*", default=list(SHARD_SIZES),
        help="shard sizes to specialize artifacts for",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": []}
    for n in args.sizes:
        for name, fn, ex_args, inputs, n_out in build_entries(n):
            lowered = fn.lower(*ex_args)
            text = to_hlo_text(lowered)
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "file": f"{name}.hlo.txt",
                    "shard_size": n,
                    "inputs": inputs,
                    "outputs": n_out,
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
