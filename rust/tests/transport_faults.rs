//! Fault injection for the multi-process transport: every failure mode
//! must surface as a **typed** `TransportError` — never a hang, never a
//! silently-wrong answer.
//!
//! Real-process faults (kill a worker mid-run) use workers spawned from
//! the actual `lcc` binary; protocol-level faults (truncated frames,
//! corrupted payloads, lying accounting, stale shard statistics) use an
//! in-test fake worker speaking the frame protocol over a localhost
//! socket, so each fault is injected at an exact byte.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::Path;

use lcc::graph::{generators, ShardedGraph};
use lcc::mpc::net::{self, FrameKind, ProcTransport, ShuffleTransport, PROTO_VERSION};
use lcc::mpc::{
    Exchange, HopSpec, MpcConfig, RoundCharge, ShuffleOps, Simulator, TransportError, WireOp,
};
use lcc::util::rng::Rng;

fn worker_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_lcc"))
}

fn small_graph(machines: usize) -> ShardedGraph {
    let flat = generators::gnp(60, 0.05, &mut Rng::new(2));
    ShardedGraph::from_graph(&flat, machines)
}

// ---------------------------------------------------------------------------
// real worker processes

#[test]
fn killed_worker_is_a_typed_error_not_a_hang() {
    let g = small_graph(2);
    let mut t = ProcTransport::spawn(2, worker_bin()).expect("spawn");
    t.load_graph(&g).expect("load");
    t.kill_worker(0);
    t.kill_worker(1);
    let err = t
        .exchange(
            "after-kill",
            RoundCharge {
                messages: 0,
                bytes: 0,
                machine_bytes: &[0, 0],
            },
            vec![Vec::new(), Vec::new()],
            None,
        )
        .expect_err("dead workers must fail the exchange");
    match err {
        TransportError::WorkerCrashed { .. }
        | TransportError::ShortRead { .. }
        | TransportError::Io { .. } => {}
        other => panic!("expected a crash-shaped error, got {other}"),
    }
}

#[test]
fn missing_worker_binary_is_a_typed_spawn_error() {
    use lcc::coordinator::{Driver, RunConfig};
    use lcc::mpc::TransportMode;
    let flat = generators::path(40);
    let driver = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines: 2,
        transport: TransportMode::Proc,
        worker_bin: Some("/nonexistent/lcc-worker-binary".into()),
        ..Default::default()
    });
    match driver.try_run_named(&flat, "faults") {
        Err(TransportError::Io { op, .. }) => assert_eq!(op, "spawn worker"),
        other => panic!("expected spawn Io error, got {other:?}"),
    }
}

#[test]
fn driver_surfaces_a_mid_run_crash_as_a_typed_error() {
    // /proc/self/exe of the test binary is NOT an lcc worker: it exits
    // without ever connecting, which the handshake reports as a typed
    // crash/deadline error — the driver path must hand it back, not hang.
    use lcc::coordinator::{Driver, RunConfig};
    use lcc::mpc::TransportMode;
    if !Path::new("/bin/false").exists() {
        eprintln!("no /bin/false on this system; skipping");
        return;
    }
    let flat = generators::path(40);
    let driver = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines: 2,
        transport: TransportMode::Proc,
        worker_bin: Some("/bin/false".into()),
        ..Default::default()
    });
    match driver.try_run_named(&flat, "faults") {
        Err(TransportError::WorkerCrashed { .. }) | Err(TransportError::Protocol { .. }) => {}
        other => panic!("expected WorkerCrashed/Protocol, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// an in-test fake worker: precise byte-level fault injection

struct FakePeer {
    stream: TcpStream,
}

impl FakePeer {
    /// Connect a coordinator-side transport to one fake worker; the fake
    /// completes the handshake and hands the test raw frame control.
    fn pair() -> (ProcTransport, FakePeer) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let fake = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            // worker side of the handshake (v5): version + pid + mesh
            // port + worker threads
            let mut hello = PROTO_VERSION.to_le_bytes().to_vec();
            hello.extend_from_slice(&std::process::id().to_le_bytes());
            hello.extend_from_slice(&0u16.to_le_bytes());
            hello.extend_from_slice(&1u32.to_le_bytes());
            let mut w = stream.try_clone().unwrap();
            net::write_frame(&mut w, FrameKind::Hello, 0, &hello).unwrap();
            let mut r = stream.try_clone().unwrap();
            let assign = net::read_frame(&mut r).unwrap();
            assert_eq!(assign.kind, FrameKind::Assign);
            FakePeer { stream }
        });
        let (coord_side, _) = listener.accept().unwrap();
        let transport = ProcTransport::from_connected(vec![coord_side]).unwrap();
        (transport, fake.join().unwrap())
    }

    fn read(&mut self) -> net::Frame {
        let mut r = self.stream.try_clone().unwrap();
        net::read_frame(&mut r).unwrap()
    }

    fn send(&mut self, kind: FrameKind, seq: u64, body: &[u8]) {
        let mut w = self.stream.try_clone().unwrap();
        net::write_frame(&mut w, kind, seq, body).unwrap();
    }

    fn send_raw(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).unwrap();
        self.stream.flush().unwrap();
    }

    /// Serve the transport's teardown tolerantly: the coordinator's Drop
    /// may close the socket without reading our Bye — that race is fine.
    fn serve_shutdown(mut self) {
        loop {
            match net::read_frame(&mut self.stream.try_clone().unwrap()) {
                Ok(f) if f.kind == FrameKind::Shutdown => {
                    let mut w = self.stream.try_clone().unwrap();
                    let _ = net::write_frame(&mut w, FrameKind::Bye, f.seq, &[]);
                    break;
                }
                Ok(_) => continue,
                Err(_) => break,
            }
        }
    }
}

fn charge1(bytes: u64) -> [u64; 1] {
    [bytes]
}

#[test]
fn truncated_ack_frame_is_a_short_read() {
    let (mut t, mut peer) = FakePeer::pair();
    let handle = std::thread::spawn(move || {
        let _round = peer.read();
        // a RoundAck cut off mid-body: encode fully, send a prefix, close
        let mut buf = Vec::new();
        net::write_frame(&mut buf, FrameKind::RoundAck, 1, &[0u8; 16]).unwrap();
        peer.send_raw(&buf[..buf.len() - 7]);
        drop(peer);
    });
    let err = t
        .exchange(
            "r",
            RoundCharge {
                messages: 0,
                bytes: 0,
                machine_bytes: &charge1(0),
            },
            vec![Vec::new()],
            None,
        )
        .expect_err("truncated ack must fail");
    handle.join().unwrap();
    assert!(
        matches!(err, TransportError::ShortRead { .. }),
        "expected ShortRead, got {err}"
    );
}

#[test]
fn corrupted_ack_frame_is_a_checksum_mismatch() {
    let (mut t, mut peer) = FakePeer::pair();
    let handle = std::thread::spawn(move || {
        let _round = peer.read();
        let mut buf = Vec::new();
        net::write_frame(&mut buf, FrameKind::RoundAck, 1, &[7u8; 16]).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x01; // flip one payload bit
        peer.send_raw(&buf);
        drop(peer);
    });
    let err = t
        .exchange(
            "r",
            RoundCharge {
                messages: 0,
                bytes: 0,
                machine_bytes: &charge1(0),
            },
            vec![Vec::new()],
            None,
        )
        .expect_err("corrupt ack must fail");
    handle.join().unwrap();
    assert!(
        matches!(err, TransportError::ChecksumMismatch { .. }),
        "expected ChecksumMismatch, got {err}"
    );
}

#[test]
fn lying_receiver_accounting_aborts_with_the_typed_error() {
    // the fake worker acknowledges more bytes than it was sent: the
    // engine must refuse the round (wrong answers are impossible, the
    // run dies with AccountingMismatch instead)
    let (t, mut peer) = FakePeer::pair();
    let handle = std::thread::spawn(move || {
        let round = peer.read();
        let mut body = Vec::new();
        body.extend_from_slice(&999u64.to_le_bytes()); // lie
        body.extend_from_slice(&0u64.to_le_bytes()); // no fold results
        peer.send(FrameKind::RoundAck, round.seq, &body);
        peer.serve_shutdown();
    });
    let mut sim = Simulator::with_transport(
        MpcConfig {
            machines: 1,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        },
        Box::new(t),
    );
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut out = vec![0u32; 4];
        sim.round_fold("r", &mut out, vec![(1u64, 5u32)], u32::min);
    }))
    .expect_err("accounting lie must abort the round");
    let err = caught
        .downcast::<TransportError>()
        .expect("typed panic payload");
    assert!(
        matches!(*err, TransportError::AccountingMismatch { .. }),
        "expected AccountingMismatch, got {err}"
    );
    drop(sim); // transport Drop sends Shutdown; the fake answers Bye
    handle.join().unwrap();
}

#[test]
fn diverging_shard_statistics_are_a_protocol_error() {
    let (mut t, mut peer) = FakePeer::pair();
    let g = small_graph(1);
    let stats_len = g.shard_stats(0).len;
    let handle = std::thread::spawn(move || {
        let load = peer.read();
        assert_eq!(load.kind, FrameKind::LoadShard);
        // ack with a wrong edge count: custody divergence
        let mut body = Vec::new();
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&(stats_len + 1).to_le_bytes());
        body.extend_from_slice(&0u64.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(stats_len + 1).to_le_bytes());
        peer.send(FrameKind::LoadAck, load.seq, &body);
        drop(peer);
    });
    let err = t.load_graph(&g).expect_err("diverging stats must fail");
    handle.join().unwrap();
    assert!(
        matches!(err, TransportError::Protocol { .. }),
        "expected Protocol, got {err}"
    );
}

#[test]
fn fold_round_with_garbage_fold_results_is_typed() {
    // fake worker returns a fold blob with a key outside the output
    // range: the merge must abort with a typed protocol error
    let (t, mut peer) = FakePeer::pair();
    let handle = std::thread::spawn(move || {
        let round = peer.read();
        let mut fold = Vec::new();
        fold.extend_from_slice(&u64::MAX.to_le_bytes()); // absurd key
        fold.extend_from_slice(&1u32.to_le_bytes());
        let mut body = Vec::new();
        body.extend_from_slice(&12u64.to_le_bytes()); // matches the charge
        body.extend_from_slice(&(fold.len() as u64).to_le_bytes());
        body.extend_from_slice(&fold);
        peer.send(FrameKind::RoundAck, round.seq, &body);
        peer.serve_shutdown();
    });
    let mut sim = Simulator::with_transport(
        MpcConfig {
            machines: 1,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        },
        Box::new(t),
    );
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut out = vec![9u32; 4];
        sim.round_fold_tagged(
            "hop",
            &mut out,
            vec![(1u64, 5u32)],
            lcc::mpc::WireFold::min_u32(),
        );
    }))
    .expect_err("garbage fold keys must abort");
    let err = caught
        .downcast::<TransportError>()
        .expect("typed panic payload");
    assert!(
        matches!(*err, TransportError::Protocol { .. }),
        "expected Protocol, got {err}"
    );
    drop(sim);
    handle.join().unwrap();
}

#[test]
fn frame_codec_faults_are_typed_at_the_byte_level() {
    // belt-and-braces at the lowest layer (the same codec both sides use)
    let mut buf = Vec::new();
    net::write_frame(&mut buf, FrameKind::Round, 3, b"abcdef").unwrap();

    let mut cut = buf.clone();
    cut.truncate(buf.len() - 3);
    assert!(matches!(
        net::read_frame(&mut &cut[..]),
        Err(TransportError::ShortRead { .. })
    ));

    let mut bad_magic = buf.clone();
    bad_magic[0] = b'Z';
    assert!(matches!(
        net::read_frame(&mut &bad_magic[..]),
        Err(TransportError::BadMagic { .. })
    ));

    let mut corrupt = buf;
    let last = corrupt.len() - 1;
    corrupt[last] ^= 0x80;
    assert!(matches!(
        net::read_frame(&mut &corrupt[..]),
        Err(TransportError::ChecksumMismatch { .. })
    ));
}

// ---------------------------------------------------------------------------
// shuffle-transport faults: the worker↔worker data plane

/// A fake shuffle worker for one-machine control-plane faults: completes
/// the proc handshake plus the `Peers` roster, then hands the test raw
/// frame control.
fn shuffle_pair() -> (ShuffleTransport, FakePeer) {
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut hello = PROTO_VERSION.to_le_bytes().to_vec();
        hello.extend_from_slice(&std::process::id().to_le_bytes());
        hello.extend_from_slice(&0u16.to_le_bytes());
        hello.extend_from_slice(&1u32.to_le_bytes());
        let mut w = stream.try_clone().unwrap();
        net::write_frame(&mut w, FrameKind::Hello, 0, &hello).unwrap();
        let mut r = stream.try_clone().unwrap();
        let assign = net::read_frame(&mut r).unwrap();
        assert_eq!(assign.kind, FrameKind::Assign);
        let peers = net::read_frame(&mut r).unwrap();
        assert_eq!(peers.kind, FrameKind::Peers);
        net::write_frame(&mut w, FrameKind::PeersAck, peers.seq, &[]).unwrap();
        FakePeer { stream }
    });
    let (coord_side, _) = listener.accept().unwrap();
    let transport = ShuffleTransport::from_connected(vec![coord_side]).unwrap();
    (transport, fake.join().unwrap())
}

/// Serve a correct StateSync ack (echo the mirror hash) on a fake.
fn fake_ack_state(peer: &mut FakePeer) {
    let sync = peer.read();
    assert_eq!(sync.kind, FrameKind::StateSync);
    let vb = sync.body[0];
    let data = &sync.body[9..];
    let hash = net::mirror_hash_of(vb, data);
    peer.send(FrameKind::StateAck, sync.seq, &hash.to_le_bytes());
}

#[test]
fn shuffle_killed_workers_with_respawn_disabled_is_typed_not_a_hang() {
    // respawn budget 0: a dead worker is terminal — the run must die with
    // the typed RecoveryExhausted (never hang, never retry forever)
    let g = small_graph(2);
    let mut cfg = net::NetConfig::from_env();
    cfg.respawn_budget = 0;
    let mut t = ShuffleTransport::spawn_with(2, worker_bin(), cfg).expect("spawn");
    t.load_graph(&g).expect("load");
    t.kill_worker(0);
    t.kill_worker(1);
    let mut sim = Simulator::with_transport(
        MpcConfig {
            machines: 2,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        },
        Box::new(t),
    );
    let vals: Vec<u32> = (0..g.num_vertices() as u32).collect();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = lcc::cc::common::min_hop(&mut sim, "hop", &g, &vals, true);
    }))
    .expect_err("dead workers must abort the hop");
    let err = caught
        .downcast::<TransportError>()
        .expect("typed panic payload");
    match *err {
        TransportError::RecoveryExhausted { attempts, ref detail } => {
            assert_eq!(attempts, 0);
            assert!(detail.contains("respawn disabled"), "{detail}");
        }
        ref other => panic!("expected RecoveryExhausted, got {other}"),
    }
}

#[test]
fn shuffle_killed_workers_recover_and_the_hop_is_bit_identical() {
    // same hop, three ways: in-process reference, undisturbed shuffle,
    // and a shuffle whose whole fleet is killed before the round — the
    // recovered run must produce the identical fold
    let g = small_graph(2);
    let vals: Vec<u32> = (0..g.num_vertices() as u32).map(|v| v * 7 % 13).collect();
    let mpc = || MpcConfig {
        machines: 2,
        space_per_machine: None,
        spill_budget: None,
        threads: 1,
    };
    let mut sim_ref = Simulator::new(mpc());
    let want = lcc::cc::common::min_hop(&mut sim_ref, "hop", &g, &vals, true);

    let mut t = ShuffleTransport::spawn(2, worker_bin()).expect("spawn");
    t.load_graph(&g).expect("load");
    t.kill_worker(0);
    t.kill_worker(1);
    let mut sim = Simulator::with_transport(mpc(), Box::new(t));
    let got = lcc::cc::common::min_hop(&mut sim, "hop", &g, &vals, true);
    assert_eq!(got, want, "recovered hop diverged");
    assert!(
        !sim.metrics.recovery.events.is_empty(),
        "the kill must be logged as a recovery event"
    );
    assert_eq!(
        sim.metrics.num_rounds(),
        sim_ref.metrics.num_rounds(),
        "replayed rounds are charged once"
    );
}

#[test]
fn shuffle_mid_batch_kill_replays_the_whole_batch_and_charges_rounds_once() {
    // a worker dies while serving round 2 of a pipelined two-round batch
    // (`kill:w1@round=3`: round 1 is the warm-up hop, the batch is rounds
    // 2 and 3): recovery must replay the WHOLE batch — the descriptor
    // frame ships again — yet labels and per-round metrics must stay
    // bit-identical to an undisturbed in-process run
    use lcc::cc::common::{fused_two_hop, min_hop};
    use lcc::graph::Csr;
    use lcc::mpc::WireFold;
    let g = small_graph(2);
    let vals: Vec<u32> = (0..g.num_vertices() as u32).map(|v| v * 7 % 13).collect();
    let csr = Csr::build_sharded(&g);
    let mpc = || MpcConfig {
        machines: 2,
        space_per_machine: None,
        spill_budget: None,
        threads: 1,
    };
    let mut sim_ref = Simulator::new(mpc());
    let w1 = min_hop(&mut sim_ref, "hop1", &g, &vals, true);
    let want = fused_two_hop(&mut sim_ref, ("hop2", "hop3"), &g, &csr, &w1, WireFold::min_u32());

    let mut cfg = net::NetConfig::from_env();
    cfg.fault_plan = Some("kill:w1@round=3".into());
    let mut t = ShuffleTransport::spawn_with(2, worker_bin(), cfg).expect("spawn");
    t.load_graph(&g).expect("load");
    let stats = t.stats();
    let mut sim = Simulator::with_transport(mpc(), Box::new(t));
    let h1 = min_hop(&mut sim, "hop1", &g, &vals, true);
    let got = fused_two_hop(&mut sim, ("hop2", "hop3"), &g, &csr, &h1, WireFold::min_u32());

    assert_eq!(got, want, "recovered batch diverged");
    assert_eq!(
        sim.metrics.rounds, sim_ref.metrics.rounds,
        "replayed batch rounds must be charged exactly once"
    );
    assert!(
        !sim.metrics.recovery.events.is_empty(),
        "the mid-batch kill must be logged as a recovery event"
    );
    assert_eq!(
        stats
            .hop_batches
            .load(std::sync::atomic::Ordering::Relaxed),
        2,
        "recovery must re-ship the whole descriptor batch, not a suffix"
    );
}

#[test]
fn shuffle_mid_parallel_batch_kill_recovers_bit_identically() {
    // the same mid-batch kill with the workers running their data plane
    // on a 4-thread pool: a worker dies while its pool is mid-generate /
    // mid-fold, recovery respawns the fleet (which comes back at the
    // same thread count via LCC_WORKER_THREADS), replays the whole
    // batch, and the result — and the once-charged round metrics — must
    // still be bit-identical to the undisturbed in-process run
    use lcc::cc::common::{fused_two_hop, min_hop};
    use lcc::graph::Csr;
    use lcc::mpc::WireFold;
    let g = small_graph(2);
    let vals: Vec<u32> = (0..g.num_vertices() as u32).map(|v| v * 7 % 13).collect();
    let csr = Csr::build_sharded(&g);
    let mpc = || MpcConfig {
        machines: 2,
        space_per_machine: None,
        spill_budget: None,
        threads: 1,
    };
    let mut sim_ref = Simulator::new(mpc());
    let w1 = min_hop(&mut sim_ref, "hop1", &g, &vals, true);
    let want = fused_two_hop(&mut sim_ref, ("hop2", "hop3"), &g, &csr, &w1, WireFold::min_u32());

    let mut cfg = net::NetConfig::from_env();
    cfg.fault_plan = Some("kill:w1@round=3".into());
    cfg.worker_threads = 4;
    let mut t = ShuffleTransport::spawn_with(2, worker_bin(), cfg).expect("spawn");
    t.load_graph(&g).expect("load");
    let mut sim = Simulator::with_transport(mpc(), Box::new(t));
    let h1 = min_hop(&mut sim, "hop1", &g, &vals, true);
    let got = fused_two_hop(&mut sim, ("hop2", "hop3"), &g, &csr, &h1, WireFold::min_u32());

    assert_eq!(got, want, "recovered parallel batch diverged");
    assert_eq!(
        sim.metrics.rounds, sim_ref.metrics.rounds,
        "replayed parallel-batch rounds must be charged exactly once"
    );
    assert!(
        !sim.metrics.recovery.events.is_empty(),
        "the mid-batch kill must be logged as a recovery event"
    );
}

#[test]
fn shuffle_lying_hop_load_is_an_accounting_mismatch() {
    let (mut t, mut peer) = shuffle_pair();
    let handle = std::thread::spawn(move || {
        fake_ack_state(&mut peer);
        let hop = peer.read();
        assert_eq!(hop.kind, FrameKind::HopRound);
        let mut body = Vec::new();
        body.extend_from_slice(&999u64.to_le_bytes()); // lie about the load
        body.extend_from_slice(&0u64.to_le_bytes());
        body.extend_from_slice(&0u64.to_le_bytes()); // mesh byte meter
        peer.send(FrameKind::HopAck, hop.seq, &body);
        peer.serve_shutdown();
    });
    let data = [1u32, 2].iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<u8>>();
    let hash = net::mirror_hash_of(4, &data);
    t.sync_mirror(4, &data, hash).expect("mirror sync");
    let spec = HopSpec {
        label: "hop",
        op: WireOp::MinU32,
        include_self: true,
    };
    let mb = [24u64];
    let charge = RoundCharge {
        messages: 2,
        bytes: 24,
        machine_bytes: &mb,
    };
    let seq = t.begin_hop(&spec, &charge).expect("begin");
    let err = t
        .finish_hop(seq, &spec, &charge, &[0u64])
        .expect_err("lying load must fail the round");
    assert!(
        matches!(err, TransportError::AccountingMismatch { .. }),
        "expected AccountingMismatch, got {err}"
    );
    drop(t);
    handle.join().unwrap();
}

#[test]
fn shuffle_diverging_fold_checksum_is_a_protocol_error() {
    let (mut t, mut peer) = shuffle_pair();
    let handle = std::thread::spawn(move || {
        fake_ack_state(&mut peer);
        let hop = peer.read();
        let mut body = Vec::new();
        body.extend_from_slice(&24u64.to_le_bytes()); // load is right...
        body.extend_from_slice(&0xDEADu64.to_le_bytes()); // ...fold is not
        body.extend_from_slice(&0u64.to_le_bytes()); // mesh byte meter
        peer.send(FrameKind::HopAck, hop.seq, &body);
        peer.serve_shutdown();
    });
    let data = [7u32, 9].iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<u8>>();
    let hash = net::mirror_hash_of(4, &data);
    t.sync_mirror(4, &data, hash).expect("mirror sync");
    let spec = HopSpec {
        label: "hop",
        op: WireOp::MinU32,
        include_self: true,
    };
    let mb = [24u64];
    let charge = RoundCharge {
        messages: 2,
        bytes: 24,
        machine_bytes: &mb,
    };
    let seq = t.begin_hop(&spec, &charge).expect("begin");
    let err = t
        .finish_hop(seq, &spec, &charge, &[1234u64])
        .expect_err("a diverging fold must fail the round");
    assert!(
        matches!(err, TransportError::Protocol { .. }),
        "expected Protocol, got {err}"
    );
    drop(t);
    handle.join().unwrap();
}

/// Spawn one real `lcc worker` process connected to `addr` (the manual
/// counterpart of `ProcTransport::spawn` for mixed real/fake topologies).
/// The peer-connect retry budget is shortened so refusal faults surface
/// in milliseconds instead of the production backoff window.
fn spawn_real_worker(addr: std::net::SocketAddr) -> std::process::Child {
    std::process::Command::new(worker_bin())
        .arg("worker")
        .arg("--connect")
        .arg(addr.to_string())
        .env("LCC_CONNECT_RETRIES", "3")
        .stdin(std::process::Stdio::null())
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::inherit())
        .spawn()
        .expect("spawn real worker")
}

#[test]
fn shuffle_peer_connect_refused_is_typed() {
    // fake worker 0 advertises a mesh port nobody listens on; real worker
    // 1 must surface the refused peer connect as a typed error through
    // the coordinator — not hang in the mesh setup.  Port 1 is reserved
    // (unprivileged processes cannot bind it), so the refusal is
    // deterministic even with parallel tests binding ephemeral ports.
    let dead_port: u16 = 1;
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    // fake first: accept order assigns it worker id 0
    let fake = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut hello = PROTO_VERSION.to_le_bytes().to_vec();
        hello.extend_from_slice(&std::process::id().to_le_bytes());
        hello.extend_from_slice(&dead_port.to_le_bytes());
        hello.extend_from_slice(&1u32.to_le_bytes());
        let mut w = stream.try_clone().unwrap();
        net::write_frame(&mut w, FrameKind::Hello, 0, &hello).unwrap();
        let mut r = stream.try_clone().unwrap();
        let assign = net::read_frame(&mut r).unwrap();
        assert_eq!(assign.kind, FrameKind::Assign);
        let peers = net::read_frame(&mut r).unwrap();
        assert_eq!(peers.kind, FrameKind::Peers);
        net::write_frame(&mut w, FrameKind::PeersAck, peers.seq, &[]).unwrap();
        stream
    });
    let (fake_side, _) = listener.accept().unwrap();
    let mut child = spawn_real_worker(addr);
    let (real_side, _) = listener.accept().unwrap();

    let err = ShuffleTransport::from_connected(vec![fake_side, real_side])
        .err()
        .expect("refused peer connect must fail the mesh");
    assert!(
        matches!(err, TransportError::Protocol { .. }),
        "expected Protocol, got {err}"
    );
    assert!(
        err.to_string().contains("mesh setup failed"),
        "unexpected detail: {err}"
    );
    let _ = fake.join().unwrap();
    let _ = child.kill();
    let _ = child.wait();
}

#[test]
fn shuffle_corrupted_peer_frame_is_typed() {
    // real worker 0 owns a shard and serves a hop; fake worker 1 answers
    // the mesh shuffle with a corrupted PeerMsgs frame — the real worker
    // must detect it (checksummed mesh frames) and fail the round typed.
    let g = small_graph(2);
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();

    let mut child = spawn_real_worker(addr);
    let (real_side, _) = listener.accept().unwrap();

    let fake_mesh = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let fake_port = fake_mesh.local_addr().unwrap().port();
    let fake = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut hello = PROTO_VERSION.to_le_bytes().to_vec();
        hello.extend_from_slice(&std::process::id().to_le_bytes());
        hello.extend_from_slice(&fake_port.to_le_bytes());
        hello.extend_from_slice(&1u32.to_le_bytes());
        let mut w = stream.try_clone().unwrap();
        net::write_frame(&mut w, FrameKind::Hello, 0, &hello).unwrap();
        let mut r = stream.try_clone().unwrap();
        let assign = net::read_frame(&mut r).unwrap();
        assert_eq!(assign.kind, FrameKind::Assign);

        // mesh: worker 1 initiates to worker 0's advertised port
        let peers = net::read_frame(&mut r).unwrap();
        assert_eq!(peers.kind, FrameKind::Peers);
        let real_mesh_port = {
            // body: count u32 | (id u32, port u16) × count — find id 0
            let mut port = 0u16;
            let count = u32::from_le_bytes(peers.body[..4].try_into().unwrap()) as usize;
            for i in 0..count {
                let off = 4 + i * 6;
                let id = u32::from_le_bytes(peers.body[off..off + 4].try_into().unwrap());
                let p = u16::from_le_bytes(peers.body[off + 4..off + 6].try_into().unwrap());
                if id == 0 {
                    port = p;
                }
            }
            port
        };
        let mesh = TcpStream::connect(("127.0.0.1", real_mesh_port)).unwrap();
        {
            let mut mw = mesh.try_clone().unwrap();
            net::write_frame(&mut mw, FrameKind::PeerHello, 0, &1u32.to_le_bytes()).unwrap();
        }
        net::write_frame(&mut w, FrameKind::PeersAck, peers.seq, &[]).unwrap();

        // shard custody for machine 1, answered honestly (serving the
        // coordinator's generation-boundary heartbeat first)
        let load = loop {
            let f = net::read_frame(&mut r).unwrap();
            if f.kind == FrameKind::Ping {
                net::write_frame(&mut w, FrameKind::Pong, f.seq, &[]).unwrap();
                continue;
            }
            break f;
        };
        assert_eq!(load.kind, FrameKind::LoadShard);
        let image = &load.body[12..];
        let (edges, checksum) = lcc::graph::spill::read_shard_bytes(
            image,
            1,
            2,
            Path::new("<test>"),
        )
        .unwrap();
        let stats = lcc::graph::spill::ShardStats::from_edges(&edges, 2, 1);
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&stats.len.to_le_bytes());
        body.extend_from_slice(&checksum.to_le_bytes());
        body.extend_from_slice(&2u32.to_le_bytes());
        for &c in &stats.peer_counts {
            body.extend_from_slice(&c.to_le_bytes());
        }
        net::write_frame(&mut w, FrameKind::LoadAck, load.seq, &body).unwrap();

        // mirror + hop descriptor
        let sync = net::read_frame(&mut r).unwrap();
        assert_eq!(sync.kind, FrameKind::StateSync);
        let hash = net::mirror_hash_of(sync.body[0], &sync.body[9..]);
        net::write_frame(&mut w, FrameKind::StateAck, sync.seq, &hash.to_le_bytes()).unwrap();
        let hop = net::read_frame(&mut r).unwrap();
        assert_eq!(hop.kind, FrameKind::HopRound);

        // the real worker ships its bucket for machine 1...
        let mut mr = mesh.try_clone().unwrap();
        let msgs = net::read_frame(&mut mr).unwrap();
        assert_eq!(msgs.kind, FrameKind::PeerMsgs);

        // ...and we answer with a corrupted frame: one flipped payload bit
        let mut buf = Vec::new();
        net::write_frame(&mut buf, FrameKind::PeerMsgs, hop.seq, &[0u8; 24]).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x40;
        let mut raw = mesh.try_clone().unwrap();
        raw.write_all(&buf).unwrap();
        raw.flush().unwrap();

        // ack our own side of the round (the coordinator reads every ack
        // before judging, so the real worker's WorkerErr wins attribution)
        let mut body = Vec::new();
        body.extend_from_slice(&0u64.to_le_bytes());
        body.extend_from_slice(&0u64.to_le_bytes());
        body.extend_from_slice(&0u64.to_le_bytes()); // mesh byte meter
        net::write_frame(&mut w, FrameKind::HopAck, hop.seq, &body).unwrap();

        // the real worker's WorkerErr goes to the coordinator; we just
        // linger until teardown
        let _ = net::read_frame(&mut r);
        (stream, mesh)
    });

    // the fake's coordinator stream was accepted second (the real worker
    // connected before the fake thread started)
    let (fake_side, _) = listener.accept().unwrap();
    let mut t =
        ShuffleTransport::from_connected(vec![real_side, fake_side]).expect("mesh up");
    t.establish_custody(&g).expect("custody");
    let data: Vec<u8> = (0..g.num_vertices() as u32)
        .flat_map(|v| v.to_le_bytes())
        .collect();
    let hash = net::mirror_hash_of(4, &data);
    t.sync_mirror(4, &data, hash).expect("mirror");
    let charge_round = g.hop_charge(12, true);
    let spec = HopSpec {
        label: "hop",
        op: WireOp::MinU32,
        include_self: true,
    };
    let charge = RoundCharge {
        messages: charge_round.messages,
        bytes: charge_round.bytes,
        machine_bytes: &charge_round.machine_bytes,
    };
    let seq = t.begin_hop(&spec, &charge).expect("begin");
    let err = t
        .finish_hop(seq, &spec, &charge, &vec![0u64; 2])
        .expect_err("corrupted peer frame must fail the round");
    assert!(
        matches!(err, TransportError::Protocol { .. }),
        "expected Protocol (worker-detected mesh corruption), got {err}"
    );
    assert!(
        err.to_string().contains("checksum"),
        "unexpected detail: {err}"
    );
    drop(t);
    let _ = fake.join();
    let _ = child.kill();
    let _ = child.wait();
}

// ---------------------------------------------------------------------------
// chaos matrix: coordinator-driven recovery at the generation boundaries
//
// Each case injects `kill:w<W>@gen=<G>` into one worker of a real shuffle
// fleet via the deterministic fault plan: the worker exits right after
// acking its G-th Rewire — the generation boundary — and the run must
// recover (respawn + custody re-ship + replay) to a report bit-identical
// to the undisturbed baseline, with the kill logged as a recovery event.

use lcc::coordinator::{Driver, Report, RunConfig};
use lcc::mpc::TransportMode;

fn chaos_cfg(machines: usize, fault_plan: Option<String>) -> RunConfig {
    RunConfig {
        algorithm: "lc".into(),
        machines,
        transport: TransportMode::Shuffle,
        worker_bin: Some(worker_bin().to_path_buf()),
        verify: true,
        fault_plan,
        respawn_budget: Some(3),
        ..Default::default()
    }
}

/// Everything bit-identity covers: labels (via the oracle check) plus the
/// full round/byte accounting.  Replayed rounds are charged once, so a
/// recovered run's metrics must equal an undisturbed run's exactly.
fn assert_bit_identical(case: &str, got: &Report, want: &Report) {
    assert_eq!(got.verified, Some(true), "{case}: oracle check");
    assert_eq!(got.num_components, want.num_components, "{case}");
    assert_eq!(got.largest_component, want.largest_component, "{case}");
    assert_eq!(got.phases, want.phases, "{case}");
    assert_eq!(got.rounds, want.rounds, "{case}");
    assert_eq!(got.edges_per_phase, want.edges_per_phase, "{case}");
    assert_eq!(got.nodes_per_phase, want.nodes_per_phase, "{case}");
    assert_eq!(got.total_shuffle_bytes, want.total_shuffle_bytes, "{case}");
    assert_eq!(got.max_round_bytes, want.max_round_bytes, "{case}");
    assert_eq!(got.dht_ops, want.dht_ops, "{case}");
}

#[test]
fn chaos_matrix_kills_every_worker_at_early_boundaries_m4() {
    // a cycle contracts over ~log n generations: boundaries 1 and 2 are
    // guaranteed mid-run, so every injected kill actually fires
    let flat = generators::cycle(96);
    let base = Driver::new(chaos_cfg(4, None))
        .try_run_named(&flat, "chaos")
        .expect("undisturbed baseline");
    assert_eq!(base.verified, Some(true));
    assert!(base.recovery.events.is_empty(), "baseline saw no faults");
    let mut recovered = 0usize;
    for w in 0..4 {
        for gen in [1u64, 2] {
            let plan = format!("kill:w{w}@gen={gen}");
            let r = Driver::new(chaos_cfg(4, Some(plan.clone())))
                .try_run_named(&flat, "chaos")
                .unwrap_or_else(|e| panic!("{plan}: {e}"));
            assert_bit_identical(&plan, &r, &base);
            recovered += r.recovery.events.len();
        }
    }
    assert!(
        recovered >= 8,
        "every mid-run kill must be healed and logged (got {recovered})"
    );
}

#[test]
fn chaos_matrix_kills_every_worker_at_the_first_boundary_m16() {
    let flat = generators::cycle(192);
    let base = Driver::new(chaos_cfg(16, None))
        .try_run_named(&flat, "chaos")
        .expect("undisturbed baseline");
    assert_eq!(base.verified, Some(true));
    let mut recovered = 0usize;
    for w in 0..16 {
        let plan = format!("kill:w{w}@gen=1");
        let r = Driver::new(chaos_cfg(16, Some(plan.clone())))
            .try_run_named(&flat, "chaos")
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_bit_identical(&plan, &r, &base);
        recovered += r.recovery.events.len();
    }
    assert!(
        recovered >= 16,
        "every mid-run kill must be healed and logged (got {recovered})"
    );
}

#[test]
fn chaos_with_respawn_disabled_is_a_typed_recovery_exhaustion() {
    let flat = generators::cycle(64);
    let mut cfg = chaos_cfg(4, Some("kill:w1@gen=1".into()));
    cfg.respawn_budget = Some(0);
    match Driver::new(cfg).try_run_named(&flat, "chaos") {
        Err(TransportError::RecoveryExhausted { attempts, detail }) => {
            assert_eq!(attempts, 0);
            assert!(detail.contains("respawn disabled"), "{detail}");
        }
        other => panic!("expected RecoveryExhausted, got {other:?}"),
    }
}

/// `exchange` used directly (same entry the simulator uses) must also
/// enforce wire-op folding round trips with a real worker process.
#[test]
fn real_worker_folds_min_u32_remotely() {
    let g = small_graph(1);
    let mut t = ProcTransport::spawn(1, worker_bin()).expect("spawn");
    t.load_graph(&g).expect("load");
    let mut payload = Vec::new();
    for (k, v) in [(3u64, 50u32), (3, 20), (5, 7)] {
        payload.extend_from_slice(&k.to_le_bytes());
        payload.extend_from_slice(&v.to_le_bytes());
    }
    let ack = t
        .exchange(
            "hop",
            RoundCharge {
                messages: 3,
                bytes: payload.len() as u64,
                machine_bytes: &charge1(payload.len() as u64),
            },
            vec![payload.clone()],
            Some(WireOp::MinU32),
        )
        .expect("fold round");
    assert_eq!(ack.machine_bytes, vec![payload.len() as u64]);
    let folded = &ack.folded.expect("fold results")[0];
    let expect = net::fold_wire_payload(WireOp::MinU32, &payload).unwrap();
    assert_eq!(folded, &expect);
    t.shutdown().expect("graceful shutdown");
}
