//! Fault injection for the multi-process transport: every failure mode
//! must surface as a **typed** `TransportError` — never a hang, never a
//! silently-wrong answer.
//!
//! Real-process faults (kill a worker mid-run) use workers spawned from
//! the actual `lcc` binary; protocol-level faults (truncated frames,
//! corrupted payloads, lying accounting, stale shard statistics) use an
//! in-test fake worker speaking the frame protocol over a localhost
//! socket, so each fault is injected at an exact byte.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::Path;

use lcc::graph::{generators, ShardedGraph};
use lcc::mpc::net::{self, FrameKind, ProcTransport, PROTO_VERSION};
use lcc::mpc::{
    Exchange, MpcConfig, RoundCharge, Simulator, TransportError, WireOp,
};
use lcc::util::rng::Rng;

fn worker_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_lcc"))
}

fn small_graph(machines: usize) -> ShardedGraph {
    let flat = generators::gnp(60, 0.05, &mut Rng::new(2));
    ShardedGraph::from_graph(&flat, machines)
}

// ---------------------------------------------------------------------------
// real worker processes

#[test]
fn killed_worker_is_a_typed_error_not_a_hang() {
    let g = small_graph(2);
    let mut t = ProcTransport::spawn(2, worker_bin()).expect("spawn");
    t.load_graph(&g).expect("load");
    t.kill_worker(0);
    t.kill_worker(1);
    let err = t
        .exchange(
            "after-kill",
            RoundCharge {
                messages: 0,
                bytes: 0,
                machine_bytes: &[0, 0],
            },
            vec![Vec::new(), Vec::new()],
            None,
        )
        .expect_err("dead workers must fail the exchange");
    match err {
        TransportError::WorkerCrashed { .. }
        | TransportError::ShortRead { .. }
        | TransportError::Io { .. } => {}
        other => panic!("expected a crash-shaped error, got {other}"),
    }
}

#[test]
fn missing_worker_binary_is_a_typed_spawn_error() {
    use lcc::coordinator::{Driver, RunConfig};
    use lcc::mpc::TransportMode;
    let flat = generators::path(40);
    let driver = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines: 2,
        transport: TransportMode::Proc,
        worker_bin: Some("/nonexistent/lcc-worker-binary".into()),
        ..Default::default()
    });
    match driver.try_run_named(&flat, "faults") {
        Err(TransportError::Io { op, .. }) => assert_eq!(op, "spawn worker"),
        other => panic!("expected spawn Io error, got {other:?}"),
    }
}

#[test]
fn driver_surfaces_a_mid_run_crash_as_a_typed_error() {
    // /proc/self/exe of the test binary is NOT an lcc worker: it exits
    // without ever connecting, which the handshake reports as a typed
    // crash/deadline error — the driver path must hand it back, not hang.
    use lcc::coordinator::{Driver, RunConfig};
    use lcc::mpc::TransportMode;
    if !Path::new("/bin/false").exists() {
        eprintln!("no /bin/false on this system; skipping");
        return;
    }
    let flat = generators::path(40);
    let driver = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines: 2,
        transport: TransportMode::Proc,
        worker_bin: Some("/bin/false".into()),
        ..Default::default()
    });
    match driver.try_run_named(&flat, "faults") {
        Err(TransportError::WorkerCrashed { .. }) | Err(TransportError::Protocol { .. }) => {}
        other => panic!("expected WorkerCrashed/Protocol, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// an in-test fake worker: precise byte-level fault injection

struct FakePeer {
    stream: TcpStream,
}

impl FakePeer {
    /// Connect a coordinator-side transport to one fake worker; the fake
    /// completes the handshake and hands the test raw frame control.
    fn pair() -> (ProcTransport, FakePeer) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let fake = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            // worker side of the handshake: version + pid
            let mut hello = PROTO_VERSION.to_le_bytes().to_vec();
            hello.extend_from_slice(&std::process::id().to_le_bytes());
            let mut w = stream.try_clone().unwrap();
            net::write_frame(&mut w, FrameKind::Hello, 0, &hello).unwrap();
            let mut r = stream.try_clone().unwrap();
            let assign = net::read_frame(&mut r).unwrap();
            assert_eq!(assign.kind, FrameKind::Assign);
            FakePeer { stream }
        });
        let (coord_side, _) = listener.accept().unwrap();
        let transport = ProcTransport::from_connected(vec![coord_side]).unwrap();
        (transport, fake.join().unwrap())
    }

    fn read(&mut self) -> net::Frame {
        let mut r = self.stream.try_clone().unwrap();
        net::read_frame(&mut r).unwrap()
    }

    fn send(&mut self, kind: FrameKind, seq: u64, body: &[u8]) {
        let mut w = self.stream.try_clone().unwrap();
        net::write_frame(&mut w, kind, seq, body).unwrap();
    }

    fn send_raw(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).unwrap();
        self.stream.flush().unwrap();
    }

    /// Serve the transport's teardown tolerantly: the coordinator's Drop
    /// may close the socket without reading our Bye — that race is fine.
    fn serve_shutdown(mut self) {
        loop {
            match net::read_frame(&mut self.stream.try_clone().unwrap()) {
                Ok(f) if f.kind == FrameKind::Shutdown => {
                    let mut w = self.stream.try_clone().unwrap();
                    let _ = net::write_frame(&mut w, FrameKind::Bye, f.seq, &[]);
                    break;
                }
                Ok(_) => continue,
                Err(_) => break,
            }
        }
    }
}

fn charge1(bytes: u64) -> [u64; 1] {
    [bytes]
}

#[test]
fn truncated_ack_frame_is_a_short_read() {
    let (mut t, mut peer) = FakePeer::pair();
    let handle = std::thread::spawn(move || {
        let _round = peer.read();
        // a RoundAck cut off mid-body: encode fully, send a prefix, close
        let mut buf = Vec::new();
        net::write_frame(&mut buf, FrameKind::RoundAck, 1, &[0u8; 16]).unwrap();
        peer.send_raw(&buf[..buf.len() - 7]);
        drop(peer);
    });
    let err = t
        .exchange(
            "r",
            RoundCharge {
                messages: 0,
                bytes: 0,
                machine_bytes: &charge1(0),
            },
            vec![Vec::new()],
            None,
        )
        .expect_err("truncated ack must fail");
    handle.join().unwrap();
    assert!(
        matches!(err, TransportError::ShortRead { .. }),
        "expected ShortRead, got {err}"
    );
}

#[test]
fn corrupted_ack_frame_is_a_checksum_mismatch() {
    let (mut t, mut peer) = FakePeer::pair();
    let handle = std::thread::spawn(move || {
        let _round = peer.read();
        let mut buf = Vec::new();
        net::write_frame(&mut buf, FrameKind::RoundAck, 1, &[7u8; 16]).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x01; // flip one payload bit
        peer.send_raw(&buf);
        drop(peer);
    });
    let err = t
        .exchange(
            "r",
            RoundCharge {
                messages: 0,
                bytes: 0,
                machine_bytes: &charge1(0),
            },
            vec![Vec::new()],
            None,
        )
        .expect_err("corrupt ack must fail");
    handle.join().unwrap();
    assert!(
        matches!(err, TransportError::ChecksumMismatch { .. }),
        "expected ChecksumMismatch, got {err}"
    );
}

#[test]
fn lying_receiver_accounting_aborts_with_the_typed_error() {
    // the fake worker acknowledges more bytes than it was sent: the
    // engine must refuse the round (wrong answers are impossible, the
    // run dies with AccountingMismatch instead)
    let (t, mut peer) = FakePeer::pair();
    let handle = std::thread::spawn(move || {
        let round = peer.read();
        let mut body = Vec::new();
        body.extend_from_slice(&999u64.to_le_bytes()); // lie
        body.extend_from_slice(&0u64.to_le_bytes()); // no fold results
        peer.send(FrameKind::RoundAck, round.seq, &body);
        peer.serve_shutdown();
    });
    let mut sim = Simulator::with_transport(
        MpcConfig {
            machines: 1,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        },
        Box::new(t),
    );
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut out = vec![0u32; 4];
        sim.round_fold("r", &mut out, vec![(1u64, 5u32)], u32::min);
    }))
    .expect_err("accounting lie must abort the round");
    let err = caught
        .downcast::<TransportError>()
        .expect("typed panic payload");
    assert!(
        matches!(*err, TransportError::AccountingMismatch { .. }),
        "expected AccountingMismatch, got {err}"
    );
    drop(sim); // transport Drop sends Shutdown; the fake answers Bye
    handle.join().unwrap();
}

#[test]
fn diverging_shard_statistics_are_a_protocol_error() {
    let (mut t, mut peer) = FakePeer::pair();
    let g = small_graph(1);
    let stats_len = g.shard_stats(0).len;
    let handle = std::thread::spawn(move || {
        let load = peer.read();
        assert_eq!(load.kind, FrameKind::LoadShard);
        // ack with a wrong edge count: custody divergence
        let mut body = Vec::new();
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&(stats_len + 1).to_le_bytes());
        body.extend_from_slice(&0u64.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(stats_len + 1).to_le_bytes());
        peer.send(FrameKind::LoadAck, load.seq, &body);
        drop(peer);
    });
    let err = t.load_graph(&g).expect_err("diverging stats must fail");
    handle.join().unwrap();
    assert!(
        matches!(err, TransportError::Protocol { .. }),
        "expected Protocol, got {err}"
    );
}

#[test]
fn fold_round_with_garbage_fold_results_is_typed() {
    // fake worker returns a fold blob with a key outside the output
    // range: the merge must abort with a typed protocol error
    let (t, mut peer) = FakePeer::pair();
    let handle = std::thread::spawn(move || {
        let round = peer.read();
        let mut fold = Vec::new();
        fold.extend_from_slice(&u64::MAX.to_le_bytes()); // absurd key
        fold.extend_from_slice(&1u32.to_le_bytes());
        let mut body = Vec::new();
        body.extend_from_slice(&12u64.to_le_bytes()); // matches the charge
        body.extend_from_slice(&(fold.len() as u64).to_le_bytes());
        body.extend_from_slice(&fold);
        peer.send(FrameKind::RoundAck, round.seq, &body);
        peer.serve_shutdown();
    });
    let mut sim = Simulator::with_transport(
        MpcConfig {
            machines: 1,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        },
        Box::new(t),
    );
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut out = vec![9u32; 4];
        sim.round_fold_tagged(
            "hop",
            &mut out,
            vec![(1u64, 5u32)],
            lcc::mpc::WireFold::min_u32(),
        );
    }))
    .expect_err("garbage fold keys must abort");
    let err = caught
        .downcast::<TransportError>()
        .expect("typed panic payload");
    assert!(
        matches!(*err, TransportError::Protocol { .. }),
        "expected Protocol, got {err}"
    );
    drop(sim);
    handle.join().unwrap();
}

#[test]
fn frame_codec_faults_are_typed_at_the_byte_level() {
    // belt-and-braces at the lowest layer (the same codec both sides use)
    let mut buf = Vec::new();
    net::write_frame(&mut buf, FrameKind::Round, 3, b"abcdef").unwrap();

    let mut cut = buf.clone();
    cut.truncate(buf.len() - 3);
    assert!(matches!(
        net::read_frame(&mut &cut[..]),
        Err(TransportError::ShortRead { .. })
    ));

    let mut bad_magic = buf.clone();
    bad_magic[0] = b'Z';
    assert!(matches!(
        net::read_frame(&mut &bad_magic[..]),
        Err(TransportError::BadMagic { .. })
    ));

    let mut corrupt = buf;
    let last = corrupt.len() - 1;
    corrupt[last] ^= 0x80;
    assert!(matches!(
        net::read_frame(&mut &corrupt[..]),
        Err(TransportError::ChecksumMismatch { .. })
    ));
}

/// `exchange` used directly (same entry the simulator uses) must also
/// enforce wire-op folding round trips with a real worker process.
#[test]
fn real_worker_folds_min_u32_remotely() {
    let g = small_graph(1);
    let mut t = ProcTransport::spawn(1, worker_bin()).expect("spawn");
    t.load_graph(&g).expect("load");
    let mut payload = Vec::new();
    for (k, v) in [(3u64, 50u32), (3, 20), (5, 7)] {
        payload.extend_from_slice(&k.to_le_bytes());
        payload.extend_from_slice(&v.to_le_bytes());
    }
    let ack = t
        .exchange(
            "hop",
            RoundCharge {
                messages: 3,
                bytes: payload.len() as u64,
                machine_bytes: &charge1(payload.len() as u64),
            },
            vec![payload.clone()],
            Some(WireOp::MinU32),
        )
        .expect("fold round");
    assert_eq!(ack.machine_bytes, vec![payload.len() as u64]);
    let folded = &ack.folded.expect("fold results")[0];
    let expect = net::fold_wire_payload(WireOp::MinU32, &payload).unwrap();
    assert_eq!(folded, &expect);
    t.shutdown().expect("graceful shutdown");
}
