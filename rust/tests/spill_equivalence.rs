//! Out-of-core equivalence: the disk-backed shard store must be
//! **bit-identical** to the resident store — labels, per-round model
//! metrics, and final graphs — across the full acceptance matrix
//! machines ∈ {1, 4, 16} × threads ∈ {1, 4, 8} × budget ∈ {unbounded,
//! tight}, for every algorithm.  Mirrors
//! `rust/tests/sharded_representation.rs`, which proves the same about
//! sharded-vs-monolithic; together they pin the whole chain
//! monolithic = resident-sharded = spilled-sharded.
//!
//! "Tight" means a budget the input already exceeds, so every round of
//! the contraction loop runs load → rewrite → spill (the CI `spill` job
//! runs this suite on every push).

use lcc::cc::{self, oracle, CcAlgorithm, RunOptions};
use lcc::graph::{generators, Graph, ShardedGraph, SpillPolicy, Vertex};
use lcc::mpc::{MpcConfig, Simulator};
use lcc::util::quickcheck::Prop;
use lcc::util::rng::Rng;

const MACHINES: [usize; 3] = [1, 4, 16];
const THREADS: [usize; 3] = [1, 4, 8];
/// Tight: a handful of edges' worth of bytes — exceeded by every test
/// graph, so the spilled store is exercised from ingest to the last
/// contraction.
const TIGHT: u64 = 64;

fn run_algo(
    algo: &str,
    g: &Graph,
    machines: usize,
    threads: usize,
    spill_budget: Option<u64>,
    seed: u64,
) -> (Vec<Vertex>, Vec<lcc::mpc::RoundMetrics>) {
    let a = cc::by_name(algo);
    let mut sim = Simulator::new(MpcConfig {
        machines,
        space_per_machine: Some(1 << 20),
        spill_budget,
        threads,
    });
    let mut rng = Rng::new(seed);
    let res = a.run(g, &mut sim, &mut rng, &RunOptions::default());
    assert!(res.completed, "{algo} incomplete");
    (res.labels, res.metrics.rounds)
}

#[test]
fn all_algorithms_bit_identical_across_budget_matrix() {
    // The acceptance matrix: for every algorithm × graph × machines ×
    // threads, a tight-budget (spilled) run must produce exactly the
    // labels and per-round metrics of the unbounded (resident) run — and
    // both must equal the oracle.
    let graphs = [
        ("gnp", generators::gnp(220, 0.018, &mut Rng::new(5))),
        ("path", generators::path(100)),
        (
            "mixture",
            generators::star(40).disjoint_union(generators::cycle(17)),
        ),
    ];
    for (gname, g) in &graphs {
        let want = oracle::components(g);
        for algo in cc::ALL_ALGORITHMS {
            for machines in MACHINES {
                let (base_labels, base_rounds) = run_algo(algo, g, machines, 1, None, 7);
                assert_eq!(
                    base_labels, want,
                    "{algo} wrong on {gname} (machines={machines})"
                );
                for threads in THREADS {
                    for budget in [None, Some(TIGHT)] {
                        let (labels, rounds) =
                            run_algo(algo, g, machines, threads, budget, 7);
                        assert_eq!(
                            labels, base_labels,
                            "{algo}/{gname}: labels diverge (machines={machines}, \
                             threads={threads}, budget={budget:?})"
                        );
                        assert_eq!(
                            rounds, base_rounds,
                            "{algo}/{gname}: metrics diverge (machines={machines}, \
                             threads={threads}, budget={budget:?})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_graph_ops_bit_identical_across_backends() {
    // Graph-layer equivalence over random raw edge lists: every operation
    // of the spilled store matches the resident store exactly, at every
    // shard count of the matrix.
    Prop::new(16).check_sized(
        "spilled-vs-resident-ops",
        350,
        |rng, size| {
            let n = size.max(2);
            let m = rng.gen_range(4 * n as u64) as usize;
            let edges: Vec<(Vertex, Vertex)> = (0..m)
                .map(|_| {
                    (
                        rng.gen_range(n as u64) as Vertex,
                        rng.gen_range(n as u64) as Vertex,
                    )
                })
                .collect();
            let labels: Vec<Vertex> = (0..n as u32)
                .map(|_| rng.gen_range(n as u64) as Vertex)
                .collect();
            (n, edges, labels)
        },
        |(n, edges, labels)| {
            for p in MACHINES {
                let resident = ShardedGraph::from_edges(*n, p, edges.clone());
                let spilled = ShardedGraph::from_edges_with(
                    *n,
                    p,
                    edges.clone(),
                    SpillPolicy::budget(0),
                );
                if resident.num_edges() > 0 && !spilled.is_spilled() {
                    return Err(format!("p={p}: budget-0 graph stayed resident"));
                }
                if spilled.to_graph() != resident.to_graph() {
                    return Err(format!("p={p}: to_graph differs"));
                }
                if spilled.degrees() != resident.degrees() {
                    return Err(format!("p={p}: degrees differ"));
                }
                let (cr, mr) = resident.contract(labels);
                let (cs, ms) = spilled.contract(labels);
                if ms != mr || cs.to_graph() != cr.to_graph() {
                    return Err(format!("p={p}: contract differs"));
                }
                let (pr, mapr) = resident.prune_isolated();
                let (ps, maps) = spilled.prune_isolated();
                if maps != mapr || ps.to_graph() != pr.to_graph() {
                    return Err(format!("p={p}: prune differs"));
                }
                let rr = resident.reshard(3);
                let rs = spilled.reshard(3);
                if rs.to_graph() != rr.to_graph() {
                    return Err(format!("p={p}: reshard differs"));
                }
                // round charges are pure functions of the cached stats —
                // identical with the edges on disk
                for include_self in [true, false] {
                    if spilled.hop_charge(12, include_self)
                        != resident.hop_charge(12, include_self)
                    {
                        return Err(format!("p={p}: hop_charge differs"));
                    }
                }
                if spilled.contract_charges() != resident.contract_charges() {
                    return Err(format!("p={p}: contract_charges differ"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cursor_walk_is_bit_identical_to_row_decode() {
    // The zero-copy read path against the legacy row path: walking a
    // columnar shard image through its borrowed cursor must yield exactly
    // the edge sequence of a row-major decode — and every derived view
    // (sub-shard slices, per-vertex touched ranges) must agree with brute
    // force over that sequence.
    use lcc::graph::{io, spill};
    Prop::new(16).check_sized(
        "cursor-vs-row-decode",
        400,
        |rng, size| {
            let n = size.max(2) as u64;
            let m = rng.gen_range(4 * n) as usize;
            let mut edges: Vec<(Vertex, Vertex)> = (0..m)
                .map(|_| (rng.gen_range(n) as Vertex, rng.gen_range(n) as Vertex))
                .collect();
            // canonical shard order, as every engine shard file holds
            edges.sort_unstable();
            edges.dedup();
            let p = 1 + rng.gen_range(7) as u32;
            let s = rng.gen_range(p as u64) as u32;
            (edges, s, p)
        },
        |(edges, s, p)| {
            let (image, ck) = spill::encode_shard_bytes(*s, *p, edges);
            if ck != spill::checksum_edges(edges) {
                return Err("encode checksum is not the logical row checksum".into());
            }
            let (cursor, vck) =
                spill::parse_shard_image(&image, *s, *p, std::path::Path::new("<prop>"))
                    .map_err(|e| format!("self-encoded image rejected: {e}"))?;
            if vck != ck {
                return Err("verified checksum differs from declared".into());
            }
            // bit-identity vs the row-major decode
            let mut rows = Vec::new();
            io::write_pairs(&mut rows, edges).unwrap();
            let decoded = io::decode_pairs(&rows);
            let walked: Vec<(Vertex, Vertex)> = cursor.iter().collect();
            if walked != decoded {
                return Err("cursor walk differs from row decode".into());
            }
            // sub-shard slices stream exactly their row ranges
            let m = edges.len();
            for (lo, hi) in [(0, m), (m / 3, 2 * m / 3), (m.saturating_sub(1), m)] {
                let sliced: Vec<(Vertex, Vertex)> = cursor.slice(lo, hi).iter().collect();
                if sliced != decoded[lo..hi] {
                    return Err(format!("slice {lo}..{hi} differs from row decode"));
                }
            }
            // the vertex index brackets exactly the rows of each source
            let mut probes: Vec<Vertex> = edges.iter().map(|&(u, _)| u).collect();
            probes.push(edges.last().map(|&(u, _)| u + 1).unwrap_or(0));
            probes.push(0);
            for v in probes {
                let got = cursor.vertex_range(v);
                let want_start = decoded.partition_point(|&(u, _)| u < v);
                let want_end = decoded.partition_point(|&(u, _)| u <= v);
                if got != (want_start..want_end) {
                    return Err(format!(
                        "vertex_range({v}) = {got:?}, brute force says {want_start}..{want_end}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn tight_budget_actually_spills_and_unbounded_does_not() {
    // Guard against the suite silently testing resident-vs-resident: the
    // tight budget must put the ingest generation on disk.
    let g = generators::gnp(220, 0.018, &mut Rng::new(5));
    let spilled = ShardedGraph::from_graph_with(&g, 4, SpillPolicy::budget(TIGHT));
    assert!(spilled.is_spilled(), "tight budget did not spill");
    assert!(spilled.edge_bytes() > TIGHT);
    let resident = ShardedGraph::from_graph_with(&g, 4, SpillPolicy::with_budget(None));
    assert!(!resident.is_spilled());
}

#[test]
fn contraction_loop_inherits_the_budget_every_round() {
    // A spilled run's intermediate generations stay governed by the same
    // policy: contract a spilled graph repeatedly and observe each
    // generation either spills (over budget) or is resident (under),
    // never "sticky" one way.
    let g = generators::gnp(300, 0.02, &mut Rng::new(9));
    let mut cur = ShardedGraph::from_graph_with(&g, 4, SpillPolicy::budget(TIGHT));
    assert!(cur.is_spilled());
    for round in 0..8 {
        if cur.num_edges() == 0 {
            break;
        }
        // merge pairs of vertices: halves the id space each round
        let labels: Vec<Vertex> = (0..cur.num_vertices() as u32).map(|v| v / 2).collect();
        let (next, _) = cur.contract(&labels);
        assert_eq!(
            next.is_spilled(),
            next.edge_bytes() > TIGHT,
            "round {round}: residency does not track the budget \
             (edges={}, bytes={})",
            next.num_edges(),
            next.edge_bytes()
        );
        cur = next;
    }
}

#[test]
fn driver_reports_identical_under_budget() {
    // The coordinator path (`lcc run --spill-budget`): phases, rounds,
    // bytes, and labels of a budgeted run equal the unbounded run.
    let g = generators::gnp(400, 0.008, &mut Rng::new(11));
    let run = |budget: Option<u64>| {
        let d = lcc::coordinator::Driver::new(lcc::coordinator::RunConfig {
            algorithm: "lc".into(),
            machines: 4,
            threads: 2,
            spill_budget: budget,
            verify: true,
            ..Default::default()
        });
        d.run_named(&g, "gnp400")
    };
    let base = run(None);
    let spilled = run(Some(TIGHT));
    assert_eq!(spilled.verified, Some(true));
    assert_eq!(base.verified, Some(true));
    assert_eq!(spilled.phases, base.phases);
    assert_eq!(spilled.rounds, base.rounds);
    assert_eq!(spilled.total_shuffle_bytes, base.total_shuffle_bytes);
    assert_eq!(spilled.max_round_bytes, base.max_round_bytes);
    assert_eq!(spilled.num_components, base.num_components);
}

#[test]
fn pipeline_summary_spills_and_merges_identically() {
    // Workers' summary shards spill straight to disk under the budget and
    // the downstream merge is unchanged.
    let g = generators::gnp(1200, 0.004, &mut Rng::new(17));
    let run = |budget: Option<u64>| {
        let cfg = lcc::coordinator::PipelineConfig {
            num_workers: 5,
            chunk_size: 128,
            channel_capacity: 2,
            spill_budget: budget,
        };
        lcc::coordinator::pipeline::run(1200, g.edges().iter().copied(), &cfg)
    };
    let resident = run(None);
    let spilled = run(Some(0));
    assert!(spilled.summary.is_spilled());
    assert!(!resident.summary.is_spilled());
    assert_eq!(spilled.summary, resident.summary);
    let want = oracle::components(&g);
    assert_eq!(
        lcc::coordinator::pipeline::merge_summary(&spilled.summary),
        want
    );
    for machines in MACHINES {
        let resharded = spilled.summary.reshard(machines);
        assert_eq!(oracle::components_sharded(&resharded), want);
        assert_eq!(
            resharded.to_graph(),
            resident.summary.reshard(machines).to_graph()
        );
    }
}
