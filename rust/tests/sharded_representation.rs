//! Cross-representation invariants: the sharded resident store must be
//! **bit-identical** to the monolithic `Graph` path, and every algorithm
//! must stay oracle-correct with engine-invariant model metrics across
//! `machines ∈ {1, 4, 16}` and `threads ∈ {1, 4, 8}`.

use lcc::cc::{self, oracle, CcAlgorithm, RunOptions};
use lcc::graph::{generators, Graph, ShardedGraph, Vertex};
use lcc::mpc::simulator::machine_of;
use lcc::mpc::{MpcConfig, Simulator};
use lcc::util::quickcheck::Prop;
use lcc::util::rng::Rng;

const MACHINES: [usize; 3] = [1, 4, 16];
const THREADS: [usize; 3] = [1, 4, 8];

fn raw_edges(rng: &mut Rng, size: usize) -> (usize, Vec<(Vertex, Vertex)>) {
    let n = size.max(2);
    let m = rng.gen_range(4 * n as u64) as usize;
    let edges = (0..m)
        .map(|_| {
            (
                rng.gen_range(n as u64) as Vertex,
                rng.gen_range(n as u64) as Vertex,
            )
        })
        .collect();
    (n, edges)
}

#[test]
fn prop_normalize_contract_degrees_bit_identical() {
    // For random raw edge lists and random labels, every graph-layer
    // operation of ShardedGraph must match the monolithic Graph exactly —
    // at every shard count.
    Prop::new(24).check_sized(
        "sharded-vs-flat",
        400,
        |rng, size| {
            let (n, edges) = raw_edges(rng, size);
            let labels: Vec<Vertex> = (0..n as u32)
                .map(|_| rng.gen_range(n as u64) as Vertex)
                .collect();
            (n, edges, labels)
        },
        |(n, edges, labels)| {
            let flat = Graph::from_edges(*n, edges.clone());
            for p in MACHINES {
                let sharded = ShardedGraph::from_edges(*n, p, edges.clone());
                if sharded.to_graph() != flat {
                    return Err(format!("normalize differs at p={p}"));
                }
                if sharded.degrees() != flat.degrees() {
                    return Err(format!("degrees differ at p={p}"));
                }
                let (cf, mf) = flat.contract(labels);
                let (cs, ms) = sharded.contract(labels);
                if ms != mf || cs.to_graph() != cf {
                    return Err(format!("contract differs at p={p}"));
                }
                let (pf, mapf) = flat.prune_isolated();
                let (ps, maps) = sharded.prune_isolated();
                if maps != mapf || ps.to_graph() != pf {
                    return Err(format!("prune differs at p={p}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shard_ownership_and_cached_stats() {
    // The resident invariant (edge lives on machine_of(min endpoint)) and
    // the cached histograms the round charges are derived from.
    Prop::new(16).check_sized(
        "shard-invariant",
        300,
        |rng, size| raw_edges(rng, size),
        |(n, edges)| {
            for p in [3usize, 8] {
                let g = ShardedGraph::from_edges(*n, p, edges.clone());
                for s in 0..g.num_shards() {
                    let data = g.read_shard(s).map_err(|e| e.to_string())?;
                    let mut peers = vec![0u64; p];
                    for (u, v) in data.iter() {
                        if u >= v {
                            return Err(format!("non-canonical edge ({u},{v})"));
                        }
                        if machine_of(u as u64, p) != s {
                            return Err(format!("edge ({u},{v}) on wrong shard {s}"));
                        }
                        peers[machine_of(v as u64, p)] += 1;
                    }
                    if peers != g.shard_stats(s).peer_counts {
                        return Err(format!("stale peer_counts on shard {s}"));
                    }
                }
                if g.vertex_counts().iter().sum::<u64>() != *n as u64 {
                    return Err("vertex_counts do not partition 0..n".into());
                }
            }
            Ok(())
        },
    );
}

fn run_algo(
    algo: &str,
    g: &Graph,
    machines: usize,
    threads: usize,
    seed: u64,
) -> (Vec<Vertex>, Vec<lcc::mpc::RoundMetrics>) {
    let a = cc::by_name(algo);
    let mut sim = Simulator::new(MpcConfig {
        machines,
        space_per_machine: Some(1 << 20),
        spill_budget: None,
        threads,
    });
    let mut rng = Rng::new(seed);
    let res = a.run(g, &mut sim, &mut rng, &RunOptions::default());
    assert!(res.completed, "{algo} incomplete");
    (res.labels, res.metrics.rounds)
}

#[test]
fn all_algorithms_oracle_correct_and_invariant_across_machines_and_threads() {
    // Acceptance matrix: machines ∈ {1,4,16} × threads ∈ {1,4,8} for every
    // algorithm.  Labels must equal the oracle everywhere; for a fixed
    // machine count the per-round model metrics (messages / bytes /
    // max_machine_bytes / space_violation) must be identical at every
    // threads setting.
    let graphs = [
        ("gnp", generators::gnp(250, 0.015, &mut Rng::new(5))),
        ("path", generators::path(120)),
        (
            "mixture",
            generators::star(40).disjoint_union(generators::cycle(17)),
        ),
    ];
    for (gname, g) in &graphs {
        let want = oracle::components(g);
        for algo in cc::ALL_ALGORITHMS {
            for machines in MACHINES {
                let (base_labels, base_rounds) = run_algo(algo, g, machines, 1, 7);
                assert_eq!(
                    base_labels, want,
                    "{algo} wrong on {gname} (machines={machines})"
                );
                for threads in THREADS {
                    let (labels, rounds) = run_algo(algo, g, machines, threads, 7);
                    assert_eq!(
                        labels, base_labels,
                        "{algo}/{gname}: labels diverge (machines={machines}, threads={threads})"
                    );
                    assert_eq!(
                        rounds, base_rounds,
                        "{algo}/{gname}: metrics diverge (machines={machines}, threads={threads})"
                    );
                }
            }
        }
    }
}

#[test]
fn sharded_and_flat_entries_agree() {
    // The trait's flat adapter and an explicit from_graph + run_sharded
    // must be the same computation.
    let g = generators::gnp(300, 0.012, &mut Rng::new(9));
    for algo in ["lc", "cracker", "tc-dht"] {
        let a = cc::by_name(algo);
        let exec_flat = || {
            let mut sim = Simulator::new(MpcConfig {
                machines: 4,
                space_per_machine: None,
                spill_budget: None,
                threads: 2,
            });
            let mut rng = Rng::new(3);
            a.run(&g, &mut sim, &mut rng, &RunOptions::default())
        };
        let exec_sharded = || {
            let mut sim = Simulator::new(MpcConfig {
                machines: 4,
                space_per_machine: None,
                spill_budget: None,
                threads: 2,
            });
            let sharded = ShardedGraph::from_graph(&g, 4);
            let mut rng = Rng::new(3);
            a.run_sharded(&sharded, &mut sim, &mut rng, &RunOptions::default())
        };
        let fr = exec_flat();
        let sr = exec_sharded();
        assert_eq!(fr.labels, sr.labels, "{algo}");
        assert_eq!(fr.metrics.rounds, sr.metrics.rounds, "{algo}");
    }
}

#[test]
fn finisher_and_pruning_stay_correct_on_sharded_loop() {
    let g = generators::gnp(400, 0.008, &mut Rng::new(11));
    let want = oracle::components(&g);
    for algo in ["lc", "lc-mtl", "tc", "cracker"] {
        for (finisher, prune) in [(0usize, true), (200, true), (200, false), (0, false)] {
            let a = cc::by_name(algo);
            let mut sim = Simulator::new(MpcConfig {
                machines: 4,
                space_per_machine: None,
                spill_budget: None,
                threads: 4,
            });
            let mut rng = Rng::new(13);
            let opts = RunOptions {
                finisher_threshold: finisher,
                prune_isolated: prune,
                ..Default::default()
            };
            let res = a.run(&g, &mut sim, &mut rng, &opts);
            assert_eq!(
                res.labels, want,
                "{algo} wrong (finisher={finisher}, prune={prune})"
            );
        }
    }
}

#[test]
fn pipeline_summary_reshards_into_any_machine_count() {
    let g = generators::gnp(1500, 0.004, &mut Rng::new(17));
    let cfg = lcc::coordinator::PipelineConfig {
        num_workers: 5,
        chunk_size: 128,
        channel_capacity: 2,
        spill_budget: None,
    };
    let res = lcc::coordinator::pipeline::run(1500, g.edges().iter().copied(), &cfg);
    assert_eq!(res.summary.num_shards(), 5);
    let want = oracle::components(&g);
    assert_eq!(lcc::coordinator::pipeline::merge_summary(&res.summary), want);
    for machines in MACHINES {
        let resharded = res.summary.reshard(machines);
        assert_eq!(resharded.num_shards(), machines);
        assert_eq!(oracle::components_sharded(&resharded), want);
        assert_eq!(resharded.to_graph(), res.summary.to_graph());
    }
}
