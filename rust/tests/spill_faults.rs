//! Fault injection for the spill files: every on-disk failure mode —
//! truncation, payload corruption, foreign files, and a spill directory
//! deleted mid-run — must surface as a **typed** [`SpillError`], never as
//! a panic from the store layer or as silently-wrong labels.  Plus the
//! crash-then-reload round trip of a persisted spilled [`ShardedGraph`].

use std::fs;
use std::path::PathBuf;

use lcc::graph::{generators, ShardedGraph, SpillError, SpillPolicy, Vertex};
use lcc::util::rng::Rng;

fn spilled_graph(seed: u64) -> ShardedGraph {
    let flat = generators::gnp(150, 0.03, &mut Rng::new(seed));
    let g = ShardedGraph::from_graph_with(&flat, 4, SpillPolicy::budget(0));
    assert!(g.is_spilled());
    g
}

/// The on-disk shard files of a spilled graph, in shard order.
fn shard_files(g: &ShardedGraph) -> Vec<PathBuf> {
    let dir = g.spill_dir().expect("graph is spilled");
    let mut files: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().map(|e| e == "lcs").unwrap_or(false))
        .collect();
    files.sort();
    files
}

#[test]
fn truncated_shard_file_is_typed_error() {
    let g = spilled_graph(1);
    let files = shard_files(&g);
    let victim = files
        .iter()
        .find(|p| fs::metadata(p).unwrap().len() > 40)
        .expect("a non-empty shard");
    let bytes = fs::read(victim).unwrap();
    fs::write(victim, &bytes[..bytes.len() - 4]).unwrap();
    let s = files.iter().position(|p| p == victim).unwrap();
    match g.read_shard(s) {
        Err(SpillError::Truncated {
            expected_bytes,
            actual_bytes,
            ..
        }) => assert_eq!(actual_bytes + 4, expected_bytes),
        other => panic!("expected SpillError::Truncated, got {other:?}"),
    }
    // the flatten path reports the same typed error
    assert!(matches!(
        g.try_to_graph(),
        Err(SpillError::Truncated { .. })
    ));
}

#[test]
fn corrupt_shard_payload_is_typed_error_not_wrong_labels() {
    let g = spilled_graph(2);
    let files = shard_files(&g);
    let victim = files
        .iter()
        .find(|p| fs::metadata(p).unwrap().len() > 40)
        .expect("a non-empty shard");
    let mut bytes = fs::read(victim).unwrap();
    // flip one payload bit (the last byte of the dst column — the file's
    // tail is the vertex index, which is a *different* fault): same
    // length, different edge
    let m = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
    let dst_end = 40 + 8 * m;
    bytes[dst_end - 1] ^= 0x01;
    fs::write(victim, &bytes).unwrap();
    let s = files.iter().position(|p| p == victim).unwrap();
    // a store without checksums would hand back a silently different edge
    // set here; ours must refuse with the typed mismatch instead
    match g.read_shard(s) {
        Err(SpillError::ChecksumMismatch {
            expected, actual, ..
        }) => assert_ne!(expected, actual),
        other => panic!("expected SpillError::ChecksumMismatch, got {other:?}"),
    }
    assert!(matches!(
        g.try_to_graph(),
        Err(SpillError::ChecksumMismatch { .. })
    ));
}

#[test]
fn corrupt_vertex_index_is_typed_corrupt() {
    // The columnar file ends with the vertex→range index.  Corrupting it
    // leaves every edge intact (the payload checksum passes), so a store
    // that trusted the index would serve wrong ranges; ours re-derives
    // the bucket histogram during the checksum walk and refuses.
    let g = spilled_graph(11);
    let files = shard_files(&g);
    let victim = files
        .iter()
        .find(|p| fs::metadata(p).unwrap().len() > 40)
        .expect("a non-empty shard");
    let mut bytes = fs::read(victim).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01; // the final byte of the last index offset
    fs::write(victim, &bytes).unwrap();
    let s = files.iter().position(|p| p == victim).unwrap();
    match g.read_shard(s) {
        Err(SpillError::Corrupt { detail, .. }) => {
            assert!(detail.contains("index"), "detail names the index: {detail}")
        }
        other => panic!("expected SpillError::Corrupt, got {other:?}"),
    }
}

#[test]
fn foreign_file_is_bad_magic() {
    let g = spilled_graph(3);
    let files = shard_files(&g);
    fs::write(&files[0], b"definitely not a shard file........").unwrap();
    assert!(matches!(g.read_shard(0), Err(SpillError::BadMagic { .. })));
}

#[test]
fn mid_run_dir_cleanup_is_typed_io_error() {
    // Someone tidies the temp dir while the graph is live: reads fail
    // with a typed Io error carrying the vanished path — no panic, no
    // empty-graph fallback.
    let g = spilled_graph(4);
    let dir = g.spill_dir().unwrap().to_path_buf();
    fs::remove_dir_all(&dir).unwrap();
    match g.read_shard(0) {
        Err(SpillError::Io { op, path, .. }) => {
            assert_eq!(op, "open");
            assert!(path.starts_with(&dir));
        }
        other => panic!("expected SpillError::Io, got {other:?}"),
    }
    match g.try_to_graph() {
        Err(e) => assert!(e.path().starts_with(&dir)),
        Ok(_) => panic!("flatten succeeded with no files on disk"),
    }
}

#[test]
fn errors_format_and_chain() {
    let g = spilled_graph(5);
    let dir = g.spill_dir().unwrap().to_path_buf();
    fs::remove_dir_all(&dir).unwrap();
    let err = g.read_shard(0).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("spill I/O"), "{msg}");
    assert!(std::error::Error::source(&err).is_some(), "Io chains its cause");
}

// ---------------------------------------------------------------------------
// crash-then-reload

fn persist_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lcc-spill-faults-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

#[test]
fn crash_then_reload_roundtrip_is_bit_identical() {
    let flat = generators::gnp(300, 0.015, &mut Rng::new(6));
    let g = ShardedGraph::from_graph_with(&flat, 4, SpillPolicy::budget(0));
    let dir = persist_dir("roundtrip");
    g.persist_spilled(&dir).unwrap();

    // "crash": drop every in-memory trace of the graph, then reload from
    // the manifest alone
    let (want_graph, want_counts) = (g.to_graph(), g.vertex_counts().to_vec());
    drop(g);

    let h = ShardedGraph::open_spilled(&dir, SpillPolicy::budget(0)).unwrap();
    assert_eq!(h.to_graph(), want_graph);
    assert_eq!(h.vertex_counts(), &want_counts[..]);
    // the reloaded graph computes like any other: contract + oracle agree
    let labels: Vec<Vertex> = lcc::cc::oracle::components_sharded(&h);
    assert_eq!(labels, lcc::cc::oracle::components(&flat));
    let (c, _) = h.contract(&labels);
    assert_eq!(c.num_edges(), 0);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn reload_rejects_corrupt_manifest_and_stale_files() {
    let flat = generators::gnp(120, 0.03, &mut Rng::new(7));
    let g = ShardedGraph::from_graph_with(&flat, 3, SpillPolicy::budget(0));
    let dir = persist_dir("stale");
    g.persist_spilled(&dir).unwrap();

    // corrupt manifest body -> checksum mismatch at open
    let manifest = dir.join("manifest.lcm");
    let mut bytes = fs::read(&manifest).unwrap();
    bytes[12] ^= 0xFF;
    fs::write(&manifest, &bytes).unwrap();
    assert!(matches!(
        ShardedGraph::open_spilled(&dir, SpillPolicy::unbounded()),
        Err(SpillError::ChecksumMismatch { .. })
    ));

    // restore manifest, truncate a shard file -> typed error at open
    g.persist_spilled(&dir).unwrap();
    let shard0 = dir.join("shard-00000.lcs");
    let bytes = fs::read(&shard0).unwrap();
    fs::write(&shard0, &bytes[..bytes.len().saturating_sub(8)]).unwrap();
    assert!(matches!(
        ShardedGraph::open_spilled(&dir, SpillPolicy::unbounded()),
        Err(SpillError::Truncated { .. })
    ));

    // missing manifest entirely -> Io
    fs::remove_file(&manifest).unwrap();
    assert!(matches!(
        ShardedGraph::open_spilled(&dir, SpillPolicy::unbounded()),
        Err(SpillError::Io { .. })
    ));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn reload_rejects_degenerate_manifest_dimensions() {
    // A checksum-valid manifest with p = 0 must be a typed Corrupt, not a
    // divide-by-zero panic in the partition hash.
    let dir = persist_dir("zerop");
    fs::create_dir_all(&dir).unwrap();
    lcc::graph::spill::write_manifest(
        &dir.join(lcc::graph::spill::MANIFEST_NAME),
        &lcc::graph::spill::Manifest {
            n: 10,
            p: 0,
            shards: vec![],
        },
    )
    .unwrap();
    assert!(matches!(
        ShardedGraph::open_spilled(&dir, SpillPolicy::unbounded()),
        Err(SpillError::Corrupt { .. })
    ));

    // ... and an n beyond the u32 vertex-id space likewise.
    lcc::graph::spill::write_manifest(
        &dir.join(lcc::graph::spill::MANIFEST_NAME),
        &lcc::graph::spill::Manifest {
            n: u64::MAX / 2,
            p: 1,
            shards: vec![lcc::graph::spill::ManifestShard {
                len: 0,
                checksum: 0,
                peer_counts: vec![0],
            }],
        },
    )
    .unwrap();
    assert!(matches!(
        ShardedGraph::open_spilled(&dir, SpillPolicy::unbounded()),
        Err(SpillError::Corrupt { .. })
    ));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn torn_manifest_rewrite_never_shadows_the_valid_one() {
    // Crash window of write_atomic: the full new image lands in
    // `manifest.lcm.tmp` first, so a crash mid-write leaves a torn tmp
    // next to the intact old manifest.  Reopening must see the old
    // manifest untouched — bit-identically — and never read the tmp.
    let flat = generators::gnp(130, 0.03, &mut Rng::new(9));
    let g = ShardedGraph::from_graph_with(&flat, 4, SpillPolicy::budget(0));
    let dir = persist_dir("torn");
    g.persist_spilled(&dir).unwrap();
    let want = g.to_graph();

    let manifest = dir.join(lcc::graph::spill::MANIFEST_NAME);
    let before = fs::read(&manifest).unwrap();
    // half the valid image, then garbage: a realistic torn write
    let mut torn = before[..before.len() / 2].to_vec();
    torn.extend_from_slice(b"crashed mid-write");
    fs::write(dir.join("manifest.lcm.tmp"), &torn).unwrap();

    let h = ShardedGraph::open_spilled(&dir, SpillPolicy::unbounded()).unwrap();
    assert_eq!(h.to_graph(), want);
    assert_eq!(fs::read(&manifest).unwrap(), before, "old manifest intact");

    // ... and the next persist simply rewrites over the stale tmp
    g.persist_spilled(&dir).unwrap();
    assert!(!dir.join("manifest.lcm.tmp").exists());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn torn_checkpoint_rewrite_keeps_the_previous_generation() {
    // Same crash window for the run checkpoint: a torn
    // `checkpoint.lcc.tmp` must not shadow the durable generation, and a
    // truncated checkpoint file itself must be a typed error, not a panic.
    use lcc::graph::spill::{read_checkpoint, write_checkpoint, RunCheckpoint, CHECKPOINT_NAME};
    let dir = persist_dir("ckpt-torn");
    fs::create_dir_all(&dir).unwrap();
    let ckpt = RunCheckpoint {
        generation: 3,
        machines: 4,
        mirror_hash: Some(0xFEED_BEEF),
        rng_state: [1, 2, 3, 4],
        rounds: 17,
        custody_dir: "gen-3".to_string(),
    };
    let path = dir.join(CHECKPOINT_NAME);
    write_checkpoint(&path, &ckpt).unwrap();
    fs::write(dir.join(format!("{CHECKPOINT_NAME}.tmp")), b"torn").unwrap();
    assert_eq!(read_checkpoint(&path).unwrap(), ckpt);

    let bytes = fs::read(&path).unwrap();
    fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
    assert!(read_checkpoint(&path).is_err(), "truncated checkpoint is typed");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn persist_works_from_a_resident_graph_too() {
    // persist/open is backend-agnostic: a resident graph persists the
    // same files a spilled one would.
    let flat = generators::gnp(100, 0.04, &mut Rng::new(8));
    let resident = ShardedGraph::from_graph(&flat, 4);
    let dir = persist_dir("resident");
    resident.persist_spilled(&dir).unwrap();
    let h = ShardedGraph::open_spilled(&dir, SpillPolicy::unbounded()).unwrap();
    assert!(h.is_spilled(), "opened graphs are disk-backed views");
    assert_eq!(h, resident);
    assert_eq!(h.to_graph(), resident.to_graph());
    let _ = fs::remove_dir_all(&dir);
}
