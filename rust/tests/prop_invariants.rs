//! Property-based invariants (the proptest-shaped suite, running on the
//! in-repo `util::quickcheck` runner — see DESIGN.md §5).
//!
//! Each property runs over dozens of generated graphs with a reportable
//! seed (`LCC_PROP_SEED`) and size-shrinking on failure.

use lcc::cc::{self, oracle, CcAlgorithm, RunOptions};
use lcc::graph::{generators, Graph};
use lcc::mpc::{MpcConfig, Simulator};
use lcc::util::quickcheck::Prop;
use lcc::util::rng::Rng;

fn random_graph(rng: &mut Rng, size: usize) -> Graph {
    let n = size.max(2);
    match rng.gen_range(4) {
        0 => generators::gnp(n, 2.0 / n as f64, rng),
        1 => generators::gnp(n, 8.0 / n as f64, rng),
        2 => generators::chung_lu(n, 5.0, 2.5, rng),
        _ => generators::rmat(
            (n as f64).log2().ceil().max(2.0) as u32,
            3 * n,
            (0.45, 0.22, 0.22, 0.11),
            rng,
        ),
    }
}

fn run_algo(algo: &str, g: &Graph, seed: u64) -> cc::CcResult {
    let a = cc::by_name(algo);
    let mut sim = Simulator::new(MpcConfig {
        machines: 4,
        space_per_machine: None,
        spill_budget: None,
        threads: 1,
    });
    let mut rng = Rng::new(seed);
    a.run(g, &mut sim, &mut rng, &RunOptions::default())
}

#[test]
fn prop_every_algorithm_matches_oracle() {
    for algo in cc::ALL_ALGORITHMS {
        Prop::new(12).check_sized(
            &format!("{algo}-matches-oracle"),
            300,
            |rng, size| (random_graph(rng, size), rng.next_u64()),
            |(g, seed)| {
                let res = run_algo(algo, g, *seed);
                if !res.completed {
                    return Err(format!("{algo} did not complete"));
                }
                let want = oracle::components(g);
                if res.labels != want {
                    return Err(format!("{algo} labels differ from oracle"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_labels_are_canonical_minima() {
    // labels[v] <= v and labels[labels[v]] == labels[v]
    Prop::new(24).check_sized(
        "labels-are-canonical",
        400,
        |rng, size| (random_graph(rng, size), rng.next_u64()),
        |(g, seed)| {
            let res = run_algo("lc", g, *seed);
            for (v, &l) in res.labels.iter().enumerate() {
                if l as usize > v {
                    return Err(format!("label {l} > vertex {v}"));
                }
                if res.labels[l as usize] != l {
                    return Err(format!("label {l} is not its own representative"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_contraction_preserves_component_count() {
    // One LC phase never merges across components and never leaves the
    // component count wrong: contracted graph's component count (plus
    // resolved singletons) equals the input's.
    Prop::new(24).check_sized(
        "phase-preserves-components",
        300,
        |rng, size| (random_graph(rng, size), rng.next_u64()),
        |(g, seed)| {
            use lcc::cc::common::{contract_mpc, Priorities};
            use lcc::graph::ShardedGraph;
            let mut sim = Simulator::new(MpcConfig {
                machines: 4,
                space_per_machine: None,
                spill_budget: None,
                threads: 1,
            });
            let sharded = ShardedGraph::from_graph(g, 4);
            let mut rng = Rng::new(*seed);
            let rho = Priorities::sample(g.num_vertices(), &mut rng);
            let labels =
                cc::local_contraction::phase_labels(&sharded, &mut sim, &rho, None);
            let (contracted, node_map) = contract_mpc(&mut sim, &sharded, &labels);
            let contracted = contracted.to_graph();
            // same-component check: label classes stay within components
            let want = oracle::components(g);
            for &(u, v) in g.edges() {
                if want[u as usize] != want[v as usize] {
                    return Err("oracle disagrees on an edge?!".into());
                }
            }
            for (v, &node) in node_map.iter().enumerate() {
                for (u, &node2) in node_map.iter().enumerate().skip(v + 1) {
                    if node == node2 && want[v] != want[u] {
                        return Err(format!("phase merged across components: {v},{u}"));
                    }
                }
            }
            // component count is preserved
            let before = {
                let mut ls = want.clone();
                ls.sort_unstable();
                ls.dedup();
                ls.len()
            };
            let after = {
                let mut ls = oracle::components(&contracted);
                ls.sort_unstable();
                ls.dedup();
                ls.len()
            };
            if before != after {
                return Err(format!("components {before} -> {after}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_edges_per_phase_monotone_for_lc() {
    Prop::new(16).check_sized(
        "edges-monotone",
        400,
        |rng, size| (random_graph(rng, size), rng.next_u64()),
        |(g, seed)| {
            let res = run_algo("lc", g, *seed);
            for w in res.edges_per_phase.windows(2) {
                if w[1] > w[0] {
                    return Err(format!("edges grew: {:?}", res.edges_per_phase));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tree_contraction_halves_nodes() {
    // Lemma 4.3 invariant, as a property over random inputs.
    Prop::new(16).check_sized(
        "tc-halves",
        300,
        |rng, size| (random_graph(rng, size), rng.next_u64()),
        |(g, seed)| {
            let res = run_algo("tc", g, *seed);
            for w in res.nodes_per_phase.windows(2) {
                // only nodes with edges are forced to merge; pruned
                // isolated nodes leave, so <= ceil(prev/2) + slack is the
                // observable bound. Use the exact lemma on edge-ful nodes:
                if w[1] > w[0] {
                    return Err(format!("nodes grew: {:?}", res.nodes_per_phase));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pipeline_matches_oracle() {
    Prop::new(16).check_sized(
        "pipeline-matches-oracle",
        600,
        |rng, size| {
            let g = random_graph(rng, size);
            let workers = 1 + rng.gen_range(6) as usize;
            (g, workers)
        },
        |(g, workers)| {
            let cfg = lcc::coordinator::PipelineConfig {
                num_workers: *workers,
                chunk_size: 64,
                channel_capacity: 2,
                spill_budget: None,
            };
            let res = lcc::coordinator::pipeline::run(
                g.num_vertices(),
                g.edges().iter().copied(),
                &cfg,
            );
            let labels = lcc::coordinator::pipeline::merge_summary(&res.summary);
            if labels != oracle::components(g) {
                return Err(format!("pipeline wrong with {workers} workers"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_graph_normalize_is_idempotent() {
    Prop::new(32).check_sized(
        "normalize-idempotent",
        500,
        |rng, size| {
            let n = size.max(2);
            let m = rng.gen_range(4 * n as u64) as usize;
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| {
                    (
                        rng.gen_range(n as u64) as u32,
                        rng.gen_range(n as u64) as u32,
                    )
                })
                .collect();
            Graph::from_edges(n, edges)
        },
        |g| {
            let mut h = g.clone();
            h.normalize();
            if &h != g {
                return Err("normalize changed an already-normal graph".into());
            }
            // canonical shape: sorted, dedup'd, no loops, (min,max) order
            for w in g.edges().windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("not sorted/dedup'd: {:?} {:?}", w[0], w[1]));
                }
            }
            for &(u, v) in g.edges() {
                if u >= v {
                    return Err(format!("non-canonical edge ({u},{v})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_binary_io_roundtrip() {
    let dir = std::env::temp_dir().join("lcc_prop_io");
    std::fs::create_dir_all(&dir).unwrap();
    Prop::new(12).check_sized(
        "binary-roundtrip",
        400,
        |rng, size| random_graph(rng, size),
        |g| {
            let p = dir.join(format!("g{}.bin", g.num_edges()));
            lcc::graph::io::write_binary(g, &p).map_err(|e| e.to_string())?;
            let h = lcc::graph::io::read_binary(&p).map_err(|e| e.to_string())?;
            if &h != g {
                return Err("binary roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dense_cpu_backend_matches_phase_labels() {
    use lcc::cc::backend::{CpuBackend, DenseBackend};
    Prop::new(16).check_sized(
        "dense-backend-coherent",
        256,
        |rng, size| (random_graph(rng, size), rng.next_u64()),
        |(g, seed)| {
            use lcc::cc::common::Priorities;
            use lcc::graph::ShardedGraph;
            let mut rng = Rng::new(*seed);
            let rho = Priorities::sample(g.num_vertices(), &mut rng);
            let prio: Vec<i32> = rho.rho.iter().map(|&p| p as i32).collect();
            let dense = CpuBackend::default().local_labels(g, &prio).unwrap();
            let mut sim = Simulator::new(MpcConfig {
                machines: 2,
                space_per_machine: None,
                spill_budget: None,
                threads: 1,
            });
            let sharded = ShardedGraph::from_graph(g, 2);
            let mpc = cc::local_contraction::phase_labels(&sharded, &mut sim, &rho, None);
            // dense returns min *priorities*; mpc returns representative
            // vertices — they must agree through the inverse permutation
            for v in 0..g.num_vertices() {
                let via_dense = rho.inv[dense[v] as usize];
                if via_dense != mpc[v] {
                    return Err(format!("vertex {v}: dense {via_dense} mpc {}", mpc[v]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_machine_of_partition_and_reshard_roundtrip() {
    // The machine_of partition + reshard must round-trip the edge
    // multiset and every cached histogram for arbitrary machine counts —
    // including machines = 1 and machines > n (empty shards).
    Prop::new(14).check_sized(
        "machine-of-reshard-roundtrip",
        160,
        |rng, size| {
            let g = random_graph(rng, size);
            let p_small = 1 + rng.gen_range(7) as usize; // 1..=7
            let p_huge = g.num_vertices() * 2 + 3; // machines > n
            (g, p_small, p_huge)
        },
        |(flat, p_small, p_huge)| {
            use lcc::graph::ShardedGraph;
            use lcc::mpc::simulator::machine_of;
            let counts = [1usize, *p_small, *p_huge];
            for &p in &counts {
                let g = ShardedGraph::from_graph(flat, p);
                check_histogram_caches(&g, &format!("p={p}"))?;
                // shard-ownership invariant on every stored edge
                for s in 0..g.num_shards() {
                    let data = g.read_shard(s).map_err(|e| format!("p={p}: {e}"))?;
                    for (u, v) in data.iter() {
                        lcc::prop_assert!(
                            u < v && machine_of(u as u64, p) == s,
                            "p={p}: edge ({u},{v}) misplaced on shard {s}"
                        );
                    }
                }
                lcc::prop_assert_eq!(
                    edge_multiset(&g),
                    flat.edges().to_vec(),
                    "p={p}: partitioning changed the edge multiset"
                );
                for &q in &counts {
                    let there = g.reshard(q);
                    check_histogram_caches(&there, &format!("p={p}->q={q}"))?;
                    lcc::prop_assert_eq!(
                        edge_multiset(&there),
                        flat.edges().to_vec(),
                        "p={p}->q={q}: reshard changed the edge multiset"
                    );
                    let back = there.reshard(p);
                    check_histogram_caches(&back, &format!("p={p}->q={q}->p"))?;
                    lcc::prop_assert!(
                        back == g,
                        "p={p}->q={q}->p: round trip is not bit-identical"
                    );
                }
            }
            Ok(())
        },
    );
}

/// Recompute a shard's ownership histogram from its actual edges.
fn brute_peer_counts(
    edges: impl IntoIterator<Item = (lcc::graph::Vertex, lcc::graph::Vertex)>,
    p: usize,
) -> Vec<u64> {
    use lcc::mpc::simulator::machine_of;
    let mut peers = vec![0u64; p];
    for (_, v) in edges {
        peers[machine_of(v as u64, p)] += 1;
    }
    peers
}

/// The canonical edge multiset of a sharded graph (flattened + sorted);
/// with canonical shards a sorted list IS the multiset.
fn edge_multiset(g: &lcc::graph::ShardedGraph) -> Vec<(lcc::graph::Vertex, lcc::graph::Vertex)> {
    let mut edges: Vec<_> = g.iter_edges().collect();
    edges.sort_unstable();
    edges
}

/// Check the full store invariant on one graph: every cached histogram
/// matches a brute-force recount of the (possibly just-loaded) edges.
fn check_histogram_caches(
    g: &lcc::graph::ShardedGraph,
    tag: &str,
) -> Result<(), String> {
    let p = g.num_shards();
    for s in 0..p {
        let data = g.read_shard(s).map_err(|e| format!("{tag}: {e}"))?;
        let stats = g.shard_stats(s);
        lcc::prop_assert_eq!(
            stats.len,
            data.len() as u64,
            "{tag}: stale len cache on shard {s}"
        );
        lcc::prop_assert_eq!(
            stats.peer_counts,
            brute_peer_counts(data.iter(), p),
            "{tag}: stale peer_counts cache on shard {s}"
        );
    }
    let total: u64 = g.vertex_counts().iter().sum();
    lcc::prop_assert_eq!(
        total,
        g.num_vertices() as u64,
        "{tag}: vertex_counts do not partition 0..n"
    );
    Ok(())
}

#[test]
fn prop_rewrites_preserve_multisets_and_caches_on_both_backends() {
    // reshard / contract / prune_isolated must preserve the expected edge
    // multiset and keep every cached histogram coherent — identically on
    // the resident and the spilled (budget 0: always disk-backed) store.
    use lcc::graph::{ShardedGraph, SpillPolicy, Vertex};
    Prop::new(12).check_sized(
        "rewrites-preserve-multisets",
        250,
        |rng, size| {
            let g = random_graph(rng, size);
            let n = g.num_vertices();
            let labels: Vec<Vertex> =
                (0..n as u32).map(|_| rng.gen_range(n as u64) as Vertex).collect();
            (g, labels)
        },
        |(flat, labels)| {
            let n = flat.num_vertices();
            let resident = ShardedGraph::from_graph(flat, 4);
            let spilled = ShardedGraph::from_graph_with(flat, 4, SpillPolicy::budget(0));
            if n > 0 && flat.num_edges() > 0 && !spilled.is_spilled() {
                return Err("budget-0 graph with edges stayed resident".into());
            }
            for (tag, g) in [("resident", &resident), ("spilled", &spilled)] {
                check_histogram_caches(g, tag)?;

                // reshard: multiset is exactly preserved
                let resharded = g.reshard(7);
                check_histogram_caches(&resharded, &format!("{tag}/reshard"))?;
                lcc::prop_assert_eq!(
                    edge_multiset(&resharded),
                    edge_multiset(g),
                    "{tag}: reshard changed the edge multiset"
                );

                // contract: multiset = relabeled, canonicalized, deduped input
                let (contracted, map) = g.contract(labels);
                check_histogram_caches(&contracted, &format!("{tag}/contract"))?;
                let mut want: Vec<(Vertex, Vertex)> = g
                    .iter_edges()
                    .filter_map(|(u, v)| {
                        let (x, y) = (map[u as usize], map[v as usize]);
                        (x != y).then(|| (x.min(y), x.max(y)))
                    })
                    .collect();
                want.sort_unstable();
                want.dedup();
                lcc::prop_assert_eq!(
                    edge_multiset(&contracted),
                    want,
                    "{tag}: contract multiset wrong"
                );

                // prune: multiset = input renamed through the compaction map
                let (pruned, pmap) = g.prune_isolated();
                check_histogram_caches(&pruned, &format!("{tag}/prune"))?;
                let mut want: Vec<(Vertex, Vertex)> = g
                    .iter_edges()
                    .map(|(u, v)| {
                        let (x, y) = (pmap[u as usize].unwrap(), pmap[v as usize].unwrap());
                        (x.min(y), x.max(y))
                    })
                    .collect();
                want.sort_unstable();
                want.dedup();
                lcc::prop_assert_eq!(
                    edge_multiset(&pruned),
                    want,
                    "{tag}: prune multiset wrong"
                );
            }
            // and the two backends agree bit-for-bit
            lcc::prop_assert_eq!(
                resident.to_graph(),
                spilled.to_graph(),
                "backends diverge"
            );
            Ok(())
        },
    );
}
