//! Coordinator-level integration: driver sweeps, report JSON, preset
//! datasets, and the streaming pipeline composed with the paper algorithms.

use lcc::coordinator::{pipeline, Driver, PipelineConfig, RunConfig};
use lcc::graph::generators::{self, presets};
use lcc::util::json;
use lcc::util::rng::Rng;

#[test]
fn driver_report_json_is_parseable_and_faithful() {
    let g = generators::gnp(500, 0.008, &mut Rng::new(1));
    let driver = Driver::new(RunConfig {
        algorithm: "lc".into(),
        verify: true,
        ..Default::default()
    });
    let report = driver.run_named(&g, "it");
    assert_eq!(report.verified, Some(true));
    let j = json::parse(&report.to_json().pretty()).unwrap();
    assert_eq!(
        j.get("num_components").unwrap().as_i64().unwrap() as usize,
        report.num_components
    );
    assert_eq!(j.get("dataset").unwrap().as_str(), Some("it"));
    assert_eq!(
        j.get("edges_per_phase").unwrap().as_arr().unwrap().len(),
        report.edges_per_phase.len()
    );
}

#[test]
fn all_presets_run_all_paper_algorithms_small() {
    for name in presets::ALL {
        let g = presets::generate(name, Some(1200), 7);
        for algo in lcc::cc::PAPER_ALGORITHMS {
            let driver = Driver::new(RunConfig {
                algorithm: algo.to_string(),
                finisher_threshold: g.num_edges() / 50,
                state_cap: 50 * g.num_edges() as u64,
                verify: true,
                max_phases: 300,
                ..Default::default()
            });
            let r = driver.run_named(&g, name);
            assert_ne!(r.verified, Some(false), "{algo} wrong on {name}");
        }
    }
}

#[test]
fn pipeline_then_lc_merge_equals_direct_lc() {
    let g = presets::generate("videos", Some(4000), 3);
    // direct
    let direct = Driver::new(RunConfig {
        algorithm: "lc".into(),
        verify: true,
        ..Default::default()
    })
    .run_named(&g, "videos");
    assert_eq!(direct.verified, Some(true));

    // pipelined: shard-local contraction, then LC on the summary
    let cfg = PipelineConfig {
        num_workers: 3,
        chunk_size: 256,
        channel_capacity: 2,
        spill_budget: None,
    };
    let res = pipeline::run(g.num_vertices(), g.edges().iter().copied(), &cfg);
    let merge = Driver::new(RunConfig {
        algorithm: "lc".into(),
        verify: false,
        ..Default::default()
    })
    .run_named_sharded(&res.summary, "summary");
    // the summary graph has exactly the same component structure
    assert_eq!(merge.num_components, direct.num_components);
    let labels = pipeline::merge_summary(&res.summary);
    assert!(lcc::cc::oracle::verify(&g, &labels).is_ok());
}

#[test]
fn median_protocol_is_stable() {
    let g = generators::gnp(800, 0.005, &mut Rng::new(9));
    let driver = Driver::new(RunConfig::default());
    let a = driver.run_median(&g, "med", 3);
    let b = driver.run_median(&g, "med", 3);
    // components are seed-independent; which seed lands on the median
    // wall time may differ, so phase counts are only sanity-bounded
    assert_eq!(a.num_components, b.num_components);
    assert!(a.phases.abs_diff(b.phases) <= 2);
}

#[test]
fn sweep_reports_cover_matrix() {
    let cfg = lcc::bench::tables::SweepConfig {
        scale: Some(600),
        runs: 1,
        ..Default::default()
    };
    let reports = lcc::bench::tables::sweep(&cfg);
    assert_eq!(reports.len(), 25, "5 algorithms x 5 datasets");
    let (t2, _) = lcc::bench::tables::table2(&reports);
    // phases for contraction algorithms stay small even at tiny scale
    assert!(t2.lines().count() >= 7);
}

#[test]
fn backpressure_engages_with_tiny_queues() {
    let g = generators::complete(400); // dense: workers slower than gen
    let cfg = PipelineConfig {
        num_workers: 2,
        chunk_size: 16,
        channel_capacity: 1,
        spill_budget: None,
    };
    let res = pipeline::run(g.num_vertices(), g.edges().iter().copied(), &cfg);
    // not guaranteed on every machine, but with 80k edges in 16-edge chunks
    // through capacity-1 queues, stalls are effectively certain
    assert!(
        res.stats.backpressure_stalls > 0,
        "no backpressure observed ({} chunks)",
        res.stats.chunks
    );
    let labels = pipeline::merge_summary(&res.summary);
    assert!(lcc::cc::oracle::verify(&g, &labels).is_ok());
}
