//! MPC model accounting: the communication/round claims of the paper,
//! measured on the simulator (the quantities of §1.1, §2.1, Lemma 3.1).

use lcc::cc::{self, CcAlgorithm, RunOptions};
use lcc::graph::generators;
use lcc::mpc::{MpcConfig, Simulator};
use lcc::util::rng::Rng;

fn run(algo: &str, g: &lcc::graph::Graph, machines: usize) -> cc::CcResult {
    let a = cc::by_name(algo);
    let mut sim = Simulator::new(MpcConfig {
        machines,
        space_per_machine: None,
        spill_budget: None,
        threads: 2,
    });
    let mut rng = Rng::new(3);
    a.run(g, &mut sim, &mut rng, &RunOptions::default())
}

#[test]
fn lc_communication_per_round_is_linear_in_m() {
    // §1.1: "the communication in each round is only O(m)".
    let g = generators::gnp(2000, 0.01, &mut Rng::new(1));
    let m = g.num_edges() as u64;
    let res = run("lc", &g, 16);
    for r in &res.metrics.rounds {
        assert!(
            r.bytes <= 30 * m,
            "round {}: {} bytes for m={m}",
            r.label,
            r.bytes
        );
    }
}

#[test]
fn lc_total_communication_shrinks_with_contraction() {
    // Because edges decay geometrically, the total over all phases stays
    // O(m) in practice (the paper's observation) — allow a small factor.
    let g = generators::preferential_attachment(5000, 8, &mut Rng::new(2));
    let m = g.num_edges() as u64;
    let res = run("lc", &g, 16);
    let total = res.metrics.total_bytes();
    assert!(
        total <= 80 * m,
        "total {total} vs m {m} ({}x)",
        total / m.max(1)
    );
    // phase-1 rounds dominate:
    let first_phase: u64 = res.metrics.rounds.iter().take(4).map(|r| r.bytes).sum();
    assert!(first_phase * 2 >= total / 2, "decay shape off");
}

#[test]
fn constant_rounds_per_phase_for_lc() {
    // Lemma 3.1 + §3: 2 label rounds + 2 contraction rounds per phase.
    let g = generators::gnp(1500, 0.008, &mut Rng::new(3));
    let res = run("lc", &g, 8);
    assert_eq!(
        res.metrics.num_rounds() as u32,
        4 * res.phases,
        "rounds {} phases {}",
        res.metrics.num_rounds(),
        res.phases
    );
}

#[test]
fn tc_dht_uses_dht_and_fewer_rounds_than_jumping() {
    let g = generators::gnp(1500, 0.008, &mut Rng::new(4));
    let jump = run("tc", &g, 8);
    let dht = run("tc-dht", &g, 8);
    assert_eq!(dht.labels, jump.labels);
    assert!(dht.metrics.total_dht_ops() > 0, "DHT unused");
    assert_eq!(jump.metrics.total_dht_ops(), 0, "jumping must not use DHT");
    assert!(
        dht.metrics.num_rounds() < jump.metrics.num_rounds(),
        "dht {} rounds vs jumping {}",
        dht.metrics.num_rounds(),
        jump.metrics.num_rounds()
    );
}

#[test]
fn load_balance_across_machines() {
    // With hash partitioning, no machine should receive more than a few
    // times the fair share on a random graph.
    let g = generators::gnp(3000, 0.005, &mut Rng::new(5));
    let machines = 16u64;
    let res = run("lc", &g, machines as usize);
    for r in &res.metrics.rounds {
        if r.bytes > 100_000 {
            let fair = r.bytes / machines;
            assert!(
                r.max_machine_bytes <= 4 * fair,
                "round {}: max {} vs fair {}",
                r.label,
                r.max_machine_bytes,
                fair
            );
        }
    }
}

#[test]
fn space_bound_flagging_works_end_to_end() {
    let g = generators::complete(60);
    let a = cc::by_name("lc");
    let mut sim = Simulator::new(MpcConfig {
        machines: 2,
        space_per_machine: Some(100), // absurdly small
        spill_budget: None,
        threads: 1,
    });
    let mut rng = Rng::new(6);
    let res = a.run(&g, &mut sim, &mut rng, &RunOptions::default());
    assert!(res.metrics.any_space_violation());
}

#[test]
fn htm_communication_dwarfs_lc_on_deep_graphs() {
    // Why the paper's Tables show HTM dying first: cluster state explodes
    // on high-diameter structures (measured ~600x on a 2k path).
    let g = generators::path(2000);
    let lc = run("lc", &g, 8);
    let htm = run("htm", &g, 8);
    assert!(
        htm.metrics.total_bytes() > 10 * lc.metrics.total_bytes(),
        "htm {} vs lc {}",
        htm.metrics.total_bytes(),
        lc.metrics.total_bytes()
    );
}

#[test]
fn model_metrics_are_engine_invariant_across_threads() {
    // The parallel round engine must not perturb the model: for every
    // algorithm and every round, messages / bytes / max_machine_bytes /
    // space_violation (and the output labels) are identical whether the
    // simulator runs on 1 thread or 8.
    let g = generators::gnp(1200, 0.008, &mut Rng::new(9));
    for algo in ["lc", "lc-mtl", "hash-min", "cracker", "tc", "htm", "two-phase"] {
        let exec = |threads: usize| {
            let a = cc::by_name(algo);
            let mut sim = Simulator::new(MpcConfig {
                machines: 8,
                space_per_machine: Some(40_000),
                spill_budget: None,
                threads,
            });
            let mut rng = Rng::new(17);
            let res = a.run(&g, &mut sim, &mut rng, &RunOptions::default());
            (res.labels, res.metrics.rounds)
        };
        let (labels1, rounds1) = exec(1);
        let (labels8, rounds8) = exec(8);
        assert_eq!(labels1, labels8, "{algo}: labels diverge");
        assert_eq!(
            rounds1.len(),
            rounds8.len(),
            "{algo}: round count diverges"
        );
        for (a_round, b_round) in rounds1.iter().zip(&rounds8) {
            assert_eq!(a_round, b_round, "{algo}: round metrics diverge");
        }
    }
}

#[test]
fn round_labels_are_informative() {
    let g = generators::gnp(500, 0.01, &mut Rng::new(7));
    let res = run("lc", &g, 4);
    let labels: Vec<&str> = res
        .metrics
        .rounds
        .iter()
        .map(|r| r.label.as_str())
        .collect();
    assert!(labels.iter().any(|l| l.starts_with("lc/hop1")));
    assert!(labels.iter().any(|l| l.starts_with("contract/")));
}
