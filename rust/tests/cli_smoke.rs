//! CLI smoke tests: run the actual `lcc` binary end to end.

use std::process::Command;

fn lcc(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_lcc"))
        .args(args)
        .output()
        .expect("spawn lcc");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_prints_usage() {
    let (ok, text) = lcc(&["help"]);
    assert!(ok);
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn unknown_command_fails() {
    let (ok, _) = lcc(&["frobnicate"]);
    assert!(!ok);
}

#[test]
fn run_lc_on_small_gnp_verifies() {
    let (ok, text) = lcc(&[
        "run", "--algo", "lc", "--graph", "gnp", "--n", "2000", "--avg-deg", "4",
        "--verify", "true",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("[verified]"), "{text}");
    assert!(text.contains("edges per phase"), "{text}");
}

#[test]
fn run_with_tight_spill_budget_verifies() {
    // the whole CLI path out-of-core: a 64-byte budget forces disk-backed
    // shards for a ~3000-edge graph, and the labels still verify
    let (ok, text) = lcc(&[
        "run", "--algo", "lc", "--graph", "gnp", "--n", "1500", "--avg-deg", "4",
        "--spill-budget", "64", "--verify", "true",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("[verified]"), "{text}");
}

#[test]
fn run_json_output_parses() {
    let (ok, text) = lcc(&[
        "run", "--algo", "tc-dht", "--graph", "star", "--n", "500", "--json",
    ]);
    assert!(ok, "{text}");
    let json_start = text.find('{').expect("no json in output");
    let j = lcc::util::json::parse(text[json_start..].trim()).expect("bad json");
    assert_eq!(j.get("num_components").unwrap().as_i64(), Some(1));
    assert_eq!(j.get("verified").unwrap(), &lcc::util::json::Json::Bool(true));
}

#[test]
fn theory_cycles_runs() {
    let (ok, text) = lcc(&["theory", "--exp", "cycles"]);
    assert!(ok, "{text}");
    assert!(text.contains("two cycles"), "{text}");
}

#[test]
fn generate_then_load_roundtrip() {
    let dir = std::env::temp_dir().join("lcc_cli_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.bin");
    let path_s = path.to_str().unwrap();
    let (ok, text) = lcc(&[
        "generate", "--graph", "cycle", "--n", "100", "--out", path_s,
    ]);
    assert!(ok, "{text}");
    let (ok, text) = lcc(&[
        "run", "--algo", "cracker", "--graph", &format!("file:{path_s}"),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("1 comps") || text.contains("     1 comps"), "{text}");
}

#[test]
fn pipeline_command_verifies() {
    let (ok, text) = lcc(&[
        "pipeline", "--graph", "gnp", "--n", "5000", "--avg-deg", "5",
        "--workers", "3", "--use-xla", "false",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("oracle-verified: true"), "{text}");
}

#[test]
fn run_rejects_wrong_labels_never_silently() {
    // sanity: verify flag default is on and reported
    let (ok, text) = lcc(&["run", "--graph", "path", "--n", "300"]);
    assert!(ok, "{text}");
    assert!(text.contains("[verified]"), "{text}");
}

#[test]
fn zero_machines_fails_at_the_flag() {
    let (ok, text) = lcc(&["run", "--graph", "path", "--n", "50", "--machines", "0"]);
    assert!(!ok);
    assert!(text.contains("--machines"), "{text}");
    assert!(text.contains(">= 1"), "{text}");
}

#[test]
fn zero_threads_fails_at_the_flag() {
    let (ok, text) = lcc(&["run", "--graph", "path", "--n", "50", "--threads", "0"]);
    assert!(!ok);
    assert!(text.contains("--threads"), "{text}");
}

#[test]
fn unparseable_spill_budget_fails_at_the_flag() {
    let (ok, text) = lcc(&[
        "run", "--graph", "path", "--n", "50", "--spill-budget", "lots",
    ]);
    assert!(!ok);
    assert!(text.contains("--spill-budget"), "{text}");
    assert!(text.contains("byte size"), "{text}");
}

#[test]
fn spill_budget_accepts_binary_suffixes() {
    let (ok, text) = lcc(&[
        "run", "--algo", "lc", "--graph", "path", "--n", "200", "--spill-budget", "1K",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("[verified]"), "{text}");
}

#[test]
fn run_on_the_proc_transport_verifies() {
    // the whole CLI path distributed: the binary spawns itself as workers
    let (ok, text) = lcc(&[
        "run", "--algo", "lc", "--graph", "gnp", "--n", "800", "--avg-deg", "4",
        "--machines", "4", "--transport", "proc", "--verify", "true",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("[verified]"), "{text}");
}

#[test]
fn worker_without_connect_fails_fast() {
    let (ok, text) = lcc(&["worker"]);
    assert!(!ok);
    assert!(text.contains("--connect"), "{text}");
}
