//! Runtime integration: the compiled XLA artifacts (Layer 1+2) against the
//! pure-Rust reference (Layer 3), across random graphs and all entry
//! points.  Skips with a notice when `make artifacts` hasn't been run.

use lcc::cc::backend::{CpuBackend, DenseBackend, INF};
use lcc::graph::generators;
use lcc::runtime::{self, ShardExecutor};
use lcc::util::rng::Rng;

fn executor() -> Option<ShardExecutor> {
    match runtime::try_default_executor() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP runtime integration: {e} (run `make artifacts`)");
            None
        }
    }
}

fn perm_prio(n: usize, seed: u64) -> Vec<i32> {
    Rng::new(seed)
        .permutation(n)
        .iter()
        .map(|&x| x as i32)
        .collect()
}

#[test]
fn local_labels_matches_cpu_on_random_graphs() {
    let Some(exec) = executor() else { return };
    let cpu = CpuBackend::default();
    for seed in 0..8u64 {
        let n = 50 + (seed as usize * 97) % 800;
        let g = generators::gnp(n, 4.0 / n as f64, &mut Rng::new(seed));
        let prio = perm_prio(n, seed + 100);
        let xla = exec.local_labels(&g, &prio).unwrap();
        let want = cpu.local_labels(&g, &prio).unwrap();
        assert_eq!(xla, want, "seed {seed} n {n}");
    }
}

#[test]
fn local_labels_matches_cpu_on_structured_graphs() {
    let Some(exec) = executor() else { return };
    let cpu = CpuBackend::default();
    let graphs = vec![
        generators::path(200),
        generators::cycle(333),
        generators::star(500),
        generators::complete(60),
        generators::grid(11, 13),
        lcc::graph::Graph::empty(10),
    ];
    for (i, g) in graphs.into_iter().enumerate() {
        let prio = perm_prio(g.num_vertices(), i as u64);
        let xla = exec.local_labels(&g, &prio).unwrap();
        let want = cpu.local_labels(&g, &prio).unwrap();
        assert_eq!(xla, want, "graph {i}");
    }
}

#[test]
fn hash_min_step_matches_cpu() {
    let Some(exec) = executor() else { return };
    let cpu = CpuBackend::default();
    for seed in 0..5u64 {
        let n = 100 + seed as usize * 150;
        let g = generators::chung_lu(n, 6.0, 2.5, &mut Rng::new(seed));
        let prio = perm_prio(n, seed + 7);
        assert_eq!(
            exec.hash_min_step(&g, &prio).unwrap(),
            cpu.hash_min_step(&g, &prio).unwrap(),
            "seed {seed}"
        );
    }
}

#[test]
fn tree_roots_matches_cpu() {
    let Some(exec) = executor() else { return };
    let cpu = CpuBackend::default();
    let mut rng = Rng::new(11);
    for case in 0..6 {
        let n = 64 + case * 120;
        // random f_rho-like pointer structure with a 2-cycle at the bottom
        let mut f: Vec<i32> = vec![0; n];
        f[0] = 1;
        f[1] = 0;
        for (v, fv) in f.iter_mut().enumerate().skip(2) {
            *fv = rng.gen_range(v as u64) as i32;
        }
        assert_eq!(
            exec.tree_roots(&f).unwrap(),
            cpu.tree_roots(&f).unwrap(),
            "case {case}"
        );
    }
}

#[test]
fn oversized_graph_is_rejected() {
    let Some(exec) = executor() else { return };
    let n = exec.shard_size() + 1;
    let g = generators::path(n);
    let prio = perm_prio(n, 1);
    assert!(exec.local_labels(&g, &prio).is_err());
}

#[test]
fn padding_slots_stay_inert() {
    let Some(exec) = executor() else { return };
    // tiny graph in a big shard: result must not depend on shard size
    let g = generators::path(5);
    let prio: Vec<i32> = vec![3, 0, 4, 1, 2];
    let labels = exec.local_labels(&g, &prio).unwrap();
    // N(N(v)) on a path of 5: v=0 sees {0,1,2} -> min prio 0 ...
    assert_eq!(labels, vec![0, 0, 0, 0, 1]);
    assert!(labels.iter().all(|&l| l != INF));
}

#[test]
fn phase_shrink_stats_counts_distinct_labels() {
    let Some(exec) = executor() else { return };
    for seed in 0..4u64 {
        let n = 300;
        let g = generators::gnp(n, 3.0 / n as f64, &mut Rng::new(seed + 40));
        let prio = perm_prio(n, seed);
        let (labels, count) = exec.phase_shrink_stats(&g, &prio).unwrap();
        let mut uniq = labels.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(count as usize, uniq.len(), "seed {seed}");
        // Lemma 4.1 (in expectation): on a random graph the shrink is real
        assert!(count as usize <= n, "seed {seed}");
    }
}

#[test]
fn full_lc_run_with_xla_matches_pure_mpc() {
    let Some(exec) = executor() else { return };
    use lcc::cc::{self, CcAlgorithm, RunOptions};
    use lcc::mpc::{MpcConfig, Simulator};
    for seed in 0..4u64 {
        let g = generators::gnp(400, 0.01, &mut Rng::new(seed + 60));
        let run = |dense: Option<&dyn DenseBackend>| {
            let algo = cc::by_name("lc");
            let mut sim = Simulator::new(MpcConfig {
                machines: 4,
                space_per_machine: None,
                spill_budget: None,
                threads: 1,
            });
            let mut rng = Rng::new(seed);
            let opts = RunOptions {
                dense_backend: dense,
                ..Default::default()
            };
            algo.run(&g, &mut sim, &mut rng, &opts)
        };
        let pure = run(None);
        let xla = run(Some(&exec));
        assert_eq!(pure.labels, xla.labels, "seed {seed}");
        assert_eq!(pure.phases, xla.phases, "seed {seed}");
    }
}

#[test]
fn both_shard_sizes_agree() {
    let dir = runtime::default_dir();
    let Ok(manifest) = runtime::Manifest::load(&dir) else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let sizes = manifest.shard_sizes("local_labels");
    if sizes.len() < 2 {
        eprintln!("SKIP: only one shard size built");
        return;
    }
    let execs: Vec<ShardExecutor> = sizes
        .iter()
        .map(|&n| ShardExecutor::load(&manifest, n).unwrap())
        .collect();
    let n = sizes[0].min(200);
    let g = generators::gnp(n, 5.0 / n as f64, &mut Rng::new(77));
    let prio = perm_prio(n, 78);
    let results: Vec<Vec<i32>> = execs
        .iter()
        .map(|e| e.local_labels(&g, &prio).unwrap())
        .collect();
    for w in results.windows(2) {
        assert_eq!(w[0], w[1], "shard sizes disagree");
    }
}
