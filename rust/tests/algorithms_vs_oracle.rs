//! Integration: every algorithm produces oracle-identical canonical labels
//! on a zoo of structured and random graphs, across seeds and with the §6
//! optimizations toggled.

use lcc::cc::{self, oracle, CcAlgorithm, RunOptions};
use lcc::graph::{generators, Graph};
use lcc::mpc::{MpcConfig, Simulator};
use lcc::util::rng::Rng;

fn run(algo: &str, g: &Graph, seed: u64, opts: &RunOptions) -> cc::CcResult {
    let algorithm = cc::by_name(algo);
    let mut sim = Simulator::new(MpcConfig {
        machines: 8,
        space_per_machine: None,
        spill_budget: None,
        threads: 2,
    });
    let mut rng = Rng::new(seed);
    algorithm.run(g, &mut sim, &mut rng, opts)
}

fn zoo() -> Vec<(String, Graph)> {
    let mut rng = Rng::new(999);
    vec![
        ("empty".into(), Graph::empty(13)),
        ("single-edge".into(), Graph::from_edges(2, vec![(0, 1)])),
        ("path-64".into(), generators::path(64)),
        ("cycle-65".into(), generators::cycle(65)),
        ("star-100".into(), generators::star(100)),
        ("complete-20".into(), generators::complete(20)),
        ("grid-9x11".into(), generators::grid(9, 11)),
        ("tree-127".into(), generators::binary_tree(127)),
        ("caterpillar".into(), generators::caterpillar(20, 3)),
        ("two-cycles".into(), generators::one_or_two_cycles(50, true)),
        ("one-cycle".into(), generators::one_or_two_cycles(50, false)),
        (
            "mixture".into(),
            generators::path(30)
                .disjoint_union(generators::complete(8))
                .disjoint_union(Graph::empty(5))
                .disjoint_union(generators::star(12)),
        ),
        ("gnp-sparse".into(), generators::gnp(300, 0.004, &mut rng)),
        ("gnp-medium".into(), generators::gnp(300, 0.02, &mut rng)),
        (
            "gnp-log".into(),
            generators::gnp_log_regime(400, 2.0, &mut rng),
        ),
        (
            "chung-lu".into(),
            generators::chung_lu(400, 6.0, 2.5, &mut rng),
        ),
        (
            "rmat".into(),
            generators::rmat(8, 800, (0.57, 0.19, 0.19, 0.05), &mut rng),
        ),
        (
            "pref-attach".into(),
            generators::preferential_attachment(300, 2, &mut rng),
        ),
    ]
}

#[test]
fn all_algorithms_match_oracle_on_zoo() {
    for (name, g) in zoo() {
        let want = oracle::components(&g);
        for algo in cc::ALL_ALGORITHMS {
            let res = run(algo, &g, 1, &RunOptions::default());
            assert!(res.completed, "{algo} incomplete on {name}");
            assert_eq!(res.labels, want, "{algo} wrong on {name}");
        }
    }
}

#[test]
fn seeds_do_not_change_answers() {
    let g = generators::gnp(250, 0.015, &mut Rng::new(5));
    let want = oracle::components(&g);
    for algo in ["lc", "lc-mtl", "tc", "tc-dht", "cracker"] {
        for seed in [0u64, 7, 123456789, u64::MAX] {
            let res = run(algo, &g, seed, &RunOptions::default());
            assert_eq!(res.labels, want, "{algo} seed {seed}");
        }
    }
}

#[test]
fn finisher_preserves_answers() {
    let g = generators::gnp(400, 0.008, &mut Rng::new(6));
    let want = oracle::components(&g);
    for algo in ["lc", "tc-dht", "cracker"] {
        for threshold in [1usize, 50, 10_000] {
            let opts = RunOptions {
                finisher_threshold: threshold,
                ..Default::default()
            };
            let res = run(algo, &g, 2, &opts);
            assert_eq!(res.labels, want, "{algo} finisher={threshold}");
        }
    }
}

#[test]
fn pruning_toggle_preserves_answers() {
    let g = generators::rmat(9, 1200, (0.57, 0.19, 0.19, 0.05), &mut Rng::new(7));
    let want = oracle::components(&g);
    for prune in [true, false] {
        let opts = RunOptions {
            prune_isolated: prune,
            ..Default::default()
        };
        let res = run("lc", &g, 3, &opts);
        assert_eq!(res.labels, want, "prune={prune}");
    }
}

#[test]
fn machine_count_is_immaterial() {
    let g = generators::gnp(200, 0.02, &mut Rng::new(8));
    let want = oracle::components(&g);
    for machines in [1usize, 2, 64] {
        let algorithm = cc::by_name("lc");
        let mut sim = Simulator::new(MpcConfig {
            machines,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        });
        let mut rng = Rng::new(4);
        let res = algorithm.run(&g, &mut sim, &mut rng, &RunOptions::default());
        assert_eq!(res.labels, want, "machines={machines}");
    }
}

#[test]
fn phase_counts_match_paper_expectations_on_random_graph() {
    // Table 2 shape: all contraction algorithms finish in <= ~6 phases on a
    // well-connected random graph; Hash-To-Min needs more.
    let g = generators::gnp_log_regime(3000, 3.0, &mut Rng::new(9));
    let lc = run("lc", &g, 5, &RunOptions::default());
    let tc = run("tc-dht", &g, 5, &RunOptions::default());
    let cracker = run("cracker", &g, 5, &RunOptions::default());
    let htm = run("htm", &g, 5, &RunOptions::default());
    assert!(lc.phases <= 6, "lc {}", lc.phases);
    assert!(tc.phases <= 8, "tc {}", tc.phases);
    assert!(cracker.phases <= 6, "cracker {}", cracker.phases);
    assert!(
        htm.phases >= lc.phases,
        "htm {} vs lc {}",
        htm.phases,
        lc.phases
    );
}

#[test]
fn figure1_shape_edges_shrink_fast_on_dense_graphs() {
    // The paper's headline observation: on high-average-degree graphs each
    // LocalContraction phase cuts edges by ~10x or more.
    let g = generators::preferential_attachment(20_000, 16, &mut Rng::new(10));
    let res = run("lc", &g, 6, &RunOptions::default());
    for w in res.edges_per_phase.windows(2) {
        if w[0] > 1000 && w[1] > 0 {
            let decay = w[0] as f64 / w[1] as f64;
            assert!(decay >= 4.0, "weak decay {decay} in {:?}", res.edges_per_phase);
        }
    }
}

#[test]
fn definition_5_1_superset_class_stays_correct_and_fast() {
    // 𝒢(n,p) (Definition 5.1): a G(n,p) sample plus an ADVERSARIAL fixed
    // edge set.  Theorem 5.5's loglog behaviour must survive the overlay
    // and correctness must be unaffected.
    let n = 4096;
    let mut rng = Rng::new(11);
    // adversarial overlay: a long path + a star, stitched across the id space
    let mut extra: Vec<(u32, u32)> = (1..n as u32 / 4).map(|v| (v - 1, v)).collect();
    extra.extend((1..200u32).map(|v| (n as u32 - 1, n as u32 - 1 - v)));
    let p = 2.0 * (n as f64).ln() / n as f64;
    let g = generators::gnp_class(n, p, &extra, &mut rng);
    let want = oracle::components(&g);
    for algo in ["lc", "lc-mtl", "tc-dht"] {
        let res = run(algo, &g, 5, &RunOptions::default());
        assert_eq!(res.labels, want, "{algo}");
        assert!(res.phases <= 6, "{algo} took {} phases", res.phases);
    }
}

#[test]
fn merge_to_large_alpha_extremes_are_safe() {
    // degenerate schedules must not break correctness
    use lcc::cc::local_contraction::LocalContraction;
    use lcc::cc::merge_to_large::Schedule;
    let g = generators::gnp(500, 0.01, &mut Rng::new(12));
    let want = oracle::components(&g);
    for (c, floor) in [(0.1, 2u64), (50.0, 2), (1.0, 1_000_000)] {
        use lcc::cc::CcAlgorithm;
        let algo = LocalContraction {
            merge_to_large: Some(Schedule { c, floor }),
        };
        let mut sim = Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        });
        let mut rng = Rng::new(13);
        let res = algo.run(&g, &mut sim, &mut rng, &RunOptions::default());
        assert_eq!(res.labels, want, "c={c} floor={floor}");
    }
}
