//! Transport equivalence: the multi-process backend must be
//! observationally identical to the in-process engine.
//!
//! For every algorithm and machine count, labels, per-round metrics
//! (message counts, shuffled bytes, per-machine loads), phase series, and
//! transport-driven graph rewrites must compare **bit-identical** between
//! `inproc` and `proc` — the workers are real OS processes spawned from
//! the `lcc` binary, the payloads really cross sockets, and the hop folds
//! are reduced remotely, so this suite is the end-to-end proof that the
//! `Exchange` boundary carries the full semantics.

use std::path::Path;

use lcc::cc::common::{contract_mpc, min_hop};
use lcc::cc::{self, CcAlgorithm, CcResult, RunOptions};
use lcc::graph::{generators, Graph, ShardedGraph, SpillPolicy};
use lcc::mpc::net::ProcTransport;
use lcc::mpc::{MpcConfig, Simulator};
use lcc::util::rng::Rng;

fn worker_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_lcc"))
}

fn cfg(machines: usize) -> MpcConfig {
    MpcConfig {
        machines,
        space_per_machine: None,
        spill_budget: None,
        threads: 2,
    }
}

fn proc_sim(g: &ShardedGraph, machines: usize) -> Simulator {
    let mut t = ProcTransport::spawn(machines, worker_bin()).expect("spawn workers");
    t.load_graph(g).expect("distribute shards");
    Simulator::with_transport(cfg(machines), Box::new(t))
}

/// A small graph with structure: a sparse random part, a path (deep
/// component), and isolated vertices from the gnp tail.
fn test_graph() -> Graph {
    let mut rng = Rng::new(9);
    generators::gnp(100, 0.03, &mut rng).disjoint_union(generators::path(30))
}

fn run_algo(algo: &str, g: &ShardedGraph, mut sim: Simulator, seed: u64) -> CcResult {
    let a = cc::by_name(algo);
    let mut rng = Rng::new(seed);
    let opts = RunOptions {
        finisher_threshold: 16,
        ..RunOptions::default()
    };
    a.run_sharded(g, &mut sim, &mut rng, &opts)
}

#[test]
fn all_algorithms_bit_identical_across_transports() {
    let flat = test_graph();
    let want = cc::oracle::components(&flat);
    for machines in [1usize, 4, 16] {
        let g = ShardedGraph::from_graph(&flat, machines);
        for algo in cc::ALL_ALGORITHMS {
            let local = run_algo(algo, &g, Simulator::new(cfg(machines)), 7);
            let remote = run_algo(algo, &g, proc_sim(&g, machines), 7);
            assert_eq!(
                local.labels, remote.labels,
                "{algo} machines={machines}: labels diverge"
            );
            assert_eq!(local.labels, want, "{algo} machines={machines}: wrong labels");
            assert_eq!(
                local.phases, remote.phases,
                "{algo} machines={machines}: phases diverge"
            );
            assert_eq!(
                local.edges_per_phase, remote.edges_per_phase,
                "{algo} machines={machines}: phase series diverge"
            );
            assert_eq!(
                local.metrics.rounds, remote.metrics.rounds,
                "{algo} machines={machines}: per-round metrics diverge"
            );
        }
    }
}

#[test]
fn transport_driven_rewrites_produce_identical_graphs() {
    // hop + contract under both transports: the *final graphs* must be
    // bit-identical, not just the labels
    let flat = test_graph();
    let machines = 4;
    let g = ShardedGraph::from_graph(&flat, machines);

    let run = |mut sim: Simulator| {
        let labels: Vec<u32> = (0..g.num_vertices() as u32).collect();
        let hopped = min_hop(&mut sim, "hop", &g, &labels, true);
        let (contracted, node_map) = contract_mpc(&mut sim, &g, &hopped);
        (hopped, contracted, node_map, sim.metrics.rounds)
    };
    let (h_l, c_l, m_l, r_l) = run(Simulator::new(cfg(machines)));
    let (h_p, c_p, m_p, r_p) = run(proc_sim(&g, machines));
    assert_eq!(h_l, h_p, "hop values diverge");
    assert_eq!(m_l, m_p, "compaction maps diverge");
    assert_eq!(c_l, c_p, "contracted sharded graphs diverge");
    assert_eq!(c_l.to_graph(), c_p.to_graph(), "flattened graphs diverge");
    assert_eq!(r_l, r_p, "rewrite round metrics diverge");
}

#[test]
fn spilled_shards_ship_without_rehydration_and_match() {
    // a disk-backed graph: the proc transport reads the shard files
    // verbatim off the spill dir; results must still be bit-identical
    let flat = test_graph();
    let machines = 4;
    let g = ShardedGraph::from_graph_with(&flat, machines, SpillPolicy::budget(0));
    assert!(g.is_spilled(), "budget 0 must spill");
    let local = run_algo("lc", &g, Simulator::new(cfg(machines)), 3);
    let remote = run_algo("lc", &g, proc_sim(&g, machines), 3);
    assert_eq!(local.labels, remote.labels);
    assert_eq!(local.metrics.rounds, remote.metrics.rounds);
    assert_eq!(local.labels, cc::oracle::components(&flat));
}

#[test]
fn driver_runs_the_proc_transport_end_to_end() {
    use lcc::coordinator::{Driver, RunConfig};
    use lcc::mpc::TransportMode;
    let flat = test_graph();
    let driver = Driver::new(RunConfig {
        algorithm: "cracker".into(),
        machines: 4,
        transport: TransportMode::Proc,
        worker_bin: Some(worker_bin().to_path_buf()),
        verify: true,
        ..Default::default()
    });
    let report = driver.try_run_named(&flat, "equiv").expect("proc run");
    assert_eq!(report.verified, Some(true));
    assert_eq!(report.transport, "proc");
    assert!(report.completed);

    let inproc = Driver::new(RunConfig {
        algorithm: "cracker".into(),
        machines: 4,
        verify: true,
        ..Default::default()
    })
    .run_named(&flat, "equiv");
    assert_eq!(inproc.transport, "inproc");
    assert_eq!(report.rounds, inproc.rounds);
    assert_eq!(report.total_shuffle_bytes, inproc.total_shuffle_bytes);
    assert_eq!(report.max_round_bytes, inproc.max_round_bytes);
}
