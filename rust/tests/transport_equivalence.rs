//! Transport equivalence: the multi-process backends must be
//! observationally identical to the in-process engine.
//!
//! For every algorithm and machine count, labels, per-round metrics
//! (message counts, shuffled bytes, per-machine loads), phase series, and
//! transport-driven graph rewrites must compare **bit-identical** across
//! `inproc`, `proc`, and `shuffle` — the workers are real OS processes
//! spawned from the `lcc` binary, the payloads really cross sockets (on
//! `shuffle`, worker↔worker over the mesh, generated from the worker-held
//! shards), and the hop folds are reduced remotely, so this suite is the
//! end-to-end proof that the `Exchange` boundary carries the full
//! semantics.  The shuffle transport additionally must keep the
//! coordinator link down to O(machines) summary bytes per described
//! round, and keep shard custody on the workers across contractions
//! (peer-to-peer re-shipping, no coordinator re-load).

use std::path::Path;
use std::sync::atomic::Ordering;

use lcc::cc::common::{contract_mpc, min_hop};
use lcc::cc::{self, CcAlgorithm, CcResult, RunOptions};
use lcc::graph::{generators, Graph, ShardedGraph, SpillPolicy};
use lcc::mpc::net::{ProcTransport, ShuffleTransport};
use lcc::mpc::{MpcConfig, Simulator};
use lcc::util::rng::Rng;

fn worker_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_lcc"))
}

fn cfg(machines: usize) -> MpcConfig {
    MpcConfig {
        machines,
        space_per_machine: None,
        spill_budget: None,
        threads: 2,
    }
}

fn proc_sim(g: &ShardedGraph, machines: usize) -> Simulator {
    let mut t = ProcTransport::spawn(machines, worker_bin()).expect("spawn workers");
    t.load_graph(g).expect("distribute shards");
    Simulator::with_transport(cfg(machines), Box::new(t))
}

fn shuffle_sim(g: &ShardedGraph, machines: usize) -> Simulator {
    let mut t = ShuffleTransport::spawn(machines, worker_bin()).expect("spawn mesh workers");
    t.load_graph(g).expect("distribute shards");
    Simulator::with_transport(cfg(machines), Box::new(t))
}

/// A small graph with structure: a sparse random part, a path (deep
/// component), and isolated vertices from the gnp tail.
fn test_graph() -> Graph {
    let mut rng = Rng::new(9);
    generators::gnp(100, 0.03, &mut rng).disjoint_union(generators::path(30))
}

fn run_algo(algo: &str, g: &ShardedGraph, mut sim: Simulator, seed: u64) -> CcResult {
    let a = cc::by_name(algo);
    let mut rng = Rng::new(seed);
    let opts = RunOptions {
        finisher_threshold: 16,
        ..RunOptions::default()
    };
    a.run_sharded(g, &mut sim, &mut rng, &opts)
}

#[test]
fn all_algorithms_bit_identical_across_transports() {
    let flat = test_graph();
    let want = cc::oracle::components(&flat);
    for machines in [1usize, 4, 16] {
        let g = ShardedGraph::from_graph(&flat, machines);
        for algo in cc::ALL_ALGORITHMS {
            let local = run_algo(algo, &g, Simulator::new(cfg(machines)), 7);
            assert_eq!(local.labels, want, "{algo} machines={machines}: wrong labels");
            for (mode, remote) in [
                ("proc", run_algo(algo, &g, proc_sim(&g, machines), 7)),
                ("shuffle", run_algo(algo, &g, shuffle_sim(&g, machines), 7)),
            ] {
                assert_eq!(
                    local.labels, remote.labels,
                    "{algo} machines={machines} {mode}: labels diverge"
                );
                assert_eq!(
                    local.phases, remote.phases,
                    "{algo} machines={machines} {mode}: phases diverge"
                );
                assert_eq!(
                    local.edges_per_phase, remote.edges_per_phase,
                    "{algo} machines={machines} {mode}: phase series diverge"
                );
                assert_eq!(
                    local.metrics.rounds, remote.metrics.rounds,
                    "{algo} machines={machines} {mode}: per-round metrics diverge"
                );
            }
        }
    }
}

#[test]
fn transport_driven_rewrites_produce_identical_graphs() {
    // hop + contract under all transports: the *final graphs* must be
    // bit-identical, not just the labels
    let flat = test_graph();
    let machines = 4;
    let g = ShardedGraph::from_graph(&flat, machines);

    let run = |mut sim: Simulator| {
        let labels: Vec<u32> = (0..g.num_vertices() as u32).collect();
        let hopped = min_hop(&mut sim, "hop", &g, &labels, true);
        let (contracted, node_map) = contract_mpc(&mut sim, &g, &hopped);
        (hopped, contracted, node_map, sim.metrics.rounds)
    };
    let (h_l, c_l, m_l, r_l) = run(Simulator::new(cfg(machines)));
    for (mode, sim) in [
        ("proc", proc_sim(&g, machines)),
        ("shuffle", shuffle_sim(&g, machines)),
    ] {
        let (h_p, c_p, m_p, r_p) = run(sim);
        assert_eq!(h_l, h_p, "{mode}: hop values diverge");
        assert_eq!(m_l, m_p, "{mode}: compaction maps diverge");
        assert_eq!(c_l, c_p, "{mode}: contracted sharded graphs diverge");
        assert_eq!(
            c_l.to_graph(),
            c_p.to_graph(),
            "{mode}: flattened graphs diverge"
        );
        assert_eq!(r_l, r_p, "{mode}: rewrite round metrics diverge");
    }
}

#[test]
fn spilled_shards_ship_without_rehydration_and_match() {
    // a disk-backed graph: both wire transports read the shard files
    // verbatim off the spill dir; results must still be bit-identical —
    // and the shuffle run re-ships contraction custody peer to peer.
    let flat = test_graph();
    let machines = 4;
    let g = ShardedGraph::from_graph_with(&flat, machines, SpillPolicy::budget(0));
    assert!(g.is_spilled(), "budget 0 must spill");
    let local = run_algo("lc", &g, Simulator::new(cfg(machines)), 3);
    let proc_res = run_algo("lc", &g, proc_sim(&g, machines), 3);
    assert_eq!(local.labels, proc_res.labels);
    assert_eq!(local.metrics.rounds, proc_res.metrics.rounds);

    let mut t = ShuffleTransport::spawn(machines, worker_bin()).expect("spawn mesh workers");
    t.load_graph(&g).expect("distribute shards");
    let stats = t.stats();
    let shuffle = run_algo("lc", &g, Simulator::with_transport(cfg(machines), Box::new(t)), 3);
    assert_eq!(local.labels, shuffle.labels);
    assert_eq!(local.metrics.rounds, shuffle.metrics.rounds);
    assert_eq!(local.labels, cc::oracle::components(&flat));

    // custody stayed worker-resident: the initial distribution is the
    // only coordinator-link shard load; every contraction (and prune)
    // re-shipped peer to peer
    assert_eq!(
        stats.custody_loads.load(Ordering::Relaxed),
        1,
        "contractions must not re-load custody through the coordinator"
    );
    assert!(
        stats.rewires.load(Ordering::Relaxed) >= 1,
        "LC on a spilled graph must trigger peer-to-peer custody re-shipping"
    );
    assert!(stats.hops.load(Ordering::Relaxed) >= 2, "hops run worker-native");
}

/// The acceptance property of the shuffle data plane: for a described
/// round whose message volume is ≫ machines, the coordinator link moves
/// only O(machines) summary bytes — descriptors out, load/checksum acks
/// back.  The O(m) stream stays on the worker mesh.
#[test]
fn shuffle_coordinator_link_is_o_machines_per_round() {
    let machines = 4;
    let n = 2000;
    let flat = generators::gnp(n, 8.0 / n as f64, &mut Rng::new(17));
    let g = ShardedGraph::from_graph(&flat, machines);
    let mut t = ShuffleTransport::spawn(machines, worker_bin()).expect("spawn mesh workers");
    t.load_graph(&g).expect("distribute shards");
    let link_bytes = t.link_bytes_counter();
    let mut sim = Simulator::with_transport(cfg(machines), Box::new(t));
    let vals: Vec<u32> = (0..n as u32).collect();

    // hop 1 syncs the value mirror (an O(n) broadcast); hop 2 chains on
    // hop 1's output, whose all-gather already kept the mirrors current —
    // a steady-state round
    let h1 = min_hop(&mut sim, "hop1", &g, &vals, true);
    let before = link_bytes.load(Ordering::Relaxed);
    let h2 = min_hop(&mut sim, "hop2", &g, &h1, true);
    let delta = link_bytes.load(Ordering::Relaxed) - before;

    let round = sim.metrics.rounds.last().expect("hop recorded");
    assert!(
        round.bytes > 100_000,
        "test graph too small to be meaningful: {} round bytes",
        round.bytes
    );
    assert!(
        delta <= 512 * machines as u64,
        "coordinator link moved {delta} bytes for one described round — \
         not O(machines) summaries"
    );
    assert!(
        round.bytes >= 50 * delta,
        "round message volume ({}) must dwarf coordinator traffic ({delta})",
        round.bytes
    );

    // and the values are still exactly the engine's
    let mut reference = Simulator::new(cfg(machines));
    let r1 = min_hop(&mut reference, "hop1", &g, &vals, true);
    let r2 = min_hop(&mut reference, "hop2", &g, &r1, true);
    assert_eq!(h2, r2, "steady-state shuffle hop diverges from inproc");
}

#[test]
fn driver_runs_the_proc_transport_end_to_end() {
    use lcc::coordinator::{Driver, RunConfig};
    use lcc::mpc::TransportMode;
    let flat = test_graph();
    let driver = Driver::new(RunConfig {
        algorithm: "cracker".into(),
        machines: 4,
        transport: TransportMode::Proc,
        worker_bin: Some(worker_bin().to_path_buf()),
        verify: true,
        ..Default::default()
    });
    let report = driver.try_run_named(&flat, "equiv").expect("proc run");
    assert_eq!(report.verified, Some(true));
    assert_eq!(report.transport, "proc");
    assert!(report.completed);

    let inproc = Driver::new(RunConfig {
        algorithm: "cracker".into(),
        machines: 4,
        verify: true,
        ..Default::default()
    })
    .run_named(&flat, "equiv");
    assert_eq!(inproc.transport, "inproc");
    assert_eq!(report.rounds, inproc.rounds);
    assert_eq!(report.total_shuffle_bytes, inproc.total_shuffle_bytes);
    assert_eq!(report.max_round_bytes, inproc.max_round_bytes);
}

#[test]
fn driver_runs_the_shuffle_transport_end_to_end() {
    use lcc::coordinator::{Driver, RunConfig};
    use lcc::mpc::TransportMode;
    let flat = test_graph();
    let driver = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines: 4,
        transport: TransportMode::Shuffle,
        worker_bin: Some(worker_bin().to_path_buf()),
        verify: true,
        ..Default::default()
    });
    let report = driver.try_run_named(&flat, "equiv").expect("shuffle run");
    assert_eq!(report.verified, Some(true));
    assert_eq!(report.transport, "shuffle");
    assert!(report.completed);

    let inproc = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines: 4,
        verify: true,
        ..Default::default()
    })
    .run_named(&flat, "equiv");
    assert_eq!(report.rounds, inproc.rounds);
    assert_eq!(report.total_shuffle_bytes, inproc.total_shuffle_bytes);
    assert_eq!(report.max_round_bytes, inproc.max_round_bytes);
}
