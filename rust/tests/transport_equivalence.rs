//! Transport equivalence: the multi-process backends must be
//! observationally identical to the in-process engine.
//!
//! For every algorithm and machine count, labels, per-round metrics
//! (message counts, shuffled bytes, per-machine loads), phase series, and
//! transport-driven graph rewrites must compare **bit-identical** across
//! `inproc`, `proc`, and `shuffle` — the workers are real OS processes
//! spawned from the `lcc` binary, the payloads really cross sockets (on
//! `shuffle`, worker↔worker over the mesh, generated from the worker-held
//! shards), and the hop folds are reduced remotely, so this suite is the
//! end-to-end proof that the `Exchange` boundary carries the full
//! semantics.  The shuffle transport additionally must keep the
//! coordinator link down to O(machines) summary bytes per described
//! round, and keep shard custody on the workers across contractions
//! (peer-to-peer re-shipping, no coordinator re-load).

use std::path::Path;
use std::sync::atomic::Ordering;

use lcc::cc::common::{contract_mpc, min_hop};
use lcc::cc::{self, CcAlgorithm, CcResult, RunOptions};
use lcc::graph::{generators, Graph, ShardedGraph, SpillPolicy};
use lcc::mpc::net::{NetConfig, ProcTransport, ShuffleTransport};
use lcc::mpc::{MpcConfig, Simulator};
use lcc::util::rng::Rng;

fn worker_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_lcc"))
}

fn cfg(machines: usize) -> MpcConfig {
    MpcConfig {
        machines,
        space_per_machine: None,
        spill_budget: None,
        threads: 2,
    }
}

fn proc_sim(g: &ShardedGraph, machines: usize) -> Simulator {
    let mut t = ProcTransport::spawn(machines, worker_bin()).expect("spawn workers");
    t.load_graph(g).expect("distribute shards");
    Simulator::with_transport(cfg(machines), Box::new(t))
}

fn shuffle_sim(g: &ShardedGraph, machines: usize) -> Simulator {
    let mut t = ShuffleTransport::spawn(machines, worker_bin()).expect("spawn mesh workers");
    t.load_graph(g).expect("distribute shards");
    Simulator::with_transport(cfg(machines), Box::new(t))
}

/// Shuffle transport with mirror deltas disabled: every sync takes the
/// full-broadcast path — the baseline the delta encoding must stay
/// bit-identical to.
fn shuffle_sim_full_sync(g: &ShardedGraph, machines: usize) -> Simulator {
    let net = NetConfig {
        delta_sync: false,
        ..NetConfig::default()
    };
    let mut t =
        ShuffleTransport::spawn_with(machines, worker_bin(), net).expect("spawn mesh workers");
    t.load_graph(g).expect("distribute shards");
    Simulator::with_transport(cfg(machines), Box::new(t))
}

/// A small graph with structure: a sparse random part, a path (deep
/// component), and isolated vertices from the gnp tail.
fn test_graph() -> Graph {
    let mut rng = Rng::new(9);
    generators::gnp(100, 0.03, &mut rng).disjoint_union(generators::path(30))
}

fn run_algo(algo: &str, g: &ShardedGraph, mut sim: Simulator, seed: u64) -> CcResult {
    let a = cc::by_name(algo);
    let mut rng = Rng::new(seed);
    let opts = RunOptions {
        finisher_threshold: 16,
        ..RunOptions::default()
    };
    a.run_sharded(g, &mut sim, &mut rng, &opts)
}

#[test]
fn all_algorithms_bit_identical_across_transports() {
    let flat = test_graph();
    let want = cc::oracle::components(&flat);
    for machines in [1usize, 4, 16] {
        let g = ShardedGraph::from_graph(&flat, machines);
        for algo in cc::ALL_ALGORITHMS {
            let local = run_algo(algo, &g, Simulator::new(cfg(machines)), 7);
            assert_eq!(local.labels, want, "{algo} machines={machines}: wrong labels");
            for (mode, remote) in [
                ("proc", run_algo(algo, &g, proc_sim(&g, machines), 7)),
                ("shuffle", run_algo(algo, &g, shuffle_sim(&g, machines), 7)),
                // deltas off: full-broadcast syncs must be a pure
                // encoding change, invisible to labels and metrics
                (
                    "shuffle-full-sync",
                    run_algo(algo, &g, shuffle_sim_full_sync(&g, machines), 7),
                ),
            ] {
                assert_eq!(
                    local.labels, remote.labels,
                    "{algo} machines={machines} {mode}: labels diverge"
                );
                assert_eq!(
                    local.phases, remote.phases,
                    "{algo} machines={machines} {mode}: phases diverge"
                );
                assert_eq!(
                    local.edges_per_phase, remote.edges_per_phase,
                    "{algo} machines={machines} {mode}: phase series diverge"
                );
                assert_eq!(
                    local.metrics.rounds, remote.metrics.rounds,
                    "{algo} machines={machines} {mode}: per-round metrics diverge"
                );
            }
        }
    }
}

/// The acceptance property of the parallel worker data plane: a fleet
/// running its generate/fold/rewire stages on a thread pool must be
/// observationally indistinguishable from the serial fleet — labels,
/// phase series, and per-round metrics (message counts, shuffled bytes,
/// per-machine loads) bit-identical, and the mesh byte counters equal to
/// the byte, because the chunk-order merge reproduces the serial byte
/// stream exactly.  Fold checksums are cross-checked worker-vs-
/// coordinator inside every StateAck, so any parallel-fold divergence
/// fails the run itself, not just these asserts.
#[test]
fn parallel_data_plane_is_bit_identical_across_thread_counts() {
    use std::sync::atomic::AtomicU64;
    let flat = test_graph();
    let want = cc::oracle::components(&flat);
    let snapshot = |s: &lcc::mpc::net::ShuffleStats| -> Vec<u64> {
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        vec![
            ld(&s.rewires),
            ld(&s.custody_loads),
            ld(&s.state_syncs),
            ld(&s.delta_syncs),
            ld(&s.hops),
            ld(&s.hop_batches),
            ld(&s.sync_bytes),
            ld(&s.mesh_bytes),
        ]
    };
    for machines in [1usize, 4, 16] {
        let g = ShardedGraph::from_graph(&flat, machines);
        for algo in cc::ALL_ALGORITHMS {
            let run_at = |threads: usize| {
                let net = NetConfig {
                    worker_threads: threads,
                    ..NetConfig::default()
                };
                let mut t = ShuffleTransport::spawn_with(machines, worker_bin(), net)
                    .expect("spawn mesh workers");
                t.load_graph(&g).expect("distribute shards");
                let stats = t.stats();
                let res = run_algo(
                    algo,
                    &g,
                    Simulator::with_transport(cfg(machines), Box::new(t)),
                    7,
                );
                (res, snapshot(&stats))
            };
            let (serial, counters_serial) = run_at(1);
            assert_eq!(
                serial.labels, want,
                "{algo} machines={machines} threads=1: wrong labels"
            );
            let (pooled, counters_pooled) = run_at(4);
            assert_eq!(
                serial.labels, pooled.labels,
                "{algo} machines={machines}: labels diverge at 4 worker threads"
            );
            assert_eq!(
                serial.phases, pooled.phases,
                "{algo} machines={machines}: phases diverge at 4 worker threads"
            );
            assert_eq!(
                serial.edges_per_phase, pooled.edges_per_phase,
                "{algo} machines={machines}: phase series diverge at 4 worker threads"
            );
            assert_eq!(
                serial.metrics.rounds, pooled.metrics.rounds,
                "{algo} machines={machines}: per-round metrics diverge at 4 worker threads"
            );
            assert_eq!(
                counters_serial, counters_pooled,
                "{algo} machines={machines}: mesh byte counters diverge at 4 worker threads \
                 (rewires/custody/syncs/deltas/hops/batches/sync_bytes/mesh_bytes)"
            );
        }
    }
}

#[test]
fn transport_driven_rewrites_produce_identical_graphs() {
    // hop + contract under all transports: the *final graphs* must be
    // bit-identical, not just the labels
    let flat = test_graph();
    let machines = 4;
    let g = ShardedGraph::from_graph(&flat, machines);

    let run = |mut sim: Simulator| {
        let labels: Vec<u32> = (0..g.num_vertices() as u32).collect();
        let hopped = min_hop(&mut sim, "hop", &g, &labels, true);
        let (contracted, node_map) = contract_mpc(&mut sim, &g, &hopped);
        (hopped, contracted, node_map, sim.metrics.rounds)
    };
    let (h_l, c_l, m_l, r_l) = run(Simulator::new(cfg(machines)));
    for (mode, sim) in [
        ("proc", proc_sim(&g, machines)),
        ("shuffle", shuffle_sim(&g, machines)),
        ("shuffle-full-sync", shuffle_sim_full_sync(&g, machines)),
    ] {
        let (h_p, c_p, m_p, r_p) = run(sim);
        assert_eq!(h_l, h_p, "{mode}: hop values diverge");
        assert_eq!(m_l, m_p, "{mode}: compaction maps diverge");
        assert_eq!(c_l, c_p, "{mode}: contracted sharded graphs diverge");
        assert_eq!(
            c_l.to_graph(),
            c_p.to_graph(),
            "{mode}: flattened graphs diverge"
        );
        assert_eq!(r_l, r_p, "{mode}: rewrite round metrics diverge");
    }
}

#[test]
fn spilled_shards_ship_without_rehydration_and_match() {
    // a disk-backed graph: both wire transports read the shard files
    // verbatim off the spill dir; results must still be bit-identical —
    // and the shuffle run re-ships contraction custody peer to peer.
    let flat = test_graph();
    let machines = 4;
    let g = ShardedGraph::from_graph_with(&flat, machines, SpillPolicy::budget(0));
    assert!(g.is_spilled(), "budget 0 must spill");
    let local = run_algo("lc", &g, Simulator::new(cfg(machines)), 3);
    let proc_res = run_algo("lc", &g, proc_sim(&g, machines), 3);
    assert_eq!(local.labels, proc_res.labels);
    assert_eq!(local.metrics.rounds, proc_res.metrics.rounds);

    let mut t = ShuffleTransport::spawn(machines, worker_bin()).expect("spawn mesh workers");
    t.load_graph(&g).expect("distribute shards");
    let stats = t.stats();
    let shuffle = run_algo("lc", &g, Simulator::with_transport(cfg(machines), Box::new(t)), 3);
    assert_eq!(local.labels, shuffle.labels);
    assert_eq!(local.metrics.rounds, shuffle.metrics.rounds);
    assert_eq!(local.labels, cc::oracle::components(&flat));

    // custody stayed worker-resident: the initial distribution is the
    // only coordinator-link shard load; every contraction (and prune)
    // re-shipped peer to peer
    assert_eq!(
        stats.custody_loads.load(Ordering::Relaxed),
        1,
        "contractions must not re-load custody through the coordinator"
    );
    assert!(
        stats.rewires.load(Ordering::Relaxed) >= 1,
        "LC on a spilled graph must trigger peer-to-peer custody re-shipping"
    );
    assert!(stats.hops.load(Ordering::Relaxed) >= 2, "hops run worker-native");
}

/// The acceptance property of the shuffle data plane: for described
/// rounds whose message volume is ≫ machines, the coordinator link moves
/// only O(machines) summary bytes — descriptors out, load/checksum acks
/// back.  The O(m) stream stays on the worker mesh.  With pipelined
/// batches the bound is per *batch*: a fused two-hop ships one
/// descriptor batch and one ack exchange for its two charged rounds.
#[test]
fn shuffle_coordinator_link_is_o_machines_per_batch() {
    use lcc::cc::common::fused_two_hop;
    use lcc::graph::Csr;
    use lcc::mpc::WireFold;
    let machines = 4;
    let n = 2000;
    let flat = generators::gnp(n, 8.0 / n as f64, &mut Rng::new(17));
    let g = ShardedGraph::from_graph(&flat, machines);
    let mut t = ShuffleTransport::spawn(machines, worker_bin()).expect("spawn mesh workers");
    t.load_graph(&g).expect("distribute shards");
    let link_bytes = t.link_bytes_counter();
    let stats = t.stats();
    let mut sim = Simulator::with_transport(cfg(machines), Box::new(t));
    let vals: Vec<u32> = (0..n as u32).collect();

    // hop 1 syncs the value mirror (an O(n) broadcast); the fused
    // two-hop chains on hop 1's output, whose retained post-fold image
    // already keeps the mirrors current — two steady-state rounds
    // shipped as ONE pipelined batch
    let h1 = min_hop(&mut sim, "hop1", &g, &vals, true);
    let csr = Csr::build_sharded(&g);
    let rounds_before = sim.metrics.rounds.len();
    let before = link_bytes.load(Ordering::Relaxed);
    let h3 = fused_two_hop(
        &mut sim,
        ("hop2", "hop3"),
        &g,
        &csr,
        &h1,
        WireFold::min_u32(),
    );
    let delta = link_bytes.load(Ordering::Relaxed) - before;

    assert_eq!(
        sim.metrics.rounds.len(),
        rounds_before + 2,
        "a pipelined batch still charges each round individually"
    );
    assert_eq!(
        stats.hop_batches.load(Ordering::Relaxed),
        1,
        "the fused two-hop must ship as one descriptor batch"
    );
    let round = sim.metrics.rounds.last().expect("hop recorded");
    assert!(
        round.bytes > 100_000,
        "test graph too small to be meaningful: {} round bytes",
        round.bytes
    );
    assert!(
        delta <= 512 * machines as u64,
        "coordinator link moved {delta} bytes for a two-round batch — \
         not O(machines) summaries"
    );
    assert!(
        round.bytes >= 50 * delta,
        "round message volume ({}) must dwarf coordinator traffic ({delta})",
        round.bytes
    );

    // and the values are still exactly the engine's
    let mut reference = Simulator::new(cfg(machines));
    let r1 = min_hop(&mut reference, "hop1", &g, &vals, true);
    let r3 = fused_two_hop(
        &mut reference,
        ("hop2", "hop3"),
        &g,
        &csr,
        &r1,
        WireFold::min_u32(),
    );
    assert_eq!(h3, r3, "steady-state pipelined batch diverges from inproc");
}

/// The acceptance property of the delta mirror sync: once the workers
/// hold a generation's mirror, a sync whose value vector changed in few
/// places ships an index/value patch, not an O(n) re-broadcast.  Over a
/// 16-machine power-law graph the steady-state sync must cost under 30%
/// of the full-broadcast baseline — and stay bit-identical to it.
#[test]
fn delta_mirror_sync_ships_under_30_percent_of_full_broadcast() {
    let machines = 16;
    let n = 4000;
    let flat = generators::chung_lu(n, 8.0, 2.5, &mut Rng::new(23));
    let g = ShardedGraph::from_graph(&flat, machines);

    // One steady-state sync per mode: hop, perturb a small fraction of
    // the output (the shape of a converging label sequence), hop again.
    // The second hop's mirror sync is the measured quantity.
    let run = |delta_sync: bool| {
        let net = NetConfig {
            delta_sync,
            ..NetConfig::default()
        };
        let mut t = ShuffleTransport::spawn_with(machines, worker_bin(), net)
            .expect("spawn mesh workers");
        t.load_graph(&g).expect("distribute shards");
        let stats = t.stats();
        let mut sim = Simulator::with_transport(cfg(machines), Box::new(t));
        let vals: Vec<u32> = (0..n as u32).collect();
        let h1 = min_hop(&mut sim, "hop1", &g, &vals, true);
        let mut perturbed = h1.clone();
        for v in (0..n).step_by(40) {
            perturbed[v] = perturbed[v].wrapping_add(1);
        }
        let before = stats.sync_bytes.load(Ordering::Relaxed);
        let h2 = min_hop(&mut sim, "hop2", &g, &perturbed, true);
        let synced = stats.sync_bytes.load(Ordering::Relaxed) - before;
        let deltas = stats.delta_syncs.load(Ordering::Relaxed);
        (h2, sim.metrics.rounds, synced, deltas)
    };
    let (h_full, r_full, sync_full, d_full) = run(false);
    let (h_delta, r_delta, sync_delta, d_delta) = run(true);

    // the encoding is invisible to the model
    assert_eq!(h_full, h_delta, "delta-synced hop diverges from full-broadcast");
    assert_eq!(r_full, r_delta, "per-round metrics diverge across sync encodings");
    assert_eq!(d_full, 0, "deltas disabled must never ship a StateDelta");
    assert!(d_delta >= 1, "steady-state sync must take the delta path");

    // and the delta is the claimed byte win
    assert!(sync_full > 0, "baseline run must re-broadcast the mirror");
    assert!(
        sync_delta * 10 < sync_full * 3,
        "steady-state delta sync moved {sync_delta} bytes — \
         not under 30% of the {sync_full}-byte full broadcast"
    );
}

#[test]
fn driver_runs_the_proc_transport_end_to_end() {
    use lcc::coordinator::{Driver, RunConfig};
    use lcc::mpc::TransportMode;
    let flat = test_graph();
    let driver = Driver::new(RunConfig {
        algorithm: "cracker".into(),
        machines: 4,
        transport: TransportMode::Proc,
        worker_bin: Some(worker_bin().to_path_buf()),
        verify: true,
        ..Default::default()
    });
    let report = driver.try_run_named(&flat, "equiv").expect("proc run");
    assert_eq!(report.verified, Some(true));
    assert_eq!(report.transport, "proc");
    assert!(report.completed);

    let inproc = Driver::new(RunConfig {
        algorithm: "cracker".into(),
        machines: 4,
        verify: true,
        ..Default::default()
    })
    .run_named(&flat, "equiv");
    assert_eq!(inproc.transport, "inproc");
    assert_eq!(report.rounds, inproc.rounds);
    assert_eq!(report.total_shuffle_bytes, inproc.total_shuffle_bytes);
    assert_eq!(report.max_round_bytes, inproc.max_round_bytes);
}

#[test]
fn driver_runs_the_shuffle_transport_end_to_end() {
    use lcc::coordinator::{Driver, RunConfig};
    use lcc::mpc::TransportMode;
    let flat = test_graph();
    let driver = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines: 4,
        transport: TransportMode::Shuffle,
        worker_bin: Some(worker_bin().to_path_buf()),
        verify: true,
        ..Default::default()
    });
    let report = driver.try_run_named(&flat, "equiv").expect("shuffle run");
    assert_eq!(report.verified, Some(true));
    assert_eq!(report.transport, "shuffle");
    assert!(report.completed);

    let inproc = Driver::new(RunConfig {
        algorithm: "lc".into(),
        machines: 4,
        verify: true,
        ..Default::default()
    })
    .run_named(&flat, "equiv");
    assert_eq!(report.rounds, inproc.rounds);
    assert_eq!(report.total_shuffle_bytes, inproc.total_shuffle_bytes);
    assert_eq!(report.max_round_bytes, inproc.max_round_bytes);
}
