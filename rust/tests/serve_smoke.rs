//! Smoke tests for `lcc serve`, the incremental connectivity daemon.
//!
//! Three layers:
//!  * end to end — the real binary, a real TCP client, streamed
//!    insertions, a threshold-triggered recontraction, and bit-identity
//!    of every answer against the from-scratch union-find oracle;
//!  * concurrency — reader threads hammering the lock-free snapshot
//!    while the writer ingests and recontracts (no torn reads, answers
//!    monotone under edge insertion);
//!  * retention — a shuffle-transport service with `--keep-generations`
//!    leaves at most K `gen-*` checkpoint dirs behind N recontractions.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use lcc::coordinator::{Driver, RunConfig};
use lcc::graph::{generators, Graph};
use lcc::mpc::TransportMode;
use lcc::serve::core::ServiceCore;
use lcc::util::json::{self, Json};
use lcc::util::rng::Rng;

/// Kill the daemon even when an assertion unwinds the test.
struct ChildGuard(Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// One newline-JSON request/response exchange.
fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &Json) -> Json {
    writeln!(stream, "{}", req.dumps()).expect("send request");
    stream.flush().expect("flush request");
    let mut line = String::new();
    reader.read_line(&mut line).expect("read reply");
    json::parse(line.trim()).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"))
}

fn get_u64(j: &Json, key: &str) -> u64 {
    j.get(key)
        .and_then(|v| v.as_i64())
        .unwrap_or_else(|| panic!("missing {key} in {}", j.dumps())) as u64
}

// ---------------------------------------------------------------------------
// end to end: real binary, real socket, oracle bit-identity

#[test]
fn serve_answers_queries_inserts_and_recontracts_bit_identically() {
    let (n, avg, seed) = (500usize, 2.0f64, 7u64);
    // the exact graph `lcc serve --graph gnp` builds (main.rs load_graph)
    let g = generators::gnp(n, avg / n as f64, &mut Rng::new(seed));

    let mut child = ChildGuard(
        Command::new(env!("CARGO_BIN_EXE_lcc"))
            .args([
                "serve",
                "--graph",
                "gnp",
                "--n",
                "500",
                "--avg-deg",
                "2",
                "--seed",
                "7",
                "--machines",
                "4",
                "--transport",
                "proc",
                "--port",
                "0",
                "--recontract-threshold",
                "8",
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn lcc serve"),
    );
    let mut ready_line = String::new();
    BufReader::new(child.0.stdout.take().expect("child stdout"))
        .read_line(&mut ready_line)
        .expect("read ready line");
    let ready = json::parse(ready_line.trim()).expect("ready line is JSON");
    assert_eq!(ready.get("event").and_then(|e| e.as_str()), Some("serving"));
    assert_eq!(get_u64(&ready, "n") as usize, n);
    let port = get_u64(&ready, "port") as u16;

    let mut stream = TcpStream::connect(("127.0.0.1", port)).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));

    // 1. bootstrap labels: every component-of answer matches the oracle
    let labels = lcc::cc::oracle::components(&g);
    for u in (0..n as u32).step_by(7) {
        let reply = request(
            &mut stream,
            &mut reader,
            &Json::obj().set("op", "component-of").set("u", u),
        );
        assert_eq!(
            get_u64(&reply, "label") as u32,
            labels[u as usize],
            "component-of({u}) diverges from the oracle"
        );
    }

    // 2. component-sizes agrees with the oracle's histogram
    let reply = request(
        &mut stream,
        &mut reader,
        &Json::obj().set("op", "component-sizes").set("top", 1),
    );
    let mut counts = std::collections::HashMap::new();
    for &l in &labels {
        *counts.entry(l).or_insert(0u64) += 1;
    }
    assert_eq!(get_u64(&reply, "components") as usize, counts.len());
    let top = reply.get("sizes").and_then(|s| s.as_arr()).expect("sizes")[0]
        .as_arr()
        .expect("pair");
    assert_eq!(
        top[1].as_i64().unwrap() as u64,
        *counts.values().max().unwrap()
    );

    // 3. stream a chain over every vertex: enough inter-component core
    // edges to cross threshold 8 and force a full recontraction pass
    let mut all_edges = g.edges().to_vec();
    for start in (0..n as u32 - 1).step_by(50) {
        let end = (start + 50).min(n as u32 - 1);
        let batch: Vec<Json> = (start..end)
            .map(|v| Json::Arr(vec![Json::from(v), Json::from(v + 1)]))
            .collect();
        all_edges.extend((start..end).map(|v| (v, v + 1)));
        let want_queued = (end - start) as u64;
        let reply = request(
            &mut stream,
            &mut reader,
            &Json::obj().set("op", "insert").set("edges", Json::Arr(batch)),
        );
        assert_eq!(get_u64(&reply, "queued"), want_queued);
    }

    // 4. flush = read-your-writes barrier; the chain connected everything
    let ack = request(&mut stream, &mut reader, &Json::obj().set("op", "flush"));
    assert_eq!(get_u64(&ack, "components"), 1, "chain must connect the graph");
    assert!(
        get_u64(&ack, "recontractions") >= 1,
        "threshold 8 must have triggered a full pass: {}",
        ack.dumps()
    );

    // 5. post-recontraction: answers are bit-identical to a from-scratch
    // oracle over the accumulated edge multiset
    let want = lcc::cc::oracle::components(&Graph::from_edges(n, all_edges));
    for u in (0..n as u32).step_by(11) {
        let reply = request(
            &mut stream,
            &mut reader,
            &Json::obj().set("op", "component-of").set("u", u),
        );
        assert_eq!(get_u64(&reply, "label") as u32, want[u as usize]);
    }
    let reply = request(
        &mut stream,
        &mut reader,
        &Json::obj()
            .set("op", "same-component")
            .set("u", 0)
            .set("v", n as u32 - 1),
    );
    assert_eq!(
        reply.get("same").map(|s| s.dumps()),
        Some("true".to_string()),
        "0 and n-1 connected after the chain"
    );

    // 6. malformed requests are errors, not disconnects
    writeln!(stream, "not json").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "bad line must yield an error reply");

    // 7. clean shutdown: daemon exits by itself
    let reply = request(&mut stream, &mut reader, &Json::obj().set("op", "shutdown"));
    assert_eq!(reply.get("stopping").map(|s| s.dumps()), Some("true".into()));
    let status = child.0.wait().expect("daemon exit");
    assert!(status.success(), "daemon exited {status:?}");
}

// ---------------------------------------------------------------------------
// concurrency: lock-free readers vs live ingest + forced recontraction

#[test]
fn snapshot_reads_are_consistent_under_concurrent_ingest() {
    // 200 disconnected edges (2i)-(2i+1); the writer then chains pairs
    // together, repeatedly crossing a tiny recontraction threshold.
    let n = 400usize;
    let base: Vec<(u32, u32)> = (0..n as u32 / 2).map(|i| (2 * i, 2 * i + 1)).collect();
    let g = Graph::from_edges(n, base.clone());
    let driver = Driver::new(RunConfig {
        machines: 4,
        ..Default::default()
    });
    let mut core = ServiceCore::bootstrap(driver, &g, "stress", 5).expect("bootstrap");
    let cell = core.cell();

    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..4)
        .map(|r| {
            let cell = Arc::clone(&cell);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut reader = cell.reader();
                let mut last_epoch = 0u64;
                // connectivity is monotone under insertion: once a pair
                // answers true it may never flip back
                let mut connected = vec![false; n / 2];
                let mut observations = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let snap = reader.current();
                    // no torn reads: a snapshot is internally consistent
                    assert_eq!(snap.labels.len(), n, "reader {r}: torn label array");
                    assert!(snap.epoch >= last_epoch, "reader {r}: epoch regressed");
                    last_epoch = snap.epoch;
                    for i in 0..n as u32 / 2 {
                        let same = snap.same_component(2 * i, (2 * i + 2) % n as u32).unwrap();
                        if connected[i as usize] {
                            assert!(
                                same,
                                "reader {r}: pair {i} flipped connected -> disconnected"
                            );
                        }
                        connected[i as usize] = same;
                        observations += 1;
                    }
                }
                observations
            })
        })
        .collect();

    // writer: chain neighbouring pairs, forcing incremental merges and
    // (threshold 5) repeated full recontraction passes mid-read
    let mut all_edges = base;
    for i in 0..(n as u32 / 2 - 1) {
        let e = (2 * i + 1, 2 * i + 2);
        all_edges.push(e);
        core.apply_batch(&[e]);
    }
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        let obs = r.join().expect("reader thread");
        assert!(obs > 0, "reader made no observations");
    }
    assert!(
        core.recontractions() >= 3,
        "chaining 199 core edges at threshold 5 must recontract repeatedly, got {}",
        core.recontractions()
    );
    // final snapshot is bit-identical to the from-scratch oracle
    let want = lcc::cc::oracle::components(&Graph::from_edges(n, all_edges));
    assert_eq!(cell.load().labels, want);
}

// ---------------------------------------------------------------------------
// retention: N recontractions leave at most K generation dirs

#[test]
fn recontractions_leave_at_most_k_generation_dirs() {
    let dir: PathBuf = std::env::temp_dir().join(format!(
        "lcc-serve-retention-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create checkpoint dir");

    let n = 48usize;
    let base: Vec<(u32, u32)> = (0..n as u32 / 2).map(|i| (2 * i, 2 * i + 1)).collect();
    let g = Graph::from_edges(n, base);
    let driver = Driver::new(RunConfig {
        machines: 2,
        transport: TransportMode::Shuffle,
        worker_bin: Some(env!("CARGO_BIN_EXE_lcc").into()),
        checkpoint_dir: Some(dir.clone()),
        keep_generations: Some(2),
        ..Default::default()
    });
    let mut core = ServiceCore::bootstrap(driver, &g, "retention", 5).expect("bootstrap");

    // every 5 chained inserts cross the threshold: >= 3 full passes over
    // the persistent shuffle fleet, each checkpointing generations
    for i in 0..(n as u32 / 2 - 1) {
        core.apply_batch(&[(2 * i + 1, 2 * i + 2)]);
    }
    assert!(
        core.recontractions() >= 3,
        "expected repeated recontractions, got {}",
        core.recontractions()
    );

    let gens: Vec<String> = std::fs::read_dir(&dir)
        .expect("read checkpoint dir")
        .flatten()
        .filter_map(|e| {
            let name = e.file_name().to_string_lossy().into_owned();
            name.starts_with("gen-").then_some(name)
        })
        .collect();
    assert!(
        gens.len() <= 2,
        "retention must cap gen dirs at keep_generations=2, found {gens:?}"
    );
    drop(core);
    let _ = std::fs::remove_dir_all(&dir);
}
