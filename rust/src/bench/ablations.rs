//! Ablation studies for the design choices DESIGN.md calls out:
//! the §6 optimizations (finisher threshold, isolated-node pruning), the
//! MergeToLarge schedule of §5, MPC machine-count scaling, and the
//! compiled dense backend.  `lcc ablation --exp <name>` / `cargo bench
//! --bench ablations`.

use crate::cc::{self, oracle, CcAlgorithm, RunOptions};
use crate::coordinator::{Driver, RunConfig};
use crate::graph::generators;
use crate::mpc::{MpcConfig, Simulator};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::AsciiTable;

/// §6 finisher-threshold sweep: phases and wall time vs threshold.
/// Shows the trade-off the paper describes ("if after some phase the
/// contracted graph is small enough, we send it to one machine").
pub fn finisher(seed: u64) -> (String, Json) {
    let g = generators::presets::generate("videos", Some(40_000), seed);
    let m = g.num_edges();
    let mut t = AsciiTable::new(&["finisher threshold", "phases", "rounds", "wall ms", "verified"]);
    let mut rows = Vec::new();
    for frac in [0.0, 0.001, 0.01, 0.1, 1.0] {
        let threshold = (m as f64 * frac) as usize;
        let driver = Driver::new(RunConfig {
            algorithm: "lc".into(),
            seed,
            finisher_threshold: threshold,
            verify: true,
            ..Default::default()
        });
        let r = driver.run_median(&g, "videos", 3);
        t.row(vec![
            format!("{threshold} ({frac} m)"),
            r.phases.to_string(),
            r.rounds.to_string(),
            format!("{:.1}", r.wall_ms),
            format!("{:?}", r.verified == Some(true)),
        ]);
        rows.push(
            Json::obj()
                .set("threshold", threshold)
                .set("phases", u64::from(r.phases))
                .set("wall_ms", r.wall_ms),
        );
    }
    (
        t.render(),
        Json::obj().set("exp", "finisher").set("rows", rows),
    )
}

/// §6 isolated-node pruning on/off: total shuffled bytes and wall time on
/// a fragmenting dataset (pruning pays off when components finish early).
pub fn pruning(seed: u64) -> (String, Json) {
    let g = generators::presets::generate("webpages", Some(60_000), seed);
    let mut t = AsciiTable::new(&["prune_isolated", "phases", "total shuffle MB", "wall ms"]);
    let mut rows = Vec::new();
    for prune in [true, false] {
        let driver = Driver::new(RunConfig {
            algorithm: "lc".into(),
            seed,
            prune_isolated: prune,
            verify: true,
            ..Default::default()
        });
        let r = driver.run_median(&g, "webpages", 3);
        assert_ne!(r.verified, Some(false));
        t.row(vec![
            prune.to_string(),
            r.phases.to_string(),
            format!("{:.2}", r.total_shuffle_bytes as f64 / 1e6),
            format!("{:.1}", r.wall_ms),
        ]);
        rows.push(
            Json::obj()
                .set("prune", prune)
                .set("bytes", r.total_shuffle_bytes)
                .set("wall_ms", r.wall_ms),
        );
    }
    (
        t.render(),
        Json::obj().set("exp", "pruning").set("rows", rows),
    )
}

/// MergeToLarge schedule sweep (§5): the `c` multiplier on `ln n` controls
/// how aggressively nodes chase large neighbors.
pub fn mtl_schedule(seed: u64) -> (String, Json) {
    use cc::local_contraction::LocalContraction;
    use cc::merge_to_large::Schedule;
    use cc::CcAlgorithm;
    let g = generators::gnp_log_regime(1 << 15, 2.0, &mut Rng::new(seed));
    let want = oracle::components(&g);
    let mut t = AsciiTable::new(&["schedule", "phases", "rounds", "correct"]);
    let mut rows = Vec::new();
    let mut cases: Vec<(String, Option<Schedule>)> = vec![("off (plain lc)".into(), None)];
    for c in [0.25, 0.5, 1.0, 2.0, 4.0] {
        cases.push((format!("c={c}"), Some(Schedule { c, floor: 2 })));
    }
    for (name, schedule) in cases {
        let algo = LocalContraction {
            merge_to_large: schedule,
        };
        let mut sim = Simulator::new(MpcConfig::default());
        let mut rng = Rng::new(seed);
        let res = algo.run(&g, &mut sim, &mut rng, &RunOptions::default());
        let ok = res.labels == want;
        t.row(vec![
            name.clone(),
            res.phases.to_string(),
            res.metrics.num_rounds().to_string(),
            ok.to_string(),
        ]);
        rows.push(
            Json::obj()
                .set("schedule", name.as_str())
                .set("phases", u64::from(res.phases))
                .set("correct", ok),
        );
    }
    (t.render(), Json::obj().set("exp", "mtl").set("rows", rows))
}

/// Machine-count scaling: model-level quantities (max per-machine load)
/// must scale ~1/p while totals stay constant — the MPC(0) balance claim.
pub fn machines(seed: u64) -> (String, Json) {
    let g = generators::gnp(50_000, 8.0 / 50_000.0, &mut Rng::new(seed));
    let mut t = AsciiTable::new(&["machines", "total MB", "max machine MB (round 1)", "balance (fair=1.0)"]);
    let mut rows = Vec::new();
    for p in [1usize, 4, 16, 64, 256] {
        let algo = cc::by_name("lc");
        let mut sim = Simulator::new(MpcConfig {
            machines: p,
            space_per_machine: None,
            spill_budget: None,
            threads: 4,
        });
        let mut rng = Rng::new(seed);
        let res = algo.run(&g, &mut sim, &mut rng, &RunOptions::default());
        let r0 = &res.metrics.rounds[0];
        let fair = r0.bytes as f64 / p as f64;
        let balance = r0.max_machine_bytes as f64 / fair;
        t.row(vec![
            p.to_string(),
            format!("{:.2}", res.metrics.total_bytes() as f64 / 1e6),
            format!("{:.3}", r0.max_machine_bytes as f64 / 1e6),
            format!("{balance:.2}"),
        ]);
        rows.push(
            Json::obj()
                .set("machines", p)
                .set("total_bytes", res.metrics.total_bytes())
                .set("max_machine_bytes", r0.max_machine_bytes)
                .set("balance", balance),
        );
    }
    (
        t.render(),
        Json::obj().set("exp", "machines").set("rows", rows),
    )
}

/// Dense backend on/off on shard-sized graphs: the XLA artifact vs the
/// MPC shuffle path for the full run (identical labels, same accounting).
pub fn dense_backend(seed: u64) -> (String, Json) {
    let mut t = AsciiTable::new(&["n", "mpc-path ms", "xla-path ms", "xla calls", "same labels"]);
    let mut rows = Vec::new();
    let xla_available = crate::runtime::try_default_executor().is_ok();
    for n in [256usize, 512, 1024] {
        let g = generators::gnp(n, 8.0 / n as f64, &mut Rng::new(seed + n as u64));
        let run = |use_xla: bool| {
            let driver = Driver::new(RunConfig {
                algorithm: "lc".into(),
                seed,
                use_xla,
                verify: true,
                ..Default::default()
            });
            driver.run_median(&g, "dense", 3)
        };
        let mpc = run(false);
        let xla = run(xla_available);
        t.row(vec![
            n.to_string(),
            format!("{:.2}", mpc.wall_ms),
            if xla_available {
                format!("{:.2}", xla.wall_ms)
            } else {
                "n/a".into()
            },
            xla.xla_calls.to_string(),
            (mpc.num_components == xla.num_components
                && mpc.verified == Some(true)
                && xla.verified == Some(true))
            .to_string(),
        ]);
        rows.push(
            Json::obj()
                .set("n", n)
                .set("mpc_ms", mpc.wall_ms)
                .set("xla_ms", xla.wall_ms)
                .set("xla_calls", xla.xla_calls),
        );
    }
    (
        t.render(),
        Json::obj().set("exp", "dense").set("rows", rows),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mtl_schedule_all_correct() {
        // (smaller instance than the bench: correctness of every schedule)
        use cc::local_contraction::LocalContraction;
        use cc::merge_to_large::Schedule;
        use cc::CcAlgorithm;
        let g = generators::gnp_log_regime(1500, 2.0, &mut Rng::new(1));
        let want = oracle::components(&g);
        for c in [0.25, 1.0, 4.0] {
            let algo = LocalContraction {
                merge_to_large: Some(Schedule { c, floor: 2 }),
            };
            let mut sim = Simulator::new(MpcConfig::default());
            let mut rng = Rng::new(2);
            let res = algo.run(&g, &mut sim, &mut rng, &RunOptions::default());
            assert_eq!(res.labels, want, "c={c}");
        }
    }

    #[test]
    fn machines_balance_improves_with_p() {
        let (_, json) = machines(3);
        let rows = json.get("rows").unwrap().as_arr().unwrap();
        // total bytes identical across machine counts (model invariant)
        let totals: Vec<i64> = rows
            .iter()
            .map(|r| r.get("total_bytes").unwrap().as_i64().unwrap())
            .collect();
        assert!(totals.windows(2).all(|w| w[0] == w[1]), "{totals:?}");
        // per-machine max shrinks as p grows
        let maxes: Vec<i64> = rows
            .iter()
            .map(|r| r.get("max_machine_bytes").unwrap().as_i64().unwrap())
            .collect();
        assert!(maxes.windows(2).all(|w| w[1] <= w[0]), "{maxes:?}");
    }
}
