//! Regeneration of the paper's evaluation artifacts: Tables 1–3 and
//! Figure 1 (§6).  Each function returns both the rendered table and the
//! raw JSON so `lcc tableN --json` and the cargo benches share one code
//! path.

use crate::cc::PAPER_ALGORITHMS;
use crate::coordinator::{Driver, Report, RunConfig};
use crate::graph::generators::presets;
use crate::graph::{stats as gstats, Graph};
use crate::util::json::Json;
use crate::util::stats::AsciiTable;

/// Shared sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Analogue size per dataset (None = preset default).
    pub scale: Option<usize>,
    pub seed: u64,
    /// Runs per cell; the median is reported (§6 protocol).
    pub runs: usize,
    /// §6 finisher threshold as a fraction of the input edges.
    pub finisher_frac: f64,
    /// Hash-To-Min memory guard as a multiple of m (the "X" behavior).
    pub htm_state_factor: u64,
    pub use_xla: bool,
    pub machines: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            scale: None,
            seed: 42,
            runs: 3,
            finisher_frac: 0.01,
            htm_state_factor: 20,
            use_xla: false,
            machines: 16,
        }
    }
}

fn dataset(name: &str, cfg: &SweepConfig) -> Graph {
    presets::generate(name, cfg.scale, cfg.seed)
}

fn driver_for(algo: &str, g: &Graph, cfg: &SweepConfig) -> Driver {
    let m = g.num_edges();
    Driver::new(RunConfig {
        algorithm: algo.to_string(),
        seed: cfg.seed,
        machines: cfg.machines,
        finisher_threshold: ((m as f64 * cfg.finisher_frac) as usize).max(64),
        prune_isolated: true,
        max_phases: 100,
        state_cap: cfg.htm_state_factor * m.max(1) as u64,
        use_xla: cfg.use_xla,
        verify: true,
        ..Default::default()
    })
}

/// Table 1: the dataset inventory — paper numbers next to the analogue's
/// measured shape.
pub fn table1(cfg: &SweepConfig) -> (String, Json) {
    let mut t = AsciiTable::new(&[
        "dataset",
        "paper nodes",
        "paper edges",
        "paper largest CC",
        "analogue n",
        "analogue m",
        "largest CC",
        "avg deg",
    ]);
    let mut rows = Vec::new();
    for name in presets::ALL {
        let spec = presets::spec(name);
        let g = dataset(name, cfg);
        let comp = gstats::component_stats(&g);
        let deg = gstats::degree_stats(&g);
        t.row(vec![
            name.to_string(),
            human(spec.paper_nodes),
            human(spec.paper_edges),
            human(spec.paper_largest_cc),
            g.num_vertices().to_string(),
            g.num_edges().to_string(),
            comp.largest.to_string(),
            format!("{:.1}", deg.avg),
        ]);
        rows.push(
            Json::obj()
                .set("dataset", name)
                .set("paper_nodes", spec.paper_nodes)
                .set("paper_edges", spec.paper_edges)
                .set("paper_largest_cc", spec.paper_largest_cc)
                .set("n", g.num_vertices())
                .set("m", g.num_edges())
                .set("largest_cc", comp.largest)
                .set("components", comp.count)
                .set("avg_deg", deg.avg),
        );
    }
    (t.render(), Json::obj().set("table", 1i64).set("rows", rows))
}

/// One full Tables-2/3 sweep: every paper algorithm on every dataset.
/// Returns the per-cell median reports.
pub fn sweep(cfg: &SweepConfig) -> Vec<Report> {
    let mut out = Vec::new();
    for name in presets::ALL {
        let g = dataset(name, cfg);
        for algo in PAPER_ALGORITHMS {
            let driver = driver_for(algo, &g, cfg);
            let r = driver.run_median(&g, name, cfg.runs);
            eprintln!("[sweep] {}", r.summary());
            out.push(r);
        }
    }
    out
}

/// Table 2: numbers of phases used by each algorithm ("X" = did not finish,
/// matching the paper's out-of-memory / timeout entries).
pub fn table2(reports: &[Report]) -> (String, Json) {
    let mut t = AsciiTable::new(&[
        "dataset",
        "LocalContraction",
        "TreeContraction",
        "Cracker",
        "Two-Phase",
        "Hash-To-Min",
    ]);
    let mut rows = Vec::new();
    for name in presets::ALL {
        let mut cells = vec![name.to_string()];
        let mut row = Json::obj().set("dataset", name);
        for algo in PAPER_ALGORITHMS {
            let r = find(reports, name, algo);
            let cell = if r.completed {
                r.phases.to_string()
            } else {
                "X".to_string()
            };
            row = row.set(algo, cell.as_str());
            cells.push(cell);
        }
        t.row(cells);
        rows.push(row);
    }
    (t.render(), Json::obj().set("table", 2i64).set("rows", rows))
}

/// Table 3: relative running times, normalized to the fastest completed
/// algorithm per dataset (row-wise, as in the paper).
pub fn table3(reports: &[Report]) -> (String, Json) {
    let mut t = AsciiTable::new(&[
        "dataset",
        "LocalContraction",
        "TreeContraction",
        "Cracker",
        "Two-Phase",
        "Hash-To-Min",
    ]);
    let mut rows = Vec::new();
    for name in presets::ALL {
        let best = PAPER_ALGORITHMS
            .iter()
            .map(|a| find(reports, name, a))
            .filter(|r| r.completed)
            .map(|r| r.wall_ms)
            .fold(f64::INFINITY, f64::min);
        let mut cells = vec![name.to_string()];
        let mut row = Json::obj().set("dataset", name);
        for algo in PAPER_ALGORITHMS {
            let r = find(reports, name, algo);
            let cell = if r.completed {
                format!("{:.2}", r.wall_ms / best)
            } else {
                "X".to_string()
            };
            row = row.set(algo, cell.as_str());
            cells.push(cell);
        }
        t.row(cells);
        rows.push(row);
    }
    (t.render(), Json::obj().set("table", 3i64).set("rows", rows))
}

/// Figure 1: numbers of edges at the beginning of each phase for the
/// contracting algorithms on two datasets (the paper plots two and notes
/// the rest look similar).
pub fn figure1(cfg: &SweepConfig, datasets: &[&str]) -> (String, Json) {
    let algos = ["lc", "tc-dht", "cracker"];
    let mut out = String::new();
    let mut series = Vec::new();
    for name in datasets {
        let g = dataset(name, cfg);
        out.push_str(&format!(
            "--- {name} (n={}, m={}) ---\n",
            g.num_vertices(),
            g.num_edges()
        ));
        for algo in algos {
            let mut c = SweepConfig {
                finisher_frac: 0.0, // disable the finisher to see the full decay
                ..cfg.clone()
            };
            c.runs = 1;
            let mut dcfg = driver_for(algo, &g, &c);
            let _ = &mut dcfg;
            let driver = Driver::new(RunConfig {
                algorithm: algo.to_string(),
                seed: cfg.seed,
                machines: cfg.machines,
                finisher_threshold: 0,
                verify: false,
                ..Default::default()
            });
            let r = driver.run_named(&g, name);
            out.push_str(&format!(
                "{:<10} edges/phase: {:?}\n",
                algo, r.edges_per_phase
            ));
            // the paper's headline: each phase shrinks edges >= 10x
            let decays: Vec<String> = r
                .edges_per_phase
                .windows(2)
                .filter(|w| w[1] > 0)
                .map(|w| format!("{:.1}x", w[0] as f64 / w[1] as f64))
                .collect();
            out.push_str(&format!("{:<10} decay:       {:?}\n", algo, decays));
            series.push(
                Json::obj()
                    .set("dataset", *name)
                    .set("algorithm", algo)
                    .set("edges_per_phase", r.edges_per_phase.clone()),
            );
        }
    }
    (out, Json::obj().set("figure", 1i64).set("series", series))
}

fn find<'a>(reports: &'a [Report], dataset: &str, algo: &str) -> &'a Report {
    use crate::cc::CcAlgorithm;
    let want = crate::cc::by_name(algo).name();
    reports
        .iter()
        .find(|r| r.dataset == dataset && r.algorithm == want)
        .unwrap_or_else(|| panic!("missing report {dataset}/{algo}"))
}

fn human(x: f64) -> String {
    if x >= 1e12 {
        format!("{:.1}T", x / 1e12)
    } else if x >= 1e9 {
        format!("{:.1}B", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.0}M", x / 1e6)
    } else {
        format!("{x:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SweepConfig {
        SweepConfig {
            scale: Some(800),
            runs: 1,
            ..Default::default()
        }
    }

    #[test]
    fn table1_renders_all_presets() {
        let (text, json) = table1(&tiny());
        for name in presets::ALL {
            assert!(text.contains(name), "{text}");
        }
        assert_eq!(json.get("rows").unwrap().as_arr().unwrap().len(), 5);
    }

    #[test]
    fn sweep_and_tables_2_3() {
        let cfg = tiny();
        let reports = sweep(&cfg);
        assert_eq!(reports.len(), 25);
        assert!(
            reports.iter().all(|r| r.verified != Some(false)),
            "some run produced wrong labels"
        );
        let (t2, j2) = table2(&reports);
        let (t3, _) = table3(&reports);
        assert!(t2.contains("orkut"));
        assert!(t3.contains("webpages"));
        // every row has a 1.00 (the normalizer) in table 3
        for line in t3.lines().skip(2) {
            assert!(line.contains("1.00"), "{line}");
        }
        assert_eq!(j2.get("rows").unwrap().as_arr().unwrap().len(), 5);
    }

    #[test]
    fn figure1_shows_decay() {
        let (text, json) = figure1(&tiny(), &["orkut"]);
        assert!(text.contains("edges/phase"));
        assert!(!json.get("series").unwrap().as_arr().unwrap().is_empty());
    }
}
