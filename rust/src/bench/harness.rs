//! Micro-benchmark harness (criterion is not available offline; this
//! implements the same warmup + sampling protocol and reports
//! median / p95 / mean).

use crate::util::stats;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
    /// Optional work units per iteration (edges, messages...) for
    /// throughput reporting.
    pub units_per_iter: Option<f64>,
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples)
    }

    pub fn p95_s(&self) -> f64 {
        stats::percentile(&self.samples, 0.95)
    }

    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    /// Units per second at the median.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / self.median_s())
    }

    /// Machine-readable form (the row schema of `BENCH_PR1.json`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        crate::util::json::Json::obj()
            .set("name", self.name.as_str())
            .set("median_s", self.median_s())
            .set("p95_s", self.p95_s())
            .set("mean_s", self.mean_s())
            .set("samples", self.samples.len() as i64)
            .set(
                "throughput_units_per_s",
                self.throughput().map(Json::Num).unwrap_or(Json::Null),
            )
    }

    pub fn report_line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:8.2} Munit/s", t / 1e6),
            Some(t) => format!("  {:8.2} unit/s", t),
            None => String::new(),
        };
        format!(
            "{:<40} median {:>10.3} ms   p95 {:>10.3} ms   mean {:>10.3} ms{}",
            self.name,
            self.median_s() * 1e3,
            self.p95_s() * 1e3,
            self.mean_s() * 1e3,
            tp
        )
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    /// Skip warmup+extra samples for slow cases (>this many seconds/iter).
    pub slow_cutoff_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 2,
            sample_iters: 7,
            slow_cutoff_s: 2.0,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup_iters: 1,
            sample_iters: 3,
            slow_cutoff_s: 1.0,
        }
    }

    /// Measure `f`, which performs one full iteration per call.
    pub fn run<F: FnMut()>(&self, name: &str, units_per_iter: Option<f64>, mut f: F) -> Measurement {
        // calibration / warmup
        let t0 = std::time::Instant::now();
        f();
        let first = t0.elapsed().as_secs_f64();
        let (warmup, samples_n) = if first > self.slow_cutoff_s {
            (0, 1) // slow case: the calibration run is the sample
        } else {
            (self.warmup_iters, self.sample_iters)
        };
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(samples_n);
        if first > self.slow_cutoff_s {
            samples.push(first);
        } else {
            for _ in 0..samples_n {
                let t = std::time::Instant::now();
                f();
                samples.push(t.elapsed().as_secs_f64());
            }
        }
        Measurement {
            name: name.to_string(),
            samples,
            units_per_iter,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let b = Bench {
            warmup_iters: 1,
            sample_iters: 5,
            slow_cutoff_s: 10.0,
        };
        let mut count = 0;
        let m = b.run("spin", Some(1000.0), || {
            count += 1;
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(count, 1 + 1 + 5); // calibration + warmup + samples
        assert_eq!(m.samples.len(), 5);
        assert!(m.median_s() >= 0.0);
        assert!(m.throughput().unwrap() > 0.0);
        assert!(m.report_line().contains("spin"));
    }

    #[test]
    fn slow_case_single_sample() {
        let b = Bench {
            warmup_iters: 3,
            sample_iters: 9,
            slow_cutoff_s: 0.0, // everything is "slow"
        };
        let mut count = 0;
        let m = b.run("slow", None, || count += 1);
        assert_eq!(count, 1);
        assert_eq!(m.samples.len(), 1);
    }
}
