//! Benchmark + experiment harness: regenerates every table and figure of
//! the paper's evaluation (§6) and the theory-validation experiments
//! (§4, §5, §7), plus the §Perf micro-benchmarks.
//!
//! Shared by the `lcc` CLI subcommands and the `cargo bench` targets in
//! `rust/benches/` (one per paper artifact).

pub mod ablations;
pub mod harness;
pub mod perf;
pub mod tables;
pub mod theory;

pub use harness::{Bench, Measurement};
