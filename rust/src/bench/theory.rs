//! Theory-validation experiments: the paper's lemmas and theorems checked
//! empirically (experiment ids L41, L45, T55, T71/T72, C1 in DESIGN.md §3).

use crate::cc::common::Priorities;
use crate::cc;
use crate::coordinator::{Driver, RunConfig};
use crate::graph::generators;
use crate::mpc::{MpcConfig, Simulator};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::AsciiTable;

fn phases_of(algo: &str, g: &crate::graph::Graph, seed: u64) -> (u32, bool) {
    let driver = Driver::new(RunConfig {
        algorithm: algo.into(),
        seed,
        finisher_threshold: 0, // measure the raw phase count
        max_phases: 500,
        ..Default::default()
    });
    let r = driver.run(g);
    (r.phases, r.completed)
}

/// L41 — Lemma 4.1: each LocalContraction phase leaves at most ~3n/4
/// distinct labels in expectation.  Reports the per-phase node-shrink
/// ratios over several graph families.
pub fn decay(seed: u64) -> (String, Json) {
    let mut t = AsciiTable::new(&["graph", "n", "phase ratios (n_{i+1}/n_i)", "max ratio"]);
    let mut rows = Vec::new();
    let cases: Vec<(&str, crate::graph::Graph)> = vec![
        ("gnp(5000, 3/n)", generators::gnp(5000, 3.0 / 5000.0, &mut Rng::new(seed))),
        ("gnp(5000, 20/n)", generators::gnp(5000, 20.0 / 5000.0, &mut Rng::new(seed + 1))),
        ("path(5000)", generators::path(5000)),
        ("star(5000)", generators::star(5000)),
        ("grid(70x70)", generators::grid(70, 70)),
    ];
    for (name, g) in cases {
        let driver = Driver::new(RunConfig {
            algorithm: "lc".into(),
            seed,
            finisher_threshold: 0,
            prune_isolated: false, // pure Lemma 4.1 setting
            ..Default::default()
        });
        let r = driver.run(&g);
        let ratios: Vec<f64> = r
            .nodes_per_phase
            .windows(2)
            .map(|w| w[1] as f64 / w[0] as f64)
            .collect();
        let max_ratio = ratios.iter().copied().fold(0.0, f64::max);
        t.row(vec![
            name.to_string(),
            g.num_vertices().to_string(),
            ratios
                .iter()
                .map(|r| format!("{r:.2}"))
                .collect::<Vec<_>>()
                .join(" "),
            format!("{max_ratio:.2}"),
        ]);
        rows.push(
            Json::obj()
                .set("graph", name)
                .set("nodes_per_phase", r.nodes_per_phase.clone())
                .set("max_ratio", max_ratio),
        );
    }
    (t.render(), Json::obj().set("exp", "decay").set("rows", rows))
}

/// L45 — Lemma 4.5: `max_v d(v) = O(log n)` for the `f_rho` pointer
/// forest on random graphs.  Sweeps n and reports max depth / log2(n).
pub fn depth(seed: u64) -> (String, Json) {
    let mut t = AsciiTable::new(&["n", "max d(v)", "log2 n", "ratio"]);
    let mut rows = Vec::new();
    for exp in [10u32, 12, 14, 16] {
        let n = 1usize << exp;
        let g = crate::graph::ShardedGraph::from_graph(
            &generators::gnp_log_regime(n, 2.0, &mut Rng::new(seed + exp as u64)),
            MpcConfig::default().machines,
        );
        let mut rng = Rng::new(seed);
        let rho = Priorities::sample(n, &mut rng);
        let mut sim = Simulator::new(MpcConfig::default());
        let f = cc::tree_contraction::build_pointers(&g, &rho, &mut sim);
        let d = cc::tree_contraction::max_chain_depth(&f);
        let ratio = d as f64 / exp as f64;
        t.row(vec![
            n.to_string(),
            d.to_string(),
            exp.to_string(),
            format!("{ratio:.2}"),
        ]);
        rows.push(Json::obj().set("n", n).set("max_depth", u64::from(d)));
    }
    (t.render(), Json::obj().set("exp", "depth").set("rows", rows))
}

/// T55 — Theorem 5.5: LocalContraction+MergeToLarge finishes in
/// `O(log log n)` phases on `G(n, c·ln n / n)`; plain LocalContraction is
/// the comparison series.
pub fn loglog(seed: u64) -> (String, Json) {
    let mut t = AsciiTable::new(&["n", "log2 n", "loglog2 n", "lc phases", "lc-mtl phases"]);
    let mut rows = Vec::new();
    for exp in [10u32, 12, 14, 16, 18] {
        let n = 1usize << exp;
        let g = generators::gnp_log_regime(n, 2.0, &mut Rng::new(seed + exp as u64));
        let (lc, _) = phases_of("lc", &g, seed);
        let (mtl, _) = phases_of("lc-mtl", &g, seed);
        t.row(vec![
            n.to_string(),
            exp.to_string(),
            format!("{:.1}", (exp as f64).log2()),
            lc.to_string(),
            mtl.to_string(),
        ]);
        rows.push(
            Json::obj()
                .set("n", n)
                .set("lc_phases", u64::from(lc))
                .set("lc_mtl_phases", u64::from(mtl)),
        );
    }
    (
        t.render(),
        Json::obj().set("exp", "loglog").set("rows", rows),
    )
}

/// T71/T72 — Theorems 7.1/7.2: Ω(log n) phases on paths for
/// LocalContraction, Cracker, Hash-To-Min and TreeContraction.
pub fn path_lower_bound(seed: u64) -> (String, Json) {
    let algos = ["lc", "cracker", "htm", "tc-dht", "hash-min"];
    let mut t = AsciiTable::new(&["n", "lc", "cracker", "htm", "tc-dht", "hash-min"]);
    let mut rows = Vec::new();
    for exp in [8u32, 10, 12, 14] {
        let n = 1usize << exp;
        let g = generators::path(n);
        let mut cells = vec![n.to_string()];
        let mut row = Json::obj().set("n", n);
        for algo in algos {
            // Θ(n)-round / Θ(n·2^round)-state baselines are capped to keep
            // the sweep interactive (the paper's own "X" entries).
            if (algo == "hash-min" && exp > 10) || (algo == "htm" && exp > 11) {
                row = row.set(algo, "skipped");
                cells.push("(skipped)".into());
                continue;
            }
            let (p, done) = phases_of(algo, &g, seed);
            let cell = if done { p.to_string() } else { format!("[{p}+]") };
            row = row.set(algo, cell.as_str());
            cells.push(cell);
        }
        t.row(cells);
        rows.push(row);
    }
    (t.render(), Json::obj().set("exp", "path").set("rows", rows))
}

/// C1 — §1.1 claim: per-round communication stays O(m).  Reports the max
/// round bytes / m over the preset datasets for LocalContraction.
pub fn comm(seed: u64, scale: Option<usize>) -> (String, Json) {
    let mut t = AsciiTable::new(&["dataset", "m", "max round bytes", "bytes per edge", "total/m"]);
    let mut rows = Vec::new();
    for name in crate::graph::generators::presets::ALL {
        let g = crate::graph::generators::presets::generate(name, scale.or(Some(20_000)), seed);
        let driver = Driver::new(RunConfig {
            algorithm: "lc".into(),
            seed,
            finisher_threshold: 0,
            ..Default::default()
        });
        let r = driver.run_named(&g, name);
        let m = g.num_edges().max(1) as u64;
        let per_edge = r.max_round_bytes as f64 / m as f64;
        let total_ratio = r.total_shuffle_bytes as f64 / m as f64;
        t.row(vec![
            name.to_string(),
            m.to_string(),
            r.max_round_bytes.to_string(),
            format!("{per_edge:.1}"),
            format!("{total_ratio:.1}"),
        ]);
        rows.push(
            Json::obj()
                .set("dataset", name)
                .set("m", m)
                .set("max_round_bytes", r.max_round_bytes)
                .set("total_bytes", r.total_shuffle_bytes),
        );
    }
    (t.render(), Json::obj().set("exp", "comm").set("rows", rows))
}

/// YV17 — the one-cycle vs two-cycles hardness instance: both must be
/// labeled correctly and phase counts reported (the conjecture says no
/// algorithm in this family can beat Ω(log n) here).
pub fn cycles(seed: u64) -> (String, Json) {
    let mut t = AsciiTable::new(&["instance", "n", "lc phases", "components found"]);
    let mut rows = Vec::new();
    for (label, two) in [("one cycle 2n", false), ("two cycles n", true)] {
        let g = generators::one_or_two_cycles(1 << 12, two);
        let driver = Driver::new(RunConfig {
            algorithm: "lc".into(),
            seed,
            verify: true,
            ..Default::default()
        });
        let r = driver.run_named(&g, label);
        assert_eq!(r.verified, Some(true));
        t.row(vec![
            label.to_string(),
            g.num_vertices().to_string(),
            r.phases.to_string(),
            r.num_components.to_string(),
        ]);
        rows.push(
            Json::obj()
                .set("instance", label)
                .set("phases", u64::from(r.phases))
                .set("components", r.num_components),
        );
    }
    (t.render(), Json::obj().set("exp", "cycles").set("rows", rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_ratios_below_three_quarters_on_random() {
        let (_, json) = decay(7);
        let rows = json.get("rows").unwrap().as_arr().unwrap();
        // the two G(n,p) rows must show expected shrink <= 0.75 on phase 1
        for row in &rows[..2] {
            let nodes = row.get("nodes_per_phase").unwrap().as_arr().unwrap();
            let r = nodes[1].as_f64().unwrap() / nodes[0].as_f64().unwrap();
            assert!(r <= 0.75, "shrink ratio {r}");
        }
    }

    #[test]
    fn depth_is_logarithmic() {
        let (_, json) = depth(3);
        for row in json.get("rows").unwrap().as_arr().unwrap() {
            let n = row.get("n").unwrap().as_f64().unwrap();
            let d = row.get("max_depth").unwrap().as_f64().unwrap();
            assert!(
                d <= 4.0 * n.log2() + 4.0,
                "depth {d} vs log2(n) {}",
                n.log2()
            );
        }
    }

    #[test]
    fn mtl_no_worse_than_plain_lc_on_random() {
        // small slice of T55 (full sweep runs in the bench)
        let g = generators::gnp_log_regime(1 << 12, 2.0, &mut Rng::new(5));
        let (lc, _) = phases_of("lc", &g, 1);
        let (mtl, _) = phases_of("lc-mtl", &g, 1);
        assert!(mtl <= lc + 1, "mtl {mtl} vs lc {lc}");
    }

    #[test]
    fn path_phases_grow_with_n() {
        let (p8, _) = phases_of("lc", &generators::path(1 << 8), 2);
        let (p12, _) = phases_of("lc", &generators::path(1 << 12), 2);
        assert!(p12 > p8, "p12 {p12} p8 {p8}");
    }

    #[test]
    fn cycles_distinguished_correctly() {
        let (_, json) = cycles(4);
        let rows = json.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("components").unwrap().as_i64(), Some(1));
        assert_eq!(rows[1].get("components").unwrap().as_i64(), Some(2));
    }
}
