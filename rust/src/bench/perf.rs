//! Performance micro-benchmarks for the §Perf pass: hot-path primitives of
//! each layer, measured with the in-repo harness (see EXPERIMENTS.md §Perf
//! for the iteration log).

use super::harness::{Bench, Measurement};
use crate::cc::backend::{CpuBackend, DenseBackend};
use crate::cc::common::{min_hop, Priorities};
use crate::graph::{generators, ShardedGraph, SpillPolicy};
use crate::mpc::net::{ProcTransport, ShuffleTransport};
use crate::mpc::{MpcConfig, Simulator, TransportMode};
use crate::util::rng::Rng;

/// L3 primitive on the multi-process transport: one min-hop round whose
/// messages genuinely cross process boundaries (spawned workers fold
/// them).  Only runs under `lcc perf --transport proc` — the worker
/// binary is this executable.  Measures the per-round wire overhead
/// against the in-process `L3/min_hop` rows.
pub fn bench_proc_min_hop(
    b: &Bench,
    n: usize,
    avg_deg: f64,
    machines: usize,
) -> Option<Measurement> {
    let flat = generators::gnp(n, avg_deg / n as f64, &mut Rng::new(1));
    let g = ShardedGraph::from_graph(&flat, machines);
    let vals: Vec<u32> = (0..n as u32).collect();
    let m = g.num_edges() as f64;
    let bin = std::env::current_exe().ok()?;
    let mut transport = match ProcTransport::spawn(machines, &bin) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("[perf] proc transport unavailable: {e}");
            return None;
        }
    };
    if let Err(e) = transport.load_graph(&g) {
        eprintln!("[perf] proc shard distribution failed: {e}");
        return None;
    }
    let mut sim = Simulator::with_transport(
        MpcConfig {
            machines,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        },
        Box::new(transport),
    );
    Some(b.run(
        &format!("L3/proc_min_hop n={n} m={} machines={machines}", g.num_edges()),
        Some(m),
        || {
            let out = min_hop(&mut sim, "bench", &g, &vals, true);
            std::hint::black_box(out);
            sim.metrics.rounds.clear();
        },
    ))
}

/// L3 primitive on the shuffle transport: one min-hop round generated on
/// the workers and shuffled worker↔worker — the coordinator issues the
/// descriptor and validates O(machines) summaries.  Only runs under
/// `lcc perf --transport shuffle` (the worker binary is this
/// executable).  Side-by-side with `L3/proc_min_hop` it measures what
/// moving the data plane off the coordinator buys per round.
pub fn bench_shuffle_min_hop(
    b: &Bench,
    n: usize,
    avg_deg: f64,
    machines: usize,
) -> Option<Measurement> {
    let flat = generators::gnp(n, avg_deg / n as f64, &mut Rng::new(1));
    let g = ShardedGraph::from_graph(&flat, machines);
    let vals: Vec<u32> = (0..n as u32).collect();
    let m = g.num_edges() as f64;
    let bin = std::env::current_exe().ok()?;
    let mut transport = match ShuffleTransport::spawn(machines, &bin) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("[perf] shuffle transport unavailable: {e}");
            return None;
        }
    };
    if let Err(e) = transport.load_graph(&g) {
        eprintln!("[perf] shuffle shard distribution failed: {e}");
        return None;
    }
    let mut sim = Simulator::with_transport(
        MpcConfig {
            machines,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        },
        Box::new(transport),
    );
    Some(b.run(
        &format!(
            "L3/shuffle_min_hop n={n} m={} machines={machines}",
            g.num_edges()
        ),
        Some(m),
        || {
            let out = min_hop(&mut sim, "bench", &g, &vals, true);
            std::hint::black_box(out);
            sim.metrics.rounds.clear();
            sim.metrics.timings.clear();
        },
    ))
}

/// One end-to-end LocalContraction run whose per-round
/// generate/shuffle/fold wall-clock breakdown (plus peak RSS) goes into
/// the perf artifact — the coordinator-vs-worker cost split the shuffle
/// transport exists to move.  Wire transports spawn real workers from
/// this executable; `None` when that fails (e.g. `cargo bench` harness).
pub fn round_breakdown(machines: usize, transport: TransportMode) -> Option<crate::util::json::Json> {
    use crate::util::json::Json;
    let flat = generators::gnp(20_000, 8.0 / 20_000.0, &mut Rng::new(11));
    let g = ShardedGraph::from_graph(&flat, machines);
    let mpc = MpcConfig {
        machines,
        space_per_machine: None,
        spill_budget: None,
        threads: 1,
    };
    let mut sim = match transport {
        TransportMode::InProc => Simulator::new(mpc),
        TransportMode::Proc => {
            let bin = std::env::current_exe().ok()?;
            let mut t = ProcTransport::spawn(machines, &bin).ok()?;
            t.load_graph(&g).ok()?;
            Simulator::with_transport(mpc, Box::new(t))
        }
        TransportMode::Shuffle => {
            let bin = std::env::current_exe().ok()?;
            let mut t = ShuffleTransport::spawn(machines, &bin).ok()?;
            t.load_graph(&g).ok()?;
            Simulator::with_transport(mpc, Box::new(t))
        }
    };
    let algo = crate::cc::by_name("lc");
    let mut rng = Rng::new(12);
    let res = algo.run_sharded(&g, &mut sim, &mut rng, &crate::cc::RunOptions::default());
    let rounds = Json::Arr(
        res.metrics
            .timings
            .iter()
            .map(|t| {
                Json::obj()
                    .set("label", t.label.as_str())
                    .set("gen_ms", t.gen_ms)
                    .set("shuffle_ms", t.shuffle_ms)
                    .set("fold_ms", t.fold_ms)
                    .set("allocs", t.allocs)
                    .set("shard_bytes_mapped", t.shard_bytes_mapped)
                    .set("shard_bytes_copied", t.shard_bytes_copied)
            })
            .collect(),
    );
    let doc = Json::obj()
        .set("algo", "lc")
        .set("n", 20_000usize)
        .set("m", g.num_edges())
        .set("machines", machines)
        .set("transport", transport.name())
        .set("rounds", rounds);
    // Mesh data-plane counters (shuffle transport only; null elsewhere —
    // same stable-schema convention as peak_rss_bytes).  These are what
    // the delta-sync and batch-pipelining work is measured by: sync vs
    // mesh bytes, delta adoption, batches vs hops.
    let doc = doc.set(
        "mesh",
        match sim.mesh_metrics() {
            Some(ms) => Json::obj()
                .set("hops", ms.hops)
                .set("hop_batches", ms.hop_batches)
                .set("state_syncs", ms.state_syncs)
                .set("delta_syncs", ms.delta_syncs)
                .set("sync_bytes", ms.sync_bytes)
                .set("mesh_bytes", ms.mesh_bytes)
                .set("rewires", ms.rewires)
                .set("custody_loads", ms.custody_loads)
                .set("worker_threads", ms.worker_threads),
            None => Json::Null,
        },
    );
    // Key always present, null when the platform can't report it
    // (/proc/self/status VmHWM is Linux-only) — consumers key on the
    // value, not the key's presence (see scripts/bench_compare.py).
    Some(doc.set(
        "peak_rss_bytes",
        match crate::util::stats::peak_rss_bytes() {
            Some(rss) => Json::from(rss),
            None => Json::Null,
        },
    ))
}

/// `lcc perf --thread-sweep`: the round breakdown re-run at worker
/// thread counts 1, 2, 4 and 8, one JSON row per count.  Each row sums
/// the per-round generate/shuffle/fold wall-clock so
/// `scripts/bench_compare.py` can gate "threads > 1 must not regress
/// generate or fold versus threads = 1" inside a single artifact — the
/// only comparison that is hardware-apples-to-apples.  The thread count
/// flows to the spawned fleet via `LCC_WORKER_THREADS` (restored
/// afterwards); rows whose fleet failed to spawn are skipped, and
/// `reported_threads` echoes what the workers' Hello frames actually
/// claimed (null off the shuffle transport, where the env is inert).
pub fn thread_sweep(machines: usize, transport: TransportMode) -> crate::util::json::Json {
    use crate::util::json::Json;
    let sum_ms = |doc: &Json, key: &str| -> f64 {
        doc.get("rounds")
            .and_then(|j| j.as_arr())
            .map(|rounds| {
                rounds
                    .iter()
                    .filter_map(|r| r.get(key).and_then(|j| j.as_f64()))
                    .sum()
            })
            .unwrap_or(0.0)
    };
    let saved = std::env::var("LCC_WORKER_THREADS").ok();
    let mut rows = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        std::env::set_var("LCC_WORKER_THREADS", threads.to_string());
        let Some(doc) = round_breakdown(machines, transport) else {
            eprintln!("[perf] thread sweep: fleet spawn failed at {threads} threads; row skipped");
            continue;
        };
        let reported = doc
            .get("mesh")
            .and_then(|m| m.get("worker_threads"))
            .and_then(|j| j.as_i64());
        let nrounds = doc
            .get("rounds")
            .and_then(|j| j.as_arr())
            .map(|a| a.len())
            .unwrap_or(0);
        rows.push(
            Json::obj()
                .set("worker_threads", threads)
                .set(
                    "reported_threads",
                    match reported {
                        Some(t) => Json::from(t),
                        None => Json::Null,
                    },
                )
                .set("rounds", nrounds)
                .set("gen_ms", sum_ms(&doc, "gen_ms"))
                .set("shuffle_ms", sum_ms(&doc, "shuffle_ms"))
                .set("fold_ms", sum_ms(&doc, "fold_ms")),
        );
    }
    match saved {
        Some(v) => std::env::set_var("LCC_WORKER_THREADS", v),
        None => std::env::remove_var("LCC_WORKER_THREADS"),
    }
    Json::Arr(rows)
}

/// L3 primitive: one min-hop MPC round over a sharded G(n,p) graph,
/// optionally under a residency budget (the out-of-core round path).
pub fn bench_min_hop(
    b: &Bench,
    n: usize,
    avg_deg: f64,
    threads: usize,
    machines: usize,
    spill_budget: Option<u64>,
) -> Measurement {
    let flat = generators::gnp(n, avg_deg / n as f64, &mut Rng::new(1));
    let g = ShardedGraph::from_graph_with(&flat, machines, SpillPolicy::with_budget(spill_budget));
    let vals: Vec<u32> = (0..n as u32).collect();
    let m = g.num_edges() as f64;
    let mut sim = Simulator::new(MpcConfig {
        machines,
        space_per_machine: None,
        spill_budget: None,
        threads,
    });
    b.run(
        &format!(
            "L3/min_hop n={n} m={} threads={threads} machines={machines}{}",
            g.num_edges(),
            if g.is_spilled() { " spilled" } else { "" },
        ),
        Some(m),
        || {
            let out = min_hop(&mut sim, "bench", &g, &vals, true);
            std::hint::black_box(out);
            sim.metrics.rounds.clear();
        },
    )
}

/// L3 primitive: a full LocalContraction phase (2 hops + contraction).
pub fn bench_lc_phase(
    b: &Bench,
    n: usize,
    avg_deg: f64,
    threads: usize,
    machines: usize,
    spill_budget: Option<u64>,
) -> Measurement {
    let flat = generators::gnp(n, avg_deg / n as f64, &mut Rng::new(2));
    let g = ShardedGraph::from_graph_with(&flat, machines, SpillPolicy::with_budget(spill_budget));
    let m = g.num_edges() as f64;
    let mut rng = Rng::new(3);
    let mut sim = Simulator::new(MpcConfig {
        machines,
        space_per_machine: None,
        spill_budget: None,
        threads,
    });
    b.run(
        &format!(
            "L3/lc_phase n={n} m={} threads={threads} machines={machines}{}",
            g.num_edges(),
            if g.is_spilled() { " spilled" } else { "" },
        ),
        Some(m),
        || {
            let rho = Priorities::sample(g.num_vertices(), &mut rng);
            let labels = crate::cc::local_contraction::phase_labels(&g, &mut sim, &rho, None);
            let out = crate::cc::common::contract_mpc(&mut sim, &g, &labels);
            std::hint::black_box(out);
            sim.metrics.rounds.clear();
        },
    )
}

/// Graph-layer primitive: shard a raw edge list (bucket + shard-local
/// normalize) — the sharded counterpart of `bench_normalize`.
pub fn bench_shard_ingest(b: &Bench, n: usize, avg_deg: f64, machines: usize) -> Measurement {
    let mut rng = Rng::new(12);
    let m_target = (n as f64 * avg_deg / 2.0) as usize;
    let raw: Vec<(u32, u32)> = (0..m_target)
        .map(|_| (rng.gen_range(n as u64) as u32, rng.gen_range(n as u64) as u32))
        .collect();
    let m = raw.len() as f64;
    b.run(
        &format!("L2/shard_ingest n={n} m={m_target} machines={machines}"),
        Some(m),
        || {
            let g = ShardedGraph::from_edges(n, machines, raw.clone());
            std::hint::black_box(g.num_edges());
        },
    )
}

/// End-to-end: full LocalContraction run, optionally under a residency
/// budget (the `--spill-budget` acceptance path: an edge set exceeding
/// the budget completes through disk-backed shards).
pub fn bench_lc_end_to_end(
    b: &Bench,
    n: usize,
    avg_deg: f64,
    machines: usize,
    spill_budget: Option<u64>,
) -> Measurement {
    let g = generators::gnp(n, avg_deg / n as f64, &mut Rng::new(4));
    let m = g.num_edges() as f64;
    let spilled = SpillPolicy::with_budget(spill_budget)
        .should_spill(g.num_edges() as u64 * crate::graph::spill::EDGE_BYTES);
    let driver = crate::coordinator::Driver::new(crate::coordinator::RunConfig {
        algorithm: "lc".into(),
        machines,
        spill_budget,
        ..Default::default()
    });
    b.run(
        &format!(
            "L3/lc_full n={n} m={} machines={machines}{}",
            g.num_edges(),
            if spilled { " spilled" } else { "" },
        ),
        Some(m),
        || {
            let r = driver.run(&g);
            std::hint::black_box(r);
        },
    )
}

/// Graph-layer primitive: the out-of-core rewrite loop — contract a
/// spilled graph (load → rewrite → spill per shard).  Only run when a
/// budget is configured.
pub fn bench_spill_contract(
    b: &Bench,
    n: usize,
    avg_deg: f64,
    machines: usize,
    budget: u64,
) -> Measurement {
    let flat = generators::gnp(n, avg_deg / n as f64, &mut Rng::new(13));
    let g = ShardedGraph::from_graph_with(&flat, machines, SpillPolicy::budget(budget));
    let labels: Vec<u32> = (0..n as u32).map(|v| v / 2).collect();
    let m = g.num_edges() as f64;
    b.run(
        &format!(
            "L2/spill_contract n={n} m={} machines={machines} budget={budget}",
            g.num_edges()
        ),
        Some(m),
        || {
            let (c, _) = g.contract(&labels);
            std::hint::black_box(c.num_edges());
        },
    )
}

/// Graph-layer primitive: `Graph::normalize` on a shuffled multigraph
/// edge list (the parallel radix-sort hot path; §Perf).
pub fn bench_normalize(b: &Bench, n: usize, avg_deg: f64) -> Measurement {
    let mut rng = Rng::new(10);
    let m_target = (n as f64 * avg_deg / 2.0) as usize;
    let raw: Vec<(u32, u32)> = (0..m_target)
        .map(|_| (rng.gen_range(n as u64) as u32, rng.gen_range(n as u64) as u32))
        .collect();
    let m = raw.len() as f64;
    b.run(&format!("L2/normalize n={n} m={m_target}"), Some(m), || {
        let g = crate::graph::Graph::from_edges(n, raw.clone());
        std::hint::black_box(g.num_edges());
    })
}

/// Streaming pipeline throughput (edges/s through shard-local contraction).
pub fn bench_pipeline(b: &Bench, n: usize, avg_deg: f64, workers: usize) -> Measurement {
    let g = generators::gnp(n, avg_deg / n as f64, &mut Rng::new(5));
    let m = g.num_edges() as f64;
    let cfg = crate::coordinator::PipelineConfig {
        num_workers: workers,
        ..Default::default()
    };
    b.run(
        &format!("L3/pipeline n={n} m={} workers={workers}", g.num_edges()),
        Some(m),
        || {
            let res = crate::coordinator::pipeline::run(n, g.edges().iter().copied(), &cfg);
            std::hint::black_box(res.stats.summary_edges);
        },
    )
}

/// Dense backend: CPU reference for the phase-label kernel on a shard.
pub fn bench_dense_cpu(b: &Bench, n: usize, avg_deg: f64) -> Measurement {
    let g = generators::gnp(n, avg_deg / n as f64, &mut Rng::new(6));
    let prio: Vec<i32> = Rng::new(7).permutation(n).iter().map(|&x| x as i32).collect();
    let backend = CpuBackend::default();
    b.run(
        &format!("L1/dense_cpu_ref n={n}"),
        Some((n * n) as f64),
        || {
            let out = backend.local_labels(&g, &prio).unwrap();
            std::hint::black_box(out);
        },
    )
}

/// Dense backend: the compiled XLA artifact (None when artifacts missing).
pub fn bench_dense_xla(b: &Bench, avg_deg: f64) -> Option<Measurement> {
    let exec = crate::runtime::try_default_executor().ok()?;
    let n = exec.shard_size();
    let g = generators::gnp(n, avg_deg / n as f64, &mut Rng::new(8));
    let prio: Vec<i32> = Rng::new(9).permutation(n).iter().map(|&x| x as i32).collect();
    Some(b.run(
        &format!("L1/dense_xla n={n} ({})", exec.platform()),
        Some((n * n) as f64),
        || {
            let out = exec.local_labels(&g, &prio).unwrap();
            std::hint::black_box(out);
        },
    ))
}

/// The whole standard suite (used by `lcc perf [--machines N]
/// [--spill-budget BYTES] [--transport proc]` and `cargo bench`).
/// `machines` is the shard count every sharded bench runs under;
/// `spill_budget` re-runs the sharded benches out-of-core (its rows are
/// tagged `spilled` when the input exceeds the budget) and adds the
/// spilled-contract primitive; `transport == Proc` adds the
/// multi-process round primitive (the in-process rows still run — the
/// point is the side-by-side).
pub fn standard_suite(
    quick: bool,
    machines: usize,
    spill_budget: Option<u64>,
    transport: TransportMode,
) -> Vec<Measurement> {
    let b = if quick { Bench::quick() } else { Bench::default() };
    let machines = machines.max(1);
    let mut out = vec![
        bench_min_hop(&b, 100_000, 8.0, 1, machines, spill_budget),
        bench_min_hop(&b, 100_000, 8.0, 8, machines, spill_budget),
        bench_lc_phase(&b, 100_000, 8.0, 1, machines, spill_budget),
        bench_lc_phase(&b, 100_000, 8.0, 8, machines, spill_budget),
        bench_normalize(&b, 100_000, 8.0),
        bench_shard_ingest(&b, 100_000, 8.0, machines),
        bench_lc_end_to_end(&b, 50_000, 8.0, machines, spill_budget),
        // pipeline rows have no simulator: `workers` IS their shard count
        bench_pipeline(&b, 200_000, 8.0, 1),
        bench_pipeline(&b, 200_000, 8.0, 4),
        bench_dense_cpu(&b, 1024, 16.0),
    ];
    if let Some(budget) = spill_budget {
        out.push(bench_spill_contract(&b, 100_000, 8.0, machines, budget));
    }
    if transport == TransportMode::Proc {
        // real processes: only meaningful from the lcc binary itself
        // (current_exe must speak `worker`), so `cargo bench` never asks
        if let Some(m) = bench_proc_min_hop(&b, 50_000, 8.0, machines) {
            out.push(m);
        }
    }
    if transport == TransportMode::Shuffle {
        // the worker-native round next to its coordinator-routed twin
        if let Some(m) = bench_proc_min_hop(&b, 50_000, 8.0, machines) {
            out.push(m);
        }
        if let Some(m) = bench_shuffle_min_hop(&b, 50_000, 8.0, machines) {
            out.push(m);
        }
    }
    if let Some(m) = bench_dense_xla(&b, 16.0) {
        out.push(m);
    } else {
        eprintln!("[perf] XLA artifacts not built; skipping L1/dense_xla");
    }
    out
}

/// The standard suite as one machine-readable document — the schema of
/// `BENCH_PR2.json` at the repo root (`lcc perf --quick --out FILE`), so
/// the perf trajectory is tracked as a checked-in artifact from PR 1 on.
/// `spill_budget` is recorded when set (the out-of-core protocol rows);
/// the transport mode is always recorded so proc-transport artifacts are
/// distinguishable in CI.
pub fn suite_json(
    measurements: &[Measurement],
    quick: bool,
    machines: usize,
    spill_budget: Option<u64>,
    transport: TransportMode,
    round_breakdown: Option<crate::util::json::Json>,
) -> crate::util::json::Json {
    use crate::util::json::Json;
    let doc = Json::obj()
        .set("suite", "lcc-perf-standard")
        .set("quick", quick)
        .set("machines", machines)
        .set("transport", transport.name());
    let doc = match spill_budget {
        Some(b) => doc.set("spill_budget", b),
        None => doc,
    };
    // null (not absent) when unavailable, so the schema is stable across
    // platforms and bench_compare.py can tell "no RSS" from "old file"
    let doc = doc.set(
        "peak_rss_bytes",
        match crate::util::stats::peak_rss_bytes() {
            Some(rss) => Json::from(rss),
            None => Json::Null,
        },
    );
    let doc = match round_breakdown {
        Some(b) => doc.set("round_breakdown", b),
        None => doc,
    };
    // Process-cumulative data-plane counters: how many shard-payload
    // bytes this run walked in place (mmap / borrowed frame) vs copied
    // through owned buffers.  CI's spilled run gates on these — a
    // regression that silently rehydrates shards flips `shard_copies`
    // nonzero and fails the job (see scripts/bench_compare.py and the
    // spill job in .github/workflows/tier1.yml).
    let dp = crate::graph::spill::data_plane_counters();
    let doc = doc.set(
        "data_plane",
        Json::obj()
            .set("shard_bytes_mapped", dp.shard_bytes_mapped)
            .set("shard_bytes_copied", dp.shard_bytes_copied)
            .set("shard_maps", dp.shard_maps)
            .set("shard_copies", dp.shard_copies)
            .set("allocs", crate::util::alloc::allocation_count()),
    );
    doc
        .set(
            "threads_available",
            crate::mpc::pool::default_threads(),
        )
        .set(
            "benches",
            Json::Arr(measurements.iter().map(|m| m.to_json()).collect()),
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn microbenches_run_quickly() {
        let b = Bench {
            warmup_iters: 0,
            sample_iters: 1,
            slow_cutoff_s: 30.0,
        };
        let m = bench_min_hop(&b, 2000, 4.0, 1, 16, None);
        assert!(m.median_s() > 0.0);
        let m = bench_min_hop(&b, 2000, 4.0, 2, 16, Some(0));
        assert!(m.median_s() > 0.0);
        let m = bench_spill_contract(&b, 2000, 4.0, 8, 64);
        assert!(m.median_s() > 0.0);
        let m = bench_dense_cpu(&b, 256, 8.0);
        assert!(m.throughput().unwrap() > 0.0);
        let m = bench_normalize(&b, 2000, 4.0);
        assert!(m.median_s() > 0.0);
        let m = bench_shard_ingest(&b, 2000, 4.0, 8);
        assert!(m.median_s() > 0.0);
    }

    #[test]
    fn suite_json_is_well_formed() {
        let b = Bench {
            warmup_iters: 0,
            sample_iters: 1,
            slow_cutoff_s: 30.0,
        };
        let ms = vec![bench_min_hop(&b, 500, 4.0, 2, 4, None)];
        let breakdown = round_breakdown(4, TransportMode::InProc);
        assert!(breakdown.is_some(), "inproc breakdown never needs workers");
        let doc = suite_json(&ms, true, 4, Some(1 << 20), TransportMode::InProc, breakdown);
        assert_eq!(
            doc.get("spill_budget").and_then(|j| j.as_i64()),
            Some(1 << 20)
        );
        assert_eq!(doc.get("suite").and_then(|j| j.as_str()), Some("lcc-perf-standard"));
        assert_eq!(doc.get("machines").and_then(|j| j.as_i64()), Some(4));
        assert_eq!(doc.get("transport").and_then(|j| j.as_str()), Some("inproc"));
        let benches = doc.get("benches").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(benches.len(), 1);
        assert!(benches[0].get("median_s").and_then(|j| j.as_f64()).unwrap() > 0.0);
        // the per-round time breakdown rides in the artifact
        let bd = doc.get("round_breakdown").expect("breakdown present");
        assert_eq!(bd.get("transport").and_then(|j| j.as_str()), Some("inproc"));
        let rounds = bd.get("rounds").and_then(|j| j.as_arr()).unwrap();
        assert!(!rounds.is_empty());
        assert!(rounds[0].get("gen_ms").and_then(|j| j.as_f64()).is_some());
        assert!(rounds[0].get("shuffle_ms").and_then(|j| j.as_f64()).is_some());
        assert!(rounds[0].get("fold_ms").and_then(|j| j.as_f64()).is_some());
        assert!(rounds[0].get("allocs").and_then(|j| j.as_i64()).is_some());
        // mesh counters key is always present; null off the shuffle transport
        assert!(
            matches!(bd.get("mesh"), Some(crate::util::json::Json::Null)),
            "inproc breakdown has null mesh counters"
        );
        // the zero-copy gate's counters ride in every artifact
        let dp = doc.get("data_plane").expect("data_plane present");
        for k in ["shard_bytes_mapped", "shard_bytes_copied", "shard_maps", "shard_copies", "allocs"] {
            assert!(dp.get(k).and_then(|j| j.as_i64()).is_some(), "missing data_plane.{k}");
        }
        // round-trips through the parser
        let text = doc.pretty();
        assert!(crate::util::json::parse(&text).is_ok());
    }

    #[test]
    fn thread_sweep_rows_are_well_formed() {
        // Inproc never spawns a fleet, so the sweep is pure schema here:
        // four rows, the env knob inert, reported_threads null.
        let before = std::env::var("LCC_WORKER_THREADS").ok();
        let sweep = thread_sweep(2, TransportMode::InProc);
        let rows = sweep.as_arr().expect("sweep is an array");
        assert_eq!(rows.len(), 4, "one row per thread count");
        let mut want = [1i64, 2, 4, 8].iter();
        for row in rows {
            assert_eq!(
                row.get("worker_threads").and_then(|j| j.as_i64()),
                Some(*want.next().unwrap())
            );
            assert!(matches!(
                row.get("reported_threads"),
                Some(crate::util::json::Json::Null)
            ));
            assert!(row.get("rounds").and_then(|j| j.as_i64()).unwrap() > 0);
            for k in ["gen_ms", "shuffle_ms", "fold_ms"] {
                assert!(row.get(k).and_then(|j| j.as_f64()).is_some(), "missing {k}");
            }
        }
        // the sweep restores the env it borrowed
        assert_eq!(std::env::var("LCC_WORKER_THREADS").ok(), before);
    }
}
