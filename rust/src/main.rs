//! `lcc` — the coordinator binary.
//!
//! Subcommands:
//!   run       run one algorithm on a generated or loaded graph
//!   serve     long-lived incremental connectivity daemon (newline-JSON TCP)
//!   worker    serve as one machine of the multi-process transport
//!   pipeline  stream a graph through the sharded local-contraction pipeline
//!   table1    regenerate Table 1 (dataset inventory)
//!   table2    regenerate Table 2 (phases per algorithm)
//!   table3    regenerate Table 3 (relative running times)
//!   figure1   regenerate Figure 1 (edges per phase)
//!   theory    run a theory-validation experiment (--exp decay|depth|loglog|path|comm|cycles)
//!   perf      run the §Perf micro-benchmark suite
//!   generate  write a dataset preset to a file
//!   runtime-check  smoke-test the compiled XLA artifacts

use lcc::bench::{ablations, perf, tables, theory};
use lcc::coordinator::{pipeline, worker, Driver, PipelineConfig, RunConfig};
use lcc::graph::{generators, io};
use lcc::mpc::TransportMode;
use lcc::util::cli::Args;
use lcc::util::json::Json;

fn main() {
    let args = Args::from_env();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "pipeline" => cmd_pipeline(&args),
        "table1" => cmd_table(&args, 1),
        "table2" | "table3" => cmd_table(&args, if cmd == "table2" { 2 } else { 3 }),
        "figure1" => cmd_figure1(&args),
        "theory" => cmd_theory(&args),
        "ablation" => cmd_ablation(&args),
        "perf" => cmd_perf(&args),
        "generate" => cmd_generate(&args),
        "runtime-check" => cmd_runtime_check(),
        _ => {
            eprintln!("{}", HELP);
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
    let unknown = args.unknown_flags();
    if !unknown.is_empty() {
        eprintln!("warning: unused flags: {unknown:?}");
    }
}

const HELP: &str = "lcc — Connected Components at Scale via Local Contractions

USAGE: lcc <run|serve|worker|pipeline|table1|table2|table3|figure1|theory|ablation|perf|generate|runtime-check> [flags]

Common flags:
  --algo lc|lc-mtl|tc|tc-dht|cracker|two-phase|htm|hash-min
  --graph <preset|path|cycle|star|grid|gnp|gnp-log|file:PATH>   --n <vertices>
  --seed N  --machines N (simulated machines = shard count; run/pipeline/perf)
  --threads N (simulation threads; run)
  --transport inproc|proc|shuffle (round transport; proc spawns one worker
                           process per machine on localhost; shuffle adds the
                           worker-to-worker data plane — workers generate and
                           shuffle the hop/rewire rounds peer to peer while the
                           coordinator issues descriptors; run/pipeline/perf)
  --spill-budget BYTES[K|M|G] (resident edge-memory budget; larger graphs
                        run with disk-backed shards; run/pipeline/perf)
  --worker-threads N (data-plane threads inside each spawned worker process;
                      bit-identical outputs at every value; env
                      LCC_WORKER_THREADS; default 1; run/serve/perf)
  --finisher N  --use-xla  --verify  --json
  --out FILE (perf: write the machine-readable suite JSON, BENCH_PR2.json schema)
  --thread-sweep (perf: rerun the shuffle round breakdown at worker-thread
                  counts 1,2,4,8 and emit one JSON row per count)
  --scale N (table/figure dataset size)  --runs N (median-of-N)
  --exp decay|depth|loglog|path|comm|cycles (theory)
  --exp finisher|pruning|mtl|machines|dense (ablation)

Fault tolerance (proc/shuffle transports; run/perf):
  --io-timeout SECS (socket I/O timeout; env LCC_IO_TIMEOUT_MS; default 120)
  --connect-retries N (worker mesh connect attempts, exponential backoff;
                       env LCC_CONNECT_RETRIES; default 10)
  --respawn-budget N (worker respawns per recovery; 0 = dead worker is
                      terminal; env LCC_RESPAWN_BUDGET; default 3)
  --checkpoint-dir DIR (persist per-generation run checkpoints here;
                        default: run-private temp dir when respawn is on)
  --keep-generations K (retain the last K gen-<id>/ checkpoint dirs;
                        env LCC_KEEP_GENERATIONS; default 1)
  --fault-plan PLAN (deterministic fault injection for the chaos suite,
                     e.g. \"kill:w2@round=3,delay:w1@round=5\"; env LCC_FAULT_PLAN)

Incremental service (lcc serve; all run flags above also apply):
  --port N (TCP port; 0 = ephemeral, announced as {\"event\":\"serving\",...}
            on stdout; newline-JSON ops: same-component, component-of,
            component-sizes, insert, flush, stats, shutdown)
  --recontract-threshold N (distinct core edges accumulated since the last
                            contraction that trigger a full pass; default 4096)
  --queue-capacity N (bounded ingest queue in messages — full queue blocks
                      inserting clients; default 4)

Worker mode (spawned by the proc transport; not for direct use):
  lcc worker --connect HOST:PORT";

/// Build the graph a command operates on.
fn load_graph(args: &Args) -> (lcc::graph::Graph, String) {
    let spec = args.str_or("graph", "gnp");
    let n = args.usize_or("n", 100_000);
    let seed = args.u64_or("seed", 42);
    let mut rng = lcc::util::rng::Rng::new(seed);
    let g = match spec.as_str() {
        "gnp" => {
            let avg = args.f64_or("avg-deg", 8.0);
            generators::gnp(n, avg / n as f64, &mut rng)
        }
        "gnp-log" => generators::gnp_log_regime(n, args.f64_or("c", 2.0), &mut rng),
        "path" => generators::path(n),
        "cycle" => generators::cycle(n),
        "star" => generators::star(n),
        "grid" => {
            let w = (n as f64).sqrt() as usize;
            generators::grid(w, w)
        }
        "orkut" | "friendster" | "clueweb" | "videos" | "webpages" => {
            generators::presets::generate(&spec, Some(n), seed)
        }
        other => {
            if let Some(path) = other.strip_prefix("file:") {
                if path.ends_with(".bin") {
                    io::read_binary(path).expect("read binary graph")
                } else {
                    io::read_snap_text(path).expect("read SNAP graph")
                }
            } else {
                panic!("unknown --graph {other:?}");
            }
        }
    };
    (g, spec)
}

/// `--spill-budget BYTES[K|M|G]` (None = unbounded residency), validated
/// at the flag with a clear error.
fn spill_budget(args: &Args) -> Option<u64> {
    args.byte_size_opt("spill-budget")
}

/// `--transport inproc|proc|shuffle`.
fn transport(args: &Args) -> TransportMode {
    TransportMode::parse(&args.str_or("transport", "inproc"))
}

/// `--fault-plan "kill:w2@round=3,..."`, validated at the flag so a typo
/// fails before any worker is spawned.
fn fault_plan(args: &Args) -> Option<String> {
    args.str_opt("fault-plan").map(|s| {
        lcc::mpc::net::FaultPlan::parse(s).unwrap_or_else(|e| panic!("--fault-plan: {e}"));
        s.to_string()
    })
}

fn cmd_run(args: &Args) {
    let (g, name) = load_graph(args);
    let cfg = RunConfig {
        algorithm: args.str_or("algo", "lc"),
        seed: args.u64_or("seed", 42),
        machines: args.nonzero_usize_or("machines", 16),
        threads: args.nonzero_usize_or("threads", lcc::mpc::pool::default_threads().max(1)),
        finisher_threshold: args.usize_or("finisher", 0),
        prune_isolated: args.bool_or("prune-isolated", true),
        max_phases: args.u64_or("max-phases", 200) as u32,
        state_cap: args.u64_or("state-cap", 0),
        use_xla: args.bool_or("use-xla", false),
        spill_budget: spill_budget(args),
        transport: transport(args),
        verify: args.bool_or("verify", true),
        io_timeout_secs: args.nonzero_u64_opt("io-timeout"),
        connect_retries: args.nonzero_usize_opt("connect-retries"),
        fault_plan: fault_plan(args),
        respawn_budget: args.usize_opt("respawn-budget"),
        checkpoint_dir: args.str_opt("checkpoint-dir").map(std::path::PathBuf::from),
        keep_generations: args.nonzero_usize_opt("keep-generations"),
        worker_threads: args.nonzero_usize_opt("worker-threads"),
        ..Default::default()
    };
    let driver = Driver::new(cfg);
    let report = match driver.try_run_named(&g, &name) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("transport error: {e}");
            std::process::exit(3);
        }
    };
    if args.bool_or("json", false) {
        println!("{}", report.to_json().pretty());
    } else {
        println!("{}", report.summary());
        println!("edges per phase: {:?}", report.edges_per_phase);
    }
    if report.verified == Some(false) {
        std::process::exit(1);
    }
}

fn cmd_serve(args: &Args) {
    let (g, name) = load_graph(args);
    let cfg = RunConfig {
        algorithm: args.str_or("algo", "lc"),
        seed: args.u64_or("seed", 42),
        machines: args.nonzero_usize_or("machines", 16),
        threads: args.nonzero_usize_or("threads", lcc::mpc::pool::default_threads().max(1)),
        finisher_threshold: args.usize_or("finisher", 0),
        prune_isolated: args.bool_or("prune-isolated", true),
        max_phases: args.u64_or("max-phases", 200) as u32,
        state_cap: args.u64_or("state-cap", 0),
        use_xla: args.bool_or("use-xla", false),
        spill_budget: spill_budget(args),
        transport: transport(args),
        // queries must answer out of the published snapshot, not wait on
        // an oracle pass per recontraction; the smoke tests verify
        // against the oracle externally
        verify: false,
        io_timeout_secs: args.nonzero_u64_opt("io-timeout"),
        connect_retries: args.nonzero_usize_opt("connect-retries"),
        fault_plan: fault_plan(args),
        respawn_budget: args.usize_opt("respawn-budget"),
        checkpoint_dir: args.str_opt("checkpoint-dir").map(std::path::PathBuf::from),
        keep_generations: args.nonzero_usize_opt("keep-generations"),
        worker_threads: args.nonzero_usize_opt("worker-threads"),
        ..Default::default()
    };
    let serve_cfg = lcc::serve::ServeConfig {
        port: args.u64_or("port", 0) as u16,
        queue_capacity: args.nonzero_usize_or("queue-capacity", 4),
        recontract_threshold: args.nonzero_usize_or("recontract-threshold", 4096),
    };
    // serve blocks for the daemon lifetime — main's post-dispatch
    // unknown-flag check would never print
    args.warn_unknown("serve");
    if let Err(e) = lcc::serve::serve(Driver::new(cfg), &g, &name, &serve_cfg) {
        eprintln!("serve: transport error: {e}");
        std::process::exit(3);
    }
}

fn cmd_worker(args: &Args) {
    let connect = args
        .str_opt("connect")
        .unwrap_or_else(|| panic!("worker: --connect HOST:PORT is required"))
        .to_string();
    if let Err(e) = worker::run_worker(&connect) {
        eprintln!("worker: {e}");
        std::process::exit(1);
    }
}

fn cmd_pipeline(args: &Args) {
    let (g, name) = load_graph(args);
    let cfg = PipelineConfig {
        num_workers: args.nonzero_usize_or("workers", 4),
        chunk_size: args.nonzero_usize_or("chunk", 64 * 1024),
        channel_capacity: args.nonzero_usize_or("capacity", 4),
        spill_budget: spill_budget(args),
    };
    let t0 = std::time::Instant::now();
    let res = pipeline::run(g.num_vertices(), g.edges().iter().copied(), &cfg);

    // Global merge: the paper's LocalContraction on the summary graph —
    // consumed in sharded form straight from the workers (re-partitioned
    // shard-to-shard onto `--machines` simulator shards), with the XLA
    // dense backend when requested.
    let driver = Driver::new(RunConfig {
        algorithm: args.str_or("algo", "lc"),
        machines: args.nonzero_usize_or("machines", 16),
        use_xla: args.bool_or("use-xla", true),
        spill_budget: spill_budget(args),
        transport: transport(args),
        verify: false,
        ..Default::default()
    });
    let merge_report = match driver.try_run_named_sharded(&res.summary, "summary") {
        Ok(r) => r,
        Err(e) => {
            eprintln!("transport error: {e}");
            std::process::exit(3);
        }
    };
    let wall = t0.elapsed().as_secs_f64() * 1e3;

    let labels = pipeline::merge_summary(&res.summary);
    let ok = lcc::cc::oracle::verify(&g, &labels).is_ok();

    println!(
        "pipeline on {name}: n={} m={}",
        g.num_vertices(),
        g.num_edges()
    );
    println!(
        "  streamed {} edges in {} chunks ({} backpressure stalls)",
        res.stats.edges_streamed, res.stats.chunks, res.stats.backpressure_stalls
    );
    println!(
        "  summary graph: {} edges ({:.1}x reduction)",
        res.stats.summary_edges,
        res.stats.edges_streamed as f64 / res.stats.summary_edges.max(1) as f64
    );
    println!("  merge: {}", merge_report.summary());
    println!("  end-to-end {wall:.1} ms, oracle-verified: {ok}");
    if !ok {
        std::process::exit(1);
    }
}

fn sweep_config(args: &Args) -> tables::SweepConfig {
    tables::SweepConfig {
        scale: args.str_opt("scale").map(|s| s.parse().expect("--scale")),
        seed: args.u64_or("seed", 42),
        runs: args.usize_or("runs", 3),
        finisher_frac: args.f64_or("finisher-frac", 0.01),
        htm_state_factor: args.u64_or("htm-state-factor", 20),
        use_xla: args.bool_or("use-xla", false),
        machines: args.nonzero_usize_or("machines", 16),
    }
}

fn cmd_table(args: &Args, which: u32) {
    let cfg = sweep_config(args);
    let (text, json) = match which {
        1 => tables::table1(&cfg),
        _ => {
            let reports = tables::sweep(&cfg);
            if which == 2 {
                tables::table2(&reports)
            } else {
                tables::table3(&reports)
            }
        }
    };
    emit(args, &text, json);
}

fn cmd_figure1(args: &Args) {
    let cfg = sweep_config(args);
    let datasets = args.str_or("datasets", "clueweb,webpages");
    let names: Vec<&str> = datasets.split(',').collect();
    let (text, json) = tables::figure1(&cfg, &names);
    emit(args, &text, json);
}

fn cmd_theory(args: &Args) {
    let seed = args.u64_or("seed", 42);
    let exp = args.str_or("exp", "loglog");
    let (text, json) = match exp.as_str() {
        "decay" => theory::decay(seed),
        "depth" => theory::depth(seed),
        "loglog" => theory::loglog(seed),
        "path" => theory::path_lower_bound(seed),
        "comm" => theory::comm(seed, args.str_opt("scale").map(|s| s.parse().unwrap())),
        "cycles" => theory::cycles(seed),
        other => panic!("unknown --exp {other:?}"),
    };
    emit(args, &text, json);
}

fn cmd_ablation(args: &Args) {
    let seed = args.u64_or("seed", 42);
    let exp = args.str_or("exp", "finisher");
    let (text, json) = match exp.as_str() {
        "finisher" => ablations::finisher(seed),
        "pruning" => ablations::pruning(seed),
        "mtl" => ablations::mtl_schedule(seed),
        "machines" => ablations::machines(seed),
        "dense" => ablations::dense_backend(seed),
        other => panic!("unknown --exp {other:?} (finisher|pruning|mtl|machines|dense)"),
    };
    emit(args, &text, json);
}

fn cmd_perf(args: &Args) {
    let quick = args.bool_or("quick", false);
    let machines = args.nonzero_usize_or("machines", 16);
    let budget = spill_budget(args);
    let mode = transport(args);
    // Fault-tolerance knobs ride through the environment: the perf suite's
    // signature stays unchanged and every transport it builds (plus the
    // workers those spawn) inherits them via NetConfig::from_env.
    if let Some(secs) = args.nonzero_u64_opt("io-timeout") {
        std::env::set_var("LCC_IO_TIMEOUT_MS", (secs * 1000).to_string());
    }
    if let Some(n) = args.nonzero_usize_opt("connect-retries") {
        std::env::set_var("LCC_CONNECT_RETRIES", n.to_string());
    }
    if let Some(n) = args.usize_opt("respawn-budget") {
        std::env::set_var("LCC_RESPAWN_BUDGET", n.to_string());
    }
    if let Some(plan) = fault_plan(args) {
        std::env::set_var("LCC_FAULT_PLAN", plan);
    }
    if let Some(dir) = args.str_opt("checkpoint-dir") {
        std::env::set_var("LCC_CHECKPOINT_DIR", dir);
    }
    if let Some(k) = args.nonzero_usize_opt("keep-generations") {
        std::env::set_var("LCC_KEEP_GENERATIONS", k.to_string());
    }
    if let Some(t) = args.nonzero_usize_opt("worker-threads") {
        std::env::set_var("LCC_WORKER_THREADS", t.to_string());
    }
    let measurements = perf::standard_suite(quick, machines, budget, mode);
    for m in &measurements {
        println!("{}", m.report_line());
    }
    let want_json = args.bool_or("json", false);
    let out_path = args.str_opt("out").map(String::from);
    if want_json || out_path.is_some() {
        let breakdown = perf::round_breakdown(machines, mode);
        let mut doc = perf::suite_json(&measurements, quick, machines, budget, mode, breakdown);
        if args.bool_or("thread-sweep", false) {
            doc = doc.set("thread_sweep", perf::thread_sweep(machines, mode));
        }
        let text = doc.pretty();
        if let Some(path) = &out_path {
            std::fs::write(path, &text)
                .unwrap_or_else(|e| panic!("cannot write --out {path}: {e}"));
            eprintln!("[perf] wrote {path}");
        }
        if want_json {
            println!("{text}");
        }
    }
}

fn cmd_generate(args: &Args) {
    let (g, name) = load_graph(args);
    let out = args.str_or("out", &format!("{name}.bin"));
    if out.ends_with(".bin") {
        io::write_binary(&g, &out).expect("write binary");
    } else {
        io::write_snap_text(&g, &out).expect("write text");
    }
    println!("wrote {out}: n={} m={}", g.num_vertices(), g.num_edges());
}

fn cmd_runtime_check() {
    match lcc::runtime::try_default_executor() {
        Err(e) => {
            eprintln!("artifacts NOT usable: {e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
        Ok(exec) => {
            use lcc::cc::backend::{CpuBackend, DenseBackend};
            let n = 200;
            let g = generators::gnp(n, 0.03, &mut lcc::util::rng::Rng::new(1));
            let prio: Vec<i32> = lcc::util::rng::Rng::new(2)
                .permutation(n)
                .iter()
                .map(|&x| x as i32)
                .collect();
            let xla = exec.local_labels(&g, &prio).expect("xla local_labels");
            let cpu = CpuBackend::default().local_labels(&g, &prio).unwrap();
            assert_eq!(xla, cpu, "XLA vs CPU mismatch");
            println!(
                "runtime OK: platform={} shard={} — local_labels matches CPU reference on {n} vertices",
                exec.platform(),
                exec.shard_size(),
            );
        }
    }
}

fn emit(args: &Args, text: &str, json: Json) {
    if args.bool_or("json", false) {
        println!("{}", json.pretty());
    } else {
        println!("{text}");
    }
    if let Some(path) = args.str_opt("out") {
        std::fs::write(path, json.pretty()).expect("write --out");
    }
}
