//! Sequential oracle + single-machine streaming finisher (§6).

use crate::graph::{Graph, Vertex};
use crate::util::dsu::DisjointSet;

/// Exact canonical component labels by streaming union-find:
/// `labels[v]` = minimum vertex id in `v`'s component.
///
/// This is both the correctness oracle for every distributed algorithm and
/// the paper's single-machine finisher ("it can process incoming edges in a
/// streaming fashion and only use space proportional to the number of
/// vertices").
pub fn components(g: &Graph) -> Vec<Vertex> {
    let mut dsu = DisjointSet::new(g.num_vertices());
    for &(u, v) in g.edges() {
        dsu.union(u, v);
    }
    dsu.canonical_labels()
}

/// Streaming variant: consumes an edge iterator without materializing a
/// `Graph` (the shape the coordinator's pipeline feeds it).
pub fn components_streaming(
    n: usize,
    edges: impl Iterator<Item = (Vertex, Vertex)>,
) -> Vec<Vertex> {
    let mut dsu = DisjointSet::new(n);
    for (u, v) in edges {
        dsu.union(u, v);
    }
    dsu.canonical_labels()
}

/// Sharded variant: walks the resident shards directly (no flattening).
/// Canonical labels are a pure function of the edge set, so this equals
/// [`components`] of the flattened graph.
pub fn components_sharded(g: &crate::graph::ShardedGraph) -> Vec<Vertex> {
    components_streaming(g.num_vertices(), g.iter_edges())
}

/// Check a candidate labeling against the oracle.  Returns `Ok(())` or a
/// description of the first disagreement.
pub fn verify(g: &Graph, labels: &[Vertex]) -> Result<(), String> {
    if labels.len() != g.num_vertices() {
        return Err(format!(
            "labels len {} != n {}",
            labels.len(),
            g.num_vertices()
        ));
    }
    let want = components(g);
    for v in 0..labels.len() {
        if labels[v] != want[v] {
            return Err(format!(
                "vertex {v}: got label {}, oracle says {}",
                labels[v], want[v]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Rng;

    #[test]
    fn path_is_one_component() {
        let labels = components(&generators::path(10));
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn mixture_components() {
        let g = generators::path(3).disjoint_union(generators::complete(3));
        let labels = components(&g);
        assert_eq!(labels, vec![0, 0, 0, 3, 3, 3]);
    }

    #[test]
    fn isolated_vertices_self_labeled() {
        let g = Graph::empty(4);
        assert_eq!(components(&g), vec![0, 1, 2, 3]);
    }

    #[test]
    fn streaming_matches_batch() {
        let mut rng = Rng::new(1);
        let g = generators::gnp(500, 0.005, &mut rng);
        let a = components(&g);
        let b = components_streaming(500, g.edges().iter().copied());
        assert_eq!(a, b);
    }

    #[test]
    fn sharded_matches_batch() {
        let mut rng = Rng::new(2);
        let g = generators::gnp(400, 0.008, &mut rng);
        let sharded = crate::graph::ShardedGraph::from_graph(&g, 8);
        assert_eq!(components_sharded(&sharded), components(&g));
    }

    #[test]
    fn verify_accepts_oracle_and_rejects_wrong() {
        let g = generators::cycle(5);
        let ok = components(&g);
        assert!(verify(&g, &ok).is_ok());
        let mut bad = ok;
        bad[3] = 3;
        assert!(verify(&g, &bad).is_err());
        assert!(verify(&g, &[0, 0]).is_err());
    }
}
