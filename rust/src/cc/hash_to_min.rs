//! **Hash-To-Min** [CDSMR13] — the cluster-growing baseline.
//!
//! Every vertex maintains a cluster `C(v)` (initially `N(v) ∪ {v}`).  Each
//! round: `m = min C(v)`; send `C(v)` to `m` and `{m}` to every `u ∈ C(v)`;
//! the new `C(v)` is the union of everything received.  Converges with the
//! component minimum holding the full component.  Communication can blow up
//! (the paper's Tables 2–3 show "X" — out of memory — on the large
//! datasets), so the run is guarded by `RunOptions::state_cap`.

use super::{CcAlgorithm, CcResult, RunOptions};
use crate::graph::{Csr, ShardedGraph, Vertex};
use crate::mpc::Simulator;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct HashToMin;

impl CcAlgorithm for HashToMin {
    fn name(&self) -> &'static str {
        "hash-to-min"
    }

    fn run_sharded(
        &self,
        g: &ShardedGraph,
        sim: &mut Simulator,
        _rng: &mut Rng,
        opts: &RunOptions,
    ) -> CcResult {
        let n = g.num_vertices();
        let csr = Csr::build_sharded(g);
        let mut clusters: Vec<Vec<u32>> = (0..n as u32)
            .map(|v| {
                let mut c: Vec<u32> = csr.neighbors(v).to_vec();
                c.push(v);
                c.sort_unstable();
                c
            })
            .collect();
        let mut phases = 0u32;
        let mut completed = true;
        let mut edges_per_phase = Vec::new();
        let mut nodes_per_phase = Vec::new();

        loop {
            // "edges" for the Figure-1 series = total cluster state here
            let state: u64 = clusters.iter().map(|c| c.len() as u64).sum();
            edges_per_phase.push(state);
            nodes_per_phase.push(n as u64);

            if opts.state_cap > 0 && state > opts.state_cap {
                completed = false; // the paper's "X": out of memory
                break;
            }

            // map: send C(v) to min(C(v)); send {min} to every member
            let mut msgs: Vec<(u64, Vec<u32>)> = Vec::new();
            for (v, c) in clusters.iter().enumerate() {
                let m = c[0]; // sorted
                if c.len() == 1 && m == v as u32 {
                    msgs.push((v as u64, vec![v as u32])); // stable singleton
                    continue;
                }
                msgs.push((m as u64, c.clone()));
                for &u in c {
                    msgs.push((u as u64, vec![m]));
                }
            }
            let folded: Vec<(u32, Vec<u32>)> = sim.round("htm/round", msgs, |key, groups| {
                let mut merged: Vec<u32> = groups.iter().flatten().copied().collect();
                merged.sort_unstable();
                merged.dedup();
                vec![(key as u32, merged)]
            });
            let mut next: Vec<Vec<u32>> = vec![Vec::new(); n];
            for (v, c) in folded {
                next[v as usize] = c;
            }
            for (v, c) in next.iter_mut().enumerate() {
                if c.is_empty() {
                    c.push(v as u32); // nothing received: own singleton
                }
            }
            phases += 1;
            if next == clusters {
                break;
            }
            clusters = next;
            if phases >= opts.max_phases {
                completed = false;
                break;
            }
        }

        let labels: Vec<Vertex> = if completed {
            clusters.iter().map(|c| c[0]).collect()
        } else {
            super::oracle::components_sharded(g)
        };
        CcResult {
            labels,
            phases,
            completed,
            edges_per_phase,
            nodes_per_phase,
            metrics: std::mem::take(&mut sim.metrics),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::oracle;
    use crate::graph::{generators, Graph};
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        })
    }

    fn check(g: &Graph) -> CcResult {
        let mut s = sim();
        let mut rng = Rng::new(1);
        let res = HashToMin.run(g, &mut s, &mut rng, &RunOptions::default());
        assert!(res.completed);
        oracle::verify(g, &res.labels).unwrap();
        res
    }

    #[test]
    fn correct_on_zoo() {
        check(&generators::path(20));
        check(&generators::cycle(15));
        check(&generators::star(25));
        check(&generators::complete(8));
        check(&Graph::empty(4));
        check(&generators::path(9).disjoint_union(generators::star(7)));
    }

    #[test]
    fn correct_on_random_graph() {
        for seed in 0..3 {
            check(&generators::gnp(200, 0.02, &mut Rng::new(seed)));
        }
    }

    #[test]
    fn converges_in_log_d_ish_rounds_on_path() {
        // conjectured O(log d): path of 64 should need ~log-ish rounds,
        // far fewer than the 64 Hash-Min needs.
        let res = check(&generators::path(64));
        assert!(res.phases <= 20, "phases {}", res.phases);
        assert!(res.phases >= 4);
    }

    #[test]
    fn state_blows_up_on_star_like_graphs() {
        // min vertex accumulates the whole component: state Ω(n) at center
        let res = check(&generators::star(200));
        let max_state = res.edges_per_phase.iter().max().copied().unwrap();
        assert!(max_state >= 400, "state {max_state}");
    }

    #[test]
    fn state_cap_aborts_as_oom() {
        let g = generators::complete(40); // clusters explode instantly
        let mut s = sim();
        let mut rng = Rng::new(2);
        let opts = RunOptions {
            state_cap: 100,
            ..Default::default()
        };
        let res = HashToMin.run(&g, &mut s, &mut rng, &opts);
        assert!(!res.completed, "should have tripped the state cap");
    }
}
