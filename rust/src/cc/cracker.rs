//! **Cracker** [LCD+17] — the strongest published baseline in Tables 2–3,
//! implemented in the equivalent formulation the paper gives in §6:
//!
//! "Assume that each node is assigned a random priority.  First, rewire the
//! edges of the graph just as in Hash-To-Min.  Then, compute labels
//! `l_p(v) = min_{w in N(v)} rho(w)` and merge together all vertices that
//! have the same label."
//!
//! The rewiring: every vertex `v` connects its closed neighborhood to its
//! minimum-priority closed neighbor `m(v)`.  One phase = rewire (2 rounds)
//! + label (1 round) + contraction (2 rounds); phases iterate under the
//! shared [`contraction_loop`].

use super::common::{contract_mpc, neighborhood_fold, Priorities};
use super::contraction_loop::{self, LoopOptions, PhaseOutcome};
use super::{CcAlgorithm, CcResult, RunOptions};
use crate::graph::{ShardedGraph, Vertex};
use crate::mpc::pool::chunk_range;
use crate::mpc::Simulator;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct Cracker;

/// Compute `m(v)` = the vertex of minimum priority in `N(v) ∪ {v}`
/// (one MPC round carrying `(priority, id)` pairs): a self-inclusive
/// [`neighborhood_fold`] over `(rho[v], v)` values.
pub fn min_neighbor(g: &ShardedGraph, rho: &Priorities, sim: &mut Simulator) -> Vec<Vertex> {
    let n = g.num_vertices();
    let vals: Vec<(u32, u32)> = (0..n as u32)
        .map(|v| (rho.rho[v as usize], v))
        .collect();
    let out = neighborhood_fold(
        sim,
        "cracker/min-nbr",
        g,
        &vals,
        true,
        crate::mpc::WireFold::min_pair_u32(),
    );
    out.into_iter().map(|(_, v)| v).collect()
}

/// Hash-To-Min style rewiring: edges `{(m(v), u) : u ∈ N(v) ∪ {v}}`.
/// One MPC round (each vertex's neighborhood is shipped to `m(v)`).
///
/// The messages are keyed by the *hub* `m(v)`, not by the shard's own
/// keys, so — unlike the hops — the per-machine loads are a genuine
/// function of `m` and stay on the per-message-accounted chunked map
/// path; the chunks are the shards themselves (plus a `1/p` range of the
/// self messages each).  The rewired edges materialize at their hubs and
/// are re-bucketed into their owner shards by `ShardedGraph::from_edges`
/// — that shuffle *is* the semantics of the round.
pub fn rewire(g: &ShardedGraph, m: &[Vertex], sim: &mut Simulator) -> ShardedGraph {
    // Worker-native path (shuffle transport): the `GatherPairU32` reduce
    // program ships in the descriptor and the workers derive, normalize,
    // and adopt the rewired generation peer-to-peer — the O(m) hub pairs
    // never rebound through the coordinator.  Accounting and the built
    // graph are bit-identical to the `round_map` path below.
    if let Some(new) = sim.try_shuffle_gather_rewire("cracker/rewire", g, m) {
        return new;
    }
    let n = g.num_vertices();
    let p = g.num_shards();
    let chunks = g.msg_chunks(move |s, _primary, edges| {
        let (sa, sb) = chunk_range(n, p, s);
        edges
            .flat_map(move |(u, v)| {
                [
                    (m[u as usize] as u64, (m[u as usize], v)),
                    (m[v as usize] as u64, (m[v as usize], u)),
                ]
            })
            .chain((sa..sb).map(move |v| (m[v] as u64, (m[v], v as u32))))
    });
    // pure message delivery: each new edge materializes at its hub machine;
    // same vertex universe + shard count, so the ownership cache carries over
    let edges: Vec<(u32, u32)> = sim.round_map_chunked("cracker/rewire", chunks, |_, pair| pair);
    g.from_edges_like(edges)
}

impl CcAlgorithm for Cracker {
    fn name(&self) -> &'static str {
        "cracker"
    }

    fn run_sharded(
        &self,
        g: &ShardedGraph,
        sim: &mut Simulator,
        rng: &mut Rng,
        opts: &RunOptions,
    ) -> CcResult {
        let loop_opts = LoopOptions {
            finisher_threshold: opts.finisher_threshold,
            prune_isolated: opts.prune_isolated,
            max_phases: opts.max_phases,
        };
        contraction_loop::run(g, sim, rng, loop_opts, |cur, sim, rng, _phase| {
            let rho = Priorities::sample(cur.num_vertices(), rng);
            let m = min_neighbor(cur, &rho, sim);
            let rewired = rewire(cur, &m, sim);
            // label on the rewired graph: min-priority closed neighbor
            let labels = min_neighbor(&rewired, &rho, sim);
            let (contracted, node_map) = contract_mpc(sim, cur, &labels);
            PhaseOutcome {
                contracted,
                node_map,
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::oracle;
    use crate::graph::{generators, Graph};
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        })
    }

    #[test]
    fn min_neighbor_identity_priorities() {
        let g = ShardedGraph::from_graph(&generators::path(4), 4);
        let rho = Priorities {
            rho: vec![0, 1, 2, 3],
            inv: vec![0, 1, 2, 3],
        };
        let mut s = sim();
        let m = min_neighbor(&g, &rho, &mut s);
        assert_eq!(m, vec![0, 0, 1, 2]);
    }

    #[test]
    fn rewire_connects_neighborhood_to_min() {
        let g = ShardedGraph::from_graph(&generators::path(4), 4);
        let m = vec![0, 0, 1, 2];
        let mut s = sim();
        let r = rewire(&g, &m, &mut s).to_graph();
        // v=1's neighborhood {0,1,2} hangs off m(1)=0; v=2's {1,2,3} off 1...
        assert!(r.edges().contains(&(0, 1)));
        assert!(r.edges().contains(&(0, 2)));
        assert!(r.edges().contains(&(1, 3)));
        assert_eq!(r.num_vertices(), 4);
    }

    fn check(g: &Graph, seed: u64) -> CcResult {
        let mut s = sim();
        let mut rng = Rng::new(seed);
        let res = Cracker.run(g, &mut s, &mut rng, &RunOptions::default());
        assert!(res.completed);
        oracle::verify(g, &res.labels).unwrap();
        res
    }

    #[test]
    fn correct_on_zoo() {
        check(&generators::path(30), 1);
        check(&generators::cycle(21), 2);
        check(&generators::star(40), 3);
        check(&generators::complete(10), 4);
        check(&generators::grid(5, 8), 5);
        check(&Graph::empty(6), 6);
        check(
            &generators::binary_tree(31).disjoint_union(generators::cycle(7)),
            7,
        );
    }

    #[test]
    fn correct_on_random_graphs() {
        for seed in 0..4 {
            check(&generators::gnp(250, 0.015, &mut Rng::new(seed + 60)), seed);
        }
    }

    #[test]
    fn few_phases_on_dense_random_graph() {
        let g = generators::gnp_log_regime(1000, 4.0, &mut Rng::new(5));
        let res = check(&g, 8);
        assert!(res.phases <= 6, "phases {}", res.phases);
    }

    #[test]
    fn lower_bound_on_path() {
        // Thm 7.1: Cracker needs Ω(log n) on a path.
        let res = check(&generators::path(1024), 9);
        assert!(res.phases >= 3, "phases {}", res.phases);
    }
}
