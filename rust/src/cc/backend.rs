//! Dense-phase backend abstraction.
//!
//! The per-phase label computation of LocalContraction (`l(v)` = min
//! priority over `N(N(v))`) has two interchangeable implementations:
//! the pure-Rust reference walk and the **compiled XLA artifact** produced
//! by `python/compile/aot.py` (the Layer-1 Pallas kernel inside the Layer-2
//! JAX graph).  The algorithms depend only on this trait; the PJRT-backed
//! implementation lives in [`crate::runtime`] so `cc` stays
//! hardware-agnostic.

use crate::graph::{Csr, Graph};

/// Identity of the INF sentinel shared with the Python side
/// (`python/compile/kernels/minprop.py`).
pub const INF: i32 = i32::MAX;

/// A backend that can evaluate dense-shard phase computations.
pub trait DenseBackend {
    /// Largest vertex count a single invocation can handle (artifact shape).
    fn max_vertices(&self) -> usize;

    /// LocalContraction phase labels over a dense shard: for each live
    /// vertex `v`, the minimum priority over `N(N(v))` (self-inclusive).
    ///
    /// `g` must have at most [`max_vertices`](Self::max_vertices) vertices;
    /// `prio[v]` are unique priorities in `[0, n)`.
    /// Returns `labels[v]` = min priority value over `N(N(v))`.
    fn local_labels(&self, g: &Graph, prio: &[i32]) -> anyhow::Result<Vec<i32>>;

    /// One min-hop (`min over N(v) ∪ {v}`) — Hash-Min / Cracker step.
    fn hash_min_step(&self, g: &Graph, prio: &[i32]) -> anyhow::Result<Vec<i32>>;

    /// Resolve a pointer forest to canonical (minimum) 2-cycle roots.
    fn tree_roots(&self, f: &[i32]) -> anyhow::Result<Vec<i32>>;
}

/// Pure-Rust reference implementation of the same contract; used in tests
/// to cross-validate the compiled artifacts and as the CPU fallback.
#[derive(Debug, Default, Clone, Copy)]
pub struct CpuBackend {
    /// Mirror the artifact's shape limit when emulating it (0 = unlimited).
    pub max_n: usize,
}

impl CpuBackend {
    fn min_hop(g: &Graph, vals: &[i32]) -> Vec<i32> {
        let csr = Csr::build(g);
        (0..g.num_vertices())
            .map(|v| {
                let mut best = vals[v];
                for &u in csr.neighbors(v as u32) {
                    best = best.min(vals[u as usize]);
                }
                best
            })
            .collect()
    }
}

impl DenseBackend for CpuBackend {
    fn max_vertices(&self) -> usize {
        if self.max_n == 0 {
            usize::MAX
        } else {
            self.max_n
        }
    }

    fn local_labels(&self, g: &Graph, prio: &[i32]) -> anyhow::Result<Vec<i32>> {
        anyhow::ensure!(prio.len() == g.num_vertices(), "prio length mismatch");
        let h1 = Self::min_hop(g, prio);
        Ok(Self::min_hop(g, &h1))
    }

    fn hash_min_step(&self, g: &Graph, prio: &[i32]) -> anyhow::Result<Vec<i32>> {
        anyhow::ensure!(prio.len() == g.num_vertices(), "prio length mismatch");
        Ok(Self::min_hop(g, prio))
    }

    fn tree_roots(&self, f: &[i32]) -> anyhow::Result<Vec<i32>> {
        let n = f.len();
        let mut cur: Vec<i32> = f.to_vec();
        // repeated squaring to a fixed point, then canonical 2-cycle min
        for _ in 0..(64 - (n.max(2) as u64).leading_zeros()) + 1 {
            let next: Vec<i32> = (0..n).map(|v| cur[cur[v] as usize]).collect();
            if next == cur {
                break;
            }
            cur = next;
        }
        Ok((0..n)
            .map(|v| {
                let a = cur[v];
                let b = f[a as usize]; // opposite-parity cycle element
                a.min(b)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Rng;

    #[test]
    fn cpu_local_labels_on_path() {
        let g = generators::path(6);
        let prio: Vec<i32> = vec![3, 5, 0, 1, 4, 2];
        let b = CpuBackend::default();
        let labels = b.local_labels(&g, &prio).unwrap();
        // N(N(v)) spans distance <= 2
        assert_eq!(labels, vec![0, 0, 0, 0, 0, 1]);
    }

    #[test]
    fn cpu_hash_min_step_is_one_hop() {
        let g = generators::star(4); // center 0
        let prio = vec![7, 1, 2, 3];
        let b = CpuBackend::default();
        assert_eq!(b.hash_min_step(&g, &prio).unwrap(), vec![1, 1, 2, 3]);
    }

    #[test]
    fn cpu_tree_roots_on_chain() {
        // f: v -> v-1, f(0)=1 makes {0,1} a 2-cycle
        let mut f: Vec<i32> = (0..64).map(|v: i32| (v - 1).max(0)).collect();
        f[0] = 1;
        let b = CpuBackend::default();
        let roots = b.tree_roots(&f).unwrap();
        assert!(roots.iter().all(|&r| r == 0), "{roots:?}");
    }

    #[test]
    fn cpu_tree_roots_self_loops_are_fixed_points() {
        let f: Vec<i32> = (0..8).collect();
        let b = CpuBackend::default();
        assert_eq!(b.tree_roots(&f).unwrap(), f);
    }

    #[test]
    fn cpu_matches_on_random_graph_vs_bruteforce() {
        let mut rng = Rng::new(5);
        let g = generators::gnp(200, 0.02, &mut rng);
        let prio: Vec<i32> = rng.permutation(200).iter().map(|&x| x as i32).collect();
        let b = CpuBackend::default();
        let got = b.local_labels(&g, &prio).unwrap();
        // brute force N(N(v))
        let csr = crate::graph::Csr::build(&g);
        for v in 0..200u32 {
            let mut best = prio[v as usize];
            let mut seen = vec![v];
            seen.extend_from_slice(csr.neighbors(v));
            for &u in seen.clone().iter() {
                best = best.min(prio[u as usize]);
                for &w in csr.neighbors(u) {
                    best = best.min(prio[w as usize]);
                }
            }
            assert_eq!(got[v as usize], best, "vertex {v}");
        }
    }
}
