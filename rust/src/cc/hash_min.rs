//! **Hash-Min** — the trivial `O(d)`-round label-propagation baseline
//! (mentioned in §1 via [CDSMR13]): every vertex repeatedly adopts the
//! minimum label in its closed neighborhood.  No contraction, no rewiring;
//! `d+1` rounds on a graph of diameter `d`, `O(m)` communication per round.

use super::common::min_hop;
use super::{CcAlgorithm, CcResult, RunOptions};
use crate::graph::{ShardedGraph, Vertex};
use crate::mpc::Simulator;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct HashMin;

impl CcAlgorithm for HashMin {
    fn name(&self) -> &'static str {
        "hash-min"
    }

    fn run_sharded(
        &self,
        g: &ShardedGraph,
        sim: &mut Simulator,
        _rng: &mut Rng,
        opts: &RunOptions,
    ) -> CcResult {
        let n = g.num_vertices();
        let mut labels: Vec<u32> = (0..n as u32).collect();
        let mut phases = 0u32;
        let mut completed = true;
        let mut edges_per_phase = Vec::new();
        let mut nodes_per_phase = Vec::new();
        loop {
            edges_per_phase.push(g.num_edges() as u64); // never contracts
            nodes_per_phase.push(n as u64);
            let next = min_hop(sim, "hash-min/hop", g, &labels, true);
            phases += 1;
            if next == labels {
                break;
            }
            labels = next;
            if phases >= opts.max_phases {
                completed = false;
                break;
            }
        }
        let labels: Vec<Vertex> = if completed {
            labels
        } else {
            super::oracle::components_sharded(g) // guard: salvage a correct answer
        };
        CcResult {
            labels,
            phases,
            completed,
            edges_per_phase,
            nodes_per_phase,
            metrics: std::mem::take(&mut sim.metrics),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::oracle;
    use crate::graph::generators;
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        })
    }

    #[test]
    fn correct_and_diameter_bounded() {
        let g = generators::path(33);
        let mut s = sim();
        let mut rng = Rng::new(1);
        let res = HashMin.run(&g, &mut s, &mut rng, &RunOptions::default());
        assert!(res.completed);
        oracle::verify(&g, &res.labels).unwrap();
        // exactly diameter+1 hops: 32 to propagate + 1 to detect stability
        assert_eq!(res.phases, 33);
    }

    #[test]
    fn fast_on_low_diameter() {
        let g = generators::star(100);
        let mut s = sim();
        let mut rng = Rng::new(2);
        let res = HashMin.run(&g, &mut s, &mut rng, &RunOptions::default());
        assert!(res.phases <= 3);
        oracle::verify(&g, &res.labels).unwrap();
    }

    #[test]
    fn guard_trips_on_long_path() {
        let g = generators::path(1000);
        let mut s = sim();
        let mut rng = Rng::new(3);
        let opts = RunOptions {
            max_phases: 5,
            ..Default::default()
        };
        let res = HashMin.run(&g, &mut s, &mut rng, &opts);
        assert!(!res.completed);
        oracle::verify(&g, &res.labels).unwrap(); // salvaged
    }

    #[test]
    fn correct_on_random_graph() {
        let g = generators::gnp(300, 0.02, &mut Rng::new(9));
        let mut s = sim();
        let mut rng = Rng::new(4);
        let res = HashMin.run(&g, &mut s, &mut rng, &RunOptions::default());
        oracle::verify(&g, &res.labels).unwrap();
    }
}
