//! **Two-Phase** [KLM+14] — alternating large-star / small-star edge
//! rewriting until the graph becomes a star forest rooted at component
//! minima.
//!
//! * `large-star(u)`: connect every strictly larger neighbor of `u` to
//!   `m(u) = min(Γ(u) ∪ {u})`;
//! * `small-star(u)`: connect `u` and its not-larger neighbors to `m(u)`.
//!
//! Following the paper's §6 note on its own implementation, one *phase* is
//! a sequence of large-star operations (to convergence) followed by one
//! small-star — "It allows to execute a sequence of large-star operations
//! followed by a small-star operation in constant number of rounds and
//! thus we count this whole sequence as one phase."  Each individual star
//! operation is still one shuffle round in the metrics.
//!
//! The vertex set never shrinks (no contraction), so the §6 small-graph
//! finisher/pruning optimizations do not apply — exactly as the paper
//! notes.

use super::{CcAlgorithm, CcResult, RunOptions};
use crate::graph::{Csr, ShardedGraph, Vertex};
use crate::mpc::Simulator;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct TwoPhase;

/// One star operation as an MPC round.  `large == true` emits edges for
/// strictly larger neighbors only; otherwise for not-larger neighbors plus
/// the center itself.  The map input walks the shards directly; the
/// rewritten star edges re-bucket into their owner shards on the way out.
pub fn star_round(g: &ShardedGraph, large: bool, sim: &mut Simulator) -> ShardedGraph {
    let msgs: Vec<(u64, u32)> = g
        .iter_edges()
        .flat_map(|(u, v)| [(u as u64, v), (v as u64, u)])
        .collect();
    let label = if large { "two-phase/large-star" } else { "two-phase/small-star" };
    let edges: Vec<(u32, u32)> = sim.round(label, msgs, |key, nbrs| {
        let u = key as u32;
        let m = nbrs.iter().copied().min().unwrap().min(u);
        let mut out = Vec::new();
        if large {
            for &w in nbrs.iter() {
                if w > u {
                    out.push((w, m));
                }
            }
        } else {
            for &w in nbrs.iter() {
                if w <= u {
                    out.push((w, m));
                }
            }
            out.push((u, m));
        }
        out
    });
    // same vertex universe + shard count: reuse the ownership cache
    g.from_edges_like(edges)
}

impl CcAlgorithm for TwoPhase {
    fn name(&self) -> &'static str {
        "two-phase"
    }

    fn run_sharded(
        &self,
        g: &ShardedGraph,
        sim: &mut Simulator,
        _rng: &mut Rng,
        opts: &RunOptions,
    ) -> CcResult {
        let n = g.num_vertices();
        let mut cur = g.clone();
        let mut phases = 0u32;
        let mut completed = true;
        let mut edges_per_phase = Vec::new();
        let mut nodes_per_phase = Vec::new();

        loop {
            edges_per_phase.push(cur.num_edges() as u64);
            nodes_per_phase.push(n as u64);
            if cur.num_edges() == 0 {
                break;
            }
            if phases >= opts.max_phases {
                completed = false;
                break;
            }

            // one phase: large-star to convergence, then one small-star
            let mut changed_any = false;
            loop {
                let next = star_round(&cur, true, sim);
                let stable = next == cur;
                cur = next;
                if stable {
                    break;
                }
                changed_any = true;
            }
            let next = star_round(&cur, false, sim);
            let small_changed = next != cur;
            cur = next;
            phases += 1;
            if !changed_any && !small_changed {
                break; // fully converged: star forest
            }
        }

        // At convergence the graph is a star forest rooted at component
        // minima (or empty for already-finished components): every vertex's
        // minimum closed neighbor is its component minimum.
        let labels: Vec<Vertex> = if completed {
            let csr = Csr::build_sharded(&cur);
            (0..n as u32)
                .map(|v| {
                    csr.neighbors(v)
                        .iter()
                        .copied()
                        .chain(std::iter::once(v))
                        .min()
                        .unwrap()
                })
                .collect()
        } else {
            super::oracle::components_sharded(g)
        };

        CcResult {
            labels,
            phases,
            completed,
            edges_per_phase,
            nodes_per_phase,
            metrics: std::mem::take(&mut sim.metrics),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::oracle;
    use crate::graph::{generators, Graph};
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        })
    }

    #[test]
    fn large_star_hangs_bigger_neighbors_on_min() {
        // star with center 2 over {0,1,2,3}: edges (2,0),(2,1),(2,3)
        let g = ShardedGraph::from_edges(4, 4, vec![(2, 0), (2, 1), (2, 3)]);
        let mut s = sim();
        let r = star_round(&g, true, &mut s).to_graph();
        // center 2: m = 0; larger neighbor 3 -> (3,0); neighbors 0,1 emit
        // for their own stars: 0 has nbr {2}: 2>0 -> (2,0); 1: (2,1)->m=1
        assert!(r.edges().contains(&(0, 3)));
        assert!(r.edges().contains(&(0, 2)));
    }

    fn check(g: &Graph) -> CcResult {
        let mut s = sim();
        let mut rng = Rng::new(1);
        let res = TwoPhase.run(g, &mut s, &mut rng, &RunOptions::default());
        assert!(res.completed, "did not converge");
        oracle::verify(g, &res.labels).unwrap();
        res
    }

    #[test]
    fn correct_on_zoo() {
        check(&generators::path(25));
        check(&generators::cycle(16));
        check(&generators::star(30));
        check(&generators::complete(9));
        check(&generators::grid(4, 6));
        check(&Graph::empty(5));
        check(&generators::path(12).disjoint_union(generators::complete(4)));
    }

    #[test]
    fn correct_on_random_graphs() {
        for seed in 0..4 {
            check(&generators::gnp(250, 0.015, &mut Rng::new(seed + 70)));
        }
    }

    #[test]
    fn star_input_converges_immediately() {
        let res = check(&generators::star(50));
        assert!(res.phases <= 2, "phases {}", res.phases);
    }

    #[test]
    fn phase_count_moderate_on_random_graph() {
        let g = generators::gnp_log_regime(800, 4.0, &mut Rng::new(3));
        let res = check(&g);
        assert!(res.phases <= 8, "phases {}", res.phases);
    }
}
