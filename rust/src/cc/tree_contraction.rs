//! **TreeContraction** (§3, Theorem 4.7).
//!
//! Each phase: sample priorities; `f_rho(v)` = the neighbor of `v` with the
//! lowest priority; contract the weakly connected components of the
//! functional graph H induced by `f_rho`.  Components halve each phase
//! (Lemma 4.3: every cluster has ≥ 2 vertices), so `O(log n)` phases.
//!
//! Resolving H's components (Lemma 4.6: every weak component terminates in
//! one 2-cycle) has two implementations, matching Theorem 4.7:
//!  * **pointer jumping** — `O(log max d(v)) = O(log log n)` w.h.p. rounds
//!    of squaring (`f ← f ∘ f`), each one MPC round;
//!  * **distributed hash table** — publish `f` (O(n) writes), then every
//!    vertex walks its chain in a single round (`O(d(v))` reads).

use super::common::{contract_mpc, neighborhood_fold, Priorities};
use super::contraction_loop::{self, LoopOptions, PhaseOutcome};
use super::{CcAlgorithm, CcResult, RunOptions};
use crate::graph::{ShardedGraph, Vertex};
use crate::mpc::{Dht, Simulator};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct TreeContraction {
    /// Use the §2.1 DHT extension (Theorem 4.7 second claim).
    pub use_dht: bool,
}

/// Build `f_rho`: lowest-priority neighbor, or self for isolated vertices.
/// One MPC round (each edge sends both endpoint priorities): a
/// self-**exclusive** [`neighborhood_fold`] over `(rho[v], v)` values —
/// the fold replaces a vertex's own value on its first neighbor message
/// (so `f_rho(v)` picks from `N(v) \ {v}`), while isolated vertices keep
/// `(rho[v], v)` and thus point at themselves.
pub fn build_pointers(g: &ShardedGraph, rho: &Priorities, sim: &mut Simulator) -> Vec<Vertex> {
    let n = g.num_vertices();
    let vals: Vec<(u32, u32)> = (0..n as u32)
        .map(|v| (rho.rho[v as usize], v))
        .collect();
    let out = neighborhood_fold(
        sim,
        "tc/pointers",
        g,
        &vals,
        false,
        crate::mpc::WireFold::min_pair_u32(),
    );
    out.into_iter().map(|(_, target)| target).collect()
}

/// Resolve roots by pointer jumping (squaring); each step is one MPC round
/// (vertex v asks machine of `f(v)` for `f(f(v))`).  Returns canonical
/// (minimum-of-2-cycle) roots and the number of jump rounds used.
pub fn roots_by_jumping(f0: &[Vertex], sim: &mut Simulator) -> (Vec<Vertex>, u32) {
    let n = f0.len();
    let mut cur: Vec<Vertex> = f0.to_vec();
    let mut rounds = 0u32;
    loop {
        // one squaring step as an MPC round: key = cur[v], value = v
        let msgs: Vec<(u64, u32)> = (0..n).map(|v| (cur[v] as u64, v as u32)).collect();
        let next_pairs = sim.round("tc/jump", msgs, |key, group| {
            // machine owning `key` knows cur[key]; answers every requester
            let target = cur[key as usize];
            group.iter().map(|&v| (v, target)).collect::<Vec<_>>()
        });
        let mut next = cur.clone();
        for (v, t) in next_pairs {
            next[v as usize] = t;
        }
        rounds += 1;
        if next == cur {
            break;
        }
        cur = next;
        if rounds > 2 * (usize::BITS - n.leading_zeros()) {
            break; // safety: cannot exceed log2(n) squarings + slack
        }
    }
    // canonical root: min of the terminal 2-cycle = min(stable, f0[stable])
    let roots = (0..n)
        .map(|v| {
            let a = cur[v];
            a.min(f0[a as usize])
        })
        .collect();
    (roots, rounds)
}

/// Resolve roots with the DHT: publish `f`, then walk each chain until the
/// 2-cycle is detected.  One logical round; `Σ d(v)` reads charged.
pub fn roots_by_dht(f0: &[Vertex], sim: &mut Simulator, dht: &mut Dht) -> Vec<Vertex> {
    let n = f0.len();
    dht.reset();
    for (v, &t) in f0.iter().enumerate() {
        dht.put(v as u64, t as u64);
    }
    dht.publish();
    // The publish is the write half of a round; charge it on a round record.
    let msgs: Vec<(u64, u32)> = (0..n).map(|v| (v as u64, 0u32)).collect();
    let _: Vec<()> = sim.round("tc/dht-walk", msgs, |_, _| vec![]);
    let mut roots = vec![0 as Vertex; n];
    for v in 0..n {
        let mut prev = v as u64;
        let mut cur = dht.get(prev).unwrap();
        loop {
            let next = dht.get(cur).unwrap();
            if next == prev {
                break; // 2-cycle {prev, cur}
            }
            prev = cur;
            cur = next;
        }
        roots[v] = prev.min(cur) as Vertex;
    }
    let (reads, writes) = dht.take_counters();
    sim.charge_dht(reads, writes);
    roots
}

/// Max pointer-chain depth `max_v d(v)` (Lemma 4.5 diagnostics).
pub fn max_chain_depth(f: &[Vertex]) -> u32 {
    let n = f.len();
    let mut depth = vec![u32::MAX; n];
    let mut best = 0;
    for v in 0..n {
        // walk with a visited stack until a known depth or a 2-cycle
        let mut stack = Vec::new();
        let mut x = v;
        loop {
            if depth[x] != u32::MAX {
                break;
            }
            // 2-cycle detection: f(f(x)) == x
            let fx = f[x] as usize;
            if f[fx] as usize == x {
                depth[x] = 0;
                if depth[fx] == u32::MAX {
                    depth[fx] = 0;
                }
                break;
            }
            stack.push(x);
            x = fx;
            if stack.len() > n {
                unreachable!("pointer walk exceeded n — not a functional graph");
            }
        }
        while let Some(y) = stack.pop() {
            depth[y] = depth[f[y] as usize].saturating_add(1);
        }
        best = best.max(depth[v]);
    }
    best
}

impl CcAlgorithm for TreeContraction {
    fn name(&self) -> &'static str {
        if self.use_dht {
            "tree-contraction+dht"
        } else {
            "tree-contraction"
        }
    }

    fn run_sharded(
        &self,
        g: &ShardedGraph,
        sim: &mut Simulator,
        rng: &mut Rng,
        opts: &RunOptions,
    ) -> CcResult {
        let loop_opts = LoopOptions {
            finisher_threshold: opts.finisher_threshold,
            prune_isolated: opts.prune_isolated,
            max_phases: opts.max_phases,
        };
        let use_dht = self.use_dht;
        let mut dht = Dht::new();
        contraction_loop::run(g, sim, rng, loop_opts, move |cur, sim, rng, _phase| {
            let rho = Priorities::sample(cur.num_vertices(), rng);
            let f = build_pointers(cur, &rho, sim);
            let roots = if use_dht {
                roots_by_dht(&f, sim, &mut dht)
            } else {
                roots_by_jumping(&f, sim).0
            };
            let (contracted, node_map) = contract_mpc(sim, cur, &roots);
            PhaseOutcome {
                contracted,
                node_map,
            }
        })
    }
}

/// Reference (non-MPC) root computation used by tests: weak components of
/// the functional graph via union-find.
pub fn roots_reference(f: &[Vertex]) -> Vec<Vertex> {
    let mut dsu = crate::util::dsu::DisjointSet::new(f.len());
    for (v, &t) in f.iter().enumerate() {
        dsu.union(v as u32, t);
    }
    dsu.canonical_labels()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::oracle;
    use crate::graph::{generators, Graph};
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 8,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        })
    }

    #[test]
    fn pointers_choose_min_priority_neighbor() {
        let g = ShardedGraph::from_graph(&generators::path(4), 8);
        let rho = Priorities {
            rho: vec![2, 0, 3, 1],
            inv: vec![1, 3, 0, 2],
        };
        let mut s = sim();
        let f = build_pointers(&g, &rho, &mut s);
        // f(0)=1 (prio 0); f(1)=0 (only smaller-prio option among {0,2} is 0);
        // f(2)=1 (prio 0 beats prio 1 of v3); f(3)=2 (its only neighbor)
        assert_eq!(f, vec![1, 0, 1, 2]);
    }

    #[test]
    fn jumping_matches_reference_partition() {
        let mut rng = Rng::new(1);
        for seed in 0..5u64 {
            let g = ShardedGraph::from_graph(
                &generators::gnp(200, 0.015, &mut Rng::new(seed + 10)),
                8,
            );
            let rho = Priorities::sample(200, &mut rng);
            let mut s = sim();
            let f = build_pointers(&g, &rho, &mut s);
            let (roots, _) = roots_by_jumping(&f, &mut s);
            let want = roots_reference(&f);
            // same partition: roots equal iff reference labels equal
            for a in 0..200 {
                for b in (a + 1)..200 {
                    assert_eq!(
                        roots[a] == roots[b],
                        want[a] == want[b],
                        "seed {seed} pair ({a},{b})"
                    );
                }
            }
        }
    }

    #[test]
    fn dht_matches_jumping() {
        let mut rng = Rng::new(2);
        let g = ShardedGraph::from_graph(&generators::gnp(150, 0.03, &mut Rng::new(99)), 8);
        let rho = Priorities::sample(150, &mut rng);
        let mut s = sim();
        let f = build_pointers(&g, &rho, &mut s);
        let (a, _) = roots_by_jumping(&f, &mut s);
        let mut dht = Dht::new();
        let b = roots_by_dht(&f, &mut s, &mut dht);
        assert_eq!(a, b);
        assert!(s.metrics.total_dht_ops() > 0);
    }

    #[test]
    fn jump_rounds_are_log_of_depth() {
        // chain f: v -> v-1 with a 2-cycle at the bottom
        let n = 1024usize;
        let mut f: Vec<Vertex> = (0..n as u32).map(|v| v.saturating_sub(1)).collect();
        f[0] = 1;
        let mut s = sim();
        let (roots, rounds) = roots_by_jumping(&f, &mut s);
        assert!(roots.iter().all(|&r| r == 0));
        assert!(rounds <= 12, "rounds {rounds} for depth {n}"); // log2(1024)=10 + slack
    }

    #[test]
    fn max_chain_depth_on_chain() {
        let mut f: Vec<Vertex> = (0..10u32).map(|v| v.saturating_sub(1)).collect();
        f[0] = 1;
        // depth: v=0,1 are on the cycle (0); v=2 -> 1 step to cycle...
        assert_eq!(max_chain_depth(&f), 8);
    }

    fn check(algo: TreeContraction, g: &Graph, seed: u64) -> CcResult {
        let mut s = sim();
        let mut rng = Rng::new(seed);
        let res = algo.run(g, &mut s, &mut rng, &RunOptions::default());
        assert!(res.completed);
        oracle::verify(g, &res.labels).unwrap();
        res
    }

    #[test]
    fn correct_on_zoo_both_variants() {
        for use_dht in [false, true] {
            let algo = TreeContraction { use_dht };
            check(algo, &generators::path(40), 1);
            check(algo, &generators::cycle(25), 2);
            check(algo, &generators::star(30), 3);
            check(algo, &generators::grid(6, 7), 4);
            check(algo, &Graph::empty(5), 5);
            check(
                algo,
                &generators::complete(10).disjoint_union(generators::path(11)),
                6,
            );
        }
    }

    #[test]
    fn correct_on_random_graphs() {
        for seed in 0..4 {
            let g = generators::gnp(300, 0.012, &mut Rng::new(seed + 30));
            check(TreeContraction { use_dht: false }, &g, seed);
            check(TreeContraction { use_dht: true }, &g, seed + 100);
        }
    }

    #[test]
    fn phases_halve_vertices() {
        // Lemma 4.3: every cluster has >= 2 vertices, n halves per phase.
        let g = generators::path(256);
        let res = check(TreeContraction { use_dht: true }, &g, 7);
        for w in res.nodes_per_phase.windows(2) {
            if w[0] > 1 {
                assert!(
                    w[1] <= w[0].div_ceil(2),
                    "nodes did not halve: {:?}",
                    res.nodes_per_phase
                );
            }
        }
        assert!(res.phases as usize <= 10, "phases {}", res.phases); // log2(256)=8 + slack
    }
}
