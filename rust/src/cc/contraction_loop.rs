//! The shared phase loop of the contraction algorithms.
//!
//! LocalContraction, TreeContraction and Cracker all follow the same outer
//! structure: repeatedly (a) run a phase that *contracts* the current graph,
//! (b) apply the §6 optimizations (prune isolated nodes, ship small graphs
//! to the single-machine finisher), and (c) stop when no edges remain.
//! This module owns that loop plus the bookkeeping that maps contracted
//! node ids back to canonical original-vertex labels.
//!
//! The loop's working graph is the resident [`ShardedGraph`]: pruning
//! re-buckets shard-locally, the finisher ships straight off the shards,
//! and the phase callback receives shards it can hand to the round helpers
//! without any flattening.

use super::oracle;
use super::CcResult;
use crate::graph::{ShardedGraph, Vertex};
use crate::mpc::simulator::machine_of;
use crate::mpc::{ShardRound, Simulator};
use crate::util::rng::Rng;

/// Outcome of one contraction phase: the contracted graph plus the map from
/// the phase-input node ids to the contracted node ids.
pub struct PhaseOutcome {
    pub contracted: ShardedGraph,
    pub node_map: Vec<Vertex>,
}

/// Loop options (a view over [`super::RunOptions`]).
#[derive(Debug, Clone, Copy)]
pub struct LoopOptions {
    pub finisher_threshold: usize,
    pub prune_isolated: bool,
    pub max_phases: u32,
}

/// Run the contraction loop.  `phase` receives the current graph and must
/// return a [`PhaseOutcome`] whose `node_map` merges only vertices of the
/// same connected component (the soundness invariant every algorithm's
/// label step guarantees).
pub fn run<F>(
    g: &ShardedGraph,
    sim: &mut Simulator,
    rng: &mut Rng,
    opts: LoopOptions,
    mut phase: F,
) -> CcResult
where
    F: FnMut(&ShardedGraph, &mut Simulator, &mut Rng, u32) -> PhaseOutcome,
{
    let n_orig = g.num_vertices();
    // node_of[v]: current node id of original vertex v (when unresolved)
    let mut node_of: Vec<Vertex> = (0..n_orig as u32).collect();
    let mut resolved: Vec<bool> = vec![false; n_orig];
    let mut final_label: Vec<Vertex> = vec![0; n_orig];
    let mut cur = g.clone();
    let mut phases = 0u32;
    let mut completed = true;
    let mut edges_per_phase = Vec::new();
    let mut nodes_per_phase = Vec::new();

    // min original vertex id per current node (canonical-label carrier)
    let min_orig = |cur_n: usize, node_of: &[Vertex], resolved: &[bool]| -> Vec<Vertex> {
        let mut m = vec![Vertex::MAX; cur_n];
        for v in 0..n_orig {
            if !resolved[v] {
                let node = node_of[v] as usize;
                if (v as Vertex) < m[node] {
                    m[node] = v as Vertex;
                }
            }
        }
        m
    };

    loop {
        edges_per_phase.push(cur.num_edges() as u64);
        nodes_per_phase.push(cur.num_vertices() as u64);

        // Termination: no edges -> every remaining node is a finished component.
        if cur.num_edges() == 0 {
            let m = min_orig(cur.num_vertices(), &node_of, &resolved);
            for v in 0..n_orig {
                if !resolved[v] {
                    resolved[v] = true;
                    final_label[v] = m[node_of[v] as usize];
                }
            }
            break;
        }

        // §6 finisher: small graph -> one machine, streaming union-find.
        // Charged as one round shipping every remaining edge to key 0 —
        // the load lands entirely on machine_of(0), straight from the
        // shard sizes.
        if opts.finisher_threshold > 0 && cur.num_edges() <= opts.finisher_threshold {
            let p = sim.cfg.machines.max(1);
            let m_edges = cur.num_edges() as u64;
            let mut machine_bytes = vec![0u64; p];
            machine_bytes[machine_of(0, p)] = 16 * m_edges; // 8 key + (u32,u32)
            let charge = ShardRound {
                messages: m_edges,
                bytes: 16 * m_edges,
                machine_bytes,
            };
            let chunks = cur.msg_chunks(|_s, _primary, edges| edges.map(|(u, v)| (0u64, (u, v))));
            let _: Vec<()> = sim.round_map_sharded("finisher/ship", chunks, charge, |_, _| ());
            let node_labels = oracle::components_sharded(&cur); // min node id per comp
            let m = min_orig(cur.num_vertices(), &node_of, &resolved);
            // canonical original label per component = min over member nodes
            let mut comp_min = vec![Vertex::MAX; cur.num_vertices()];
            for node in 0..cur.num_vertices() {
                let c = node_labels[node] as usize;
                comp_min[c] = comp_min[c].min(m[node]);
            }
            for v in 0..n_orig {
                if !resolved[v] {
                    resolved[v] = true;
                    let c = node_labels[node_of[v] as usize] as usize;
                    final_label[v] = comp_min[c];
                }
            }
            phases += 1; // the finisher consumes one round = one phase
            break;
        }

        if phases >= opts.max_phases {
            // Resource guard tripped: resolve via the oracle so the result
            // is still usable, but mark the run incomplete.
            completed = false;
            let node_labels = oracle::components_sharded(&cur);
            let m = min_orig(cur.num_vertices(), &node_of, &resolved);
            let mut comp_min = vec![Vertex::MAX; cur.num_vertices()];
            for node in 0..cur.num_vertices() {
                let c = node_labels[node] as usize;
                comp_min[c] = comp_min[c].min(m[node]);
            }
            for v in 0..n_orig {
                if !resolved[v] {
                    resolved[v] = true;
                    let c = node_labels[node_of[v] as usize] as usize;
                    final_label[v] = comp_min[c];
                }
            }
            break;
        }

        // ---- one contraction phase -----------------------------------------
        let outcome = phase(&cur, sim, rng, phases);
        phases += 1;
        debug_assert_eq!(outcome.node_map.len(), cur.num_vertices());
        for v in 0..n_orig {
            if !resolved[v] {
                node_of[v] = outcome.node_map[node_of[v] as usize];
            }
        }
        cur = outcome.contracted;

        // §6: prune isolated nodes — their component is complete.  The
        // prune re-buckets surviving edges shard-locally.
        if opts.prune_isolated {
            let m = min_orig(cur.num_vertices(), &node_of, &resolved);
            let (pruned, map) = cur.prune_isolated();
            if pruned.num_vertices() < cur.num_vertices() {
                // shuffle transport: custody follows the prune peer to
                // peer (dropped vertices have no edges, so the MAX
                // sentinel never lands on a live endpoint); the O(n) map
                // materializes only when workers actually hold custody
                if sim.has_shuffle_custody(&cur) {
                    let wire_map: Vec<Vertex> =
                        map.iter().map(|m| m.unwrap_or(Vertex::MAX)).collect();
                    sim.shuffle_rewire(&cur, &wire_map, &pruned);
                }
                for v in 0..n_orig {
                    if !resolved[v] {
                        match map[node_of[v] as usize] {
                            Some(new_id) => node_of[v] = new_id,
                            None => {
                                resolved[v] = true;
                                final_label[v] = m[node_of[v] as usize];
                            }
                        }
                    }
                }
                cur = pruned;
            }
        }
    }

    CcResult {
        labels: final_label,
        phases,
        completed,
        edges_per_phase,
        nodes_per_phase,
        metrics: std::mem::take(&mut sim.metrics),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr, Graph};
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        })
    }

    fn shard(g: &Graph) -> ShardedGraph {
        ShardedGraph::from_graph(g, 4)
    }

    /// A toy phase: merge every node with its minimum neighbor (Hash-Min
    /// style single hop) — converges, merges only within components.
    fn toy_phase(g: &ShardedGraph, _s: &mut Simulator, _r: &mut Rng, _p: u32) -> PhaseOutcome {
        let csr = Csr::build_sharded(g);
        let labels: Vec<Vertex> = (0..g.num_vertices() as u32)
            .map(|v| {
                csr.neighbors(v)
                    .iter()
                    .copied()
                    .chain(std::iter::once(v))
                    .min()
                    .unwrap()
            })
            .collect();
        let (contracted, node_map) = g.contract(&labels);
        PhaseOutcome {
            contracted,
            node_map,
        }
    }

    #[test]
    fn loop_terminates_and_labels_are_canonical() {
        let flat = generators::path(17).disjoint_union(generators::complete(5));
        let g = shard(&flat);
        let mut s = sim();
        let mut rng = Rng::new(1);
        let opts = LoopOptions {
            finisher_threshold: 0,
            prune_isolated: true,
            max_phases: 100,
        };
        let res = run(&g, &mut s, &mut rng, opts, toy_phase);
        assert!(res.completed);
        assert!(oracle::verify(&flat, &res.labels).is_ok());
        assert!(res.phases >= 2);
        assert_eq!(res.edges_per_phase[0], flat.num_edges() as u64);
    }

    #[test]
    fn finisher_short_circuits() {
        let flat = generators::path(64);
        let g = shard(&flat);
        let mut s = sim();
        let mut rng = Rng::new(2);
        let with_fin = run(
            &g,
            &mut s,
            &mut rng,
            LoopOptions {
                finisher_threshold: 1000, // larger than the graph
                prune_isolated: true,
                max_phases: 100,
            },
            toy_phase,
        );
        assert_eq!(with_fin.phases, 1, "finisher takes over immediately");
        assert!(oracle::verify(&flat, &with_fin.labels).is_ok());
        // the ship round's load sits entirely on machine_of(0)
        let ship = s_metrics_round(&with_fin, "finisher/ship");
        assert_eq!(ship.bytes, 16 * flat.num_edges() as u64);
        assert_eq!(ship.max_machine_bytes, ship.bytes);
    }

    fn s_metrics_round<'a>(
        res: &'a CcResult,
        label: &str,
    ) -> &'a crate::mpc::RoundMetrics {
        res.metrics
            .rounds
            .iter()
            .find(|r| r.label == label)
            .expect("round not recorded")
    }

    #[test]
    fn max_phases_guard_marks_incomplete() {
        let flat = generators::path(1 << 10);
        let g = shard(&flat);
        let mut s = sim();
        let mut rng = Rng::new(3);
        let res = run(
            &g,
            &mut s,
            &mut rng,
            LoopOptions {
                finisher_threshold: 0,
                prune_isolated: false,
                max_phases: 1,
            },
            toy_phase,
        );
        assert!(!res.completed);
        // labels still correct thanks to the guard resolution
        assert!(oracle::verify(&flat, &res.labels).is_ok());
    }

    #[test]
    fn isolated_vertices_resolve_immediately() {
        let g = ShardedGraph::empty(5, 4);
        let mut s = sim();
        let mut rng = Rng::new(4);
        let res = run(
            &g,
            &mut s,
            &mut rng,
            LoopOptions {
                finisher_threshold: 0,
                prune_isolated: true,
                max_phases: 10,
            },
            toy_phase,
        );
        assert_eq!(res.labels, vec![0, 1, 2, 3, 4]);
        assert_eq!(res.phases, 0);
    }

    #[test]
    fn edges_per_phase_is_monotone_for_contractive_phase() {
        let mut rng = Rng::new(5);
        let flat = generators::gnp(300, 0.02, &mut Rng::new(50));
        let g = shard(&flat);
        let mut s = sim();
        let res = run(
            &g,
            &mut s,
            &mut rng,
            LoopOptions {
                finisher_threshold: 0,
                prune_isolated: true,
                max_phases: 100,
            },
            toy_phase,
        );
        for w in res.edges_per_phase.windows(2) {
            assert!(w[1] <= w[0], "{:?}", res.edges_per_phase);
        }
    }
}
