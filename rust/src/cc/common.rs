//! Shared building blocks for the MPC algorithms: per-phase priorities,
//! neighborhood min/max hops as MPC rounds, and contraction as MPC rounds
//! (Lemma 3.1).

use crate::graph::{Csr, Graph, Vertex};
use crate::mpc::pool::{self, chunk_range};
use crate::mpc::simulator::machine_of;
use crate::mpc::Simulator;
use crate::util::rng::Rng;

/// Per-phase random ordering `rho` plus its inverse.
///
/// The paper samples "a random hash chosen uniformly from [0,1]"; since the
/// algorithms only *compare* priorities (§3), a uniform random permutation
/// of `[0, n)` is an equivalent and exactly-invertible encoding: `rho[v]`
/// is the priority of `v`, `inv[p]` the vertex holding priority `p`.
#[derive(Debug, Clone)]
pub struct Priorities {
    pub rho: Vec<u32>,
    pub inv: Vec<u32>,
}

impl Priorities {
    pub fn sample(n: usize, rng: &mut Rng) -> Self {
        // permutation() returns a uniformly random bijection; read it as
        // rho (vertex -> priority) and invert it.
        let rho = rng.permutation(n);
        let mut inv = vec![0u32; n];
        for (v, &p) in rho.iter().enumerate() {
            inv[p as usize] = v as u32;
        }
        Priorities { rho, inv }
    }
}

/// One MPC round computing, for every vertex, `op` over the values of its
/// neighbors (and itself if `include_self`).
///
/// Mapper: each edge `(u,v)` emits `(u, vals[v])` and `(v, vals[u])`;
/// each vertex emits its own value when `include_self`.  Reducer folds
/// with `op`.  This is exactly the label-computation round of Lemma 3.1.
pub fn neighborhood_fold<V>(
    sim: &mut Simulator,
    label: &str,
    g: &Graph,
    vals: &[V],
    include_self: bool,
    op: fn(V, V) -> V,
) -> Vec<V>
where
    V: crate::mpc::WireSize + Copy + Send + Sync,
{
    let n = g.num_vertices();
    debug_assert_eq!(vals.len(), n);
    // Associative+commutative per-key fold -> the simulator's grouping-free
    // chunked fast path: the edge list (and the self-message range) is
    // sliced into one lazy message chunk per configured thread, folded
    // edge-parallel on the worker pool (identical semantics and
    // accounting; §Perf).
    let mut out: Vec<V> = vals.to_vec();
    let edges = g.edges();
    let t = sim.cfg.threads.max(1);
    let chunks: Vec<_> = (0..t)
        .map(|i| {
            let (ea, eb) = chunk_range(edges.len(), t, i);
            let (sa, sb) = if include_self {
                chunk_range(n, t, i)
            } else {
                (0, 0)
            };
            // vertices with no messages keep their own value (out
            // prefilled), and the fold *replaces* on a key's first
            // message, so with include_self=false a vertex's own value
            // correctly drops out as soon as any neighbor message
            // arrives, and is kept otherwise.
            edges[ea..eb]
                .iter()
                .flat_map(move |&(u, v)| {
                    [
                        (u as u64, vals[v as usize]),
                        (v as u64, vals[u as usize]),
                    ]
                })
                .chain((sa..sb).map(move |v| (v as u64, vals[v])))
        })
        .collect();
    sim.round_fold_chunked(label, &mut out, chunks, op);
    out
}

/// `min` over `N(v) (∪ {v})` — the hop both LocalContraction hops and
/// Hash-Min use.
pub fn min_hop(
    sim: &mut Simulator,
    label: &str,
    g: &Graph,
    vals: &[u32],
    include_self: bool,
) -> Vec<u32> {
    neighborhood_fold(sim, label, g, vals, include_self, u32::min)
}

/// `max` over `N(v) (∪ {v})` — used by the MergeToLarge step to pick the
/// large node of largest priority within reach.
pub fn max_hop(
    sim: &mut Simulator,
    label: &str,
    g: &Graph,
    vals: &[u32],
    include_self: bool,
) -> Vec<u32> {
    neighborhood_fold(sim, label, g, vals, include_self, u32::max)
}

/// Two **fused** self-inclusive neighborhood hops (the `l_rho` two-hop of
/// §3 and the MergeToLarge reach-2 step of §5): one CSR traversal per hop
/// on the worker pool, while the model is charged exactly the two rounds
/// the unfused [`neighborhood_fold`] pair would record.
///
/// The fusion is metric-exact because both hops ship the same message
/// *shape*: each edge sends a fixed-size value both ways and every vertex
/// sends itself its own value, so `messages`, `bytes`, and the per-machine
/// key loads coincide for hop 1 and hop 2 — they are computed once and
/// recorded under both labels.  `op` must be associative and commutative
/// (min/max), which also makes the CSR evaluation order irrelevant.
pub fn fused_two_hop<V>(
    sim: &mut Simulator,
    labels: (&str, &str),
    g: &Graph,
    csr: &Csr,
    vals: &[V],
    op: fn(V, V) -> V,
) -> Vec<V>
where
    V: crate::mpc::WireSize + Copy + Send + Sync,
{
    let n = g.num_vertices();
    debug_assert_eq!(vals.len(), n);
    debug_assert_eq!(csr.num_vertices(), n);
    let t = sim.cfg.threads.max(1);
    let p = sim.cfg.machines.max(1);
    let edges = g.edges();

    // Per-machine load of one hop round: every edge charges both endpoint
    // keys, every vertex charges its own key (self message).  The charge
    // assumes every value of V reports one wire size (true of the Copy
    // scalar impls), so bytes = messages * msg_size; a variable-size V
    // would need the unfused per-message accounting instead.
    let msg_size: u64 = vals.first().map(|v| 8 + v.wire_size()).unwrap_or(0);
    debug_assert!(
        vals.iter().all(|v| 8 + v.wire_size() == msg_size),
        "fused_two_hop requires a uniform wire size across values"
    );
    let mb_parts = pool::global().run_jobs(
        (0..t)
            .map(|i| {
                let (ea, eb) = chunk_range(edges.len(), t, i);
                let (va, vb) = chunk_range(n, t, i);
                let edges = &edges[ea..eb];
                move || {
                    let mut mb = vec![0u64; p];
                    for &(u, v) in edges {
                        mb[machine_of(u as u64, p)] += msg_size;
                        mb[machine_of(v as u64, p)] += msg_size;
                    }
                    for v in va..vb {
                        mb[machine_of(v as u64, p)] += msg_size;
                    }
                    mb
                }
            })
            .collect(),
    );
    let mut machine_bytes = vec![0u64; p];
    for part in mb_parts {
        for (a, b) in machine_bytes.iter_mut().zip(&part) {
            *a += b;
        }
    }
    let messages = 2 * edges.len() as u64 + n as u64;
    let bytes = messages * msg_size;

    // The hop itself: vertex-chunked CSR traversal on the pool.
    let hop = |src: &[V]| -> Vec<V> {
        let parts = pool::global().run_jobs(
            (0..t)
                .map(|i| {
                    let (va, vb) = chunk_range(n, t, i);
                    move || {
                        (va..vb)
                            .map(|v| {
                                let mut best = src[v];
                                for &u in csr.neighbors(v as Vertex) {
                                    best = op(best, src[u as usize]);
                                }
                                best
                            })
                            .collect::<Vec<V>>()
                    }
                })
                .collect(),
        );
        let mut out = Vec::with_capacity(n);
        for part in parts {
            out.extend(part);
        }
        out
    };

    let h1 = hop(vals);
    sim.charge_round(labels.0, messages, bytes, &machine_bytes);
    let h2 = hop(&h1);
    sim.charge_round(labels.1, messages, bytes, &machine_bytes);
    h2
}

/// Contraction step as MPC rounds (Lemma 3.1): relabel both endpoints of
/// every edge through `labels`, dedup, and build the contracted graph.
///
/// Two shuffle rounds: round 1 keys edges by `u` and rewrites the left
/// endpoint; round 2 keys the half-rewritten edges by `v` and rewrites the
/// right endpoint ("these messages are grouped again by vertices and the
/// label mapping is applied").  Returns the contracted graph plus the
/// old-node -> new-node compaction map.
///
/// The two per-message transform rounds are **fused** into one chunked
/// pass on the worker pool, so the half-rewritten edge vector is never
/// materialized.  The accounting stays round-exact: round 1 sends
/// `(u, v)` keyed by `u`, round 2 sends `(l(u),)` keyed by the original
/// `v` — both 12-byte messages whose machine loads depend only on the
/// keys, so one pass computes both loads and charges the two rounds
/// separately.
pub fn contract_mpc(
    sim: &mut Simulator,
    g: &Graph,
    labels: &[Vertex],
) -> (Graph, Vec<Vertex>) {
    let p = sim.cfg.machines.max(1);
    let t = sim.cfg.threads.max(1);
    let edges = g.edges();
    let m = edges.len();
    let parts = pool::global().run_jobs(
        (0..t)
            .map(|i| {
                let (a, b) = chunk_range(m, t, i);
                let edges = &edges[a..b];
                move || {
                    let mut out = Vec::with_capacity(edges.len());
                    let mut mb_left = vec![0u64; p];
                    let mut mb_right = vec![0u64; p];
                    for &(u, v) in edges {
                        mb_left[machine_of(u as u64, p)] += 12;
                        mb_right[machine_of(v as u64, p)] += 12;
                        out.push((labels[u as usize], labels[v as usize]));
                    }
                    (out, mb_left, mb_right)
                }
            })
            .collect(),
    );
    let mut relabeled: Vec<(u32, u32)> = Vec::with_capacity(m);
    let mut mb_left = vec![0u64; p];
    let mut mb_right = vec![0u64; p];
    for (out, left, right) in parts {
        relabeled.extend(out);
        for (a, b) in mb_left.iter_mut().zip(&left) {
            *a += b;
        }
        for (a, b) in mb_right.iter_mut().zip(&right) {
            *a += b;
        }
    }
    let bytes = 12 * m as u64;
    sim.charge_round("contract/left", m as u64, bytes, &mb_left);
    sim.charge_round("contract/right", m as u64, bytes, &mb_right);

    // Build the contracted graph over the compacted label space (duplicate
    // removal is "standard", charged inside the same rounds).  Labels are
    // vertex ids < n, so compaction is the shared dense rank table
    // (`graph::label_ranks`) rather than per-edge binary search (§Perf).
    let n = labels.len();
    let (rank_of, count) = crate::graph::label_ranks(labels, n);
    let compact: Vec<Vertex> = labels.iter().map(|&l| rank_of[l as usize]).collect();
    let edges: Vec<(Vertex, Vertex)> = relabeled
        .into_iter()
        .map(|(lu, lv)| (rank_of[lu as usize], rank_of[lv as usize]))
        .collect();
    (Graph::from_edges(count, edges), compact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            threads: 1,
        })
    }

    #[test]
    fn priorities_are_inverse_consistent() {
        let mut rng = Rng::new(1);
        let p = Priorities::sample(100, &mut rng);
        for v in 0..100usize {
            assert_eq!(p.inv[p.rho[v] as usize], v as u32);
        }
    }

    #[test]
    fn min_hop_on_path() {
        let g = generators::path(5);
        let vals = vec![4, 3, 0, 1, 2];
        let mut s = sim();
        let out = min_hop(&mut s, "t", &g, &vals, true);
        assert_eq!(out, vec![3, 0, 0, 0, 1]);
        let out2 = min_hop(&mut s, "t", &g, &out, true);
        assert_eq!(out2, vec![0, 0, 0, 0, 0]);
        assert_eq!(s.metrics.num_rounds(), 2);
    }

    #[test]
    fn min_hop_excluding_self() {
        let g = generators::path(3);
        let vals = vec![0, 5, 9];
        let mut s = sim();
        let out = min_hop(&mut s, "t", &g, &vals, false);
        // vertex 0 sees only neighbor 1; vertex 1 sees {0,2}; vertex 2 sees {1}
        assert_eq!(out, vec![5, 0, 5]);
    }

    #[test]
    fn isolated_vertex_keeps_value() {
        let g = Graph::from_edges(3, vec![(0, 1)]);
        let vals = vec![2, 1, 7];
        let mut s = sim();
        let out = min_hop(&mut s, "t", &g, &vals, false);
        assert_eq!(out[2], 7);
    }

    #[test]
    fn max_hop_on_star() {
        let g = generators::star(4);
        let vals = vec![0, 5, 9, 2];
        let mut s = sim();
        let out = max_hop(&mut s, "t", &g, &vals, true);
        assert_eq!(out, vec![9, 5, 9, 2]);
    }

    #[test]
    fn contract_mpc_matches_graph_contract() {
        let g = generators::cycle(6);
        let labels: Vec<Vertex> = vec![0, 0, 2, 2, 4, 4];
        let mut s = sim();
        let (cm, compact_m) = contract_mpc(&mut s, &g, &labels);
        let (cg, compact_g) = g.contract(&labels);
        assert_eq!(cm, cg);
        assert_eq!(compact_m, compact_g);
        assert_eq!(s.metrics.num_rounds(), 2, "contraction is O(1) rounds");
    }

    fn sim_threads(threads: usize) -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            threads,
        })
    }

    #[test]
    fn fused_two_hop_matches_two_min_hops_on_random_graphs() {
        // Property: for random graphs, the fused CSR two-hop equals two
        // sequential min_hop rounds — same values AND same per-round model
        // metrics (messages, bytes, max_machine_bytes, space_violation).
        crate::util::quickcheck::Prop::new(24).check_sized(
            "fused-two-hop",
            300,
            |rng, size| {
                let n = size.max(2);
                generators::gnp(n, 4.0 / n as f64, rng)
            },
            |g| {
                let n = g.num_vertices();
                let vals: Vec<u32> = (0..n as u32).map(|v| v.wrapping_mul(2654435761)).collect();
                for threads in [1usize, 4] {
                    let mut s_seq = sim_threads(threads);
                    let h1 = min_hop(&mut s_seq, "hop1", g, &vals, true);
                    let h2 = min_hop(&mut s_seq, "hop2", g, &h1, true);

                    let mut s_fused = sim_threads(threads);
                    let csr = crate::graph::Csr::build(g);
                    let fused =
                        fused_two_hop(&mut s_fused, ("hop1", "hop2"), g, &csr, &vals, u32::min);

                    crate::prop_assert!(fused == h2, "values diverge (threads={threads})");
                    crate::prop_assert!(
                        s_fused.metrics.rounds == s_seq.metrics.rounds,
                        "metrics diverge (threads={threads}): {:?} vs {:?}",
                        s_fused.metrics.rounds,
                        s_seq.metrics.rounds
                    );
                }
                Ok(())
            },
        );
    }

    #[test]
    fn neighborhood_fold_is_engine_invariant() {
        let mut rng = Rng::new(21);
        let g = generators::gnp(800, 0.01, &mut rng);
        let vals: Vec<u32> = (0..800u32).rev().collect();
        let exec = |threads: usize, include_self: bool| {
            let mut s = sim_threads(threads);
            let out = neighborhood_fold(&mut s, "t", &g, &vals, include_self, u32::min);
            (out, s.metrics.rounds)
        };
        for include_self in [true, false] {
            let base = exec(1, include_self);
            for threads in [4, 8] {
                assert_eq!(
                    exec(threads, include_self),
                    base,
                    "threads={threads} include_self={include_self}"
                );
            }
        }
    }

    #[test]
    fn contract_mpc_is_engine_invariant() {
        let mut rng = Rng::new(22);
        let g = generators::gnp(600, 0.01, &mut rng);
        let labels: Vec<Vertex> = (0..600u32).map(|v| v % 97).collect();
        let exec = |threads: usize| {
            let mut s = sim_threads(threads);
            let (cg, compact) = contract_mpc(&mut s, &g, &labels);
            (cg, compact, s.metrics.rounds)
        };
        let base = exec(1);
        for threads in [4, 8] {
            assert_eq!(exec(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn contract_mpc_charges_o_m_bytes() {
        let mut rng = Rng::new(2);
        let g = generators::gnp(300, 0.02, &mut rng);
        let labels: Vec<Vertex> = (0..300u32).map(|v| v / 2).collect();
        let mut s = sim();
        let _ = contract_mpc(&mut s, &g, &labels);
        let bytes = s.metrics.total_bytes();
        let m = g.num_edges() as u64;
        assert!(bytes >= m * 12 && bytes <= m * 40, "bytes {bytes} vs m {m}");
    }
}
