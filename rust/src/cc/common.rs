//! Shared building blocks for the MPC algorithms: per-phase priorities,
//! neighborhood min/max hops as MPC rounds, and contraction as MPC rounds
//! (Lemma 3.1).

use crate::graph::{Graph, Vertex};
use crate::mpc::Simulator;
use crate::util::rng::Rng;

/// Per-phase random ordering `rho` plus its inverse.
///
/// The paper samples "a random hash chosen uniformly from [0,1]"; since the
/// algorithms only *compare* priorities (§3), a uniform random permutation
/// of `[0, n)` is an equivalent and exactly-invertible encoding: `rho[v]`
/// is the priority of `v`, `inv[p]` the vertex holding priority `p`.
#[derive(Debug, Clone)]
pub struct Priorities {
    pub rho: Vec<u32>,
    pub inv: Vec<u32>,
}

impl Priorities {
    pub fn sample(n: usize, rng: &mut Rng) -> Self {
        let mut inv = rng.permutation(n); // inv[p] = vertex with priority p
        // actually build rho first, then invert — permutation() returns a
        // uniformly random bijection either way.
        let rho = std::mem::take(&mut inv);
        let mut inv = vec![0u32; n];
        for (v, &p) in rho.iter().enumerate() {
            inv[p as usize] = v as u32;
        }
        Priorities { rho, inv }
    }
}

/// One MPC round computing, for every vertex, `op` over the values of its
/// neighbors (and itself if `include_self`).
///
/// Mapper: each edge `(u,v)` emits `(u, vals[v])` and `(v, vals[u])`;
/// each vertex emits its own value when `include_self`.  Reducer folds
/// with `op`.  This is exactly the label-computation round of Lemma 3.1.
pub fn neighborhood_fold<V>(
    sim: &mut Simulator,
    label: &str,
    g: &Graph,
    vals: &[V],
    include_self: bool,
    op: fn(V, V) -> V,
) -> Vec<V>
where
    V: crate::mpc::WireSize + Copy + Send + Sync,
{
    let n = g.num_vertices();
    debug_assert_eq!(vals.len(), n);
    // Associative+commutative per-key fold -> the simulator's grouping-free
    // fast path (identical semantics and accounting; §Perf).
    let mut out: Vec<V> = vals.to_vec();
    let edge_msgs = g.edges().iter().flat_map(|&(u, v)| {
        [
            (u as u64, vals[v as usize]),
            (v as u64, vals[u as usize]),
        ]
    });
    let self_msgs = (0..if include_self { n } else { 0 }).map(|v| (v as u64, vals[v]));
    // vertices with no messages keep their own value (out prefilled), and
    // round_fold overwrites on first touch, so self-inclusion is exact.
    // round_fold *replaces* on a key's first message, so with
    // include_self=false a vertex's own value correctly drops out as soon
    // as any neighbor message arrives, and is kept otherwise.
    sim.round_fold(label, &mut out, edge_msgs.chain(self_msgs), op);
    out
}

/// `min` over `N(v) (∪ {v})` — the hop both LocalContraction hops and
/// Hash-Min use.
pub fn min_hop(
    sim: &mut Simulator,
    label: &str,
    g: &Graph,
    vals: &[u32],
    include_self: bool,
) -> Vec<u32> {
    neighborhood_fold(sim, label, g, vals, include_self, u32::min)
}

/// `max` over `N(v) (∪ {v})` — used by the MergeToLarge step to pick the
/// large node of largest priority within reach.
pub fn max_hop(
    sim: &mut Simulator,
    label: &str,
    g: &Graph,
    vals: &[u32],
    include_self: bool,
) -> Vec<u32> {
    neighborhood_fold(sim, label, g, vals, include_self, u32::max)
}

/// Contraction step as MPC rounds (Lemma 3.1): relabel both endpoints of
/// every edge through `labels`, dedup, and build the contracted graph.
///
/// Two shuffle rounds: round 1 keys edges by `u` and rewrites the left
/// endpoint; round 2 keys the half-rewritten edges by `v` and rewrites the
/// right endpoint ("these messages are grouped again by vertices and the
/// label mapping is applied").  Returns the contracted graph plus the
/// old-node -> new-node compaction map.
pub fn contract_mpc(
    sim: &mut Simulator,
    g: &Graph,
    labels: &[Vertex],
) -> (Graph, Vec<Vertex>) {
    // Both rounds are per-message transforms (the machine owning the key
    // applies the label map) -> the simulator's grouping-free map path.
    // round 1: (u, v) -> (l(u), v), keyed by u
    let half: Vec<(u32, u32)> = sim.round_map(
        "contract/left",
        g.edges().iter().map(|&(u, v)| (u as u64, v)),
        |u, v| (labels[u as usize], v),
    );
    // round 2: (l(u), v) -> (l(u), l(v)), keyed by v
    let relabeled: Vec<(u32, u32)> = sim.round_map(
        "contract/right",
        half.into_iter().map(|(lu, v)| (v as u64, lu)),
        |v, lu| (lu, labels[v as usize]),
    );

    // Build the contracted graph over the compacted label space (duplicate
    // removal is "standard", charged inside the same rounds).  Labels are
    // vertex ids < n, so compaction is a rank table rather than per-edge
    // binary search (§Perf).
    let n = labels.len();
    let mut present = vec![false; n];
    for &l in labels {
        present[l as usize] = true;
    }
    let mut rank_of = vec![0 as Vertex; n];
    let mut next = 0 as Vertex;
    for l in 0..n {
        if present[l] {
            rank_of[l] = next;
            next += 1;
        }
    }
    let compact: Vec<Vertex> = labels.iter().map(|&l| rank_of[l as usize]).collect();
    let edges: Vec<(Vertex, Vertex)> = relabeled
        .into_iter()
        .map(|(lu, lv)| (rank_of[lu as usize], rank_of[lv as usize]))
        .collect();
    (Graph::from_edges(next as usize, edges), compact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            threads: 1,
        })
    }

    #[test]
    fn priorities_are_inverse_consistent() {
        let mut rng = Rng::new(1);
        let p = Priorities::sample(100, &mut rng);
        for v in 0..100usize {
            assert_eq!(p.inv[p.rho[v] as usize], v as u32);
        }
    }

    #[test]
    fn min_hop_on_path() {
        let g = generators::path(5);
        let vals = vec![4, 3, 0, 1, 2];
        let mut s = sim();
        let out = min_hop(&mut s, "t", &g, &vals, true);
        assert_eq!(out, vec![3, 0, 0, 0, 1]);
        let out2 = min_hop(&mut s, "t", &g, &out, true);
        assert_eq!(out2, vec![0, 0, 0, 0, 0]);
        assert_eq!(s.metrics.num_rounds(), 2);
    }

    #[test]
    fn min_hop_excluding_self() {
        let g = generators::path(3);
        let vals = vec![0, 5, 9];
        let mut s = sim();
        let out = min_hop(&mut s, "t", &g, &vals, false);
        // vertex 0 sees only neighbor 1; vertex 1 sees {0,2}; vertex 2 sees {1}
        assert_eq!(out, vec![5, 0, 5]);
    }

    #[test]
    fn isolated_vertex_keeps_value() {
        let g = Graph::from_edges(3, vec![(0, 1)]);
        let vals = vec![2, 1, 7];
        let mut s = sim();
        let out = min_hop(&mut s, "t", &g, &vals, false);
        assert_eq!(out[2], 7);
    }

    #[test]
    fn max_hop_on_star() {
        let g = generators::star(4);
        let vals = vec![0, 5, 9, 2];
        let mut s = sim();
        let out = max_hop(&mut s, "t", &g, &vals, true);
        assert_eq!(out, vec![9, 5, 9, 2]);
    }

    #[test]
    fn contract_mpc_matches_graph_contract() {
        let g = generators::cycle(6);
        let labels: Vec<Vertex> = vec![0, 0, 2, 2, 4, 4];
        let mut s = sim();
        let (cm, compact_m) = contract_mpc(&mut s, &g, &labels);
        let (cg, compact_g) = g.contract(&labels);
        assert_eq!(cm, cg);
        assert_eq!(compact_m, compact_g);
        assert_eq!(s.metrics.num_rounds(), 2, "contraction is O(1) rounds");
    }

    #[test]
    fn contract_mpc_charges_o_m_bytes() {
        let mut rng = Rng::new(2);
        let g = generators::gnp(300, 0.02, &mut rng);
        let labels: Vec<Vertex> = (0..300u32).map(|v| v / 2).collect();
        let mut s = sim();
        let _ = contract_mpc(&mut s, &g, &labels);
        let bytes = s.metrics.total_bytes();
        let m = g.num_edges() as u64;
        assert!(bytes >= m * 12 && bytes <= m * 40, "bytes {bytes} vs m {m}");
    }
}
