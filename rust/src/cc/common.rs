//! Shared building blocks for the MPC algorithms: per-phase priorities,
//! neighborhood min/max hops as MPC rounds, and contraction as MPC rounds
//! (Lemma 3.1) — all consuming the resident [`ShardedGraph`] natively.
//!
//! Message chunking is **by shard**, never by a `chunk_range` slice of one
//! flat edge vector: every helper walks the shards the machine partition
//! already owns, and the per-machine byte accounting comes pre-computed
//! from shard statistics ([`ShardedGraph::hop_charge`],
//! [`ShardedGraph::contract_charges`]) rather than a `machine_of` call per
//! message.

use crate::graph::{Csr, ShardedGraph, Vertex};
use crate::mpc::pool::{self, chunk_range};
use crate::mpc::{Simulator, WireFold};
use crate::util::rng::Rng;

/// Per-phase random ordering `rho` plus its inverse.
///
/// The paper samples "a random hash chosen uniformly from [0,1]"; since the
/// algorithms only *compare* priorities (§3), a uniform random permutation
/// of `[0, n)` is an equivalent and exactly-invertible encoding: `rho[v]`
/// is the priority of `v`, `inv[p]` the vertex holding priority `p`.
#[derive(Debug, Clone)]
pub struct Priorities {
    pub rho: Vec<u32>,
    pub inv: Vec<u32>,
}

impl Priorities {
    pub fn sample(n: usize, rng: &mut Rng) -> Self {
        // permutation() returns a uniformly random bijection; read it as
        // rho (vertex -> priority) and invert it.
        let rho = rng.permutation(n);
        let mut inv = vec![0u32; n];
        for (v, &p) in rho.iter().enumerate() {
            inv[p as usize] = v as u32;
        }
        Priorities { rho, inv }
    }
}

/// Guard for the shard-count contract: `MpcConfig.machines` is the single
/// source of the shard count.  A hard assert (O(1), once per round): on a
/// mismatch the shard-derived charges would silently corrupt the
/// per-machine metrics, so failing loudly beats a wrong `max_machine_bytes`.
#[inline]
fn check_shards(g: &ShardedGraph, sim: &Simulator) {
    assert_eq!(
        g.num_shards(),
        sim.cfg.machines.max(1),
        "shard count diverged from MpcConfig.machines — reshard the graph \
         (ShardedGraph::reshard) or fix the simulator config"
    );
}

/// One MPC round computing, for every vertex, `op` over the values of its
/// neighbors (and itself if `include_self`).
///
/// Mapper: each edge `(u,v)` emits `(u, vals[v])` and `(v, vals[u])`;
/// each vertex emits its own value when `include_self`.  Reducer folds
/// with `op`.  This is exactly the label-computation round of Lemma 3.1.
///
/// The message stream is one lazy chunk per **shard** (edges the shard
/// owns, plus a `1/p` range of the self messages — an arbitrary but fixed
/// assignment, legal because the fold is associative and commutative), so
/// both the values and the metrics are functions of `machines` alone,
/// never of `threads`.  The chunks load spilled shards on the workers
/// that fold them ([`ShardedGraph::msg_chunks`]), so an out-of-core graph
/// streams through the round with at most one shard per thread in RAM.
///
/// `fold` carries the op's wire identity ([`WireFold`]): on the
/// multi-process transport a tagged fold is reduced by the worker
/// processes owning the keys — same values, same metrics, real shuffle.
pub fn neighborhood_fold<V>(
    sim: &mut Simulator,
    label: &str,
    g: &ShardedGraph,
    vals: &[V],
    include_self: bool,
    fold: WireFold<V>,
) -> Vec<V>
where
    V: crate::mpc::WireSize + Copy + Send + Sync,
{
    let n = g.num_vertices();
    debug_assert_eq!(vals.len(), n);
    check_shards(g, sim);
    let p = g.num_shards();
    let msg_size = vals.first().map(|v| 8 + v.wire_size()).unwrap_or(0);
    debug_assert!(
        vals.iter().all(|v| 8 + v.wire_size() == msg_size),
        "sharded hop accounting requires a uniform wire size across values"
    );
    let charge = g.hop_charge(msg_size, include_self);
    // Worker-native path (shuffle transport): the workers generate this
    // exact round from their owned shards and shuffle it peer to peer;
    // the engine computes the same fold locally and validates the
    // workers' load counts + fold checksums against it.  `None` means
    // the transport has no worker data plane — fall through.
    if let Some(out) = sim.try_shuffle_hop(label, g, vals, include_self, fold, &charge) {
        return out;
    }
    let mut out: Vec<V> = vals.to_vec();
    // Sub-shard splitting (non-wire only): with more pool threads than
    // shards, whole-shard chunks would leave workers idle; splitting by
    // row range keeps them fed, and a mapped (spilled) shard hands each
    // sub-chunk a borrowed cursor slice over the same image — no copy.
    // The fold being associative + commutative, and the charge being
    // pre-computed, values and metrics stay bit-identical for every
    // `parts`.  The wire path keeps one chunk per shard: it serializes
    // each machine's byte image in chunk stream order, which must not
    // depend on the thread count.
    let parts = if sim.wire_mode() {
        1
    } else {
        sim.cfg.threads.max(1)
    };
    // vertices with no messages keep their own value (out prefilled), and
    // the fold *replaces* on a key's first message, so with
    // include_self=false a vertex's own value correctly drops out as soon
    // as any neighbor message arrives, and is kept otherwise.  The shard's
    // `1/p` range of self messages rides on its primary chunk only, so
    // splitting never duplicates them.
    let chunks = g.msg_chunks_split(parts, move |s, primary, edges| {
        let (sa, sb) = if include_self && primary {
            chunk_range(n, p, s)
        } else {
            (0, 0)
        };
        edges
            .flat_map(move |(u, v)| {
                [
                    (u as u64, vals[v as usize]),
                    (v as u64, vals[u as usize]),
                ]
            })
            .chain((sa..sb).map(move |v| (v as u64, vals[v])))
    });
    sim.round_fold_sharded_tagged(label, &mut out, chunks, charge, fold);
    out
}

/// `min` over `N(v) (∪ {v})` — the hop both LocalContraction hops and
/// Hash-Min use.
pub fn min_hop(
    sim: &mut Simulator,
    label: &str,
    g: &ShardedGraph,
    vals: &[u32],
    include_self: bool,
) -> Vec<u32> {
    neighborhood_fold(sim, label, g, vals, include_self, WireFold::min_u32())
}

/// `max` over `N(v) (∪ {v})` — used by the MergeToLarge step to pick the
/// large node of largest priority within reach.
pub fn max_hop(
    sim: &mut Simulator,
    label: &str,
    g: &ShardedGraph,
    vals: &[u32],
    include_self: bool,
) -> Vec<u32> {
    neighborhood_fold(sim, label, g, vals, include_self, WireFold::max_u32())
}

/// Two **fused** self-inclusive neighborhood hops (the `l_rho` two-hop of
/// §3 and the MergeToLarge reach-2 step of §5): one CSR traversal per hop
/// on the worker pool, while the model is charged exactly the two rounds
/// the unfused [`neighborhood_fold`] pair would record.
///
/// The fusion is metric-exact because both hops ship the same message
/// *shape*: each edge sends a fixed-size value both ways and every vertex
/// sends itself its own value, so `messages`, `bytes`, and the per-machine
/// key loads coincide for hop 1 and hop 2 — and, with the sharded store,
/// they fall directly out of [`ShardedGraph::hop_charge`]: the extra
/// load-computation pass over the edge list the unsharded engine needed is
/// gone.  The fold must be associative and commutative (min/max), which
/// also makes the CSR evaluation order irrelevant.
///
/// The fusion is a **shared-memory** optimization: both hops read the CSR
/// in place, which no transport that actually moves bytes can replicate.
/// On a wire transport the helper therefore runs the two real hop rounds
/// instead — same values and same per-round metrics (that equivalence is
/// exactly what `fused_two_hop_matches_two_min_hops_on_random_graphs`
/// enforces), with the messages genuinely shuffled.
pub fn fused_two_hop<V>(
    sim: &mut Simulator,
    labels: (&str, &str),
    g: &ShardedGraph,
    csr: &Csr,
    vals: &[V],
    fold: WireFold<V>,
) -> Vec<V>
where
    V: crate::mpc::WireSize + Copy + Send + Sync,
{
    let n = g.num_vertices();
    debug_assert_eq!(vals.len(), n);
    debug_assert_eq!(csr.num_vertices(), n);
    check_shards(g, sim);
    if sim.wire_mode() {
        // The two hops have no coordinator data dependency between them
        // (hop 2 folds hop 1's output over the same graph) — on a
        // shuffle transport they ship as ONE pipelined descriptor batch
        // and the workers run them back-to-back, acking once.  Charges
        // and outputs are bit-identical to the sequential rounds below.
        let msg_size: u64 = vals.first().map(|v| 8 + v.wire_size()).unwrap_or(0);
        if vals.iter().all(|v| 8 + v.wire_size() == msg_size) {
            let charge = g.hop_charge(msg_size, true);
            let plan = crate::mpc::RoundPlan {
                labels: &[labels.0, labels.1],
                include_self: true,
            };
            if let Some(out) = sim.try_shuffle_hop_plan(plan, g, vals, fold, &charge) {
                return out;
            }
        }
        let h1 = neighborhood_fold(sim, labels.0, g, vals, true, fold);
        return neighborhood_fold(sim, labels.1, g, &h1, true, fold);
    }
    let op = fold.f;
    let t = sim.cfg.threads.max(1);

    // Per-machine load of one hop round, straight from shard membership.
    // The charge assumes every value of V reports one wire size (true of
    // the Copy scalar impls); a variable-size V would need the unfused
    // per-message accounting instead.
    let msg_size: u64 = vals.first().map(|v| 8 + v.wire_size()).unwrap_or(0);
    debug_assert!(
        vals.iter().all(|v| 8 + v.wire_size() == msg_size),
        "fused_two_hop requires a uniform wire size across values"
    );
    let charge = g.hop_charge(msg_size, true);

    // The hop itself: vertex-chunked CSR traversal on the pool.
    let hop = |src: &[V]| -> Vec<V> {
        let parts = pool::global().run_jobs(
            (0..t)
                .map(|i| {
                    let (va, vb) = chunk_range(n, t, i);
                    move || {
                        (va..vb)
                            .map(|v| {
                                let mut best = src[v];
                                for &u in csr.neighbors(v as Vertex) {
                                    best = op(best, src[u as usize]);
                                }
                                best
                            })
                            .collect::<Vec<V>>()
                    }
                })
                .collect(),
        );
        let mut out = Vec::with_capacity(n);
        for part in parts {
            out.extend(part);
        }
        out
    };

    let h1 = hop(vals);
    sim.charge_round(labels.0, charge.messages, charge.bytes, &charge.machine_bytes);
    let h2 = hop(&h1);
    sim.charge_round(labels.1, charge.messages, charge.bytes, &charge.machine_bytes);
    h2
}

/// Contraction step as MPC rounds (Lemma 3.1): relabel both endpoints of
/// every edge through `labels`, dedup, and build the contracted graph.
///
/// Two shuffle rounds: round 1 keys edges by `u` and rewrites the left
/// endpoint; round 2 keys the half-rewritten edges by `v` and rewrites the
/// right endpoint ("these messages are grouped again by vertices and the
/// label mapping is applied").  Returns the contracted graph plus the
/// old-node -> new-node compaction map.
///
/// With the sharded store both halves collapse into the graph layer:
/// round 1's key is the owner shard itself and round 2's key lands on the
/// cached peer histogram, so the two charges are pure shard statistics
/// ([`ShardedGraph::contract_charges`]), and the relabel + re-bucket into
/// the new owner shards happens in one shard-parallel pass
/// ([`ShardedGraph::contract`]) — the half-rewritten edge vector is never
/// materialized, and neither is any flat concatenation.
pub fn contract_mpc(
    sim: &mut Simulator,
    g: &ShardedGraph,
    labels: &[Vertex],
) -> (ShardedGraph, Vec<Vertex>) {
    check_shards(g, sim);
    let (left, right) = g.contract_charges();
    let (contracted, compact) = g.contract(labels);
    sim.charge_round("contract/left", left.messages, left.bytes, &left.machine_bytes);
    sim.charge_round(
        "contract/right",
        right.messages,
        right.bytes,
        &right.machine_bytes,
    );
    // Shuffle transport: shard custody survives the contraction — the
    // workers rewrite their own edges through the compaction map and ship
    // them peer to peer to the next generation's owners (validated
    // against `contracted`); a no-op on every other transport.
    sim.shuffle_rewire(g, &compact, &contracted);
    (contracted, compact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Graph};
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        })
    }

    fn shard(g: &Graph, p: usize) -> ShardedGraph {
        ShardedGraph::from_graph(g, p)
    }

    #[test]
    fn priorities_are_inverse_consistent() {
        let mut rng = Rng::new(1);
        let p = Priorities::sample(100, &mut rng);
        for v in 0..100usize {
            assert_eq!(p.inv[p.rho[v] as usize], v as u32);
        }
    }

    #[test]
    fn min_hop_on_path() {
        let g = shard(&generators::path(5), 4);
        let vals = vec![4, 3, 0, 1, 2];
        let mut s = sim();
        let out = min_hop(&mut s, "t", &g, &vals, true);
        assert_eq!(out, vec![3, 0, 0, 0, 1]);
        let out2 = min_hop(&mut s, "t", &g, &out, true);
        assert_eq!(out2, vec![0, 0, 0, 0, 0]);
        assert_eq!(s.metrics.num_rounds(), 2);
    }

    #[test]
    fn min_hop_excluding_self() {
        let g = shard(&generators::path(3), 4);
        let vals = vec![0, 5, 9];
        let mut s = sim();
        let out = min_hop(&mut s, "t", &g, &vals, false);
        // vertex 0 sees only neighbor 1; vertex 1 sees {0,2}; vertex 2 sees {1}
        assert_eq!(out, vec![5, 0, 5]);
    }

    #[test]
    fn isolated_vertex_keeps_value() {
        let g = ShardedGraph::from_edges(3, 4, vec![(0, 1)]);
        let vals = vec![2, 1, 7];
        let mut s = sim();
        let out = min_hop(&mut s, "t", &g, &vals, false);
        assert_eq!(out[2], 7);
    }

    #[test]
    fn max_hop_on_star() {
        let g = shard(&generators::star(4), 4);
        let vals = vec![0, 5, 9, 2];
        let mut s = sim();
        let out = max_hop(&mut s, "t", &g, &vals, true);
        assert_eq!(out, vec![9, 5, 9, 2]);
    }

    #[test]
    fn hop_metrics_match_per_message_reference() {
        // The shard-derived charge must equal round_fold's per-message
        // accounting on the same multiset — same label so the whole
        // RoundMetrics compares equal.
        let flat = generators::gnp(300, 0.02, &mut Rng::new(5));
        let g = shard(&flat, 4);
        let vals: Vec<u32> = (0..300u32).rev().collect();
        for include_self in [true, false] {
            let mut s_ref = sim();
            let mut out_ref = vals.clone();
            let edge_msgs = flat.edges().iter().flat_map(|&(u, v)| {
                [
                    (u as u64, vals[v as usize]),
                    (v as u64, vals[u as usize]),
                ]
            });
            let self_msgs = (0..if include_self { 300u64 } else { 0 })
                .map(|v| (v, vals[v as usize]));
            s_ref.round_fold("t", &mut out_ref, edge_msgs.chain(self_msgs), u32::min);

            let mut s = sim();
            let out = min_hop(&mut s, "t", &g, &vals, include_self);
            assert_eq!(out, out_ref, "include_self={include_self}");
            assert_eq!(
                s.metrics.rounds[0], s_ref.metrics.rounds[0],
                "include_self={include_self}"
            );
        }
    }

    #[test]
    fn contract_mpc_matches_graph_contract() {
        let flat = generators::cycle(6);
        let g = shard(&flat, 4);
        let labels: Vec<Vertex> = vec![0, 0, 2, 2, 4, 4];
        let mut s = sim();
        let (cm, compact_m) = contract_mpc(&mut s, &g, &labels);
        let (cg, compact_g) = flat.contract(&labels);
        assert_eq!(cm.to_graph(), cg);
        assert_eq!(compact_m, compact_g);
        assert_eq!(s.metrics.num_rounds(), 2, "contraction is O(1) rounds");
    }

    #[test]
    fn contract_mpc_metrics_match_per_message_reference() {
        use crate::mpc::simulator::machine_of;
        let flat = generators::gnp(250, 0.02, &mut Rng::new(6));
        let g = shard(&flat, 4);
        let labels: Vec<Vertex> = (0..250u32).map(|v| v % 41).collect();
        let mut s = sim();
        let _ = contract_mpc(&mut s, &g, &labels);
        let m = flat.num_edges() as u64;
        let mut mb_left = vec![0u64; 4];
        let mut mb_right = vec![0u64; 4];
        for &(u, v) in flat.edges() {
            mb_left[machine_of(u as u64, 4)] += 12;
            mb_right[machine_of(v as u64, 4)] += 12;
        }
        let left = &s.metrics.rounds[0];
        let right = &s.metrics.rounds[1];
        assert_eq!((left.messages, left.bytes), (m, 12 * m));
        assert_eq!(left.max_machine_bytes, mb_left.iter().copied().max().unwrap());
        assert_eq!((right.messages, right.bytes), (m, 12 * m));
        assert_eq!(
            right.max_machine_bytes,
            mb_right.iter().copied().max().unwrap()
        );
    }

    fn sim_threads(threads: usize) -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads,
        })
    }

    #[test]
    fn fused_two_hop_matches_two_min_hops_on_random_graphs() {
        // Property: for random graphs, the fused CSR two-hop equals two
        // sequential min_hop rounds — same values AND same per-round model
        // metrics (messages, bytes, max_machine_bytes, space_violation).
        crate::util::quickcheck::Prop::new(24).check_sized(
            "fused-two-hop",
            300,
            |rng, size| {
                let n = size.max(2);
                generators::gnp(n, 4.0 / n as f64, rng)
            },
            |flat| {
                let n = flat.num_vertices();
                let g = ShardedGraph::from_graph(flat, 4);
                let vals: Vec<u32> = (0..n as u32).map(|v| v.wrapping_mul(2654435761)).collect();
                for threads in [1usize, 4] {
                    let mut s_seq = sim_threads(threads);
                    let h1 = min_hop(&mut s_seq, "hop1", &g, &vals, true);
                    let h2 = min_hop(&mut s_seq, "hop2", &g, &h1, true);

                    let mut s_fused = sim_threads(threads);
                    let csr = Csr::build_sharded(&g);
                    let fused =
                        fused_two_hop(
                            &mut s_fused,
                            ("hop1", "hop2"),
                            &g,
                            &csr,
                            &vals,
                            WireFold::min_u32(),
                        );

                    crate::prop_assert!(fused == h2, "values diverge (threads={threads})");
                    crate::prop_assert!(
                        s_fused.metrics.rounds == s_seq.metrics.rounds,
                        "metrics diverge (threads={threads}): {:?} vs {:?}",
                        s_fused.metrics.rounds,
                        s_seq.metrics.rounds
                    );
                }
                Ok(())
            },
        );
    }

    #[test]
    fn neighborhood_fold_is_engine_invariant() {
        let mut rng = Rng::new(21);
        let g = shard(&generators::gnp(800, 0.01, &mut rng), 4);
        let vals: Vec<u32> = (0..800u32).rev().collect();
        let exec = |threads: usize, include_self: bool| {
            let mut s = sim_threads(threads);
            let out =
                neighborhood_fold(&mut s, "t", &g, &vals, include_self, WireFold::min_u32());
            (out, s.metrics.rounds)
        };
        for include_self in [true, false] {
            let base = exec(1, include_self);
            for threads in [4, 8] {
                assert_eq!(
                    exec(threads, include_self),
                    base,
                    "threads={threads} include_self={include_self}"
                );
            }
        }
    }

    #[test]
    fn contract_mpc_is_engine_invariant() {
        let mut rng = Rng::new(22);
        let g = shard(&generators::gnp(600, 0.01, &mut rng), 4);
        let labels: Vec<Vertex> = (0..600u32).map(|v| v % 97).collect();
        let exec = |threads: usize| {
            let mut s = sim_threads(threads);
            let (cg, compact) = contract_mpc(&mut s, &g, &labels);
            (cg, compact, s.metrics.rounds)
        };
        let base = exec(1);
        for threads in [4, 8] {
            assert_eq!(exec(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn contract_mpc_charges_o_m_bytes() {
        let mut rng = Rng::new(2);
        let g = shard(&generators::gnp(300, 0.02, &mut rng), 4);
        let labels: Vec<Vertex> = (0..300u32).map(|v| v / 2).collect();
        let mut s = sim();
        let _ = contract_mpc(&mut s, &g, &labels);
        let bytes = s.metrics.total_bytes();
        let m = g.num_edges() as u64;
        assert!(bytes >= m * 12 && bytes <= m * 40, "bytes {bytes} vs m {m}");
    }
}
