//! Connected-components algorithms (§3–§7 of the paper).
//!
//! The paper's contributions — [`local_contraction`] (with the
//! [`merge_to_large`] step of §5) and [`tree_contraction`] — plus the
//! published baselines it evaluates against: [`cracker`], [`two_phase`],
//! [`hash_to_min`], and the trivial O(d) [`hash_min`].  All run on the
//! [`crate::mpc`] simulator and are checked against the sequential
//! [`oracle`].
//!
//! Every algorithm returns [`CcResult`] with **canonical labels**
//! (`labels[v]` = minimum original vertex id in `v`'s component) so outputs
//! are comparable with plain equality across algorithms and the oracle.

pub mod backend;
pub mod common;
pub mod contraction_loop;
pub mod cracker;
pub mod hash_min;
pub mod hash_to_min;
pub mod local_contraction;
pub mod merge_to_large;
pub mod oracle;
pub mod tree_contraction;
pub mod two_phase;

use crate::graph::{Graph, ShardedGraph, Vertex};
use crate::mpc::{Metrics, Simulator};
use crate::util::rng::Rng;

/// Result of a connected-components run.
#[derive(Debug, Clone)]
pub struct CcResult {
    /// Canonical labels: `labels[v]` = min original vertex id in the
    /// component of `v`.
    pub labels: Vec<Vertex>,
    /// Logical phases executed (the unit Tables 2/3 count).
    pub phases: u32,
    /// Whether the run completed (Hash-To-Min style algorithms can be
    /// aborted by the resource guard — the paper's "X" entries).
    pub completed: bool,
    /// Edges at the *beginning* of each phase (Figure 1 series).
    pub edges_per_phase: Vec<u64>,
    /// Nodes at the beginning of each phase.
    pub nodes_per_phase: Vec<u64>,
    /// MPC round/communication accounting.
    pub metrics: Metrics,
}

impl CcResult {
    pub fn num_components(&self) -> usize {
        let mut ls: Vec<Vertex> = self.labels.clone();
        ls.sort_unstable();
        ls.dedup();
        ls.len()
    }
}

/// Per-run options shared by the algorithms.
#[derive(Clone, Copy)]
pub struct RunOptions<'a> {
    /// Ship the graph to one machine (streaming union-find) once it has at
    /// most this many edges (§6 optimization).  0 disables.
    pub finisher_threshold: usize,
    /// Drop isolated nodes after each phase (§6 optimization).
    pub prune_isolated: bool,
    /// Hard cap on phases (resource guard; generous default).
    pub max_phases: u32,
    /// Hard cap on live state per vertex-set for cluster-growing algorithms
    /// (Hash-To-Min guard, in total stored vertex ids). 0 = unlimited.
    pub state_cap: u64,
    /// Optional compiled dense backend (the XLA artifact path) used for the
    /// per-phase label computation when the current graph fits a shard.
    pub dense_backend: Option<&'a dyn backend::DenseBackend>,
}

impl Default for RunOptions<'_> {
    fn default() -> Self {
        RunOptions {
            finisher_threshold: 0,
            prune_isolated: true,
            max_phases: 200,
            state_cap: 0,
            dense_backend: None,
        }
    }
}

/// Common interface: run under `sim`, seeded deterministically.
///
/// The primary entry is [`run_sharded`](CcAlgorithm::run_sharded) — the
/// algorithms compute on the resident [`ShardedGraph`], whose shard count
/// must equal `sim.cfg.machines` (the single source of the shard count).
/// [`run`](CcAlgorithm::run) is the flat-ingest adapter: it shards `g`
/// once and delegates.
pub trait CcAlgorithm {
    fn name(&self) -> &'static str;

    /// Run on the sharded resident representation.  Callers must shard
    /// with `sim.cfg.machines` shards (debug-asserted by the round
    /// helpers in [`common`]).
    fn run_sharded(
        &self,
        g: &ShardedGraph,
        sim: &mut Simulator,
        rng: &mut Rng,
        opts: &RunOptions,
    ) -> CcResult;

    /// Flat-ingest convenience: shard `g` by `sim.cfg.machines` and run.
    /// The simulator's `spill_budget` becomes the graph's residency
    /// policy, so an over-budget edge set runs disk-backed from ingest
    /// through every contracted generation.
    fn run(
        &self,
        g: &Graph,
        sim: &mut Simulator,
        rng: &mut Rng,
        opts: &RunOptions,
    ) -> CcResult {
        let sharded = ShardedGraph::from_graph_with(
            g,
            sim.cfg.machines.max(1),
            crate::graph::SpillPolicy::with_budget(sim.cfg.spill_budget),
        );
        self.run_sharded(&sharded, sim, rng, opts)
    }
}

/// Instantiate an algorithm by CLI name.
pub fn by_name(name: &str) -> Box<dyn CcAlgorithm> {
    match name {
        "lc" | "local-contraction" => Box::new(local_contraction::LocalContraction::default()),
        "lc-mtl" | "local-contraction-mtl" => Box::new(local_contraction::LocalContraction {
            merge_to_large: Some(merge_to_large::Schedule::default()),
        }),
        "tc" | "tree-contraction" => Box::new(tree_contraction::TreeContraction { use_dht: false }),
        "tc-dht" | "tree-contraction-dht" => {
            Box::new(tree_contraction::TreeContraction { use_dht: true })
        }
        "cracker" => Box::new(cracker::Cracker),
        "two-phase" => Box::new(two_phase::TwoPhase),
        "htm" | "hash-to-min" => Box::new(hash_to_min::HashToMin),
        "hash-min" => Box::new(hash_min::HashMin),
        other => panic!("unknown algorithm {other:?} (try: lc, lc-mtl, tc, tc-dht, cracker, two-phase, htm, hash-min)"),
    }
}

/// All algorithm CLI names (for table sweeps).
pub const ALL_ALGORITHMS: [&str; 8] = [
    "lc", "lc-mtl", "tc", "tc-dht", "cracker", "two-phase", "htm", "hash-min",
];

/// The five algorithms of Tables 2–3.
pub const PAPER_ALGORITHMS: [&str; 5] = ["lc", "tc-dht", "cracker", "two-phase", "htm"];
