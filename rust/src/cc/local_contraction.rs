//! **LocalContraction** (§3) — the paper's headline algorithm.
//!
//! Each phase: sample a random ordering `rho`; every vertex computes the
//! label `l_rho(v)` = vertex with the smallest priority in `N(N(v))`
//! (self-inclusive, two min-hops = two MPC rounds); vertices with equal
//! labels merge (contraction, two more rounds by Lemma 3.1).  Terminates
//! when the graph has no edges — `O(log n)` phases w.h.p. (Lemma 4.1),
//! `O(log log n)` with the [`super::merge_to_large`] step on `G(n,p)`-class
//! inputs (Theorem 5.5).

use super::backend::{DenseBackend, INF};
use super::common::{contract_mpc, fused_two_hop, Priorities};
use super::contraction_loop::{self, LoopOptions, PhaseOutcome};
use super::merge_to_large::{self, Schedule};
use super::{CcAlgorithm, CcResult, RunOptions};
use crate::graph::{Csr, ShardedGraph, Vertex};
use crate::mpc::Simulator;
use crate::util::rng::Rng;

/// LocalContraction, optionally with the MergeToLarge step of §5.
#[derive(Debug, Clone, Default)]
pub struct LocalContraction {
    pub merge_to_large: Option<Schedule>,
}

/// One phase's label computation: `labels[v]` = the *vertex id* holding the
/// minimum priority over `N(N(v))` — two min-hops over `rho`, then the
/// inverse permutation recovers the representative vertex.
pub fn phase_labels(
    g: &ShardedGraph,
    sim: &mut Simulator,
    rho: &Priorities,
    dense: Option<&dyn DenseBackend>,
) -> Vec<Vertex> {
    let n = g.num_vertices();

    // Dense path: the compiled XLA artifact evaluates both hops in one
    // executable when the graph fits a shard. The shuffle the artifact
    // replaces is still charged to the model (same messages either way);
    // only the *compute* moves onto the compiled kernel.  The artifact's
    // input format is the flat edge list — a graph that fits one dense
    // shard is small, so the conversion is the backend boundary, not a
    // resident-representation round trip.
    if let Some(backend) = dense {
        if n <= backend.max_vertices() {
            let flat = g.to_graph();
            let prio: Vec<i32> = rho.rho.iter().map(|&p| p as i32).collect();
            if let Ok(labels) = backend.local_labels(&flat, &prio) {
                charge_label_rounds(sim, g, n);
                return labels
                    .into_iter()
                    .enumerate()
                    .map(|(v, l)| {
                        if l == INF {
                            v as Vertex // empty neighborhood: own label
                        } else {
                            rho.inv[l as usize]
                        }
                    })
                    .collect();
            }
            // fall through to the MPC path on backend error
        }
    }

    // Out-of-core path: a spilled graph must not materialize an O(m)
    // adjacency, so the two min-hops run as streaming sharded fold rounds
    // (one loaded shard per worker).  Values and per-round metrics are
    // bit-identical to the fused path below — the fusion is charged as
    // exactly these two rounds (enforced by
    // `fused_two_hop_matches_two_min_hops_on_random_graphs` and
    // `rust/tests/spill_equivalence.rs`).
    if g.is_spilled() {
        let h1 = super::common::min_hop(sim, "lc/hop1", g, &rho.rho, true);
        let h2 = super::common::min_hop(sim, "lc/hop2", g, &h1, true);
        return h2.into_iter().map(|p| rho.inv[p as usize]).collect();
    }

    // Fused MPC path: build the CSR once per phase (straight off the
    // shards) and evaluate both min-hops in one traversal; the model is
    // still charged the two label rounds with accounting identical to two
    // `min_hop` calls (enforced by
    // `fused_two_hop_matches_two_min_hops_on_random_graphs`).
    let csr = Csr::build_sharded(g);
    let h2 = fused_two_hop(
        sim,
        ("lc/hop1", "lc/hop2"),
        g,
        &csr,
        &rho.rho,
        crate::mpc::WireFold::min_u32(),
    );
    h2.into_iter().map(|p| rho.inv[p as usize]).collect()
}

/// Charge the two label rounds to the metrics when the dense backend
/// computed the values (communication is identical; see Lemma 3.1).
fn charge_label_rounds(sim: &mut Simulator, g: &ShardedGraph, n: usize) {
    for label in ["lc/hop1(dense)", "lc/hop2(dense)"] {
        let msgs = 2 * g.num_edges() as u64 + n as u64;
        sim.metrics.record(crate::mpc::RoundMetrics {
            label: label.to_string(),
            messages: msgs,
            bytes: msgs * 12,
            max_machine_bytes: msgs * 12 / sim.cfg.machines.max(1) as u64,
            ..Default::default()
        });
    }
}

impl CcAlgorithm for LocalContraction {
    fn name(&self) -> &'static str {
        if self.merge_to_large.is_some() {
            "local-contraction+mtl"
        } else {
            "local-contraction"
        }
    }

    fn run_sharded(
        &self,
        g: &ShardedGraph,
        sim: &mut Simulator,
        rng: &mut Rng,
        opts: &RunOptions,
    ) -> CcResult {
        let loop_opts = LoopOptions {
            finisher_threshold: opts.finisher_threshold,
            prune_isolated: opts.prune_isolated,
            max_phases: opts.max_phases,
        };
        let mtl = self.merge_to_large.clone();
        let dense = opts.dense_backend;
        contraction_loop::run(g, sim, rng, loop_opts, move |cur, sim, rng, phase| {
            let rho = Priorities::sample(cur.num_vertices(), rng);
            let labels = phase_labels(cur, sim, &rho, dense);
            let (contracted, node_map) = contract_mpc(sim, cur, &labels);

            match &mtl {
                None => PhaseOutcome {
                    contracted,
                    node_map,
                },
                Some(schedule) => {
                    // §5: merge small nodes into nearby large nodes.
                    let (g2, map2) = merge_to_large::step(
                        &contracted,
                        &node_map,
                        &rho,
                        schedule.alpha(phase, cur.num_vertices()),
                        sim,
                    );
                    let node_map = node_map
                        .iter()
                        .map(|&m| map2[m as usize])
                        .collect();
                    PhaseOutcome {
                        contracted: g2,
                        node_map,
                    }
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::oracle;
    use crate::graph::{generators, Graph};
    use crate::mpc::MpcConfig;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 8,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        })
    }

    fn check(g: &Graph, seed: u64) -> CcResult {
        let mut s = sim();
        let mut rng = Rng::new(seed);
        let res = LocalContraction::default().run(g, &mut s, &mut rng, &RunOptions::default());
        assert!(res.completed);
        oracle::verify(g, &res.labels).unwrap();
        res
    }

    #[test]
    fn correct_on_zoo() {
        check(&generators::path(50), 1);
        check(&generators::cycle(33), 2);
        check(&generators::star(40), 3);
        check(&generators::complete(12), 4);
        check(&generators::grid(7, 9), 5);
        check(&generators::binary_tree(63), 6);
        check(&Graph::empty(7), 7);
        check(
            &generators::path(20).disjoint_union(generators::cycle(9)),
            8,
        );
    }

    #[test]
    fn correct_on_random_graphs() {
        for seed in 0..5 {
            let g = generators::gnp(400, 0.01, &mut Rng::new(seed + 100));
            check(&g, seed);
        }
    }

    #[test]
    fn phase_labels_match_min_of_two_hop() {
        let flat = generators::path(6);
        let g = ShardedGraph::from_graph(&flat, 8);
        let mut s = sim();
        let mut rng = Rng::new(9);
        let rho = Priorities::sample(6, &mut rng);
        let labels = phase_labels(&g, &mut s, &rho, None);
        // each label's priority must equal min rho over N(N(v))
        let csr = crate::graph::Csr::build(&flat);
        for v in 0..6u32 {
            let mut best = rho.rho[v as usize];
            let mut two_hop = vec![v];
            two_hop.extend_from_slice(csr.neighbors(v));
            for &u in &two_hop {
                best = best.min(rho.rho[u as usize]);
                for &w in csr.neighbors(u) {
                    best = best.min(rho.rho[w as usize]);
                }
            }
            assert_eq!(rho.rho[labels[v as usize] as usize], best);
        }
    }

    #[test]
    fn star_collapses_in_one_phase() {
        let g = generators::star(100);
        let res = check(&g, 11);
        assert_eq!(res.phases, 1);
    }

    #[test]
    fn clique_collapses_in_one_phase() {
        let res = check(&generators::complete(32), 12);
        assert_eq!(res.phases, 1);
    }

    #[test]
    fn phases_logarithmic_on_path() {
        // Thm 7.1: Ω(log n); Lemma 4.1: O(log n). A path of 4^5=1024
        // shortens at most 5x per phase -> at least log_5(1024) ≈ 4.3.
        let res = check(&generators::path(1024), 13);
        assert!(res.phases >= 4, "phases {}", res.phases);
        assert!(res.phases <= 30, "phases {}", res.phases);
    }

    #[test]
    fn mtl_variant_is_correct() {
        for seed in 0..3 {
            let g = generators::gnp_log_regime(600, 5.0, &mut Rng::new(seed + 50));
            let mut s = sim();
            let mut rng = Rng::new(seed);
            let algo = LocalContraction {
                merge_to_large: Some(Schedule::default()),
            };
            let res = algo.run(&g, &mut s, &mut rng, &RunOptions::default());
            assert!(res.completed);
            oracle::verify(&g, &res.labels).unwrap();
        }
    }

    #[test]
    fn dense_backend_path_matches_mpc_path() {
        use crate::cc::backend::CpuBackend;
        let g = generators::gnp(200, 0.02, &mut Rng::new(77));
        let backend = CpuBackend { max_n: 1024 };
        let run_with = |dense: Option<&dyn DenseBackend>| {
            let mut s = sim();
            let mut rng = Rng::new(5);
            let opts = RunOptions {
                dense_backend: dense,
                ..RunOptions::default()
            };
            LocalContraction::default().run(&g, &mut s, &mut rng, &opts)
        };
        let a = run_with(None);
        let b = run_with(Some(&backend));
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.phases, b.phases);
    }

    #[test]
    fn full_run_is_engine_invariant_across_threads() {
        // Acceptance: for every round, messages/bytes/max_machine_bytes/
        // space_violation — and the labels — are identical across thread
        // counts.  The rng is driven identically, so the whole run is
        // deterministic in everything but wall clock.
        let g = generators::gnp(500, 0.015, &mut Rng::new(31));
        let exec = |threads: usize| {
            let mut s = Simulator::new(MpcConfig {
                machines: 8,
                space_per_machine: Some(50_000),
                spill_budget: None,
                threads,
            });
            let mut rng = Rng::new(32);
            let res =
                LocalContraction::default().run(&g, &mut s, &mut rng, &RunOptions::default());
            (res.labels, res.phases, res.metrics.rounds)
        };
        let base = exec(1);
        for threads in [4, 8] {
            assert_eq!(exec(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn communication_is_linear_in_m_per_phase() {
        // §1.1: the communication in each round is O(m).
        let g = generators::gnp(500, 0.02, &mut Rng::new(88));
        let mut s = sim();
        let mut rng = Rng::new(6);
        let res = LocalContraction::default().run(&g, &mut s, &mut rng, &RunOptions::default());
        let m0 = g.num_edges() as u64;
        for r in &res.metrics.rounds {
            assert!(
                r.bytes <= 40 * m0 + 1000,
                "round {} bytes {} vs m {}",
                r.label,
                r.bytes,
                m0
            );
        }
    }
}
