//! **MergeToLarge** step (§5) — the addition that turns LocalContraction's
//! `O(log n)` into `O(log log n)` phases on `𝒢(n,p)`-class random graphs
//! (Theorem 5.5).
//!
//! At the end of phase `i`: nodes created by merging at least `α_i` vertices
//! are *large*; a large node's priority is the `α_i`-th largest vertex hash
//! it contains (using the hashes from phase `i`); every node with a large
//! node within two hops merges into the reachable large node of largest
//! priority.  All of it is O(1) extra MPC rounds.

use super::common::{contract_mpc, neighborhood_fold, Priorities};
use crate::graph::{ShardedGraph, Vertex};
use crate::mpc::Simulator;

/// The `(α_i)` parameter schedule.
///
/// Lemma 5.4 doubles the exponent each phase (`α_{i+1} = Ω(α_i²)`) starting
/// from `α_0 = Θ(log n)`, with the step parameterized by `α/4`.  We follow
/// that shape: `α_i = max(floor, (c·ln n)^(2^i) / 4)`, capped at `n`.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Multiplier `c` on `ln n` for the base density guess.
    pub c: f64,
    /// Minimum α (below 2 the step would merge everything blindly).
    pub floor: u64,
}

impl Default for Schedule {
    fn default() -> Self {
        Schedule { c: 1.0, floor: 2 }
    }
}

impl Schedule {
    /// α for phase `i` on a phase-input graph of `n` vertices.
    pub fn alpha(&self, phase: u32, n: usize) -> u64 {
        let ln_n = (n.max(3) as f64).ln() * self.c;
        let exp = (1u64 << phase.min(6)) as f64; // 2^i, saturating
        let a = ln_n.powf(exp) / 4.0;
        let capped = a.min(n as f64);
        (capped as u64).max(self.floor)
    }
}

/// Apply one MergeToLarge step.
///
/// * `contracted` — the graph H produced by this phase's contraction;
/// * `node_map` — phase-input vertex -> H node (defines cluster sizes);
/// * `rho` — the phase's priorities over the phase-input vertices;
/// * `alpha` — the largeness threshold `α_i`.
///
/// Returns the re-contracted graph and the map H-node -> new node.
pub fn step(
    contracted: &ShardedGraph,
    node_map: &[Vertex],
    rho: &Priorities,
    alpha: u64,
    sim: &mut Simulator,
) -> (ShardedGraph, Vec<Vertex>) {
    let h_n = contracted.num_vertices();

    // Cluster membership: rho values of the phase-input vertices that were
    // merged into each H node.
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); h_n];
    for (v, &node) in node_map.iter().enumerate() {
        members[node as usize].push(rho.rho[v]);
    }

    // Large-node detection + priority = α-th largest member hash.
    // Encoding for the max-hops: 0 = "no large node seen";
    // otherwise ((priority + 1) << 32) | node_id.
    let mut vals: Vec<u64> = vec![0; h_n];
    for (node, ms) in members.iter_mut().enumerate() {
        if ms.len() as u64 >= alpha {
            ms.sort_unstable_by(|a, b| b.cmp(a)); // descending
            let pri = ms[(alpha - 1) as usize] as u64;
            vals[node] = ((pri + 1) << 32) | node as u64;
        }
    }

    // Two max-hops: best large node within distance <= 2 (self-inclusive).
    let h1 = neighborhood_fold(
        sim,
        "mtl/hop1",
        contracted,
        &vals,
        true,
        crate::mpc::WireFold::max_u64(),
    );
    let h2 = neighborhood_fold(
        sim,
        "mtl/hop2",
        contracted,
        &h1,
        true,
        crate::mpc::WireFold::max_u64(),
    );

    // Merge labels: the winning large node, or self if none reachable.
    let labels: Vec<Vertex> = h2
        .iter()
        .enumerate()
        .map(|(v, &enc)| {
            if enc == 0 {
                v as Vertex
            } else {
                (enc & 0xFFFF_FFFF) as Vertex
            }
        })
        .collect();

    contract_mpc(sim, contracted, &labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::MpcConfig;
    use crate::util::rng::Rng;

    fn sim() -> Simulator {
        Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        })
    }

    #[test]
    fn schedule_doubles_exponent() {
        let s = Schedule::default();
        let n = 1_000_000;
        let a0 = s.alpha(0, n);
        let a1 = s.alpha(1, n);
        let a2 = s.alpha(2, n);
        assert!(a0 >= 2);
        assert!(a1 > a0, "a1 {a1} a0 {a0}");
        assert!(a2 > a1 * 2, "a2 {a2} a1 {a1}");
        assert!(s.alpha(20, 100) <= 100, "capped at n");
    }

    #[test]
    fn step_merges_small_into_large() {
        // H: star with center 0; node 0 is a large cluster (5 members),
        // leaves are singletons -> everything should merge into node 0.
        let h = ShardedGraph::from_graph(&crate::graph::generators::star(4), 4);
        // phase-input: 8 vertices; 0..5 merged into node 0, rest singletons
        let node_map: Vec<Vertex> = vec![0, 0, 0, 0, 0, 1, 2, 3];
        let mut rng = Rng::new(1);
        let rho = Priorities::sample(8, &mut rng);
        let mut s = sim();
        let (g2, map2) = step(&h, &node_map, &rho, 3, &mut s);
        assert_eq!(g2.num_vertices(), 1);
        assert!(map2.iter().all(|&m| m == 0));
        assert_eq!(g2.num_edges(), 0);
    }

    #[test]
    fn step_without_large_nodes_is_identity_shape() {
        let h = ShardedGraph::from_graph(&crate::graph::generators::path(4), 4);
        let node_map: Vec<Vertex> = (0..4).collect(); // all singletons
        let mut rng = Rng::new(2);
        let rho = Priorities::sample(4, &mut rng);
        let mut s = sim();
        let (g2, map2) = step(&h, &node_map, &rho, 2, &mut s);
        assert_eq!(g2.num_vertices(), 4);
        assert_eq!(map2, vec![0, 1, 2, 3]);
    }

    #[test]
    fn two_hop_reach() {
        // path of nodes: L - a - b ; L large, b at distance 2 must merge.
        let h = ShardedGraph::from_graph(&crate::graph::generators::path(3), 4);
        let node_map: Vec<Vertex> = vec![0, 0, 0, 1, 2]; // node 0 has 3 members
        let mut rng = Rng::new(3);
        let rho = Priorities::sample(5, &mut rng);
        let mut s = sim();
        let (g2, map2) = step(&h, &node_map, &rho, 3, &mut s);
        assert_eq!(g2.num_vertices(), 1, "{map2:?}");
    }

    #[test]
    fn merge_picks_largest_priority_large_node() {
        // Two large nodes L1-x-L2 with different priorities; x must pick the
        // one whose alpha-th member hash is larger (deterministic check via
        // engineered rho).
        let h = ShardedGraph::from_graph(&crate::graph::generators::path(3), 4); // nodes 0,1,2
        // members: node0 = {0,1}, node1 = {2}, node2 = {3,4}
        let node_map: Vec<Vertex> = vec![0, 0, 1, 2, 2];
        // engineered priorities: rho = identity permutation
        let rho = Priorities {
            rho: vec![0, 1, 2, 3, 4],
            inv: vec![0, 1, 2, 3, 4],
        };
        // alpha=2: node0 priority = 2nd largest of {0,1} = 0;
        //          node2 priority = 2nd largest of {3,4} = 3 -> node2 wins.
        let mut s = sim();
        let (g2, map2) = step(&h, &node_map, &rho, 2, &mut s);
        assert_eq!(g2.num_vertices(), 1);
        assert!(map2.iter().all(|&m| m == 0));
        let _ = g2;
    }

    #[test]
    fn step_is_constant_rounds() {
        let h = ShardedGraph::from_graph(&crate::graph::generators::cycle(10), 4);
        let node_map: Vec<Vertex> = (0..10).collect();
        let mut rng = Rng::new(4);
        let rho = Priorities::sample(10, &mut rng);
        let mut s = sim();
        let _ = step(&h, &node_map, &rho, 2, &mut s);
        assert_eq!(s.metrics.num_rounds(), 4); // 2 hops + 2 contraction
    }
}
