//! Generation-swapped label snapshots: the read side of `lcc serve`.
//!
//! Every query answers out of one immutable [`Snapshot`] — a frozen
//! canonical label array plus its derived component-size table — so a
//! single request can never observe a half-updated labeling (no torn
//! reads by construction).  Publication is ArcSwap-shaped with an epoch
//! counter: the writer swaps the shared `Arc` under a short-lived slot
//! lock and bumps the epoch; readers hold a thread-local cached `Arc`
//! and revalidate it with **one atomic epoch load per query**.  The
//! steady-state query path therefore takes no lock and allocates
//! nothing; only the first query after a publish touches the slot lock
//! to trade the stale `Arc` for the fresh one.  Old snapshots are freed
//! by reference count the moment the last in-flight reader drops them.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One immutable published labeling of the accumulated graph.
///
/// `labels[v]` is the canonical component label — the **minimum original
/// vertex id** in `v`'s component ([`crate::util::dsu`]), which makes
/// snapshots implementation-independent: the incremental union-find
/// path and a full contraction pass over the same edge multiset publish
/// bit-identical snapshots.
#[derive(Debug)]
pub struct Snapshot {
    /// Monotone publish counter (1 = the bootstrap contraction).
    pub epoch: u64,
    /// Full recontraction passes behind this snapshot (0 until the
    /// first threshold-triggered recontraction).
    pub recontractions: u64,
    /// Canonical labels, one per vertex of the fixed universe.
    pub labels: Vec<u32>,
    /// `(canonical label, component size)` sorted by size descending,
    /// label ascending — computed once at publish so `component-sizes`
    /// never walks the label array on the query path.
    pub sizes: Vec<(u32, u64)>,
}

impl Snapshot {
    /// Freeze a labeling into a snapshot (derives the size table).
    pub fn from_labels(epoch: u64, recontractions: u64, labels: Vec<u32>) -> Snapshot {
        let mut counts: HashMap<u32, u64> = HashMap::new();
        for &l in &labels {
            *counts.entry(l).or_insert(0) += 1;
        }
        let mut sizes: Vec<(u32, u64)> = counts.into_iter().collect();
        sizes.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        Snapshot {
            epoch,
            recontractions,
            labels,
            sizes,
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.labels.len()
    }

    pub fn num_components(&self) -> usize {
        self.sizes.len()
    }

    /// Canonical label of `u`; `None` when `u` is outside the vertex
    /// universe.
    pub fn component_of(&self, u: u32) -> Option<u32> {
        self.labels.get(u as usize).copied()
    }

    /// Are `u` and `v` in the same component under this snapshot?
    pub fn same_component(&self, u: u32, v: u32) -> Option<bool> {
        Some(self.component_of(u)? == self.component_of(v)?)
    }
}

/// The publish/subscribe cell: one writer (the ingest thread) swaps in
/// whole snapshots; any number of readers observe either the previous or
/// the next one, never a mixture.
#[derive(Debug)]
pub struct SnapshotCell {
    /// Bumped after each swap; readers revalidate their cached `Arc`
    /// against it with a single atomic load.
    epoch: AtomicU64,
    /// Writer-swapped slot.  Locked only by the writer during a publish
    /// and by a reader that just observed a stale epoch — never on the
    /// steady-state query path.
    slot: Mutex<Arc<Snapshot>>,
}

impl SnapshotCell {
    pub fn new(first: Snapshot) -> SnapshotCell {
        let epoch = first.epoch;
        SnapshotCell {
            epoch: AtomicU64::new(epoch),
            slot: Mutex::new(Arc::new(first)),
        }
    }

    /// Atomically replace the published snapshot.  The epoch store is
    /// `Release` and happens after the slot swap: a reader observing the
    /// new epoch and refreshing is guaranteed to load the new (or an
    /// even newer) snapshot, so answers are always consistent with a
    /// pre- or post-swap labeling.
    pub fn publish(&self, next: Snapshot) {
        let epoch = next.epoch;
        let mut slot = self.slot.lock().unwrap();
        *slot = Arc::new(next);
        self.epoch.store(epoch, Ordering::Release);
    }

    /// The currently published epoch (one atomic load).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Clone the current snapshot `Arc` (slot lock; the readers' slow
    /// path and the writer's own read-back).
    pub fn load(&self) -> Arc<Snapshot> {
        self.slot.lock().unwrap().clone()
    }

    /// A per-thread reader handle over this cell.
    pub fn reader(self: &Arc<Self>) -> SnapshotReader {
        SnapshotReader {
            cached: self.load(),
            epoch: self.epoch(),
            cell: Arc::clone(self),
        }
    }
}

/// A reader's cached view of a [`SnapshotCell`]: each connection handler
/// owns one, so the per-query cost is a single atomic epoch load plus a
/// pointer dereference — no lock, no allocation, no contention between
/// readers.
pub struct SnapshotReader {
    cell: Arc<SnapshotCell>,
    cached: Arc<Snapshot>,
    epoch: u64,
}

impl SnapshotReader {
    /// The snapshot to answer the current query from.  Refreshes the
    /// cached `Arc` only when a publish happened since the last call.
    pub fn current(&mut self) -> &Snapshot {
        let e = self.cell.epoch();
        if e != self.epoch {
            self.cached = self.cell.load();
            // the slot may have advanced again between the two loads;
            // record the epoch of what we actually hold
            self.epoch = self.cached.epoch;
        }
        &self.cached
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_queries_and_sizes() {
        let s = Snapshot::from_labels(1, 0, vec![0, 0, 2, 2, 2, 5]);
        assert_eq!(s.num_components(), 3);
        assert_eq!(s.component_of(3), Some(2));
        assert_eq!(s.component_of(9), None);
        assert_eq!(s.same_component(0, 1), Some(true));
        assert_eq!(s.same_component(1, 2), Some(false));
        assert_eq!(s.same_component(0, 99), None);
        // sorted by size desc, label asc on ties
        assert_eq!(s.sizes, vec![(2, 3), (0, 2), (5, 1)]);
    }

    #[test]
    fn publish_swaps_whole_snapshots() {
        let cell = Arc::new(SnapshotCell::new(Snapshot::from_labels(1, 0, vec![0, 1])));
        let mut r = cell.reader();
        assert_eq!(r.current().epoch, 1);
        assert_eq!(r.current().same_component(0, 1), Some(false));
        cell.publish(Snapshot::from_labels(2, 0, vec![0, 0]));
        assert_eq!(cell.epoch(), 2);
        let snap = r.current();
        assert_eq!(snap.epoch, 2);
        assert_eq!(snap.same_component(0, 1), Some(true));
    }

    #[test]
    fn readers_see_monotone_epochs_under_concurrent_publishes() {
        let cell = Arc::new(SnapshotCell::new(Snapshot::from_labels(1, 0, vec![0; 64])));
        let stop = Arc::new(AtomicU64::new(0));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let cell = Arc::clone(&cell);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut r = cell.reader();
                    let mut last = 0;
                    while stop.load(Ordering::Relaxed) == 0 {
                        let s = r.current();
                        assert!(s.epoch >= last, "epoch went backwards");
                        assert_eq!(s.labels.len(), 64, "torn snapshot");
                        last = s.epoch;
                    }
                    last
                })
            })
            .collect();
        for e in 2..200 {
            cell.publish(Snapshot::from_labels(e, 0, vec![0; 64]));
        }
        stop.store(1, Ordering::Relaxed);
        for r in readers {
            assert!(r.join().unwrap() <= 199);
        }
    }
}
