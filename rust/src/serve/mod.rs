//! `lcc serve` — a long-lived incremental connectivity service.
//!
//! The daemon brings the worker mesh up **once** (via
//! [`crate::coordinator::DriverSession`]), keeps shard custody and the
//! canonical label array warm, and answers connectivity queries over a
//! newline-delimited JSON TCP protocol:
//!
//! ```text
//! -> {"op":"same-component","u":3,"v":17}
//! <- {"ok":true,"same":true,"epoch":4}
//! -> {"op":"component-of","u":3}
//! <- {"ok":true,"label":0,"epoch":4}
//! -> {"op":"component-sizes","top":3}
//! <- {"ok":true,"components":9,"sizes":[[0,812],[640,9],[771,4]],"epoch":4}
//! -> {"op":"insert","edges":[[1,2],[2,3]]}
//! <- {"ok":true,"queued":2}
//! -> {"op":"flush"}
//! <- {"ok":true,"epoch":5,"components":8,...}
//! ```
//!
//! The module splits cleanly along the read/write axis:
//!
//! * [`snapshot`] — immutable generation-swapped label snapshots; the
//!   query path is **lock-free** (one atomic epoch load per query
//!   against a per-connection cached `Arc`).
//! * [`core`] — the single-writer ingest sink: bounded-queue batching,
//!   incremental union-find over the contracted core, and
//!   threshold-triggered full recontraction passes over the live fleet.
//!
//! Queries never wait on ingest, ingest never waits on queries, and a
//! recontraction (seconds of fleet work) happens entirely on the write
//! side — readers keep answering out of the previous snapshot until the
//! new one is swapped in.

pub mod core;
pub mod snapshot;

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;

use crate::coordinator::Driver;
use crate::graph::Graph;
use crate::mpc::TransportError;
use crate::util::json::{self, Json};

use self::core::{FlushAck, IngestMsg, ServiceCore};
use self::snapshot::SnapshotReader;

/// Service-plane knobs (the fleet/run knobs live in
/// [`crate::coordinator::RunConfig`]).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP port to listen on (`0` = ephemeral; the chosen port is
    /// announced on stdout).
    pub port: u16,
    /// Bound of the ingest queue in *messages* — senders block when it
    /// is full (backpressure, mirroring [`crate::mpc::net`]'s bounded
    /// frame queues).
    pub queue_capacity: usize,
    /// Full-pass trigger: distinct core edges accumulated since the
    /// last contraction.
    pub recontract_threshold: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            port: 0,
            queue_capacity: 4,
            recontract_threshold: 4096,
        }
    }
}

/// Bring up the fleet, bind the socket, and serve until a `shutdown`
/// request arrives.  Blocks the calling thread for the daemon lifetime;
/// the announced `{"event":"serving",...}` line on stdout is the ready
/// signal scripts and tests wait for.
pub fn serve(driver: Driver, g: &Graph, dataset: &str, cfg: &ServeConfig) -> Result<(), TransportError> {
    let core = ServiceCore::bootstrap(driver, g, dataset, cfg.recontract_threshold)?;
    let cell = core.cell();
    let transport = core.transport_name();

    let listener = TcpListener::bind(("127.0.0.1", cfg.port)).map_err(|e| TransportError::Io {
        worker: None,
        op: "bind serve socket",
        source: e,
    })?;
    let port = listener
        .local_addr()
        .map_err(|e| TransportError::Io {
            worker: None,
            op: "resolve serve socket",
            source: e,
        })?
        .port();
    set_serve_port(port);

    let (tx, rx) = sync_channel::<IngestMsg>(cfg.queue_capacity.max(1));
    let ingest = std::thread::Builder::new()
        .name("lcc-serve-ingest".into())
        .spawn(move || core.run_ingest(rx))
        .expect("spawn ingest thread");

    // The ready line: exactly one JSON object, explicitly flushed —
    // stdout is block-buffered when piped, and clients parse this line
    // to learn the ephemeral port.
    let ready = Json::obj()
        .set("event", "serving")
        .set("port", port as u64)
        .set("n", g.num_vertices())
        .set("edges", g.num_edges())
        .set("transport", transport)
        .set("recontract_threshold", cfg.recontract_threshold as u64);
    println!("{}", ready.dumps());
    std::io::stdout().flush().ok();

    let shutdown = Arc::new(AtomicBool::new(false));
    let mut handlers = Vec::new();
    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let reader = cell.reader();
        let tx = tx.clone();
        let shutdown = Arc::clone(&shutdown);
        handlers.push(
            std::thread::Builder::new()
                .name("lcc-serve-conn".into())
                .spawn(move || handle_connection(stream, reader, tx, shutdown))
                .expect("spawn connection handler"),
        );
    }
    // Shutdown path: stop ingest first (it may still be recontracting),
    // then join the handlers that are still draining their sockets.
    let _ = tx.send(IngestMsg::Shutdown);
    drop(tx);
    let _ = ingest.join();
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

/// One client connection: newline-JSON requests in, newline-JSON
/// responses out.  Owns its [`SnapshotReader`], so queries are a single
/// atomic load against the cached snapshot.
fn handle_connection(
    stream: TcpStream,
    mut reader: SnapshotReader,
    tx: SyncSender<IngestMsg>,
    shutdown: Arc<AtomicBool>,
) {
    let peer_read = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut lines = BufReader::new(peer_read);
    let mut out = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match lines.read_line(&mut line) {
            Ok(0) | Err(_) => return, // peer hung up
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        let (reply, quit) = handle_request(line.trim(), &mut reader, &tx, &shutdown);
        if writeln!(out, "{}", reply.dumps()).and_then(|_| out.flush()).is_err() {
            return;
        }
        if quit {
            return;
        }
    }
}

fn err(msg: &str) -> Json {
    Json::obj().set("ok", false).set("error", msg)
}

fn ack_json(ack: &FlushAck) -> Json {
    Json::obj()
        .set("ok", true)
        .set("epoch", ack.epoch)
        .set("components", ack.num_components)
        .set("core_edges", ack.core_edges)
        .set("recontractions", ack.recontractions)
        .set("edges", ack.edges)
        .set("rejected", ack.rejected)
}

/// Decode and execute one request line.  Returns the reply and whether
/// the connection should close after sending it.
fn handle_request(
    line: &str,
    reader: &mut SnapshotReader,
    tx: &SyncSender<IngestMsg>,
    shutdown: &Arc<AtomicBool>,
) -> (Json, bool) {
    let req = match json::parse(line) {
        Ok(j) => j,
        Err(e) => return (err(&format!("bad json: {e}")), false),
    };
    let op = match req.get("op").and_then(|o| o.as_str()) {
        Some(op) => op.to_string(),
        None => return (err("missing op"), false),
    };
    let vertex = |key: &str| -> Option<u32> {
        req.get(key)
            .and_then(|v| v.as_i64())
            .and_then(|v| u32::try_from(v).ok())
    };
    match op.as_str() {
        "same-component" => {
            let (Some(u), Some(v)) = (vertex("u"), vertex("v")) else {
                return (err("same-component needs u and v"), false);
            };
            let snap = reader.current();
            match snap.same_component(u, v) {
                Some(same) => (
                    Json::obj()
                        .set("ok", true)
                        .set("same", same)
                        .set("epoch", snap.epoch),
                    false,
                ),
                None => (err("vertex out of range"), false),
            }
        }
        "component-of" => {
            let Some(u) = vertex("u") else {
                return (err("component-of needs u"), false);
            };
            let snap = reader.current();
            match snap.component_of(u) {
                Some(label) => (
                    Json::obj()
                        .set("ok", true)
                        .set("label", label)
                        .set("epoch", snap.epoch),
                    false,
                ),
                None => (err("vertex out of range"), false),
            }
        }
        "component-sizes" => {
            let top = req
                .get("top")
                .and_then(|t| t.as_i64())
                .map(|t| t.max(0) as usize)
                .unwrap_or(10);
            let snap = reader.current();
            let sizes: Vec<Json> = snap
                .sizes
                .iter()
                .take(top)
                .map(|&(label, size)| Json::Arr(vec![Json::from(label as u64), Json::from(size)]))
                .collect();
            (
                Json::obj()
                    .set("ok", true)
                    .set("components", snap.num_components())
                    .set("n", snap.num_vertices())
                    .set("sizes", Json::Arr(sizes))
                    .set("epoch", snap.epoch),
                false,
            )
        }
        "insert" => {
            let Some(raw) = req.get("edges").and_then(|e| e.as_arr()) else {
                return (err("insert needs edges: [[u,v],...]"), false);
            };
            let mut edges = Vec::with_capacity(raw.len());
            for pair in raw {
                let uv = pair.as_arr().filter(|p| p.len() == 2).and_then(|p| {
                    Some((
                        u32::try_from(p[0].as_i64()?).ok()?,
                        u32::try_from(p[1].as_i64()?).ok()?,
                    ))
                });
                match uv {
                    Some(e) => edges.push(e),
                    None => return (err("edges entries must be [u,v] pairs"), false),
                }
            }
            let queued = edges.len();
            // blocking send = backpressure: a full queue throttles the
            // inserting client instead of growing daemon memory
            if tx.send(IngestMsg::Edges(edges)).is_err() {
                return (err("ingest stopped"), false);
            }
            (Json::obj().set("ok", true).set("queued", queued), false)
        }
        "flush" => {
            let (ack_tx, ack_rx) = sync_channel::<FlushAck>(1);
            if tx.send(IngestMsg::Flush(ack_tx)).is_err() {
                return (err("ingest stopped"), false);
            }
            match ack_rx.recv() {
                Ok(ack) => (ack_json(&ack), false),
                Err(_) => (err("ingest stopped"), false),
            }
        }
        "stats" => {
            // flush doubles as the stats barrier: the ack carries every
            // counter the service tracks
            let (ack_tx, ack_rx) = sync_channel::<FlushAck>(1);
            if tx.send(IngestMsg::Flush(ack_tx)).is_err() {
                return (err("ingest stopped"), false);
            }
            match ack_rx.recv() {
                Ok(ack) => {
                    let snap = reader.current();
                    (
                        ack_json(&ack)
                            .set("n", snap.num_vertices())
                            .set("snapshot_epoch", snap.epoch),
                        false,
                    )
                }
                Err(_) => (err("ingest stopped"), false),
            }
        }
        "shutdown" => {
            shutdown.store(true, Ordering::SeqCst);
            // wake the acceptor loop so it observes the flag
            let _ = TcpStream::connect(("127.0.0.1", local_port(tx)));
            (Json::obj().set("ok", true).set("stopping", true), true)
        }
        other => (err(&format!("unknown op: {other}")), false),
    }
}

/// The acceptor wake-up needs the listening port; rather than threading
/// it through every handler we stash it in a process-global set once by
/// [`serve`].  (A `SyncSender` can't tell us.)
static SERVE_PORT: std::sync::atomic::AtomicU32 = std::sync::atomic::AtomicU32::new(0);

fn local_port(_tx: &SyncSender<IngestMsg>) -> u16 {
    SERVE_PORT.load(Ordering::SeqCst) as u16
}

pub(crate) fn set_serve_port(port: u16) {
    SERVE_PORT.store(port as u32, Ordering::SeqCst);
}
