//! The write side of `lcc serve`: incremental label maintenance plus the
//! batching ingest sink.
//!
//! One [`ServiceCore`] owns the accumulated edge multiset, a streaming
//! union-find over it, and the persistent [`DriverSession`] fleet.  Edge
//! batches update labels incrementally (union-find over the contracted
//! core — §6's streaming finisher applied online); once the number of
//! **distinct core edges** (label pairs bridging current components)
//! inserted since the last contraction crosses
//! [`ServeConfig::recontract_threshold`](super::ServeConfig), a full
//! contraction pass runs through the regular [`Driver`] stack over the
//! live worker fleet and the fresh labeling is swapped in atomically.
//! Because labels are canonical (min vertex id per component), the
//! incremental path and the full pass agree **bit for bit** — the
//! recontraction revalidates and rebases rather than changing answers.
//!
//! Ingest is decoupled from connection handlers by a bounded
//! [`sync_channel`]: handlers block in `send` when the ingest thread
//! falls behind, mirroring the backpressure discipline of the bounded
//! frame queues in [`crate::mpc::net`] — memory stays bounded and slow
//! consumers throttle producers instead of OOMing the daemon.

use std::collections::HashSet;
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError};
use std::sync::Arc;

use crate::coordinator::{Driver, DriverSession};
use crate::graph::{Graph, ShardedGraph, SpillPolicy};
use crate::mpc::TransportError;
use crate::util::dsu::DisjointSet;

use super::snapshot::{Snapshot, SnapshotCell};

/// A message into the ingest sink.
pub enum IngestMsg {
    /// A batch of undirected edges to insert.
    Edges(Vec<(u32, u32)>),
    /// Barrier: apply everything queued before this message, then reply
    /// with the service state.  Lets clients (and the smoke tests) wait
    /// for their insertions to be visible.
    Flush(SyncSender<FlushAck>),
    /// Drain the queue, then exit the ingest loop.
    Shutdown,
}

/// Reply to an [`IngestMsg::Flush`] barrier.
#[derive(Debug, Clone)]
pub struct FlushAck {
    /// Epoch of the snapshot covering everything before the barrier.
    pub epoch: u64,
    pub num_components: usize,
    /// Distinct core edges accumulated since the last contraction.
    pub core_edges: usize,
    /// Full contraction passes completed so far.
    pub recontractions: u64,
    /// Total edges accepted since startup (graph + inserted).
    pub edges: usize,
    /// Inserted edges rejected for being out of range or self-loops.
    pub rejected: u64,
}

/// What one batch did (exposed for the in-process stress tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchOutcome {
    /// Components merged by this batch.
    pub merged: usize,
    /// Whether the batch tripped a full recontraction pass.
    pub recontracted: bool,
}

/// Incremental connectivity state over a persistent fleet.
pub struct ServiceCore {
    n: usize,
    /// Dataset name stamped on recontraction reports.
    dataset: String,
    /// The full accumulated edge multiset (initial graph + insertions);
    /// recontractions and the oracle both rebuild from it.
    edges: Vec<(u32, u32)>,
    /// Streaming union-find over the contracted core, reseeded from the
    /// published labels after every full pass.
    dsu: DisjointSet,
    /// Current canonical labels (always equal to
    /// `dsu.canonical_labels()`).
    labels: Vec<u32>,
    /// Distinct `(min label, max label)` pairs that bridged two live
    /// components when inserted, accumulated since the last full pass —
    /// a measure of how much contracted core the streaming finisher is
    /// holding, and the trigger for the next pass.
    core: HashSet<(u32, u32)>,
    session: DriverSession,
    cell: Arc<SnapshotCell>,
    recontract_threshold: usize,
    epoch: u64,
    recontractions: u64,
    batches: u64,
    inserted: u64,
    rejected: u64,
}

impl ServiceCore {
    /// Bring up the fleet, run the bootstrap contraction on `g`, and
    /// publish the first snapshot.
    pub fn bootstrap(
        driver: Driver,
        g: &Graph,
        dataset: &str,
        recontract_threshold: usize,
    ) -> Result<ServiceCore, TransportError> {
        let n = g.num_vertices();
        let machines = driver.config().machines.max(1);
        let policy = SpillPolicy::with_budget(driver.config().spill_budget);
        let sharded = ShardedGraph::from_graph_with(g, machines, policy);
        let mut session = driver.into_session(&sharded)?;
        let (labels, _report) = session.run(&sharded, dataset)?;
        let mut dsu = DisjointSet::new(n);
        for v in 0..n as u32 {
            dsu.union(v, labels[v as usize]);
        }
        let cell = Arc::new(SnapshotCell::new(Snapshot::from_labels(
            1,
            0,
            labels.clone(),
        )));
        Ok(ServiceCore {
            n,
            dataset: dataset.to_string(),
            edges: g.edges().to_vec(),
            dsu,
            labels,
            core: HashSet::new(),
            session,
            cell,
            recontract_threshold: recontract_threshold.max(1),
            epoch: 1,
            recontractions: 0,
            batches: 0,
            inserted: 0,
            rejected: 0,
        })
    }

    /// The cell query handlers subscribe to.
    pub fn cell(&self) -> Arc<SnapshotCell> {
        Arc::clone(&self.cell)
    }

    pub fn num_vertices(&self) -> usize {
        self.n
    }

    pub fn core_edges(&self) -> usize {
        self.core.len()
    }

    pub fn recontractions(&self) -> u64 {
        self.recontractions
    }

    pub fn transport_name(&self) -> &'static str {
        self.session.transport_name()
    }

    fn ack(&self) -> FlushAck {
        FlushAck {
            epoch: self.epoch,
            num_components: self.dsu.components(),
            core_edges: self.core.len(),
            recontractions: self.recontractions,
            edges: self.edges.len(),
            rejected: self.rejected,
        }
    }

    /// Apply one batch of inserted edges: union-find over the contracted
    /// core, snapshot publish if anything merged, full recontraction if
    /// the core crossed the threshold.  Out-of-range endpoints and
    /// self-loops are counted and skipped, never fatal.
    pub fn apply_batch(&mut self, batch: &[(u32, u32)]) -> BatchOutcome {
        self.batches += 1;
        let mut merged = 0usize;
        for &(u, v) in batch {
            if u as usize >= self.n || v as usize >= self.n || u == v {
                self.rejected += 1;
                continue;
            }
            self.inserted += 1;
            self.edges.push((u.min(v), u.max(v)));
            let (lu, lv) = (self.labels[u as usize], self.labels[v as usize]);
            if lu != lv {
                // a genuine core edge: it bridges two components of the
                // last contraction's labeling
                self.core.insert((lu.min(lv), lu.max(lv)));
            }
            if self.dsu.union(u, v) {
                merged += 1;
            }
        }
        if merged > 0 {
            self.labels = self.dsu.canonical_labels();
            self.epoch += 1;
            self.cell.publish(Snapshot::from_labels(
                self.epoch,
                self.recontractions,
                self.labels.clone(),
            ));
        }
        let mut recontracted = false;
        if self.core.len() >= self.recontract_threshold {
            match self.recontract() {
                Ok(()) => recontracted = true,
                Err(e) => {
                    // Keep answering out of the (still correct) incremental
                    // labels; the next threshold cross retries the pass.
                    eprintln!("[serve] recontraction failed, staying incremental: {e}");
                }
            }
        }
        BatchOutcome {
            merged,
            recontracted,
        }
    }

    /// One full contraction pass over the accumulated edge multiset on
    /// the live fleet.  Canonical labels make this a *revalidation*: the
    /// distributed result must agree bit-for-bit with the incremental
    /// labels (divergence means a bug; we log it loudly and adopt the
    /// distributed answer, which the verify path cross-checks).
    fn recontract(&mut self) -> Result<(), TransportError> {
        let machines = self.session.config().machines.max(1);
        let policy = SpillPolicy::with_budget(self.session.config().spill_budget);
        let g = Graph::from_edges(self.n, self.edges.clone());
        let sharded = ShardedGraph::from_graph_with(&g, machines, policy);
        let (labels, _report) = self.session.run(&sharded, &self.dataset)?;
        if labels != self.labels {
            eprintln!(
                "[serve] WARNING: recontraction labels diverge from incremental \
                 labels — adopting the distributed result"
            );
            self.labels = labels;
        }
        // rebase: fresh union-find seeded from the contracted labeling,
        // empty core — the threshold now measures post-pass insertions
        self.dsu = DisjointSet::new(self.n);
        for v in 0..self.n as u32 {
            self.dsu.union(v, self.labels[v as usize]);
        }
        self.core.clear();
        self.recontractions += 1;
        self.epoch += 1;
        self.cell.publish(Snapshot::from_labels(
            self.epoch,
            self.recontractions,
            self.labels.clone(),
        ));
        // Checkpoint retention for the long-lived fleet: the transport
        // prunes on every generation write, but a run that ends without
        // writing (e.g. recovery disabled) would otherwise accumulate
        // dirs across recontractions.
        if let Some(dir) = &self.session.config().checkpoint_dir {
            let keep = self.session.config().keep_generations.unwrap_or(1);
            crate::graph::spill::prune_generations(dir, keep);
        }
        Ok(())
    }

    /// The ingest loop: drain the bounded channel until shutdown.
    /// Consecutive queued `Edges` messages are coalesced into one batch
    /// so a burst of small insertions pays one label rebuild, not many.
    pub fn run_ingest(mut self, rx: Receiver<IngestMsg>) -> ServiceCore {
        let mut pending: Option<IngestMsg> = None;
        loop {
            let msg = match pending.take() {
                Some(m) => m,
                None => match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break, // all senders gone
                },
            };
            match msg {
                IngestMsg::Edges(mut batch) => {
                    // coalesce whatever else is already queued
                    loop {
                        match rx.try_recv() {
                            Ok(IngestMsg::Edges(more)) => batch.extend(more),
                            Ok(other) => {
                                pending = Some(other);
                                break;
                            }
                            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                        }
                    }
                    self.apply_batch(&batch);
                }
                IngestMsg::Flush(reply) => {
                    // best-effort: the client may have hung up
                    let _ = reply.send(self.ack());
                }
                IngestMsg::Shutdown => break,
            }
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunConfig;
    use crate::graph::generators;
    use crate::util::rng::Rng;

    fn core_on(g: &Graph, threshold: usize) -> ServiceCore {
        let driver = Driver::new(RunConfig {
            machines: 4,
            ..Default::default()
        });
        ServiceCore::bootstrap(driver, g, "test", threshold).expect("bootstrap")
    }

    #[test]
    fn bootstrap_publishes_oracle_labels() {
        let g = generators::gnp(200, 0.01, &mut Rng::new(3));
        let core = core_on(&g, 1_000_000);
        let snap = core.cell().load();
        assert_eq!(snap.labels, crate::cc::oracle::components(&g));
        assert_eq!(snap.epoch, 1);
    }

    #[test]
    fn incremental_batches_match_oracle_and_recontract() {
        let g = generators::gnp(120, 0.008, &mut Rng::new(5));
        let mut core = core_on(&g, 6);
        let mut all_edges = g.edges().to_vec();
        let mut recontracted = false;
        // chain batches force many inter-component merges
        for start in (0..110u32).step_by(10) {
            let batch: Vec<(u32, u32)> = (start..start + 9).map(|v| (v, v + 1)).collect();
            all_edges.extend(&batch);
            let out = core.apply_batch(&batch);
            recontracted |= out.recontracted;
            let want = crate::cc::oracle::components(&Graph::from_edges(120, all_edges.clone()));
            let snap = core.cell().load();
            assert_eq!(snap.labels, want, "after batch at {start}");
        }
        assert!(recontracted, "threshold 6 must trip at least one full pass");
        assert!(core.recontractions() >= 1);
        // after a pass the core counter was rebased
        assert!(core.core_edges() < 6);
    }

    #[test]
    fn bad_edges_are_rejected_not_fatal() {
        let g = generators::path(10);
        let mut core = core_on(&g, 1_000_000);
        let out = core.apply_batch(&[(3, 3), (900, 2), (1, 90_000)]);
        assert_eq!(out.merged, 0);
        assert_eq!(core.ack().rejected, 3);
        let snap = core.cell().load();
        assert_eq!(snap.epoch, 1, "rejected-only batch publishes nothing");
    }
}
