//! The round-transport boundary: **how a round shuffles** as a trait.
//!
//! The MPC model separates local computation from key-shuffled
//! communication; this module makes that separation a compile-time
//! boundary.  [`Exchange`] owns the three things a round needs from its
//! communication substrate:
//!
//! * **message routing** — delivering each machine's wire payload to the
//!   machine owning its keys (the `machine_of` partition stays the single
//!   routing hash; payloads arrive pre-partitioned by it);
//! * **per-machine load accounting** — reporting the bytes each machine
//!   *actually received*, which the [`super::Simulator`] validates against
//!   the model charge (a divergence is a typed
//!   [`TransportError::AccountingMismatch`], never a silently-wrong
//!   metric);
//! * **barrier semantics** — `exchange` does not return until every
//!   machine has received (and acknowledged) its full load, so round
//!   `r + 1` cannot begin before round `r` is globally complete.
//!
//! Two implementations exist:
//!
//! * [`InProcess`] — the simulator's classic backend: all machines share
//!   the address space, messages never serialize
//!   ([`Exchange::wants_wire`] is `false`), routing and reduction run on
//!   the worker pool, and `exchange` is a pure accounting barrier.  This
//!   is the fast path and the reference semantics.
//! * [`crate::mpc::net::ProcTransport`] — the multi-process backend: one
//!   OS process per machine, each owning its [`crate::graph::EdgeShard`],
//!   exchanging length-prefixed checksummed frames per round over
//!   localhost sockets.  Fold rounds tagged with a [`WireOp`] are reduced
//!   *by the worker processes* and merged back; everything else ships its
//!   exact charged byte image for receiver-side accounting.  Shard
//!   custody crosses this boundary zero-copy: a `LoadShard` body is the
//!   columnar shard-file image of [`crate::graph::spill`] verbatim —
//!   mmap'd spill bytes are written borrowed into the socket, and the
//!   receiving worker keeps the frame body as its working representation,
//!   walking it through a borrowed [`crate::graph::spill::ShardCursor`].
//!
//! The eight algorithms and the contraction loop are written against
//! [`super::Simulator`]'s round API only — they compile and run unchanged
//! on either backend, and `rust/tests/transport_equivalence.rs` enforces
//! that labels, per-round metrics, and derived graphs are bit-identical
//! across them.
//!
//! **Error path.**  Round signatures cannot carry `Result` (the
//! algorithms are transport-agnostic), so a failed exchange aborts the
//! run by unwinding with the typed [`TransportError`] as the panic
//! payload; [`crate::coordinator::Driver`]'s `try_*` entry points catch
//! the unwind and surface the typed error.

use std::fmt;

/// Which transport a run shuffles on (the `--transport` CLI selector).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportMode {
    /// Single-process simulator (the default).
    #[default]
    InProc,
    /// Multi-process workers on localhost ([`crate::mpc::net`]); the
    /// coordinator routes every round's byte image.
    Proc,
    /// Multi-process workers with a worker↔worker data plane
    /// ([`crate::mpc::net::ShuffleTransport`]): workers generate the hop
    /// and rewire rounds from their owned shards and shuffle peer to
    /// peer; the coordinator is a control plane (descriptors + barriers +
    /// O(machines) summaries).
    Shuffle,
}

impl TransportMode {
    /// Parse the CLI spelling; panics with a clear message otherwise.
    pub fn parse(s: &str) -> TransportMode {
        match s {
            "inproc" | "in-process" | "local" => TransportMode::InProc,
            "proc" | "process" | "multi-process" => TransportMode::Proc,
            "shuffle" | "mesh" => TransportMode::Shuffle,
            other => panic!("unknown transport {other:?} (try: inproc, proc, shuffle)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportMode::InProc => "inproc",
            TransportMode::Proc => "proc",
            TransportMode::Shuffle => "shuffle",
        }
    }
}

/// Fold operators a remote machine can apply to its received messages
/// without shipping code: the associative, commutative reductions the
/// algorithms' hop rounds use.  The tag travels in the round header; the
/// wire value width is implied by the op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireOp {
    MinU32,
    MaxU32,
    MinU64,
    MaxU64,
    /// Lexicographic min over `(u32, u32)` pairs (the priority/id pairs of
    /// Cracker's and TreeContraction's pointer rounds).
    MinPairU32,
    MaxPairU32,
    /// Grouped **gather** over `(u32, u32)` pairs: not a 1-per-key fold —
    /// the receiving machine sorts its `(key, pair)` records, drops exact
    /// duplicates, and keeps *every* distinct pair per key.  This is the
    /// reduce program of grouped rounds (Cracker's hub rewire gathers all
    /// rewritten edges incident to a hub), shipped in the same round
    /// header slot the fold ops use.
    GatherPairU32,
}

impl WireOp {
    pub fn code(self) -> u8 {
        match self {
            WireOp::MinU32 => 1,
            WireOp::MaxU32 => 2,
            WireOp::MinU64 => 3,
            WireOp::MaxU64 => 4,
            WireOp::MinPairU32 => 5,
            WireOp::MaxPairU32 => 6,
            WireOp::GatherPairU32 => 7,
        }
    }

    pub fn from_code(code: u8) -> Option<WireOp> {
        Some(match code {
            1 => WireOp::MinU32,
            2 => WireOp::MaxU32,
            3 => WireOp::MinU64,
            4 => WireOp::MaxU64,
            5 => WireOp::MinPairU32,
            6 => WireOp::MaxPairU32,
            7 => WireOp::GatherPairU32,
            _ => return None,
        })
    }

    /// Encoded bytes of one value under this op.
    pub fn value_bytes(self) -> usize {
        match self {
            WireOp::MinU32 | WireOp::MaxU32 => 4,
            WireOp::MinU64
            | WireOp::MaxU64
            | WireOp::MinPairU32
            | WireOp::MaxPairU32
            | WireOp::GatherPairU32 => 8,
        }
    }
}

/// A fold operator plus its optional wire identity: `f` is what the local
/// engine evaluates; `wire` (when the op is one a remote machine can
/// apply) lets a wire transport run the reduce on the receiving worker
/// process instead.  Untagged folds still run correctly on every
/// transport — the coordinator folds locally and ships the byte image for
/// accounting only.
pub struct WireFold<V> {
    pub f: fn(V, V) -> V,
    pub wire: Option<WireOp>,
}

// Manual Clone/Copy: the derive would demand `V: Clone`, but the struct
// only holds a fn pointer and a tag.
impl<V> Clone for WireFold<V> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<V> Copy for WireFold<V> {}

impl<V> fmt::Debug for WireFold<V> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "WireFold({:?})", self.wire)
    }
}

impl<V> WireFold<V> {
    /// A fold with no wire identity: always reduced by the coordinator.
    pub fn untagged(f: fn(V, V) -> V) -> WireFold<V> {
        WireFold { f, wire: None }
    }
}

fn pair_min(a: (u32, u32), b: (u32, u32)) -> (u32, u32) {
    a.min(b)
}
fn pair_max(a: (u32, u32), b: (u32, u32)) -> (u32, u32) {
    a.max(b)
}

impl WireFold<u32> {
    pub fn min_u32() -> WireFold<u32> {
        WireFold {
            f: u32::min,
            wire: Some(WireOp::MinU32),
        }
    }
    pub fn max_u32() -> WireFold<u32> {
        WireFold {
            f: u32::max,
            wire: Some(WireOp::MaxU32),
        }
    }
}

impl WireFold<u64> {
    // (a min_u64 constructor joins this set when a min-u64 hop exists;
    // WireOp::MinU64 is already on the wire protocol)
    pub fn max_u64() -> WireFold<u64> {
        WireFold {
            f: u64::max,
            wire: Some(WireOp::MaxU64),
        }
    }
}

impl WireFold<(u32, u32)> {
    pub fn min_pair_u32() -> WireFold<(u32, u32)> {
        WireFold {
            f: pair_min,
            wire: Some(WireOp::MinPairU32),
        }
    }
    pub fn max_pair_u32() -> WireFold<(u32, u32)> {
        WireFold {
            f: pair_max,
            wire: Some(WireOp::MaxPairU32),
        }
    }
}

/// The model-side accounting of one round, borrowed from the engine: the
/// quantities the transport must make true on the receiving side.
#[derive(Debug, Clone, Copy)]
pub struct RoundCharge<'a> {
    pub messages: u64,
    pub bytes: u64,
    /// Bytes destined to each machine; `len` = machine count.
    pub machine_bytes: &'a [u64],
}

/// What came back from one exchange.
#[derive(Debug)]
pub struct ExchangeAck {
    /// Bytes received per machine, as counted by the **receiving side**.
    /// The simulator validates these against the model charge.
    pub machine_bytes: Vec<u64>,
    /// For fold rounds ([`WireOp`] tagged): per machine, the folded
    /// `(key u64, value)` pairs it computed over its received messages,
    /// in the round's wire encoding.  `None` for untagged rounds and for
    /// transports that do not move bytes.
    pub folded: Option<Vec<Vec<u8>>>,
}

/// Typed failures of a round transport.  Every fault mode the
/// multi-process backend can hit — a crashed worker, a frame cut short, a
/// corrupted payload, protocol desync, accounting divergence — has its own
/// variant; none of them may surface as hangs or wrong answers.
#[derive(Debug)]
pub enum TransportError {
    /// Underlying socket/pipe/spawn failure (timeouts included).
    Io {
        worker: Option<usize>,
        op: &'static str,
        source: std::io::Error,
    },
    /// A worker process exited or its connection closed mid-protocol.
    WorkerCrashed { worker: usize, detail: String },
    /// A frame ended before its declared length.
    ShortRead {
        worker: Option<usize>,
        wanted: u64,
        got: u64,
    },
    /// A frame did not start with the protocol magic.
    BadMagic { worker: Option<usize> },
    /// Frame body bytes do not hash to the header checksum.
    ChecksumMismatch {
        worker: Option<usize>,
        expected: u64,
        actual: u64,
    },
    /// Structurally valid traffic that violates the protocol (unexpected
    /// kind, wrong sequence number, malformed body, shard statistics that
    /// disagree with the coordinator's cache, ...).
    Protocol {
        worker: Option<usize>,
        detail: String,
    },
    /// Receiver-observed load differs from the model charge — the
    /// transport delivered different bytes than the round accounted.
    AccountingMismatch {
        label: String,
        machine: usize,
        expected: u64,
        actual: u64,
    },
    /// Shard shipping hit a spill-layer fault (the shard wire format is
    /// the spill file framing).
    Spill(crate::graph::spill::SpillError),
    /// Worker recovery ran out of respawn attempts (or respawn was
    /// disabled): the run cannot make progress.  `detail` carries the
    /// underlying fault that triggered recovery.
    RecoveryExhausted { attempts: usize, detail: String },
}

impl TransportError {
    /// Attach a worker index to an error raised below the per-worker
    /// layer (frame codecs report `worker: None`).
    pub fn for_worker(self, worker: usize) -> TransportError {
        match self {
            TransportError::Io {
                worker: None,
                op,
                source,
            } => TransportError::Io {
                worker: Some(worker),
                op,
                source,
            },
            TransportError::ShortRead {
                worker: None,
                wanted,
                got,
            } => TransportError::ShortRead {
                worker: Some(worker),
                wanted,
                got,
            },
            TransportError::BadMagic { worker: None } => TransportError::BadMagic {
                worker: Some(worker),
            },
            TransportError::ChecksumMismatch {
                worker: None,
                expected,
                actual,
            } => TransportError::ChecksumMismatch {
                worker: Some(worker),
                expected,
                actual,
            },
            TransportError::Protocol {
                worker: None,
                detail,
            } => TransportError::Protocol {
                worker: Some(worker),
                detail,
            },
            other => other,
        }
    }

    /// Is this a disconnect-shaped fault a worker respawn can heal?
    /// Crashes, short reads, and socket I/O errors are; correctness
    /// failures (checksum/accounting/protocol divergence, spill faults)
    /// are not — replaying a lying worker would launder a wrong answer.
    pub fn recoverable(&self) -> bool {
        matches!(
            self,
            TransportError::WorkerCrashed { .. }
                | TransportError::ShortRead { .. }
                | TransportError::Io { .. }
        )
    }

    /// The worker index the fault is attributed to, when known.
    pub fn worker(&self) -> Option<usize> {
        match self {
            TransportError::Io { worker, .. }
            | TransportError::ShortRead { worker, .. }
            | TransportError::BadMagic { worker }
            | TransportError::ChecksumMismatch { worker, .. }
            | TransportError::Protocol { worker, .. } => *worker,
            TransportError::WorkerCrashed { worker, .. } => Some(*worker),
            TransportError::AccountingMismatch { machine, .. } => Some(*machine),
            TransportError::Spill(_) | TransportError::RecoveryExhausted { .. } => None,
        }
    }
}

impl From<crate::graph::spill::SpillError> for TransportError {
    fn from(e: crate::graph::spill::SpillError) -> TransportError {
        TransportError::Spill(e)
    }
}

fn wtag(worker: &Option<usize>) -> String {
    match worker {
        Some(w) => format!("worker {w}: "),
        None => String::new(),
    }
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Io { worker, op, source } => {
                write!(f, "{}transport I/O: {op}: {source}", wtag(worker))
            }
            TransportError::WorkerCrashed { worker, detail } => {
                write!(f, "worker {worker} crashed: {detail}")
            }
            TransportError::ShortRead {
                worker,
                wanted,
                got,
            } => write!(
                f,
                "{}short read: frame needed {wanted} bytes, got {got}",
                wtag(worker)
            ),
            TransportError::BadMagic { worker } => {
                write!(f, "{}not a transport frame (bad magic)", wtag(worker))
            }
            TransportError::ChecksumMismatch {
                worker,
                expected,
                actual,
            } => write!(
                f,
                "{}frame checksum {actual:#018x} != header {expected:#018x}",
                wtag(worker)
            ),
            TransportError::Protocol { worker, detail } => {
                write!(f, "{}protocol violation: {detail}", wtag(worker))
            }
            TransportError::AccountingMismatch {
                label,
                machine,
                expected,
                actual,
            } => write!(
                f,
                "round {label:?}: machine {machine} received {actual} bytes, \
                 model charged {expected}"
            ),
            TransportError::Spill(e) => write!(f, "shard shipping: {e}"),
            TransportError::RecoveryExhausted { attempts, detail } => write!(
                f,
                "worker recovery exhausted after {attempts} respawn attempt(s): {detail}"
            ),
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Io { source, .. } => Some(source),
            TransportError::Spill(e) => Some(e),
            _ => None,
        }
    }
}

/// The round-transport abstraction (see module docs).  One value lives
/// inside each [`super::Simulator`]; every model round goes through
/// [`exchange`](Exchange::exchange).
pub trait Exchange: fmt::Debug {
    /// Short backend name (`"inproc"` / `"proc"`), recorded in reports.
    fn name(&self) -> &'static str;

    /// Does this transport physically move bytes?  When `false`, rounds
    /// stay in-process (no serialization) and `exchange` receives empty
    /// payloads — it is a pure accounting barrier.
    fn wants_wire(&self) -> bool;

    /// Machine count the transport is bound to (`None` = any; the
    /// in-process backend adapts to the simulator config).
    fn machines(&self) -> Option<usize> {
        None
    }

    /// (Re)establish the resident graph on the backend between runs —
    /// the persistent-session path (`lcc serve`): a long-lived fleet is
    /// handed each new generation instead of being torn down and
    /// respawned per run.  The wire backends re-ship shard custody; the
    /// in-process backend holds no remote state, so the default is a
    /// no-op.
    fn load_graph(&mut self, g: &crate::graph::ShardedGraph) -> Result<(), TransportError> {
        let _ = g;
        Ok(())
    }

    /// Execute one round's communication: deliver `payloads[j]` to
    /// machine `j` (an **empty** `payloads` vector marks a charge-only
    /// round whose bytes never materialize — fused phases, graph-layer
    /// contractions — which the transport must still barrier and account
    /// at the declared loads), block until every machine has acknowledged
    /// (the barrier), and return the receiver-observed loads.  `fold`
    /// asks the receiving machines to reduce their `(key, value)`
    /// messages with the tagged op and return the folded pairs.
    fn exchange(
        &mut self,
        label: &str,
        charge: RoundCharge<'_>,
        payloads: Vec<Vec<u8>>,
        fold: Option<WireOp>,
    ) -> Result<ExchangeAck, TransportError>;

    /// Descriptor-driven worker-native rounds, when this backend has a
    /// worker↔worker data plane ([`ShuffleOps`]).  `None` (the default)
    /// means rounds flow through [`exchange`](Exchange::exchange) with
    /// coordinator-routed payloads.
    fn shuffle(&mut self) -> Option<&mut dyn ShuffleOps> {
        None
    }

    /// Snapshot of the mesh data-plane counters (hops, batches, mirror
    /// syncs, worker↔worker and sync bytes), when this backend has a mesh
    /// to meter.  `None` for backends without one.
    fn mesh_stats(&self) -> Option<crate::mpc::metrics::MeshMetrics> {
        None
    }
}

/// One worker-native hop round, described instead of shipped: each worker
/// generates the round's messages from its **owned shard** and the
/// synchronized value mirror (`(u, vals[v])` and `(v, vals[u])` per edge,
/// plus its `chunk_range(n, p, s)` slice of the self messages when
/// `include_self`), shuffles them straight to the peer workers owning the
/// keys, and folds what it receives with `op`.
#[derive(Debug, Clone, Copy)]
pub struct HopSpec<'a> {
    pub label: &'a str,
    pub op: WireOp,
    pub include_self: bool,
}

/// What one successful [`ShuffleOps::recover`] did: how many respawn
/// attempts it took and how long the mesh rebuild ran.  The engine logs
/// this into [`crate::mpc::Metrics`]' recovery block.
#[derive(Debug, Clone, Copy, Default)]
pub struct RecoveryInfo {
    /// Respawn attempts consumed (1 = first respawn succeeded).
    pub respawn_attempts: usize,
    /// Wall-clock of the respawn + mesh rebuild, in milliseconds.
    pub wall_ms: f64,
}

/// The control-plane operations of a shuffle-capable transport
/// ([`crate::mpc::net::ShuffleTransport`]).  Everything here moves
/// O(machines) or O(n) bytes over the coordinator links — descriptors,
/// state mirrors, and summaries — never the O(m) message stream, which
/// stays on the worker mesh.
///
/// **Validation model.**  The coordinator computes every descriptor
/// round's result locally too (it is the algorithm driver and needs the
/// output anyway); workers return per-machine load counts and fold/shard
/// *checksums*, which the engine validates against the local result.  A
/// divergence — wrong bytes moved, wrong fold computed, wrong custody
/// adopted — is a typed [`TransportError`], never a silently different
/// answer.
pub trait ShuffleOps {
    /// Generation id of the [`crate::graph::ShardedGraph`] the workers
    /// currently hold custody of (`None` before the first load).
    fn custody(&self) -> Option<u64>;

    /// Re-ship shard custody for `g` over the coordinator links (the
    /// fallback when an algorithm rebuilt the graph coordinator-side;
    /// contractions and prunes stay peer-to-peer via
    /// [`rewire`](ShuffleOps::rewire)).
    fn establish_custody(&mut self, g: &crate::graph::ShardedGraph)
        -> Result<(), TransportError>;

    /// Content hash of the value mirror the workers currently hold.
    fn mirror_hash(&self) -> Option<u64>;

    /// Bring every worker's value mirror to `data` (wire-encoded,
    /// `value_bytes` per vertex); `hash` is the caller-computed
    /// [`mirror_hash_of`](crate::mpc::net::mirror_hash_of), echoed by each
    /// worker as its application receipt — always over the worker's
    /// **full** resulting mirror, so the receipt pins the mirror contents
    /// whichever encoding travelled.  The transport is free to ship only
    /// the `(vertex, new_value)` pairs that changed since the mirror it
    /// last synced (the delta path), falling back to the full broadcast
    /// when too much changed or the shapes differ.
    fn sync_mirror(
        &mut self,
        value_bytes: u8,
        data: &[u8],
        hash: u64,
    ) -> Result<(), TransportError>;

    /// Record that the workers' mirrors now hold `data` hashing to `hash`
    /// (they applied the validated fold results of a hop in place).  The
    /// transport retains the bytes as the base the next
    /// [`sync_mirror`](ShuffleOps::sync_mirror) computes its delta
    /// against.
    fn set_mirror(&mut self, value_bytes: u8, data: &[u8], hash: u64);

    /// Issue a hop descriptor to every worker and return the round's
    /// sequence number; workers start generating/shuffling immediately
    /// while the coordinator computes its local fold.
    fn begin_hop(
        &mut self,
        spec: &HopSpec<'_>,
        charge: &RoundCharge<'_>,
    ) -> Result<u64, TransportError>;

    /// Collect the hop acks (the barrier): validate each worker's
    /// receiver-observed load against the charge and its fold checksum
    /// against `expected_folds[j]` (the coordinator-computed canonical
    /// fold image of machine `j`'s keys).
    fn finish_hop(
        &mut self,
        seq: u64,
        spec: &HopSpec<'_>,
        charge: &RoundCharge<'_>,
        expected_folds: &[u64],
    ) -> Result<(), TransportError>;

    /// Ship a whole [`RoundPlan`](crate::mpc::simulator::RoundPlan) of
    /// consecutive hop rounds as **one** descriptor batch: the workers
    /// run generate→shuffle→fold back-to-back for every round in the
    /// plan (their mirrors self-advance through the fold all-gather, so
    /// no coordinator data dependency exists between the rounds) and ack
    /// once at the end.  All rounds share `charge` — a plan is only legal
    /// when the graph (and therefore every round's message shape) is
    /// unchanged across it.  Returns the batch's base sequence number;
    /// round `k` of the plan runs at `base + k` on the mesh.
    fn begin_hop_batch(
        &mut self,
        specs: &[HopSpec<'_>],
        charge: &RoundCharge<'_>,
    ) -> Result<u64, TransportError>;

    /// Collect the one-per-worker batch acks: per round `k` and worker
    /// `j`, validate the receiver-observed load against `charge` and the
    /// fold checksum against `expected_folds[k][j]` — exactly the
    /// [`finish_hop`](ShuffleOps::finish_hop) validation, once per round
    /// of the plan.
    fn finish_hop_batch(
        &mut self,
        seq: u64,
        specs: &[HopSpec<'_>],
        charge: &RoundCharge<'_>,
        expected_folds: &[Vec<u64>],
    ) -> Result<(), TransportError>;

    /// Worker-native grouped rewrite (the wire-programmable grouped
    /// reduce): broadcast `map` as the mirror, ship a one-byte reduce
    /// program ([`WireOp::GatherPairU32`]), and have every worker emit
    /// `(map[u], v)` / `(map[v], u)` per owned edge plus `(map[v], v)`
    /// for its `chunk_range` slice of the vertices, normalize each pair
    /// (min endpoint first, self-loops dropped), ship them to the new
    /// owner workers, and adopt the sorted-deduped merge as its
    /// next-generation shard — Cracker's hub rewire without rebounding
    /// the edges through the coordinator.  Validated like
    /// [`rewire`](ShuffleOps::rewire): each worker's new shard statistics
    /// and payload checksum must match `new` (the coordinator's
    /// locally-computed generation) before custody advances.
    fn gather_rewire(
        &mut self,
        map: &[u32],
        new: &crate::graph::ShardedGraph,
    ) -> Result<(), TransportError>;

    /// Peer-to-peer custody handoff after a graph rewrite: broadcast
    /// `map` (old vertex → new vertex; `u32::MAX` = dropped), have every
    /// worker rewrite its own edges, re-bucket them by the new ownership,
    /// ship them straight to the new owner workers, and adopt the merged
    /// result as its next-generation shard.  Each worker's new shard
    /// statistics and payload checksum are validated against `new` (the
    /// coordinator's locally-computed generation) before custody advances.
    fn rewire(
        &mut self,
        map: &[u32],
        new: &crate::graph::ShardedGraph,
    ) -> Result<(), TransportError>;

    /// Heal the mesh after a disconnect-shaped fault (`cause`): kill the
    /// surviving workers, respawn a fresh fleet with bounded
    /// retry-with-exponential-backoff, and rebuild the peer mesh.  Shard
    /// custody and the value mirror are *not* re-shipped here — recovery
    /// drops them so the next round's custody/mirror checks lazily
    /// re-establish both (from the generation checkpoint's spill files
    /// when checkpointing is on), replaying from the last generation
    /// barrier.  A respawn budget of zero (respawn disabled) or an
    /// exhausted budget fails with the typed
    /// [`TransportError::RecoveryExhausted`].
    fn recover(&mut self, cause: &TransportError) -> Result<RecoveryInfo, TransportError>;
}

/// The in-process backend: machines share the address space, so routing
/// and reduction already happened on the worker pool by the time the
/// round completes — `exchange` is the accounting barrier only, and the
/// receiver-observed loads are the charge itself.
#[derive(Debug, Clone, Copy, Default)]
pub struct InProcess;

impl Exchange for InProcess {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn wants_wire(&self) -> bool {
        false
    }

    fn exchange(
        &mut self,
        _label: &str,
        charge: RoundCharge<'_>,
        _payloads: Vec<Vec<u8>>,
        _fold: Option<WireOp>,
    ) -> Result<ExchangeAck, TransportError> {
        Ok(ExchangeAck {
            machine_bytes: charge.machine_bytes.to_vec(),
            folded: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_mode_parses() {
        assert_eq!(TransportMode::parse("inproc"), TransportMode::InProc);
        assert_eq!(TransportMode::parse("proc"), TransportMode::Proc);
        assert_eq!(TransportMode::parse("shuffle"), TransportMode::Shuffle);
        assert_eq!(TransportMode::InProc.name(), "inproc");
        assert_eq!(TransportMode::Proc.name(), "proc");
        assert_eq!(TransportMode::Shuffle.name(), "shuffle");
    }

    #[test]
    #[should_panic(expected = "unknown transport")]
    fn transport_mode_rejects_garbage() {
        let _ = TransportMode::parse("carrier-pigeon");
    }

    #[test]
    fn wire_op_codes_roundtrip() {
        for op in [
            WireOp::MinU32,
            WireOp::MaxU32,
            WireOp::MinU64,
            WireOp::MaxU64,
            WireOp::MinPairU32,
            WireOp::MaxPairU32,
            WireOp::GatherPairU32,
        ] {
            assert_eq!(WireOp::from_code(op.code()), Some(op));
        }
        assert_eq!(WireOp::from_code(0), None);
        assert_eq!(WireOp::from_code(200), None);
    }

    #[test]
    fn gather_program_is_pair_width() {
        assert_eq!(WireOp::GatherPairU32.value_bytes(), 8);
    }

    #[test]
    fn tagged_folds_apply_their_op() {
        assert_eq!((WireFold::min_u32().f)(3, 5), 3);
        assert_eq!((WireFold::max_u32().f)(3, 5), 5);
        assert_eq!((WireFold::max_u64().f)(3, 5), 5);
        assert_eq!((WireFold::min_pair_u32().f)((1, 9), (1, 2)), (1, 2));
        assert_eq!((WireFold::max_pair_u32().f)((1, 9), (1, 2)), (1, 9));
        assert_eq!(WireFold::untagged(u32::min).wire, None);
    }

    #[test]
    fn inproc_echoes_the_charge() {
        let mut t = InProcess;
        assert!(!t.wants_wire());
        let mb = [10u64, 0, 7];
        let ack = t
            .exchange(
                "r",
                RoundCharge {
                    messages: 3,
                    bytes: 17,
                    machine_bytes: &mb,
                },
                Vec::new(),
                None,
            )
            .unwrap();
        assert_eq!(ack.machine_bytes, vec![10, 0, 7]);
        assert!(ack.folded.is_none());
    }

    #[test]
    fn errors_format_with_worker_context() {
        let e = TransportError::ShortRead {
            worker: None,
            wanted: 8,
            got: 3,
        }
        .for_worker(2);
        assert!(e.to_string().contains("worker 2"), "{e}");
        let e = TransportError::AccountingMismatch {
            label: "hop".into(),
            machine: 1,
            expected: 12,
            actual: 8,
        };
        assert!(e.to_string().contains("charged 12"), "{e}");
        let e = TransportError::RecoveryExhausted {
            attempts: 3,
            detail: "worker 2 crashed".into(),
        };
        assert!(e.to_string().contains("3 respawn attempt"), "{e}");
        assert!(e.to_string().contains("worker 2 crashed"), "{e}");
    }

    #[test]
    fn only_disconnect_faults_are_recoverable() {
        assert!(TransportError::WorkerCrashed {
            worker: 1,
            detail: "gone".into()
        }
        .recoverable());
        assert!(TransportError::ShortRead {
            worker: None,
            wanted: 8,
            got: 0
        }
        .recoverable());
        assert!(TransportError::Io {
            worker: Some(0),
            op: "read",
            source: std::io::Error::from(std::io::ErrorKind::ConnectionReset),
        }
        .recoverable());
        // correctness failures must abort, never replay
        assert!(!TransportError::BadMagic { worker: None }.recoverable());
        assert!(!TransportError::ChecksumMismatch {
            worker: None,
            expected: 1,
            actual: 2
        }
        .recoverable());
        assert!(!TransportError::Protocol {
            worker: Some(0),
            detail: "lied".into()
        }
        .recoverable());
        assert!(!TransportError::AccountingMismatch {
            label: "hop".into(),
            machine: 0,
            expected: 1,
            actual: 2
        }
        .recoverable());
        assert!(!TransportError::RecoveryExhausted {
            attempts: 0,
            detail: "off".into()
        }
        .recoverable());
    }
}
