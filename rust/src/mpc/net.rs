//! The multi-process round transports: length-prefixed, checksummed
//! frames over localhost sockets, one worker **process** per simulated
//! machine — split along a **control-plane / data-plane** boundary.
//!
//! **Frame format** (all integers little-endian, every link and both
//! planes):
//!
//! ```text
//! LCCFRME1 | kind u8 | seq u64 | body_len u64 | fnv1a64(body) u64 | body
//! ```
//!
//! **Shard custody frames carry the one zero-copy shard layout** — the
//! `LCCSHRD2` columnar image defined in [`crate::graph::spill`] (header +
//! checksummed `src[]`/`dst[]` columns + vertex→range index).  The
//! [`FrameKind::LoadShard`] body *is* the shard file image: a spilled
//! shard ships its mmap'd bytes borrowed straight into the socket write
//! (no decode, no re-encode), a resident shard encodes once
//! ([`crate::graph::spill::encode_shard_bytes`]), and the receiving
//! worker keeps the frame body as its working representation, walking it
//! through a borrowed [`crate::graph::spill::ShardCursor`] — disk, wire,
//! and round generation all read the same bytes in place.
//!
//! Two wire backends implement [`super::transport::Exchange`]:
//!
//! * [`ProcTransport`] — the coordinator **is** the data plane: it spawns
//!   `machines` copies of `lcc worker`, hands each custody of its shard
//!   in the image framing above, and drives one
//!   [`FrameKind::Round`] exchange per model round, serializing and
//!   routing every machine's exact charged byte image itself.  Each
//!   machine counts its bytes on the receiving side and, for
//!   [`WireOp`]-tagged folds, reduces them remotely; all acks collected =
//!   the barrier.  Simple, but the coordinator serializes O(m) bytes per
//!   round — a serial throughput cap no machine count can lift.
//!
//! * [`ShuffleTransport`] — the workers are the data plane and the
//!   coordinator shrinks to a **control plane**.  On top of the proc
//!   handshake it distributes the mesh roster ([`FrameKind::Peers`], from
//!   the listener ports each worker advertises in its Hello) and then
//!   drives the dominant rounds as O(1) **descriptors**:
//!   [`FrameKind::HopRound`] makes every worker generate the hop's
//!   messages *from its owned shard* and a synchronized value mirror
//!   ([`FrameKind::StateSync`], skipped when chained hops keep the
//!   mirrors current), shuffle each bucket straight to the peer owning
//!   the keys ([`FrameKind::PeerMsgs`]), fold what it receives, and
//!   all-gather the fold images ([`FrameKind::PeerFold`]); the ack is
//!   **O(1)**: received-byte count + fold checksum, which the engine
//!   validates against the shard-derived charge and its locally-computed
//!   fold.  [`FrameKind::Rewire`] hands shard custody across a
//!   contraction the same way: workers relabel their own edges through
//!   the map mirror and ship them peer to peer
//!   ([`FrameKind::PeerEdges`]) to the next generation's owners,
//!   validated shard-by-shard against the coordinator's generation.
//!   Rounds with no descriptor shape (grouped reduces, arbitrary maps)
//!   fall back to coordinator routing, proc-style — bit-identity always,
//!   worker-native speed where it matters.
//!
//! Every fault mode is a typed [`TransportError`]: a killed worker
//! surfaces as [`TransportError::WorkerCrashed`] (or a short read, if the
//! connection dies mid-frame), a truncated frame as
//! [`TransportError::ShortRead`], a corrupted body — coordinator link or
//! peer mesh — as [`TransportError::ChecksumMismatch`], a lying load
//! report as [`TransportError::AccountingMismatch`], a diverging fold or
//! shard as [`TransportError::Protocol`] — never a hang (reads, writes,
//! and mesh waits all carry [`IO_TIMEOUT`]; dead peers surface
//! immediately via their reader threads) and never a silently-wrong
//! answer.
//!
//! The worker-side loop lives in [`crate::coordinator::worker`].

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use super::transport::{Exchange, ExchangeAck, RoundCharge, TransportError, WireOp};
use crate::graph::spill::{self, Fnv1a};
use crate::graph::ShardedGraph;

/// Magic prefix of every transport frame.
pub const FRAME_MAGIC: &[u8; 8] = b"LCCFRME1";
/// Protocol version exchanged in the handshake.  v2: `Hello` carries the
/// worker's mesh listener port and the worker↔worker shuffle frames
/// exist.  v3: `Ping`/`Pong` heartbeats and the fault-injection /
/// recovery envs (`LCC_FAULT_PLAN`, `LCC_IO_TIMEOUT_MS`,
/// `LCC_CONNECT_RETRIES`).  v4: the mesh data-plane perf frames —
/// `StateDelta` mirror patches, `HopBatch`/`HopBatchAck` pipelined round
/// plans, `GatherRewire` worker-native grouped contraction — and acks
/// carry the worker's mesh byte meter.  v5: `Hello` carries the worker's
/// data-plane thread count (`LCC_WORKER_THREADS`), reported back in the
/// mesh metrics so an artifact records how parallel the fleet ran.
pub const PROTO_VERSION: u32 = 5;
/// Sanity cap on a peer-declared frame body, 4 GiB (a garbage length
/// must not drive a huge allocation).
pub const MAX_FRAME_BODY: u64 = 1 << 32;
/// magic + kind + seq + len + checksum — the fixed per-frame overhead
/// (workers count it when metering their mesh sends, the coordinator
/// when metering sync broadcasts).
pub const FRAME_HEADER_BYTES: u64 = 8 + 1 + 8 + 8 + 8;

/// Per-read/per-write socket timeout: a wedged peer (one that neither
/// answers nor drains) becomes a typed I/O error, not a hang.  This is
/// the *default*; runs override it via [`NetConfig::io_timeout`]
/// (`--io-timeout` / `LCC_IO_TIMEOUT_MS`).
pub const IO_TIMEOUT: Duration = Duration::from_secs(120);
/// How long the coordinator waits for all workers to connect.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(20);
/// Default worker→peer connect retry budget (exponential backoff,
/// [`CONNECT_BACKOFF_MS`] base, doubling — ~5 s total at the default).
pub const DEFAULT_CONNECT_RETRIES: usize = 10;
/// Base backoff of the peer-connect retry loop, in milliseconds.
pub const CONNECT_BACKOFF_MS: u64 = 5;
/// Default worker respawn budget of shuffle recovery (`--respawn-budget`
/// / `LCC_RESPAWN_BUDGET`; 0 disables recovery).
pub const DEFAULT_RESPAWN_BUDGET: usize = 3;
/// Base backoff between respawn attempts, in milliseconds (doubles per
/// attempt).
pub const DEFAULT_RESPAWN_BACKOFF_MS: u64 = 50;
/// Default generation-checkpoint retention (`--keep-generations` /
/// `LCC_KEEP_GENERATIONS`): how many `gen-<id>/` custody directories
/// survive each checkpoint.  A bounded batch run only ever needs the
/// current one; `lcc serve` raises it so a recontraction that fails
/// mid-persist still has the previous durable generation to recover
/// from.
pub const DEFAULT_KEEP_GENERATIONS: usize = 1;

// ---------------------------------------------------------------------------
// transport configuration + deterministic fault injection

/// Tunable knobs of the wire transports.  Every knob has an env spelling
/// so spawned `lcc worker` processes (which parse no run flags) inherit
/// the coordinator's settings; [`NetConfig::from_env`] is the worker-side
/// (and default coordinator-side) reader, and the driver overlays its
/// `--io-timeout`/`--connect-retries`/`--fault-plan`/`--respawn-budget`
/// flags on top.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Per-read/per-write socket timeout (`LCC_IO_TIMEOUT_MS`).
    pub io_timeout: Duration,
    /// Worker→peer mesh connect attempts, exponential backoff
    /// (`LCC_CONNECT_RETRIES`).
    pub connect_retries: usize,
    /// Deterministic fault plan, raw CLI spelling (`LCC_FAULT_PLAN`);
    /// shipped to the workers verbatim via their environment.  Parse with
    /// [`FaultPlan::parse`].
    pub fault_plan: Option<String>,
    /// Worker respawn attempts per recovery (`LCC_RESPAWN_BUDGET`;
    /// 0 = recovery disabled, a dead worker is terminal).
    pub respawn_budget: usize,
    /// Base respawn backoff in milliseconds, doubling per attempt
    /// (`LCC_RESPAWN_BACKOFF_MS`).
    pub respawn_backoff_ms: u64,
    /// Directory for per-generation run checkpoints
    /// (`LCC_CHECKPOINT_DIR`); `None` = a run-private temp dir when
    /// checkpointing is active.
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// How many checkpointed `gen-<id>/` custody directories to retain
    /// (`LCC_KEEP_GENERATIONS`; clamped to ≥ 1).  Long-lived processes
    /// that recontract repeatedly prune to this bound at every
    /// checkpoint — see [`spill::prune_generations`].
    pub keep_generations: usize,
    /// Whether mirror syncs may ship [`FrameKind::StateDelta`] patches
    /// instead of full [`FrameKind::StateSync`] broadcasts when few
    /// entries changed (`LCC_DELTA_SYNC`; `0`/`off` disables).  On by
    /// default; disabling forces every sync down the full-broadcast path
    /// (the bit-identity baseline the delta path is tested against).
    pub delta_sync: bool,
    /// Data-plane threads per worker process (`LCC_WORKER_THREADS`;
    /// clamped to ≥ 1).  1 = the serial hot path; above it each worker
    /// runs generate/fold on a [`crate::mpc::pool::WorkerPool`] with
    /// chunk-merge order pinned so every byte stream stays identical to
    /// the serial one.
    pub worker_threads: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            io_timeout: IO_TIMEOUT,
            connect_retries: DEFAULT_CONNECT_RETRIES,
            fault_plan: None,
            respawn_budget: DEFAULT_RESPAWN_BUDGET,
            respawn_backoff_ms: DEFAULT_RESPAWN_BACKOFF_MS,
            checkpoint_dir: None,
            keep_generations: DEFAULT_KEEP_GENERATIONS,
            delta_sync: true,
            worker_threads: 1,
        }
    }
}

fn env_u64(key: &str) -> Option<u64> {
    std::env::var(key).ok().and_then(|v| v.trim().parse().ok())
}

impl NetConfig {
    /// Read the env spellings over the defaults (unparseable values fall
    /// back to the default rather than crashing a worker mid-handshake).
    pub fn from_env() -> NetConfig {
        let mut cfg = NetConfig::default();
        if let Some(ms) = env_u64("LCC_IO_TIMEOUT_MS").filter(|&ms| ms > 0) {
            cfg.io_timeout = Duration::from_millis(ms);
        }
        if let Some(n) = env_u64("LCC_CONNECT_RETRIES") {
            cfg.connect_retries = n as usize;
        }
        if let Some(plan) = std::env::var("LCC_FAULT_PLAN").ok().filter(|s| !s.is_empty()) {
            cfg.fault_plan = Some(plan);
        }
        if let Some(n) = env_u64("LCC_RESPAWN_BUDGET") {
            cfg.respawn_budget = n as usize;
        }
        if let Some(ms) = env_u64("LCC_RESPAWN_BACKOFF_MS") {
            cfg.respawn_backoff_ms = ms;
        }
        if let Some(dir) = std::env::var("LCC_CHECKPOINT_DIR").ok().filter(|s| !s.is_empty()) {
            cfg.checkpoint_dir = Some(std::path::PathBuf::from(dir));
        }
        if let Some(k) = env_u64("LCC_KEEP_GENERATIONS").filter(|&k| k > 0) {
            cfg.keep_generations = k as usize;
        }
        if let Ok(v) = std::env::var("LCC_DELTA_SYNC") {
            let v = v.trim();
            if v == "0" || v.eq_ignore_ascii_case("off") {
                cfg.delta_sync = false;
            }
        }
        if let Some(t) = env_u64("LCC_WORKER_THREADS").filter(|&t| t > 0) {
            cfg.worker_threads = t as usize;
        }
        cfg
    }
}

/// What an injected fault does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `kill`: the worker exits immediately (no ack, socket dropped) —
    /// the coordinator sees a crash.
    Kill,
    /// `delay`: the worker sleeps 100 ms before serving the frame —
    /// exercises the timeout/backoff paths without killing anyone.
    Delay,
}

/// Where in the run an injected fault fires, counted per worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Before serving the worker's `n`-th round frame (`Round`,
    /// `HopRound`, or `Rewire`; 1-based).
    Round(u64),
    /// Immediately *after* acking the worker's `n`-th `Rewire` frame
    /// (1-based) — the generation boundary: custody advanced, then the
    /// worker dies.
    Gen(u64),
}

/// One injected fault: `kill:w2@round=3` = worker 2 exits on its 3rd
/// round frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultAction {
    pub kind: FaultKind,
    pub worker: usize,
    pub site: FaultSite,
}

/// A deterministic fault plan: comma-separated actions, each
/// `<kill|delay>:w<ID>@<round|gen>=<N>` (`--fault-plan
/// "kill:w2@round=3,delay:w1@round=5"`).  Workers receive the raw string
/// via `LCC_FAULT_PLAN`, parse it after learning their id from `Assign`,
/// and enact only their own actions — every failure is reproducible by
/// construction (frame counters, not wall clocks).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultPlan {
    pub actions: Vec<FaultAction>,
}

impl FaultPlan {
    /// Parse the CLI/env spelling; `Err` carries a message naming the
    /// offending clause.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut actions = Vec::new();
        for clause in s.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let bad = |why: &str| format!("bad fault clause {clause:?}: {why}");
            let (kind, rest) = clause
                .split_once(':')
                .ok_or_else(|| bad("expected <kill|delay>:w<ID>@<round|gen>=<N>"))?;
            let kind = match kind {
                "kill" => FaultKind::Kill,
                "delay" => FaultKind::Delay,
                other => return Err(bad(&format!("unknown action {other:?}"))),
            };
            let (who, site) = rest
                .split_once('@')
                .ok_or_else(|| bad("expected w<ID>@<round|gen>=<N>"))?;
            let worker: usize = who
                .strip_prefix('w')
                .and_then(|id| id.parse().ok())
                .ok_or_else(|| bad("worker must be w<ID>"))?;
            let (at, n) = site
                .split_once('=')
                .ok_or_else(|| bad("expected <round|gen>=<N>"))?;
            let n: u64 = n.parse().map_err(|_| bad("count must be an integer"))?;
            if n == 0 {
                return Err(bad("counts are 1-based (got 0)"));
            }
            let site = match at {
                "round" => FaultSite::Round(n),
                "gen" => FaultSite::Gen(n),
                other => return Err(bad(&format!("unknown site {other:?}"))),
            };
            if kind == FaultKind::Delay && matches!(site, FaultSite::Gen(_)) {
                return Err(bad("delay is only meaningful at round sites"));
            }
            actions.push(FaultAction { kind, worker, site });
        }
        Ok(FaultPlan { actions })
    }

    /// The actions worker `w` must enact.
    pub fn for_worker(&self, w: usize) -> Vec<FaultAction> {
        self.actions.iter().copied().filter(|a| a.worker == w).collect()
    }
}

/// Frame discriminators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// worker → coordinator, first frame after connect: `version u32 |
    /// pid u32 | mesh_port u16 | worker_threads u32` (the pid lets the
    /// coordinator align spawned children with the accept-order worker
    /// ids; the mesh port is where this worker accepts peer connections —
    /// used only by the shuffle transport; worker_threads is the
    /// data-plane pool width the worker runs its rounds on, v5).
    Hello,
    /// coordinator → worker: `version u32 | worker_id u32 | machines u32`.
    Assign,
    /// coordinator → worker: `shard u32 | image_len u64 | image` (the
    /// spill shard-file framing, shipped verbatim).
    LoadShard,
    /// worker → coordinator: `shard u32 | len u64 | checksum u64 |
    /// p u32 | peer_counts p × u64` — the worker's independently
    /// recomputed shard statistics.
    LoadAck,
    /// coordinator → worker: `virtual u8 | wire_op u8 | declared u64 |
    /// label_len u16 | label | payload_len u64 | payload`.
    Round,
    /// worker → coordinator: `accounted u64 | fold_len u64 | fold pairs`.
    RoundAck,
    /// coordinator → worker: empty body; the worker replies [`FrameKind::Bye`]
    /// and exits.
    Shutdown,
    Bye,
    /// worker → coordinator: utf-8 detail of a protocol violation the
    /// worker detected (surfaced as [`TransportError::Protocol`]).
    WorkerErr,

    // ---- shuffle control plane (coordinator link; O(machines)/O(n)) ----
    /// coordinator → worker: `count u32 | (worker_id u32, port u16) ×
    /// count` — the mesh roster.  Worker `i` connects to every `j < i`
    /// and accepts from every `j > i`, then acks [`FrameKind::PeersAck`].
    Peers,
    /// worker → coordinator: empty body — the full mesh is up.
    PeersAck,
    /// coordinator → worker: `value_bytes u8 | len u64 | data` — replace
    /// the worker's value mirror (wire-encoded vertex values).
    StateSync,
    /// worker → coordinator: `hash u64` — receipt of the applied mirror
    /// ([`mirror_hash_of`]).
    StateAck,
    /// coordinator → worker: `op u8 | include_self u8 | label_len u16 |
    /// label` — one worker-native hop round descriptor
    /// ([`crate::mpc::transport::HopSpec`]), identical for every worker
    /// (loads are validated coordinator-side against the acks).
    HopRound,
    /// worker → coordinator: `received u64 | fold_checksum u64` — the
    /// receiver-observed load and the FNV-1a of the worker's canonical
    /// fold image (ascending key order).  O(1) bytes: the fold results
    /// themselves stay on the workers.
    HopAck,
    /// coordinator → worker: `new_n u64` — rewrite custody through the
    /// previously-synced map mirror and re-ship edges peer to peer.
    Rewire,
    /// worker → coordinator: `len u64 | checksum u64 | p u32 |
    /// peer_counts p × u64` — the adopted next-generation shard's
    /// statistics and payload checksum.
    RewireAck,

    // ---- worker↔worker mesh (the data plane; never the coordinator) ----
    /// peer → peer, once per connection: `from u32`.
    PeerHello,
    /// peer → peer: one hop round's bucket for the receiving machine
    /// (raw `key u64 | value` records).
    PeerMsgs,
    /// peer → peer: the sender's canonical fold image (its owned keys,
    /// ascending) — the mirror-maintenance all-gather.
    PeerFold,
    /// peer → peer: rewritten edges owned by the receiver after a
    /// [`FrameKind::Rewire`] (raw `(u32, u32)` pairs).
    PeerEdges,

    // ---- liveness (coordinator link; O(1)) ----
    /// coordinator → worker: empty body — heartbeat probe.  Sent at
    /// generation boundaries so a worker that died *between* rounds is a
    /// typed crash before the next round's traffic, not mid-protocol.
    Ping,
    /// worker → coordinator: empty body — heartbeat answer.
    Pong,

    // ---- mesh data-plane perf (v4; coordinator link) ----
    /// coordinator → worker: `value_bytes u8 | total_len u64 | count u64
    /// | (index u32 | value value_bytes) × count` — patch `count`
    /// entries of the worker's existing value mirror in place.  The
    /// worker's [`FrameKind::StateAck`] receipt hashes the *full*
    /// resulting mirror, so applying a delta over the wrong base is a
    /// typed divergence, never silent skew.
    StateDelta,
    /// coordinator → worker: `count u16 | (op u8 | include_self u8 |
    /// label_len u16 | label) × count` — a pipelined plan of consecutive
    /// hop rounds with no coordinator data dependency between them.  The
    /// batch frame carries the *base* seq; round `k` of the plan runs at
    /// `base + k` on the mesh, and the worker acks the whole plan once
    /// with [`FrameKind::HopBatchAck`] at the base seq.
    HopBatch,
    /// worker → coordinator: `count u16 | (received u64 | fold_checksum
    /// u64 | mesh_sent u64) × count` — per-round receipts of a
    /// [`FrameKind::HopBatch`], same fields as [`FrameKind::HopAck`],
    /// one ack frame per batch.
    HopBatchAck,
    /// coordinator → worker: `new_n u64 | program u8` — worker-native
    /// grouped contraction: rewrite custody through the previously-synced
    /// map mirror, gathering *every* distinct rewritten edge per owner
    /// under the shipped [`WireOp`] gather program (not a 1-per-key
    /// fold), and re-ship peer to peer.  Acked with
    /// [`FrameKind::RewireAck`].
    GatherRewire,
}

impl FrameKind {
    pub fn code(self) -> u8 {
        match self {
            FrameKind::Hello => 1,
            FrameKind::Assign => 2,
            FrameKind::LoadShard => 3,
            FrameKind::LoadAck => 4,
            FrameKind::Round => 5,
            FrameKind::RoundAck => 6,
            FrameKind::Shutdown => 7,
            FrameKind::Bye => 8,
            FrameKind::WorkerErr => 9,
            FrameKind::Peers => 10,
            FrameKind::PeersAck => 11,
            FrameKind::StateSync => 12,
            FrameKind::StateAck => 13,
            FrameKind::HopRound => 14,
            FrameKind::HopAck => 15,
            FrameKind::Rewire => 16,
            FrameKind::RewireAck => 17,
            FrameKind::PeerHello => 18,
            FrameKind::PeerMsgs => 19,
            FrameKind::PeerFold => 20,
            FrameKind::PeerEdges => 21,
            FrameKind::Ping => 22,
            FrameKind::Pong => 23,
            FrameKind::StateDelta => 24,
            FrameKind::HopBatch => 25,
            FrameKind::HopBatchAck => 26,
            FrameKind::GatherRewire => 27,
        }
    }

    pub fn from_code(code: u8) -> Option<FrameKind> {
        Some(match code {
            1 => FrameKind::Hello,
            2 => FrameKind::Assign,
            3 => FrameKind::LoadShard,
            4 => FrameKind::LoadAck,
            5 => FrameKind::Round,
            6 => FrameKind::RoundAck,
            7 => FrameKind::Shutdown,
            8 => FrameKind::Bye,
            9 => FrameKind::WorkerErr,
            10 => FrameKind::Peers,
            11 => FrameKind::PeersAck,
            12 => FrameKind::StateSync,
            13 => FrameKind::StateAck,
            14 => FrameKind::HopRound,
            15 => FrameKind::HopAck,
            16 => FrameKind::Rewire,
            17 => FrameKind::RewireAck,
            18 => FrameKind::PeerHello,
            19 => FrameKind::PeerMsgs,
            20 => FrameKind::PeerFold,
            21 => FrameKind::PeerEdges,
            22 => FrameKind::Ping,
            23 => FrameKind::Pong,
            24 => FrameKind::StateDelta,
            25 => FrameKind::HopBatch,
            26 => FrameKind::HopBatchAck,
            27 => FrameKind::GatherRewire,
            _ => return None,
        })
    }
}

/// One decoded frame.
#[derive(Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub seq: u64,
    pub body: Vec<u8>,
}

fn io_err(op: &'static str, e: std::io::Error) -> TransportError {
    TransportError::Io {
        worker: None,
        op,
        source: e,
    }
}

/// `read_exact` that reports how many bytes actually arrived, so a peer
/// dying mid-frame is a [`TransportError::ShortRead`] with real numbers.
fn read_exact_counted<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    op: &'static str,
) -> Result<(), TransportError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(TransportError::ShortRead {
                    worker: None,
                    wanted: buf.len() as u64,
                    got: filled as u64,
                })
            }
            Ok(k) => filled += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(io_err(op, e)),
        }
    }
    Ok(())
}

/// Write one frame (header + checksummed body) and flush.
pub fn write_frame<W: Write>(
    w: &mut W,
    kind: FrameKind,
    seq: u64,
    body: &[u8],
) -> Result<(), TransportError> {
    write_frame_parts(w, kind, seq, body, &[])
}

/// [`write_frame`] with the body supplied as two parts (the checksum and
/// declared length cover their concatenation): lets the round path send
/// its fixed header fields plus the payload buffer **without copying the
/// payload into a fresh body vector** — every shuffled byte would
/// otherwise be memcpy'd once more per round.
pub fn write_frame_parts<W: Write>(
    w: &mut W,
    kind: FrameKind,
    seq: u64,
    head: &[u8],
    tail: &[u8],
) -> Result<(), TransportError> {
    write_frame_slices(w, kind, seq, &[head, tail])
}

/// The general form of [`write_frame_parts`]: the body is the
/// concatenation of `parts` (checksum and declared length cover the
/// whole), each slice written straight from where it lives.  The parallel
/// generate path sends a peer bucket as its per-thread chunk slices in
/// chunk order — the wire bytes equal the serial single-buffer stream
/// without ever merging the chunks into one allocation.
pub fn write_frame_slices<W: Write>(
    w: &mut W,
    kind: FrameKind,
    seq: u64,
    parts: &[&[u8]],
) -> Result<(), TransportError> {
    let mut h = Fnv1a::new();
    let mut body_len = 0u64;
    for part in parts {
        h.update(part);
        body_len += part.len() as u64;
    }
    let checksum = h.finish();
    let mut header = Vec::with_capacity(FRAME_HEADER_BYTES as usize);
    header.extend_from_slice(FRAME_MAGIC);
    header.push(kind.code());
    header.extend_from_slice(&seq.to_le_bytes());
    header.extend_from_slice(&body_len.to_le_bytes());
    header.extend_from_slice(&checksum.to_le_bytes());
    w.write_all(&header).map_err(|e| io_err("write frame header", e))?;
    for part in parts {
        if !part.is_empty() {
            w.write_all(part).map_err(|e| io_err("write frame body", e))?;
        }
    }
    w.flush().map_err(|e| io_err("flush frame", e))
}

/// Read and validate one frame: magic, kind, declared length (sanity
/// capped), body checksum.  Truncation → [`TransportError::ShortRead`],
/// corruption → [`TransportError::ChecksumMismatch`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, TransportError> {
    let mut header = [0u8; FRAME_HEADER_BYTES as usize];
    read_exact_counted(r, &mut header, "read frame header")?;
    if &header[..8] != FRAME_MAGIC {
        return Err(TransportError::BadMagic { worker: None });
    }
    let kind = FrameKind::from_code(header[8]).ok_or_else(|| TransportError::Protocol {
        worker: None,
        detail: format!("unknown frame kind {}", header[8]),
    })?;
    let seq = u64::from_le_bytes(header[9..17].try_into().unwrap());
    let body_len = u64::from_le_bytes(header[17..25].try_into().unwrap());
    let expected = u64::from_le_bytes(header[25..33].try_into().unwrap());
    if body_len > MAX_FRAME_BODY {
        return Err(TransportError::Protocol {
            worker: None,
            detail: format!("frame declares {body_len}-byte body (cap {MAX_FRAME_BODY})"),
        });
    }
    let mut body = vec![0u8; body_len as usize];
    read_exact_counted(r, &mut body, "read frame body")?;
    let mut h = Fnv1a::new();
    h.update(&body);
    let actual = h.finish();
    if actual != expected {
        return Err(TransportError::ChecksumMismatch {
            worker: None,
            expected,
            actual,
        });
    }
    Ok(Frame { kind, seq, body })
}

// ---------------------------------------------------------------------------
// body codecs

/// Cursor over a frame body; shortage is a typed protocol error.
pub struct BodyReader<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> BodyReader<'a> {
    pub fn new(bytes: &'a [u8]) -> BodyReader<'a> {
        BodyReader { bytes, off: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], TransportError> {
        let end = self.off.checked_add(n).filter(|&e| e <= self.bytes.len());
        match end {
            Some(end) => {
                let s = &self.bytes[self.off..end];
                self.off = end;
                Ok(s)
            }
            None => Err(TransportError::Protocol {
                worker: None,
                detail: format!("frame body too short reading {what}"),
            }),
        }
    }

    pub fn u8(&mut self, what: &str) -> Result<u8, TransportError> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u16(&mut self, what: &str) -> Result<u16, TransportError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    pub fn u32(&mut self, what: &str) -> Result<u32, TransportError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn u64(&mut self, what: &str) -> Result<u64, TransportError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], TransportError> {
        self.take(n, what)
    }

    pub fn expect_end(&self, what: &str) -> Result<(), TransportError> {
        if self.off == self.bytes.len() {
            Ok(())
        } else {
            Err(TransportError::Protocol {
                worker: None,
                detail: format!(
                    "{what}: {} trailing bytes in frame body",
                    self.bytes.len() - self.off
                ),
            })
        }
    }
}

/// The fixed fields of a [`FrameKind::Round`] body — everything except
/// the payload bytes themselves, which the coordinator appends zero-copy
/// via [`write_frame_parts`].
pub fn encode_round_head(
    virtual_round: bool,
    fold: Option<WireOp>,
    declared_bytes: u64,
    label: &str,
    payload_len: usize,
) -> Vec<u8> {
    let label = label.as_bytes();
    let label_len = label.len().min(u16::MAX as usize);
    let mut head = Vec::with_capacity(1 + 1 + 8 + 2 + label_len + 8);
    head.push(u8::from(virtual_round));
    head.push(fold.map(WireOp::code).unwrap_or(0));
    head.extend_from_slice(&declared_bytes.to_le_bytes());
    head.extend_from_slice(&(label_len as u16).to_le_bytes());
    head.extend_from_slice(&label[..label_len]);
    head.extend_from_slice(&(payload_len as u64).to_le_bytes());
    head
}

/// Build a complete [`FrameKind::Round`] body (head + payload) — the
/// convenience form for tests and fakes; the transport's round loop uses
/// [`encode_round_head`] + [`write_frame_parts`] to avoid copying the
/// payload.
pub fn encode_round_body(
    virtual_round: bool,
    fold: Option<WireOp>,
    declared_bytes: u64,
    label: &str,
    payload: &[u8],
) -> Vec<u8> {
    let mut body = encode_round_head(virtual_round, fold, declared_bytes, label, payload.len());
    body.extend_from_slice(payload);
    body
}

/// Parsed [`FrameKind::Round`] body.
pub struct RoundMsg<'a> {
    pub virtual_round: bool,
    pub fold: Option<WireOp>,
    pub declared_bytes: u64,
    pub label: String,
    pub payload: &'a [u8],
}

/// Decode a [`FrameKind::Round`] body.
pub fn decode_round_body(body: &[u8]) -> Result<RoundMsg<'_>, TransportError> {
    let mut r = BodyReader::new(body);
    let virtual_round = r.u8("virtual flag")? != 0;
    let fold_code = r.u8("wire op")?;
    let fold = if fold_code == 0 {
        None
    } else {
        Some(WireOp::from_code(fold_code).ok_or_else(|| TransportError::Protocol {
            worker: None,
            detail: format!("unknown wire op {fold_code}"),
        })?)
    };
    let declared_bytes = r.u64("declared bytes")?;
    let label_len = r.u16("label length")? as usize;
    let label = String::from_utf8_lossy(r.bytes(label_len, "label")?).into_owned();
    let payload_len = r.u64("payload length")? as usize;
    let payload = r.bytes(payload_len, "payload")?;
    r.expect_end("round body")?;
    Ok(RoundMsg {
        virtual_round,
        fold,
        declared_bytes,
        label,
        payload,
    })
}

/// Fold `(key u64, value)` records into one value per key with min/max
/// over `Ord`, emitting `key | value` pairs in ascending key order
/// (`BTreeMap` iteration — deterministic).  Consumes the payload as a
/// list of slices (a `RoundInbox`'s buckets, fed in place) and folds only
/// keys in `[lo, hi)` (`hi` `None` = unbounded).
fn fold_records<V: Ord + Copy>(
    parts: &[&[u8]],
    rec: usize,
    lo: u64,
    hi: Option<u64>,
    take_min: bool,
    decode: impl Fn(&[u8]) -> V,
    encode: impl Fn(V, &mut Vec<u8>),
) -> Vec<u8> {
    let mut acc: std::collections::BTreeMap<u64, V> = std::collections::BTreeMap::new();
    for part in parts {
        for c in part.chunks_exact(rec) {
            let k = u64::from_le_bytes(c[..8].try_into().unwrap());
            if k < lo || hi.is_some_and(|h| k >= h) {
                continue;
            }
            let v = decode(&c[8..]);
            acc.entry(k)
                .and_modify(|cur| *cur = if take_min { (*cur).min(v) } else { (*cur).max(v) })
                .or_insert(v);
        }
    }
    let mut out = Vec::with_capacity(acc.len() * rec);
    for (k, v) in acc {
        out.extend_from_slice(&k.to_le_bytes());
        encode(v, &mut out);
    }
    out
}

/// Reject any payload slice that is not a whole number of `op` records.
/// Split from the fold itself so every thread count validates (and
/// errors) identically before any sub-range fold runs.
pub fn validate_fold_parts(op: WireOp, parts: &[&[u8]]) -> Result<(), String> {
    let rec = 8 + op.value_bytes();
    for part in parts {
        if part.len() % rec != 0 {
            return Err(format!(
                "fold payload is {} bytes, not a multiple of the {rec}-byte record",
                part.len()
            ));
        }
    }
    Ok(())
}

/// Fold a round payload (`(key u64, value)` records, value width implied
/// by `op`) the way the owning machine would: one folded value per
/// distinct key, emitted in ascending key order (deterministic).  Shared
/// by the worker process and the in-process loopback tests.
pub fn fold_wire_payload(op: WireOp, payload: &[u8]) -> Result<Vec<u8>, String> {
    fold_wire_payload_multi(op, &[payload])
}

/// [`fold_wire_payload`] over a list of payload slices, consumed where
/// they already live (a worker's own bucket plus each received peer
/// bucket) — the staging concat of the receive volume is gone.  Record
/// *order* across slices is irrelevant to the output: the min/max ops are
/// commutative and the gather sorts + dedups, so any slice order produces
/// the same ascending-key image.
pub fn fold_wire_payload_multi(op: WireOp, parts: &[&[u8]]) -> Result<Vec<u8>, String> {
    validate_fold_parts(op, parts)?;
    Ok(fold_wire_payload_in_range(op, parts, 0, None))
}

/// Fold only the records of `parts` whose key lies in `[lo, hi)` (`hi`
/// `None` = unbounded), emitting ascending keys.  `parts` must already
/// have passed [`validate_fold_parts`].  Because the full fold image is
/// ascending in the key, concatenating the images of consecutive key
/// ranges reproduces it byte for byte — this is what makes the worker's
/// key-partitioned parallel fold bit-identical to the serial one by
/// construction.  The **last** range of a partition must run unbounded so
/// garbage keys from a corrupt peer (≥ every valid key) still land in
/// exactly one range and surface downstream as the same typed error the
/// serial path raises.
pub fn fold_wire_payload_in_range(
    op: WireOp,
    parts: &[&[u8]],
    lo: u64,
    hi: Option<u64>,
) -> Vec<u8> {
    let rec = 8 + op.value_bytes();
    let take_min = matches!(op, WireOp::MinU32 | WireOp::MinU64 | WireOp::MinPairU32);
    match op {
        WireOp::MinU32 | WireOp::MaxU32 => fold_records(
            parts,
            rec,
            lo,
            hi,
            take_min,
            |b| u32::from_le_bytes(b[..4].try_into().unwrap()),
            |v, out| out.extend_from_slice(&v.to_le_bytes()),
        ),
        WireOp::MinU64 | WireOp::MaxU64 => fold_records(
            parts,
            rec,
            lo,
            hi,
            take_min,
            |b| u64::from_le_bytes(b[..8].try_into().unwrap()),
            |v, out| out.extend_from_slice(&v.to_le_bytes()),
        ),
        WireOp::MinPairU32 | WireOp::MaxPairU32 => fold_records(
            parts,
            rec,
            lo,
            hi,
            take_min,
            |b| {
                (
                    u32::from_le_bytes(b[..4].try_into().unwrap()),
                    u32::from_le_bytes(b[4..8].try_into().unwrap()),
                )
            },
            |(a, b), out| {
                out.extend_from_slice(&a.to_le_bytes());
                out.extend_from_slice(&b.to_le_bytes());
            },
        ),
        // a gather is not a 1-per-key fold: every distinct (key, pair)
        // record survives, sorted lexicographically and deduped exactly —
        // the canonical image of a grouped reduce.  Duplicates share a
        // key, so a key-range partition never splits a dedup pair.
        WireOp::GatherPairU32 => {
            let mut recs: Vec<(u64, u32, u32)> = Vec::new();
            for part in parts {
                for c in part.chunks_exact(rec) {
                    let k = u64::from_le_bytes(c[..8].try_into().unwrap());
                    if k < lo || hi.is_some_and(|h| k >= h) {
                        continue;
                    }
                    recs.push((
                        k,
                        u32::from_le_bytes(c[8..12].try_into().unwrap()),
                        u32::from_le_bytes(c[12..16].try_into().unwrap()),
                    ));
                }
            }
            recs.sort_unstable();
            recs.dedup();
            let mut out = Vec::with_capacity(recs.len() * rec);
            for (k, a, b) in recs {
                out.extend_from_slice(&k.to_le_bytes());
                out.extend_from_slice(&a.to_le_bytes());
                out.extend_from_slice(&b.to_le_bytes());
            }
            out
        }
    }
}

// ---------------------------------------------------------------------------
// the coordinator-side transport

/// A socket that counts every byte it moves (both directions share one
/// counter).  Wrapped around each coordinator↔worker link so tests can
/// assert the control-plane property directly: in shuffle mode a round's
/// coordinator-link traffic is O(machines) summary bytes while the O(m)
/// message stream stays on the worker mesh.
struct Meter {
    sock: TcpStream,
    counter: std::sync::Arc<std::sync::atomic::AtomicU64>,
}

impl Read for Meter {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let k = self.sock.read(buf)?;
        self.counter
            .fetch_add(k as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(k)
    }
}

impl Write for Meter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let k = self.sock.write(buf)?;
        self.counter
            .fetch_add(k as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(k)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.sock.flush()
    }
}

struct Conn {
    reader: BufReader<Meter>,
    writer: BufWriter<Meter>,
}

impl std::fmt::Debug for Conn {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Conn")
    }
}

/// The multi-process [`Exchange`] backend (coordinator side): owns the
/// worker connections (and, when it spawned them, the child processes).
#[derive(Debug)]
pub struct ProcTransport {
    conns: Vec<Conn>,
    /// Aligned to worker ids by [`ProcTransport::spawn`] via the Hello
    /// pid: `children[j]` is worker `j`'s process (empty for
    /// [`ProcTransport::from_connected`]).
    children: Vec<Child>,
    /// Worker-reported pid per machine, in worker-id order.
    worker_pids: Vec<u32>,
    /// Worker mesh-listener port per machine (from the v2 Hello), used
    /// only by the shuffle transport's `Peers` roster.
    mesh_ports: Vec<u16>,
    /// Data-plane thread count each worker reported in its v5 Hello
    /// (what the fleet *actually* runs, not what the coordinator asked
    /// for) — surfaced through the mesh metrics.
    worker_threads: Vec<u32>,
    /// Total bytes moved over the coordinator links, both directions
    /// (shared by every [`Meter`]).
    link_bytes: std::sync::Arc<std::sync::atomic::AtomicU64>,
    machines: usize,
    seq: u64,
    finished: bool,
    /// Configuration this transport (and its spawned workers) runs under.
    cfg: NetConfig,
    /// The binary replacement workers respawn from (`None` for
    /// [`ProcTransport::from_connected`]: nothing to respawn).
    worker_bin: Option<std::path::PathBuf>,
}

impl ProcTransport {
    /// Spawn `machines` worker processes (`worker_bin worker --connect
    /// ADDR`) on localhost and complete the handshake with each.  The
    /// driver passes its own executable; tests pass
    /// `env!("CARGO_BIN_EXE_lcc")`.  Configuration comes from the
    /// environment ([`NetConfig::from_env`]); use
    /// [`spawn_with`](ProcTransport::spawn_with) for explicit settings.
    pub fn spawn(machines: usize, worker_bin: &Path) -> Result<ProcTransport, TransportError> {
        Self::spawn_with(machines, worker_bin, NetConfig::from_env())
    }

    /// [`spawn`](ProcTransport::spawn) under an explicit [`NetConfig`]:
    /// the workers inherit `cfg`'s io-timeout / connect-retries / fault
    /// plan through their environment.
    pub fn spawn_with(
        machines: usize,
        worker_bin: &Path,
        cfg: NetConfig,
    ) -> Result<ProcTransport, TransportError> {
        let counter = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        Self::spawn_counted(machines, worker_bin, cfg, counter, 0)
    }

    /// The spawn body; `counter`/`seq0` let a recovery respawn keep the
    /// original transport's byte counter and round counter.
    fn spawn_counted(
        machines: usize,
        worker_bin: &Path,
        cfg: NetConfig,
        counter: std::sync::Arc<std::sync::atomic::AtomicU64>,
        seq0: u64,
    ) -> Result<ProcTransport, TransportError> {
        let machines = machines.max(1);
        let listener = TcpListener::bind(("127.0.0.1", 0))
            .map_err(|e| io_err("bind coordinator listener", e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| io_err("coordinator listener addr", e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| io_err("listener nonblocking", e))?;

        let mut children: Vec<Child> = Vec::with_capacity(machines);
        for j in 0..machines {
            let mut cmd = Command::new(worker_bin);
            cmd.arg("worker")
                .arg("--connect")
                .arg(addr.to_string())
                .env("LCC_IO_TIMEOUT_MS", cfg.io_timeout.as_millis().to_string())
                .env("LCC_CONNECT_RETRIES", cfg.connect_retries.to_string())
                .env("LCC_WORKER_THREADS", cfg.worker_threads.max(1).to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::inherit());
            // the plan must not leak into replacement workers (their
            // predecessors already enacted it — an inherited env would
            // re-kill every respawn), so an absent plan is scrubbed
            match &cfg.fault_plan {
                Some(plan) => cmd.env("LCC_FAULT_PLAN", plan),
                None => cmd.env_remove("LCC_FAULT_PLAN"),
            };
            match cmd.spawn() {
                Ok(c) => children.push(c),
                Err(e) => {
                    kill_children(&mut children);
                    return Err(TransportError::Io {
                        worker: Some(j),
                        op: "spawn worker",
                        source: e,
                    });
                }
            }
        }

        // accept all workers, surfacing an early-exiting child as a crash
        // instead of waiting out the deadline
        let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
        let mut streams: Vec<TcpStream> = Vec::with_capacity(machines);
        while streams.len() < machines {
            match listener.accept() {
                Ok((s, _peer)) => streams.push(s),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    for (j, c) in children.iter_mut().enumerate() {
                        if let Ok(Some(status)) = c.try_wait() {
                            kill_children(&mut children);
                            return Err(TransportError::WorkerCrashed {
                                worker: j,
                                detail: format!("exited during handshake: {status}"),
                            });
                        }
                    }
                    if Instant::now() >= deadline {
                        kill_children(&mut children);
                        return Err(TransportError::Protocol {
                            worker: None,
                            detail: format!(
                                "{}/{} workers connected before the handshake deadline",
                                streams.len(),
                                machines
                            ),
                        });
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    kill_children(&mut children);
                    return Err(io_err("accept worker", e));
                }
            }
        }

        let mut t = match Self::handshake(streams, cfg, counter, seq0) {
            Ok(t) => t,
            Err(e) => {
                kill_children(&mut children);
                return Err(e);
            }
        };
        t.worker_bin = Some(worker_bin.to_path_buf());
        // Worker ids follow accept order, children spawn order — align
        // them by the pid each worker reported in its Hello so
        // `children[j]` really is worker `j`'s process (kill_worker and
        // crash attribution depend on it).  A pid with no matching child
        // is left at the end, untargeted but still reaped.
        let mut aligned: Vec<Child> = Vec::with_capacity(children.len());
        for &pid in &t.worker_pids {
            if let Some(pos) = children.iter().position(|c| c.id() == pid) {
                aligned.push(children.remove(pos));
            }
        }
        aligned.extend(children);
        t.children = aligned;
        Ok(t)
    }

    /// Build a transport over already-connected streams, running the
    /// `Hello`/`Assign` handshake on each (the fault-injection tests play
    /// the worker side themselves; no processes are owned).
    pub fn from_connected(streams: Vec<TcpStream>) -> Result<ProcTransport, TransportError> {
        let counter = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        Self::handshake(streams, NetConfig::from_env(), counter, 0)
    }

    fn handshake(
        streams: Vec<TcpStream>,
        cfg: NetConfig,
        link_bytes: std::sync::Arc<std::sync::atomic::AtomicU64>,
        seq0: u64,
    ) -> Result<ProcTransport, TransportError> {
        if streams.is_empty() {
            return Err(TransportError::Protocol {
                worker: None,
                detail: "a proc transport needs at least one worker".into(),
            });
        }
        let machines = streams.len();
        let mut conns = Vec::with_capacity(streams.len());
        let mut worker_pids = Vec::with_capacity(streams.len());
        let mut mesh_ports = Vec::with_capacity(streams.len());
        let mut worker_threads = Vec::with_capacity(streams.len());
        for (j, s) in streams.into_iter().enumerate() {
            let counter = std::sync::Arc::clone(&link_bytes);
            let prep = || -> Result<Conn, TransportError> {
                s.set_nonblocking(false)
                    .map_err(|e| io_err("stream blocking mode", e))?;
                s.set_nodelay(true).map_err(|e| io_err("set nodelay", e))?;
                s.set_read_timeout(Some(cfg.io_timeout))
                    .map_err(|e| io_err("set read timeout", e))?;
                // writes too: a worker that stops draining must not block
                // a large LoadShard/Round write forever
                s.set_write_timeout(Some(cfg.io_timeout))
                    .map_err(|e| io_err("set write timeout", e))?;
                let dup = s.try_clone().map_err(|e| io_err("clone stream", e))?;
                let reader = BufReader::new(Meter {
                    sock: dup,
                    counter: std::sync::Arc::clone(&counter),
                });
                Ok(Conn {
                    reader,
                    writer: BufWriter::new(Meter { sock: s, counter }),
                })
            };
            let mut conn = prep().map_err(|e| e.for_worker(j))?;
            let hello = read_frame(&mut conn.reader).map_err(|e| e.for_worker(j))?;
            if hello.kind != FrameKind::Hello {
                return Err(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!("expected Hello, got {:?}", hello.kind),
                });
            }
            let mut r = BodyReader::new(&hello.body);
            let version = r.u32("hello version").map_err(|e| e.for_worker(j))?;
            if version != PROTO_VERSION {
                return Err(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!(
                        "worker speaks protocol {version}, coordinator {PROTO_VERSION}"
                    ),
                });
            }
            let pid = r.u32("hello pid").map_err(|e| e.for_worker(j))?;
            let port = r.u16("hello mesh port").map_err(|e| e.for_worker(j))?;
            let threads = r.u32("hello worker threads").map_err(|e| e.for_worker(j))?;
            worker_pids.push(pid);
            mesh_ports.push(port);
            worker_threads.push(threads.max(1));
            let mut body = Vec::with_capacity(12);
            body.extend_from_slice(&PROTO_VERSION.to_le_bytes());
            body.extend_from_slice(&(j as u32).to_le_bytes());
            body.extend_from_slice(&(machines as u32).to_le_bytes());
            write_frame(&mut conn.writer, FrameKind::Assign, 0, &body)
                .map_err(|e| e.for_worker(j))?;
            conns.push(conn);
        }
        Ok(ProcTransport {
            conns,
            children: Vec::new(),
            worker_pids,
            mesh_ports,
            worker_threads,
            link_bytes,
            machines,
            seq: seq0,
            finished: false,
            cfg,
            worker_bin: None,
        })
    }

    pub fn num_machines(&self) -> usize {
        self.machines
    }

    /// Shared counter of every byte moved over the coordinator links,
    /// both directions.  Clone the handle before boxing the transport to
    /// observe a run's control-plane traffic from the outside.
    pub fn link_bytes_counter(&self) -> std::sync::Arc<std::sync::atomic::AtomicU64> {
        std::sync::Arc::clone(&self.link_bytes)
    }

    /// Distribute the graph: shard `s` (in the spill shard-file framing —
    /// a spilled graph ships its raw file bytes, no rehydration) goes to
    /// worker `s`, which validates the framing, re-derives the shard
    /// statistics from the edges, and acks them; the coordinator
    /// cross-checks the ack against its cached stats so custody
    /// divergence is a typed error before any round runs.
    pub fn load_graph(&mut self, g: &ShardedGraph) -> Result<(), TransportError> {
        self.load_graph_from(g, None)
    }

    /// [`load_graph`](ProcTransport::load_graph), optionally preferring
    /// shard files under `override_dir` (a generation checkpoint's
    /// custody directory) over the graph's own residency: recovery
    /// re-ships a respawned fleet from the checkpointed spill files so
    /// custody restoration never depends on the live graph having stayed
    /// spilled.  Every source is validated against the same cached
    /// coordinator checksum, so a stale or torn checkpoint file is a
    /// typed divergence, not silent corruption.
    pub fn load_graph_from(
        &mut self,
        g: &ShardedGraph,
        override_dir: Option<&Path>,
    ) -> Result<(), TransportError> {
        if g.num_shards() != self.machines {
            return Err(TransportError::Protocol {
                worker: None,
                detail: format!(
                    "graph has {} shards, transport has {} machines",
                    g.num_shards(),
                    self.machines
                ),
            });
        }
        let p = self.machines;
        self.seq += 1;
        let seq = self.seq;
        let mut want_checksums = Vec::with_capacity(p);
        for s in 0..p {
            // The frame body IS the shard file image (one layout on disk
            // and wire): a checkpointed file ships verbatim, a spilled
            // shard ships its mmap'd image borrowed straight into the
            // socket write — no decode, no re-encode, no copy — and only
            // a resident shard encodes fresh bytes.
            let checkpointed = override_dir
                .map(|d| d.join(spill::shard_file_name(s)))
                .and_then(|path| std::fs::read(path).ok());
            let mut mapped: Option<&[u8]> = None;
            let owned: Option<Vec<u8>> = match checkpointed {
                Some(bytes) => Some(bytes),
                None => {
                    let data = g.shard_data(s);
                    match (data.image(), data.as_pairs()) {
                        // image/as_pairs borrow from the store (`'g`),
                        // not the view, so the borrow outlives `data`
                        (Some(img), _) => {
                            mapped = Some(img);
                            None
                        }
                        (None, Some(pairs)) => {
                            Some(spill::encode_shard_bytes(s as u32, p as u32, pairs).0)
                        }
                        (None, None) => Some(
                            spill::encode_shard_bytes(s as u32, p as u32, &data.into_vec()).0,
                        ),
                    }
                }
            };
            let image: &[u8] = mapped
                .or(owned.as_deref())
                .expect("shard image resolved above");
            let checksum = shard_payload_checksum(g, s);
            want_checksums.push(checksum);
            let mut head = Vec::with_capacity(4 + 8);
            head.extend_from_slice(&(s as u32).to_le_bytes());
            head.extend_from_slice(&(image.len() as u64).to_le_bytes());
            write_frame_parts(&mut self.conns[s].writer, FrameKind::LoadShard, seq, &head, image)
                .map_err(|e| self.crash_context(s, e))?;
        }
        for s in 0..p {
            let frame =
                read_frame(&mut self.conns[s].reader).map_err(|e| self.crash_context(s, e))?;
            match frame.kind {
                FrameKind::LoadAck => {}
                FrameKind::WorkerErr => {
                    return Err(TransportError::Protocol {
                        worker: Some(s),
                        detail: String::from_utf8_lossy(&frame.body).into_owned(),
                    })
                }
                other => {
                    return Err(TransportError::Protocol {
                        worker: Some(s),
                        detail: format!("expected LoadAck, got {other:?}"),
                    })
                }
            }
            if frame.seq != seq {
                return Err(TransportError::Protocol {
                    worker: Some(s),
                    detail: format!("LoadAck seq {} != {seq}", frame.seq),
                });
            }
            let mut r = BodyReader::new(&frame.body);
            let ack = (|| -> Result<(u32, u64, u64, Vec<u64>), TransportError> {
                let shard = r.u32("ack shard")?;
                let len = r.u64("ack len")?;
                let checksum = r.u64("ack checksum")?;
                let ack_p = r.u32("ack shard count")? as usize;
                let mut peers = Vec::with_capacity(ack_p.min(1 << 16));
                for _ in 0..ack_p {
                    peers.push(r.u64("ack peer count")?);
                }
                r.expect_end("load ack")?;
                Ok((shard, len, checksum, peers))
            })()
            .map_err(|e| e.for_worker(s))?;
            let (shard, len, checksum, peers) = ack;
            let stats = g.shard_stats(s);
            if shard != s as u32
                || len != stats.len
                || checksum != want_checksums[s]
                || peers != stats.peer_counts
            {
                return Err(TransportError::Protocol {
                    worker: Some(s),
                    detail: format!(
                        "worker shard statistics diverge from the coordinator cache \
                         (shard {shard}, {len} edges, checksum {checksum:#018x})"
                    ),
                });
            }
        }
        Ok(())
    }

    /// Kill worker `j`'s process outright (fault injection for tests; the
    /// next exchange must surface a typed error, not hang).
    pub fn kill_worker(&mut self, j: usize) {
        if let Some(c) = self.children.get_mut(j) {
            let _ = c.kill();
            let _ = c.wait();
        }
    }

    /// Map a low-level error on worker `j`'s connection: if a child is
    /// observably dead, report the crash; otherwise keep the precise
    /// fault (a short read from a live worker is a truncated frame, not a
    /// crash).
    fn crash_context(&mut self, j: usize, e: TransportError) -> TransportError {
        let disconnect = match &e {
            TransportError::ShortRead { .. } => true,
            TransportError::Io { source, .. } => matches!(
                source.kind(),
                std::io::ErrorKind::BrokenPipe
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::WriteZero
            ),
            _ => false,
        };
        if disconnect {
            // children are pid-aligned to worker ids: probe this worker's
            // own process first, then report any other casualty under its
            // real machine index
            if let Some(c) = self.children.get_mut(j) {
                if let Ok(Some(status)) = c.try_wait() {
                    return TransportError::WorkerCrashed {
                        worker: j,
                        detail: format!("worker process exited ({status}) mid-round"),
                    };
                }
            }
            for (k, c) in self.children.iter_mut().enumerate() {
                if let Ok(Some(status)) = c.try_wait() {
                    return TransportError::WorkerCrashed {
                        worker: k,
                        detail: format!("worker process exited ({status}) mid-round"),
                    };
                }
            }
        }
        e.for_worker(j)
    }

    /// Heartbeat barrier: `Ping` every worker and require a `Pong` back.
    /// Called at generation boundaries only — the per-hop paths stay
    /// heartbeat-free so the O(machines)-per-round coordinator-link bound
    /// is unchanged.  A dead worker surfaces here as a typed
    /// [`TransportError::WorkerCrashed`] *before* a multi-round replay
    /// window opens, which is what keeps recovery replay windows at most
    /// one generation deep.
    pub fn probe_workers(&mut self) -> Result<(), TransportError> {
        self.seq += 1;
        let seq = self.seq;
        for j in 0..self.conns.len() {
            write_frame(&mut self.conns[j].writer, FrameKind::Ping, seq, &[])
                .map_err(|e| self.crash_context(j, e))?;
        }
        for j in 0..self.conns.len() {
            let frame =
                read_frame(&mut self.conns[j].reader).map_err(|e| self.crash_context(j, e))?;
            if frame.kind != FrameKind::Pong || frame.seq != seq {
                return Err(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!(
                        "expected Pong seq {seq}, got {:?} seq {}",
                        frame.kind, frame.seq
                    ),
                });
            }
        }
        Ok(())
    }

    /// Spawn a replacement fleet: same machine count, same binary, same
    /// shared byte counter and round counter, but with the fault plan
    /// scrubbed (the dead workers already enacted it; replacements
    /// re-running the same kills would make recovery a fixpoint-free
    /// loop).  The old fleet is killed first so replacement listeners
    /// never race the casualties for ports.  `Err` if this transport
    /// doesn't own its workers ([`ProcTransport::from_connected`]).
    fn respawn_fleet(&mut self) -> Result<ProcTransport, TransportError> {
        let bin = self.worker_bin.clone().ok_or_else(|| TransportError::Protocol {
            worker: None,
            detail: "transport owns no worker binary to respawn from".into(),
        })?;
        self.conns.clear();
        kill_children(&mut self.children);
        let mut cfg = self.cfg.clone();
        cfg.fault_plan = None;
        Self::spawn_counted(
            self.machines,
            &bin,
            cfg,
            std::sync::Arc::clone(&self.link_bytes),
            self.seq,
        )
    }

    /// Graceful shutdown: every worker acks with `Bye` and exits; child
    /// processes are reaped.  [`Drop`] does the same best-effort.
    pub fn shutdown(mut self) -> Result<(), TransportError> {
        self.seq += 1;
        let seq = self.seq;
        for j in 0..self.conns.len() {
            write_frame(&mut self.conns[j].writer, FrameKind::Shutdown, seq, &[])
                .map_err(|e| self.crash_context(j, e))?;
        }
        for j in 0..self.conns.len() {
            let frame =
                read_frame(&mut self.conns[j].reader).map_err(|e| self.crash_context(j, e))?;
            if frame.kind != FrameKind::Bye {
                return Err(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!("expected Bye, got {:?}", frame.kind),
                });
            }
        }
        self.finished = true;
        let mut children = std::mem::take(&mut self.children);
        reap_children(&mut children);
        Ok(())
    }
}

fn kill_children(children: &mut Vec<Child>) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
    children.clear();
}

/// Wait briefly for children to exit on their own, then kill stragglers.
fn reap_children(children: &mut Vec<Child>) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while Instant::now() < deadline {
        if children
            .iter_mut()
            .all(|c| matches!(c.try_wait(), Ok(Some(_))))
        {
            children.clear();
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    kill_children(children);
}

impl Drop for ProcTransport {
    fn drop(&mut self) {
        if self.finished {
            return;
        }
        self.seq += 1;
        for conn in &mut self.conns {
            let _ = write_frame(&mut conn.writer, FrameKind::Shutdown, self.seq, &[]);
        }
        self.conns.clear(); // drop the sockets: a wedged worker sees EOF
        let mut children = std::mem::take(&mut self.children);
        reap_children(&mut children);
    }
}

impl Exchange for ProcTransport {
    fn name(&self) -> &'static str {
        "proc"
    }

    fn wants_wire(&self) -> bool {
        true
    }

    fn machines(&self) -> Option<usize> {
        Some(self.machines)
    }

    /// Persistent-session reload: re-ship every shard of `g` to the live
    /// fleet (workers replace their custody on a fresh `LoadShard` — the
    /// same path recovery re-ships through).
    fn load_graph(&mut self, g: &ShardedGraph) -> Result<(), TransportError> {
        ProcTransport::load_graph(self, g)
    }

    fn exchange(
        &mut self,
        label: &str,
        charge: RoundCharge<'_>,
        payloads: Vec<Vec<u8>>,
        fold: Option<WireOp>,
    ) -> Result<ExchangeAck, TransportError> {
        let p = self.machines;
        if charge.machine_bytes.len() != p {
            return Err(TransportError::Protocol {
                worker: None,
                detail: format!(
                    "round charge is {} machines wide, transport has {p}",
                    charge.machine_bytes.len()
                ),
            });
        }
        let virtual_round = payloads.is_empty();
        if !virtual_round && payloads.len() != p {
            return Err(TransportError::Protocol {
                worker: None,
                detail: format!("{} payloads for {p} machines", payloads.len()),
            });
        }
        self.seq += 1;
        let seq = self.seq;

        for j in 0..p {
            let payload: &[u8] = if virtual_round { &[] } else { &payloads[j] };
            let head = encode_round_head(
                virtual_round,
                fold,
                charge.machine_bytes[j],
                label,
                payload.len(),
            );
            write_frame_parts(&mut self.conns[j].writer, FrameKind::Round, seq, &head, payload)
                .map_err(|e| self.crash_context(j, e))?;
        }

        let mut machine_bytes = Vec::with_capacity(p);
        let mut folded = fold.map(|_| Vec::with_capacity(p));
        for j in 0..p {
            let frame =
                read_frame(&mut self.conns[j].reader).map_err(|e| self.crash_context(j, e))?;
            match frame.kind {
                FrameKind::RoundAck => {}
                FrameKind::WorkerErr => {
                    return Err(TransportError::Protocol {
                        worker: Some(j),
                        detail: String::from_utf8_lossy(&frame.body).into_owned(),
                    })
                }
                other => {
                    return Err(TransportError::Protocol {
                        worker: Some(j),
                        detail: format!("expected RoundAck, got {other:?}"),
                    })
                }
            }
            if frame.seq != seq {
                return Err(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!("RoundAck seq {} != round seq {seq}", frame.seq),
                });
            }
            let mut r = BodyReader::new(&frame.body);
            let accounted = r.u64("accounted bytes").map_err(|e| e.for_worker(j))?;
            let fold_len = r.u64("fold length").map_err(|e| e.for_worker(j))? as usize;
            let fold_bytes = r
                .bytes(fold_len, "fold pairs")
                .map_err(|e| e.for_worker(j))?;
            r.expect_end("round ack").map_err(|e| e.for_worker(j))?;
            machine_bytes.push(accounted);
            if let Some(fs) = folded.as_mut() {
                fs.push(fold_bytes.to_vec());
            }
        }
        Ok(ExchangeAck {
            machine_bytes,
            folded,
        })
    }
}

// ---------------------------------------------------------------------------
// the shuffle transport: worker-native data plane, coordinator control plane

/// Domain-separated content hash of a worker value mirror: the value
/// width and length are hashed ahead of the wire-encoded data, so mirrors
/// of different shapes can never collide.  Both sides compute it — the
/// coordinator to decide whether a `StateSync` is needed, the worker as
/// its application receipt.
pub fn mirror_hash_of(value_bytes: u8, data: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(&[value_bytes]);
    h.update(&(data.len() as u64).to_le_bytes());
    h.update(data);
    h.finish()
}

/// Observability counters of a [`ShuffleTransport`] (shared handle:
/// clone via [`ShuffleTransport::stats`] before boxing).  Tests assert
/// the custody story through these — e.g. that a contraction really
/// re-shipped peer-to-peer (`rewires`) instead of falling back to a
/// coordinator re-load (`custody_loads`).
#[derive(Debug, Default)]
pub struct ShuffleStats {
    /// Peer-to-peer custody handoffs ([`FrameKind::Rewire`]).
    pub rewires: std::sync::atomic::AtomicU64,
    /// Coordinator-link custody (re-)loads ([`FrameKind::LoadShard`]),
    /// including the initial distribution.
    pub custody_loads: std::sync::atomic::AtomicU64,
    /// Mirror broadcasts ([`FrameKind::StateSync`]) *and* delta patches
    /// ([`FrameKind::StateDelta`]) — every mirror sync, either encoding.
    pub state_syncs: std::sync::atomic::AtomicU64,
    /// Mirror syncs that shipped as [`FrameKind::StateDelta`] patches
    /// (subset of `state_syncs`).
    pub delta_syncs: std::sync::atomic::AtomicU64,
    /// Worker-native hop rounds ([`FrameKind::HopRound`] plus every
    /// round of each [`FrameKind::HopBatch`]).
    pub hops: std::sync::atomic::AtomicU64,
    /// Pipelined hop plans shipped ([`FrameKind::HopBatch`]).
    pub hop_batches: std::sync::atomic::AtomicU64,
    /// Coordinator→worker mirror-sync bytes (frame headers + bodies of
    /// every `StateSync`/`StateDelta`, summed across workers) — the
    /// O(changed)-vs-O(n) surface the delta path is measured on.
    pub sync_bytes: std::sync::atomic::AtomicU64,
    /// Worker↔worker mesh bytes as metered by the workers themselves
    /// (frame headers + bodies of `PeerMsgs`/`PeerFold`/`PeerEdges`
    /// sends, accumulated from hop/rewire acks).
    pub mesh_bytes: std::sync::atomic::AtomicU64,
    /// Generation checkpoints persisted ([`spill::write_checkpoint`]).
    pub checkpoints: std::sync::atomic::AtomicU64,
    /// Successful worker-fleet recoveries ([`ShuffleOps::recover`]).
    pub recoveries: std::sync::atomic::AtomicU64,
}

/// The worker↔worker shuffle backend (coordinator side): the same
/// spawned workers, sockets, and frame protocol as [`ProcTransport`]
/// (which it wraps for every coordinator-routed round), plus the mesh
/// control plane — `Peers` roster, value-mirror sync, hop descriptors,
/// and peer-to-peer custody rewires.  See the module docs for the
/// protocol and `EXPERIMENTS.md` §Distributed protocol for the frame
/// walk-through.
#[derive(Debug)]
pub struct ShuffleTransport {
    links: ProcTransport,
    /// Generation id of the graph the workers hold custody of.
    custody: Option<u64>,
    /// Content hash of the worker-side value mirror.
    mirror: Option<u64>,
    /// The synced mirror's wire bytes, retained as the base the next
    /// [`ShuffleOps::sync_mirror`] diffs against (empty = no base; the
    /// next sync is a full broadcast).
    mirror_data: Vec<u8>,
    /// Value width of `mirror_data` (a width change forces a full
    /// broadcast — deltas never patch across shapes).
    mirror_vb: u8,
    stats: std::sync::Arc<ShuffleStats>,
    /// Generation-checkpoint state; `None` = checkpointing off.
    checkpoint: Option<CheckpointState>,
}

/// Where and what the coordinator checkpoints at generation boundaries.
#[derive(Debug)]
struct CheckpointState {
    /// Owns `checkpoint.lcc` plus one `gen-<id>/` custody directory of
    /// spill files per live checkpoint.
    dir: spill::SpillDir,
    /// The run's RNG stream position, recorded in every
    /// [`spill::RunCheckpoint`].  In-stack recovery keeps the live RNG
    /// (the algorithm state never dies), so this is captured once at run
    /// start for the on-disk format's completeness and external resume
    /// tooling, not re-sampled per generation.
    rng_state: [u64; 4],
}

impl ShuffleTransport {
    /// Spawn `machines` workers (exactly [`ProcTransport::spawn`]) and
    /// bring up the worker mesh: ship each the `Peers` roster built from
    /// the Hello mesh ports and barrier on every `PeersAck`.
    pub fn spawn(machines: usize, worker_bin: &Path) -> Result<ShuffleTransport, TransportError> {
        Self::from_links(ProcTransport::spawn(machines, worker_bin)?)
    }

    /// [`spawn`](ShuffleTransport::spawn) under an explicit
    /// [`NetConfig`] (see [`ProcTransport::spawn_with`]).
    pub fn spawn_with(
        machines: usize,
        worker_bin: &Path,
        cfg: NetConfig,
    ) -> Result<ShuffleTransport, TransportError> {
        Self::from_links(ProcTransport::spawn_with(machines, worker_bin, cfg)?)
    }

    /// Build over already-connected streams (fault-injection tests play
    /// the worker side), running the proc handshake plus the mesh roster.
    pub fn from_connected(streams: Vec<TcpStream>) -> Result<ShuffleTransport, TransportError> {
        Self::from_links(ProcTransport::from_connected(streams)?)
    }

    fn from_links(mut links: ProcTransport) -> Result<ShuffleTransport, TransportError> {
        Self::mesh_up(&mut links)?;
        Ok(ShuffleTransport {
            links,
            custody: None,
            mirror: None,
            mirror_data: Vec::new(),
            mirror_vb: 0,
            stats: std::sync::Arc::new(ShuffleStats::default()),
            checkpoint: None,
        })
    }

    /// Bring up the worker↔worker mesh over `links`: ship each worker the
    /// `Peers` roster built from the Hello mesh ports, barrier on every
    /// `PeersAck`.  Also the respawn path's mesh bring-up during
    /// [`ShuffleOps::recover`].
    fn mesh_up(links: &mut ProcTransport) -> Result<(), TransportError> {
        let p = links.machines;
        links.seq += 1;
        let seq = links.seq;
        let mut roster = Vec::with_capacity(4 + p * 6);
        roster.extend_from_slice(&(p as u32).to_le_bytes());
        for j in 0..p {
            roster.extend_from_slice(&(j as u32).to_le_bytes());
            roster.extend_from_slice(&links.mesh_ports[j].to_le_bytes());
        }
        for j in 0..p {
            write_frame(&mut links.conns[j].writer, FrameKind::Peers, seq, &roster)
                .map_err(|e| links.crash_context(j, e))?;
        }
        for j in 0..p {
            let frame =
                read_frame(&mut links.conns[j].reader).map_err(|e| links.crash_context(j, e))?;
            match frame.kind {
                FrameKind::PeersAck => {}
                FrameKind::WorkerErr => {
                    return Err(TransportError::Protocol {
                        worker: Some(j),
                        detail: String::from_utf8_lossy(&frame.body).into_owned(),
                    })
                }
                other => {
                    return Err(TransportError::Protocol {
                        worker: Some(j),
                        detail: format!("expected PeersAck, got {other:?}"),
                    })
                }
            }
        }
        Ok(())
    }

    /// Enable per-generation checkpointing into `dir` (see
    /// [`spill::RunCheckpoint`]); `rng_state` is the run's RNG stream
    /// position as seeded ([`crate::util::rng::Rng::state`]).
    pub fn set_checkpoint(&mut self, dir: spill::SpillDir, rng_state: [u64; 4]) {
        self.checkpoint = Some(CheckpointState { dir, rng_state });
    }

    /// Persist the generation checkpoint for `g`: custody spill files
    /// first, the checksummed [`spill::RunCheckpoint`] after (atomic
    /// tmp-write + fsync + rename), so a crash mid-persist leaves the
    /// previous checkpoint intact and pointing at intact files.  Older
    /// generation directories are pruned only once the new checkpoint is
    /// durable.  No-op when checkpointing is off.
    fn checkpoint_generation(&mut self, g: &ShardedGraph) -> Result<(), TransportError> {
        let Some(ck) = &self.checkpoint else {
            return Ok(());
        };
        let generation = g.generation();
        let custody_dir = format!("gen-{generation}");
        g.persist_spilled(ck.dir.path().join(&custody_dir))?;
        spill::write_checkpoint(
            &ck.dir.path().join(spill::CHECKPOINT_NAME),
            &spill::RunCheckpoint {
                generation,
                machines: self.links.machines as u32,
                mirror_hash: self.mirror,
                rng_state: ck.rng_state,
                rounds: self.links.seq,
                custody_dir,
            },
        )?;
        // best-effort retention prune: keep the configured window of most
        // recent generations (a stale directory beyond it is inert — the
        // checkpoint no longer names it — just disk, which a long-lived
        // serve process cannot afford to leak per recontraction)
        spill::prune_generations(ck.dir.path(), self.links.cfg.keep_generations);
        self.stats
            .checkpoints
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(())
    }

    pub fn num_machines(&self) -> usize {
        self.links.num_machines()
    }

    /// See [`ProcTransport::link_bytes_counter`].
    pub fn link_bytes_counter(&self) -> std::sync::Arc<std::sync::atomic::AtomicU64> {
        self.links.link_bytes_counter()
    }

    /// Shared observability counters (see [`ShuffleStats`]).
    pub fn stats(&self) -> std::sync::Arc<ShuffleStats> {
        std::sync::Arc::clone(&self.stats)
    }

    /// Initial shard distribution; establishes custody of `g`.
    pub fn load_graph(&mut self, g: &ShardedGraph) -> Result<(), TransportError> {
        self.establish_custody(g)
    }

    /// Kill worker `j`'s process outright (fault injection; see
    /// [`ProcTransport::kill_worker`]).
    pub fn kill_worker(&mut self, j: usize) {
        self.links.kill_worker(j);
    }

    /// Graceful shutdown (see [`ProcTransport::shutdown`]).
    pub fn shutdown(self) -> Result<(), TransportError> {
        self.links.shutdown()
    }

    /// Read one control ack of `want` from worker `j`, surfacing
    /// `WorkerErr` and kind/seq mismatches as typed protocol errors.
    fn read_ack(&mut self, j: usize, want: FrameKind, seq: u64) -> Result<Frame, TransportError> {
        let frame = read_frame(&mut self.links.conns[j].reader)
            .map_err(|e| self.links.crash_context(j, e))?;
        if frame.kind == FrameKind::WorkerErr {
            return Err(TransportError::Protocol {
                worker: Some(j),
                detail: String::from_utf8_lossy(&frame.body).into_owned(),
            });
        }
        if frame.kind != want {
            return Err(TransportError::Protocol {
                worker: Some(j),
                detail: format!("expected {want:?}, got {:?}", frame.kind),
            });
        }
        if frame.seq != seq {
            return Err(TransportError::Protocol {
                worker: Some(j),
                detail: format!("{want:?} seq {} != {seq}", frame.seq),
            });
        }
        Ok(frame)
    }

    /// Validate every worker's `RewireAck` against the coordinator's own
    /// next-generation shards (shared by [`ShuffleOps::rewire`] and
    /// [`ShuffleOps::gather_rewire`] — both custody handoffs ack the
    /// adopted shard's statistics, payload checksum, and mesh meter).
    fn read_rewire_acks(&mut self, seq: u64, new: &ShardedGraph) -> Result<(), TransportError> {
        let p = self.links.machines;
        for j in 0..p {
            let ack = self.read_ack(j, FrameKind::RewireAck, seq)?;
            let mut r = BodyReader::new(&ack.body);
            let parsed = (|| -> Result<(u64, u64, Vec<u64>, u64), TransportError> {
                let len = r.u64("rewire ack len")?;
                let checksum = r.u64("rewire ack checksum")?;
                let ack_p = r.u32("rewire ack shard count")? as usize;
                let mut peers = Vec::with_capacity(ack_p.min(1 << 16));
                for _ in 0..ack_p {
                    peers.push(r.u64("rewire ack peer count")?);
                }
                let mesh = r.u64("rewire ack mesh bytes")?;
                r.expect_end("rewire ack")?;
                Ok((len, checksum, peers, mesh))
            })()
            .map_err(|e| e.for_worker(j))?;
            let (len, checksum, peers, mesh) = parsed;
            self.stats
                .mesh_bytes
                .fetch_add(mesh, std::sync::atomic::Ordering::Relaxed);
            let stats = new.shard_stats(j);
            if len != stats.len
                || peers != stats.peer_counts
                || checksum != shard_payload_checksum(new, j)
            {
                return Err(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!(
                        "rewired shard diverges from the coordinator's generation \
                         ({len} edges, checksum {checksum:#018x})"
                    ),
                });
            }
        }
        Ok(())
    }
}

/// Canonical payload checksum of shard `s` of `g`: the spill-cached one
/// when the graph is on disk, recomputed from the resident edges
/// otherwise (the same [`spill::checksum_edges`] either way).
fn shard_payload_checksum(g: &ShardedGraph, s: usize) -> u64 {
    match g.shard_checksum(s) {
        Some(c) => c,
        None => spill::checksum_pairs(g.shard_data(s).iter()),
    }
}

impl Exchange for ShuffleTransport {
    fn name(&self) -> &'static str {
        "shuffle"
    }

    fn wants_wire(&self) -> bool {
        true
    }

    fn machines(&self) -> Option<usize> {
        Some(self.links.machines)
    }

    /// Persistent-session reload: establish custody of the new
    /// generation on the live mesh (probe → re-ship → checkpoint), so a
    /// serve daemon's recontractions reuse the fleet instead of
    /// respawning it.
    fn load_graph(&mut self, g: &ShardedGraph) -> Result<(), TransportError> {
        crate::mpc::transport::ShuffleOps::establish_custody(self, g)
    }

    /// Rounds without a worker-native descriptor (grouped reduces,
    /// per-message maps, untagged folds, charge-only barriers) flow
    /// through the coordinator exactly as on the proc backend — same
    /// routing, same receiver-side accounting, same bit-identity.
    fn exchange(
        &mut self,
        label: &str,
        charge: RoundCharge<'_>,
        payloads: Vec<Vec<u8>>,
        fold: Option<WireOp>,
    ) -> Result<ExchangeAck, TransportError> {
        self.links.exchange(label, charge, payloads, fold)
    }

    fn shuffle(&mut self) -> Option<&mut dyn crate::mpc::transport::ShuffleOps> {
        Some(self)
    }

    fn mesh_stats(&self) -> Option<crate::mpc::metrics::MeshMetrics> {
        use std::sync::atomic::Ordering::Relaxed;
        let s = &self.stats;
        Some(crate::mpc::metrics::MeshMetrics {
            hops: s.hops.load(Relaxed),
            hop_batches: s.hop_batches.load(Relaxed),
            state_syncs: s.state_syncs.load(Relaxed),
            delta_syncs: s.delta_syncs.load(Relaxed),
            sync_bytes: s.sync_bytes.load(Relaxed),
            mesh_bytes: s.mesh_bytes.load(Relaxed),
            rewires: s.rewires.load(Relaxed),
            custody_loads: s.custody_loads.load(Relaxed),
            // what the fleet reported in its Hellos, not what was asked
            // for (a worker clamps); homogeneous fleets make max == all
            worker_threads: self
                .links
                .worker_threads
                .iter()
                .copied()
                .max()
                .unwrap_or(1) as u64,
        })
    }
}

impl crate::mpc::transport::ShuffleOps for ShuffleTransport {
    fn custody(&self) -> Option<u64> {
        self.custody
    }

    fn establish_custody(&mut self, g: &ShardedGraph) -> Result<(), TransportError> {
        // generation-boundary heartbeat: surface a dead worker as a typed
        // crash before a multi-frame custody ship starts (hop paths stay
        // heartbeat-free — the O(machines)-per-round link bound holds)
        self.links.probe_workers()?;
        // a respawned fleet re-ships from the checkpointed custody files
        // when this generation has them (the live graph may have mutated
        // residency since the checkpoint was cut)
        let ckpt_dir = self
            .checkpoint
            .as_ref()
            .map(|ck| ck.dir.path().join(format!("gen-{}", g.generation())))
            .filter(|d| d.is_dir());
        self.links.load_graph_from(g, ckpt_dir.as_deref())?;
        self.custody = Some(g.generation());
        self.stats
            .custody_loads
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.checkpoint_generation(g)
    }

    fn mirror_hash(&self) -> Option<u64> {
        self.mirror
    }

    fn sync_mirror(
        &mut self,
        value_bytes: u8,
        data: &[u8],
        hash: u64,
    ) -> Result<(), TransportError> {
        let p = self.links.machines;
        // Delta path: the workers hold a validated base of the same
        // shape, so ship only the changed entries as (index, value)
        // patches.  Past n/4 changed entries the per-entry index stops
        // paying for itself and a full broadcast is cheaper — and the
        // first sync (no base) or a width change is always full.
        let vb = value_bytes as usize;
        let mut delta: Option<Vec<u8>> = None;
        if self.links.cfg.delta_sync
            && self.mirror.is_some()
            && self.mirror_vb == value_bytes
            && self.mirror_data.len() == data.len()
            && vb > 0
        {
            let n = data.len() / vb;
            let mut changed: Vec<u32> = Vec::new();
            for i in 0..n {
                let at = i * vb;
                if data[at..at + vb] != self.mirror_data[at..at + vb] {
                    changed.push(i as u32);
                }
            }
            if changed.len() <= n / 4 {
                let mut body = Vec::with_capacity(1 + 8 + 8 + changed.len() * (4 + vb));
                body.push(value_bytes);
                body.extend_from_slice(&(data.len() as u64).to_le_bytes());
                body.extend_from_slice(&(changed.len() as u64).to_le_bytes());
                for &i in &changed {
                    body.extend_from_slice(&i.to_le_bytes());
                    let at = i as usize * vb;
                    body.extend_from_slice(&data[at..at + vb]);
                }
                delta = Some(body);
            }
        }
        let is_delta = delta.is_some();
        self.links.seq += 1;
        let seq = self.links.seq;
        let wire_body_len = match &delta {
            Some(body) => {
                for j in 0..p {
                    write_frame(
                        &mut self.links.conns[j].writer,
                        FrameKind::StateDelta,
                        seq,
                        body,
                    )
                    .map_err(|e| self.links.crash_context(j, e))?;
                }
                body.len() as u64
            }
            None => {
                let mut head = Vec::with_capacity(1 + 8);
                head.push(value_bytes);
                head.extend_from_slice(&(data.len() as u64).to_le_bytes());
                for j in 0..p {
                    write_frame_parts(
                        &mut self.links.conns[j].writer,
                        FrameKind::StateSync,
                        seq,
                        &head,
                        data,
                    )
                    .map_err(|e| self.links.crash_context(j, e))?;
                }
                (head.len() + data.len()) as u64
            }
        };
        for j in 0..p {
            let ack = self.read_ack(j, FrameKind::StateAck, seq)?;
            let mut r = BodyReader::new(&ack.body);
            let got = r.u64("state ack hash").map_err(|e| e.for_worker(j))?;
            r.expect_end("state ack").map_err(|e| e.for_worker(j))?;
            // the receipt always hashes the worker's *full* resulting
            // mirror, so a delta applied over a skewed base diverges here
            if got != hash {
                return Err(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!(
                        "worker applied a mirror hashing {got:#018x}, coordinator sent {hash:#018x}"
                    ),
                });
            }
        }
        self.mirror = Some(hash);
        self.mirror_vb = value_bytes;
        self.mirror_data.clear();
        self.mirror_data.extend_from_slice(data);
        self.stats
            .state_syncs
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if is_delta {
            self.stats
                .delta_syncs
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        self.stats.sync_bytes.fetch_add(
            (FRAME_HEADER_BYTES + wire_body_len) * p as u64,
            std::sync::atomic::Ordering::Relaxed,
        );
        Ok(())
    }

    fn set_mirror(&mut self, value_bytes: u8, data: &[u8], hash: u64) {
        self.mirror = Some(hash);
        self.mirror_vb = value_bytes;
        self.mirror_data.clear();
        self.mirror_data.extend_from_slice(data);
    }

    fn begin_hop(
        &mut self,
        spec: &crate::mpc::transport::HopSpec<'_>,
        charge: &RoundCharge<'_>,
    ) -> Result<u64, TransportError> {
        let p = self.links.machines;
        debug_assert_eq!(charge.machine_bytes.len(), p);
        self.links.seq += 1;
        let seq = self.links.seq;
        let label = spec.label.as_bytes();
        let label_len = label.len().min(u16::MAX as usize);
        // one shared descriptor body: the workers need no per-machine
        // fields (loads are validated coordinator-side from the acks)
        let mut body = Vec::with_capacity(1 + 1 + 2 + label_len);
        body.push(spec.op.code());
        body.push(u8::from(spec.include_self));
        body.extend_from_slice(&(label_len as u16).to_le_bytes());
        body.extend_from_slice(&label[..label_len]);
        for j in 0..p {
            write_frame(&mut self.links.conns[j].writer, FrameKind::HopRound, seq, &body)
                .map_err(|e| self.links.crash_context(j, e))?;
        }
        self.stats
            .hops
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(seq)
    }

    fn finish_hop(
        &mut self,
        seq: u64,
        spec: &crate::mpc::transport::HopSpec<'_>,
        charge: &RoundCharge<'_>,
        expected_folds: &[u64],
    ) -> Result<(), TransportError> {
        let p = self.links.machines;
        debug_assert_eq!(expected_folds.len(), p);
        // Read every ack before judging: a worker that failed its round
        // answers WorkerErr while poisoning its mesh phases
        // (coordinator/worker.rs), so its peers complete fast with
        // *damaged* loads/folds — the root-cause WorkerErr must win the
        // attribution over those symptoms.  Socket-level failures (crash,
        // truncation) still abort immediately.
        let mut root_cause: Option<TransportError> = None;
        let mut damage: Option<TransportError> = None;
        for j in 0..p {
            let frame = read_frame(&mut self.links.conns[j].reader)
                .map_err(|e| self.links.crash_context(j, e))?;
            if frame.kind == FrameKind::WorkerErr {
                root_cause.get_or_insert(TransportError::Protocol {
                    worker: Some(j),
                    detail: String::from_utf8_lossy(&frame.body).into_owned(),
                });
                continue;
            }
            if frame.kind != FrameKind::HopAck || frame.seq != seq {
                damage.get_or_insert(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!(
                        "expected HopAck seq {seq}, got {:?} seq {}",
                        frame.kind, frame.seq
                    ),
                });
                continue;
            }
            let parsed = (|| -> Result<(u64, u64, u64), TransportError> {
                let mut r = BodyReader::new(&frame.body);
                let received = r.u64("hop ack received")?;
                let fold = r.u64("hop ack fold checksum")?;
                let mesh = r.u64("hop ack mesh bytes")?;
                r.expect_end("hop ack")?;
                Ok((received, fold, mesh))
            })();
            let (received, fold, mesh) = match parsed {
                Ok(v) => v,
                Err(e) => {
                    damage.get_or_insert(e.for_worker(j));
                    continue;
                }
            };
            self.stats
                .mesh_bytes
                .fetch_add(mesh, std::sync::atomic::Ordering::Relaxed);
            if received != charge.machine_bytes[j] {
                damage.get_or_insert(TransportError::AccountingMismatch {
                    label: spec.label.to_string(),
                    machine: j,
                    expected: charge.machine_bytes[j],
                    actual: received,
                });
            } else if fold != expected_folds[j] {
                damage.get_or_insert(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!(
                        "round {:?}: worker fold image hashes {fold:#018x}, \
                         coordinator computed {:#018x}",
                        spec.label, expected_folds[j]
                    ),
                });
            }
        }
        match root_cause.or(damage) {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn begin_hop_batch(
        &mut self,
        specs: &[crate::mpc::transport::HopSpec<'_>],
        charge: &RoundCharge<'_>,
    ) -> Result<u64, TransportError> {
        let p = self.links.machines;
        debug_assert_eq!(charge.machine_bytes.len(), p);
        debug_assert!(!specs.is_empty());
        // the batch frame ships at the base seq; round k of the plan
        // runs at base + k on the mesh, so the shared counter advances
        // once per round exactly as if the rounds had shipped singly
        let base = self.links.seq + 1;
        self.links.seq += specs.len() as u64;
        let mut body = Vec::with_capacity(2 + specs.len() * 16);
        body.extend_from_slice(&(specs.len() as u16).to_le_bytes());
        for spec in specs {
            let label = spec.label.as_bytes();
            let label_len = label.len().min(u16::MAX as usize);
            body.push(spec.op.code());
            body.push(u8::from(spec.include_self));
            body.extend_from_slice(&(label_len as u16).to_le_bytes());
            body.extend_from_slice(&label[..label_len]);
        }
        for j in 0..p {
            write_frame(&mut self.links.conns[j].writer, FrameKind::HopBatch, base, &body)
                .map_err(|e| self.links.crash_context(j, e))?;
        }
        self.stats
            .hops
            .fetch_add(specs.len() as u64, std::sync::atomic::Ordering::Relaxed);
        self.stats
            .hop_batches
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(base)
    }

    fn finish_hop_batch(
        &mut self,
        seq: u64,
        specs: &[crate::mpc::transport::HopSpec<'_>],
        charge: &RoundCharge<'_>,
        expected_folds: &[Vec<u64>],
    ) -> Result<(), TransportError> {
        let p = self.links.machines;
        debug_assert_eq!(expected_folds.len(), specs.len());
        // same root-cause-over-symptoms attribution as finish_hop: a
        // worker that failed mid-batch poisons its mesh phases, so its
        // peers ack with damaged loads — the WorkerErr wins
        let mut root_cause: Option<TransportError> = None;
        let mut damage: Option<TransportError> = None;
        for j in 0..p {
            let frame = read_frame(&mut self.links.conns[j].reader)
                .map_err(|e| self.links.crash_context(j, e))?;
            if frame.kind == FrameKind::WorkerErr {
                root_cause.get_or_insert(TransportError::Protocol {
                    worker: Some(j),
                    detail: String::from_utf8_lossy(&frame.body).into_owned(),
                });
                continue;
            }
            if frame.kind != FrameKind::HopBatchAck || frame.seq != seq {
                damage.get_or_insert(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!(
                        "expected HopBatchAck seq {seq}, got {:?} seq {}",
                        frame.kind, frame.seq
                    ),
                });
                continue;
            }
            let parsed = (|| -> Result<Vec<(u64, u64, u64)>, TransportError> {
                let mut r = BodyReader::new(&frame.body);
                let count = r.u16("batch ack count")? as usize;
                let mut acks = Vec::with_capacity(count.min(1 << 16));
                for _ in 0..count {
                    let received = r.u64("batch ack received")?;
                    let fold = r.u64("batch ack fold checksum")?;
                    let mesh = r.u64("batch ack mesh bytes")?;
                    acks.push((received, fold, mesh));
                }
                r.expect_end("hop batch ack")?;
                Ok(acks)
            })();
            let acks = match parsed {
                Ok(v) => v,
                Err(e) => {
                    damage.get_or_insert(e.for_worker(j));
                    continue;
                }
            };
            if acks.len() != specs.len() {
                damage.get_or_insert(TransportError::Protocol {
                    worker: Some(j),
                    detail: format!(
                        "batch ack covers {} rounds, plan has {}",
                        acks.len(),
                        specs.len()
                    ),
                });
                continue;
            }
            for (k, &(received, fold, mesh)) in acks.iter().enumerate() {
                self.stats
                    .mesh_bytes
                    .fetch_add(mesh, std::sync::atomic::Ordering::Relaxed);
                if received != charge.machine_bytes[j] {
                    damage.get_or_insert(TransportError::AccountingMismatch {
                        label: specs[k].label.to_string(),
                        machine: j,
                        expected: charge.machine_bytes[j],
                        actual: received,
                    });
                } else if fold != expected_folds[k][j] {
                    damage.get_or_insert(TransportError::Protocol {
                        worker: Some(j),
                        detail: format!(
                            "round {:?}: worker fold image hashes {fold:#018x}, \
                             coordinator computed {:#018x}",
                            specs[k].label, expected_folds[k][j]
                        ),
                    });
                }
            }
        }
        match root_cause.or(damage) {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn rewire(&mut self, map: &[u32], new: &ShardedGraph) -> Result<(), TransportError> {
        // generation-boundary heartbeat (see establish_custody)
        self.links.probe_workers()?;
        let p = self.links.machines;
        // the map rides the mirror channel (wire-encoded u32s)
        let mut data = Vec::with_capacity(map.len() * 4);
        for &m in map {
            data.extend_from_slice(&m.to_le_bytes());
        }
        let hash = mirror_hash_of(4, &data);
        if self.mirror != Some(hash) {
            self.sync_mirror(4, &data, hash)?;
        }
        self.links.seq += 1;
        let seq = self.links.seq;
        let body = (new.num_vertices() as u64).to_le_bytes();
        for j in 0..p {
            write_frame(&mut self.links.conns[j].writer, FrameKind::Rewire, seq, &body)
                .map_err(|e| self.links.crash_context(j, e))?;
        }
        self.read_rewire_acks(seq, new)?;
        self.custody = Some(new.generation());
        self.stats
            .rewires
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.checkpoint_generation(new)
    }

    fn gather_rewire(&mut self, map: &[u32], new: &ShardedGraph) -> Result<(), TransportError> {
        // generation-boundary heartbeat (see establish_custody)
        self.links.probe_workers()?;
        let p = self.links.machines;
        // the map rides the mirror channel exactly like rewire's — and
        // since the labels usually just synced after the last hop, the
        // repeat sync here is a cheap delta, not a second broadcast
        let mut data = Vec::with_capacity(map.len() * 4);
        for &m in map {
            data.extend_from_slice(&m.to_le_bytes());
        }
        let hash = mirror_hash_of(4, &data);
        if self.mirror != Some(hash) {
            self.sync_mirror(4, &data, hash)?;
        }
        self.links.seq += 1;
        let seq = self.links.seq;
        // the reduce program ships in the descriptor like a fold op does
        let mut body = Vec::with_capacity(8 + 1);
        body.extend_from_slice(&(new.num_vertices() as u64).to_le_bytes());
        body.push(WireOp::GatherPairU32.code());
        for j in 0..p {
            write_frame(&mut self.links.conns[j].writer, FrameKind::GatherRewire, seq, &body)
                .map_err(|e| self.links.crash_context(j, e))?;
        }
        self.read_rewire_acks(seq, new)?;
        self.custody = Some(new.generation());
        self.stats
            .rewires
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.checkpoint_generation(new)
    }

    fn recover(
        &mut self,
        cause: &TransportError,
    ) -> Result<crate::mpc::transport::RecoveryInfo, TransportError> {
        let start = Instant::now();
        let budget = self.links.cfg.respawn_budget;
        if budget == 0 {
            return Err(TransportError::RecoveryExhausted {
                attempts: 0,
                detail: format!("respawn disabled (budget 0); fault: {cause}"),
            });
        }
        if self.links.worker_bin.is_none() {
            return Err(TransportError::RecoveryExhausted {
                attempts: 0,
                detail: format!("no worker binary to respawn from; fault: {cause}"),
            });
        }
        let mut last_err: Option<TransportError> = None;
        for attempt in 1..=budget {
            if attempt > 1 {
                // exponential backoff between attempts: base, 2x, 4x, ...
                let shift = (attempt as u32 - 2).min(16);
                let ms = self
                    .links
                    .cfg
                    .respawn_backoff_ms
                    .saturating_mul(1u64 << shift);
                std::thread::sleep(Duration::from_millis(ms));
            }
            let fleet = self.links.respawn_fleet().and_then(|mut links| {
                Self::mesh_up(&mut links)?;
                Ok(links)
            });
            match fleet {
                Ok(links) => {
                    self.links = links;
                    // custody and mirror died with the old fleet: the
                    // next round lazily re-establishes both, from this
                    // generation's checkpointed custody files when on
                    // (and the delta base goes with them — the first
                    // sync after recovery is a full broadcast)
                    self.custody = None;
                    self.mirror = None;
                    self.mirror_data.clear();
                    self.mirror_vb = 0;
                    self.stats
                        .recoveries
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    return Ok(crate::mpc::transport::RecoveryInfo {
                        respawn_attempts: attempt,
                        wall_ms: start.elapsed().as_secs_f64() * 1e3,
                    });
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(TransportError::RecoveryExhausted {
            attempts: budget,
            detail: match last_err {
                Some(e) => format!("fault: {cause}; last respawn error: {e}"),
                None => format!("fault: {cause}"),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Round, 7, b"hello body").unwrap();
        let frame = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(frame.kind, FrameKind::Round);
        assert_eq!(frame.seq, 7);
        assert_eq!(frame.body, b"hello body");
    }

    #[test]
    fn truncated_frame_is_short_read() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::RoundAck, 1, &[1, 2, 3, 4, 5]).unwrap();
        // cut inside the body
        match read_frame(&mut &buf[..buf.len() - 2]) {
            Err(TransportError::ShortRead { wanted, got, .. }) => {
                assert_eq!(wanted, 5);
                assert_eq!(got, 3);
            }
            other => panic!("expected ShortRead, got {other:?}"),
        }
        // cut inside the header
        assert!(matches!(
            read_frame(&mut &buf[..10]),
            Err(TransportError::ShortRead { .. })
        ));
    }

    #[test]
    fn corrupt_body_is_checksum_mismatch() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Round, 2, b"payload!").unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x10;
        assert!(matches!(
            read_frame(&mut &buf[..]),
            Err(TransportError::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn wrong_magic_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Hello, 0, &[]).unwrap();
        buf[0] = b'X';
        assert!(matches!(
            read_frame(&mut &buf[..]),
            Err(TransportError::BadMagic { .. })
        ));
    }

    #[test]
    fn oversized_declared_body_is_protocol_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Round, 0, &[]).unwrap();
        // body_len sits at offset 17..25
        buf[17..25].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            read_frame(&mut &buf[..]),
            Err(TransportError::Protocol { .. })
        ));
    }

    #[test]
    fn round_body_roundtrip() {
        let payload = [9u8; 24];
        let body = encode_round_body(false, Some(WireOp::MinU32), 24, "lc/hop1", &payload);
        let msg = decode_round_body(&body).unwrap();
        assert!(!msg.virtual_round);
        assert_eq!(msg.fold, Some(WireOp::MinU32));
        assert_eq!(msg.declared_bytes, 24);
        assert_eq!(msg.label, "lc/hop1");
        assert_eq!(msg.payload, &payload);

        let body = encode_round_body(true, None, 4096, "contract/left", &[]);
        let msg = decode_round_body(&body).unwrap();
        assert!(msg.virtual_round);
        assert_eq!(msg.fold, None);
        assert_eq!(msg.declared_bytes, 4096);
        assert!(msg.payload.is_empty());
    }

    fn rec_u32(key: u64, v: u32) -> Vec<u8> {
        let mut r = key.to_le_bytes().to_vec();
        r.extend_from_slice(&v.to_le_bytes());
        r
    }

    #[test]
    fn fold_payload_folds_per_key_in_key_order() {
        let mut payload = Vec::new();
        payload.extend(rec_u32(5, 30));
        payload.extend(rec_u32(2, 9));
        payload.extend(rec_u32(5, 11));
        payload.extend(rec_u32(2, 40));
        let out = fold_wire_payload(WireOp::MinU32, &payload).unwrap();
        let mut expect = Vec::new();
        expect.extend(rec_u32(2, 9));
        expect.extend(rec_u32(5, 11));
        assert_eq!(out, expect);
        let out = fold_wire_payload(WireOp::MaxU32, &payload).unwrap();
        let mut expect = Vec::new();
        expect.extend(rec_u32(2, 40));
        expect.extend(rec_u32(5, 30));
        assert_eq!(out, expect);
    }

    #[test]
    fn fold_payload_pairs_are_lexicographic() {
        let mut payload = Vec::new();
        for (k, a, b) in [(1u64, 7u32, 3u32), (1, 7, 1), (1, 2, 9)] {
            payload.extend_from_slice(&k.to_le_bytes());
            payload.extend_from_slice(&a.to_le_bytes());
            payload.extend_from_slice(&b.to_le_bytes());
        }
        let out = fold_wire_payload(WireOp::MinPairU32, &payload).unwrap();
        assert_eq!(
            out,
            {
                let mut e = 1u64.to_le_bytes().to_vec();
                e.extend_from_slice(&2u32.to_le_bytes());
                e.extend_from_slice(&9u32.to_le_bytes());
                e
            }
        );
    }

    #[test]
    fn fold_payload_rejects_ragged_input() {
        assert!(fold_wire_payload(WireOp::MinU32, &[0u8; 13]).is_err());
        assert!(fold_wire_payload(WireOp::MaxU64, &[0u8; 20]).is_err());
        assert!(fold_wire_payload(WireOp::GatherPairU32, &[0u8; 15]).is_err());
    }

    #[test]
    fn gather_payload_keeps_every_distinct_pair_per_key() {
        // not a 1-per-key fold: both of key 1's distinct pairs survive,
        // the exact duplicate collapses, and keys come out ascending
        let mut payload = Vec::new();
        for (k, a, b) in [(5u64, 8u32, 2u32), (1, 7, 3), (1, 2, 9), (1, 7, 3)] {
            payload.extend_from_slice(&k.to_le_bytes());
            payload.extend_from_slice(&a.to_le_bytes());
            payload.extend_from_slice(&b.to_le_bytes());
        }
        let out = fold_wire_payload(WireOp::GatherPairU32, &payload).unwrap();
        let mut expect = Vec::new();
        for (k, a, b) in [(1u64, 2u32, 9u32), (1, 7, 3), (5, 8, 2)] {
            expect.extend_from_slice(&k.to_le_bytes());
            expect.extend_from_slice(&a.to_le_bytes());
            expect.extend_from_slice(&b.to_le_bytes());
        }
        assert_eq!(out, expect);
    }

    #[test]
    fn sliced_frame_writes_match_the_single_buffer_stream() {
        // a bucket shipped as chunk slices must put the exact same bytes
        // on the wire as the merged buffer — header, checksum, and all
        let body = b"chunk0chunk1chunk2";
        let mut whole = Vec::new();
        write_frame(&mut whole, FrameKind::PeerMsgs, 9, body).unwrap();
        let mut sliced = Vec::new();
        write_frame_slices(
            &mut sliced,
            FrameKind::PeerMsgs,
            9,
            &[b"chunk0", b"", b"chunk1", b"chunk2"],
        )
        .unwrap();
        assert_eq!(whole, sliced);
        let frame = read_frame(&mut &sliced[..]).unwrap();
        assert_eq!(frame.body, body);
    }

    #[test]
    fn multi_slice_fold_matches_the_concatenated_fold() {
        let mut a = Vec::new();
        a.extend(rec_u32(5, 30));
        a.extend(rec_u32(2, 9));
        let mut b = Vec::new();
        b.extend(rec_u32(5, 11));
        b.extend(rec_u32(2, 40));
        let mut all = a.clone();
        all.extend_from_slice(&b);
        for op in [WireOp::MinU32, WireOp::MaxU32] {
            assert_eq!(
                fold_wire_payload_multi(op, &[&a, &b]).unwrap(),
                fold_wire_payload(op, &all).unwrap()
            );
            // slice order is irrelevant: the ops are commutative
            assert_eq!(
                fold_wire_payload_multi(op, &[&b, &a]).unwrap(),
                fold_wire_payload(op, &all).unwrap()
            );
        }
        // raggedness is caught per slice, before any folding
        assert!(fold_wire_payload_multi(WireOp::MinU32, &[&a, &[0u8; 13]]).is_err());
    }

    #[test]
    fn ranged_folds_concatenate_to_the_full_image() {
        let mut payload = Vec::new();
        for (k, v) in [(7u64, 1u32), (0, 5), (3, 2), (7, 9), (1, 4), (0, 8)] {
            payload.extend(rec_u32(k, v));
        }
        let parts: &[&[u8]] = &[&payload];
        let full = fold_wire_payload(WireOp::MinU32, &payload).unwrap();
        // key space [0, 8) in 3 contiguous ranges, last one unbounded so
        // out-of-mirror garbage keys would land exactly once
        let mut cat = fold_wire_payload_in_range(WireOp::MinU32, parts, 0, Some(3));
        cat.extend(fold_wire_payload_in_range(WireOp::MinU32, parts, 3, Some(6)));
        cat.extend(fold_wire_payload_in_range(WireOp::MinU32, parts, 6, None));
        assert_eq!(cat, full);
        // the gather variant partitions the same way (dedup pairs share
        // their key, so a range never splits one)
        let mut gp = Vec::new();
        for (k, a, b) in [(4u64, 7u32, 3u32), (1, 2, 9), (4, 7, 3), (0, 1, 1)] {
            gp.extend_from_slice(&k.to_le_bytes());
            gp.extend_from_slice(&a.to_le_bytes());
            gp.extend_from_slice(&b.to_le_bytes());
        }
        let gparts: &[&[u8]] = &[&gp];
        let gfull = fold_wire_payload(WireOp::GatherPairU32, &gp).unwrap();
        let mut gcat = fold_wire_payload_in_range(WireOp::GatherPairU32, gparts, 0, Some(2));
        gcat.extend(fold_wire_payload_in_range(WireOp::GatherPairU32, gparts, 2, None));
        assert_eq!(gcat, gfull);
    }

    #[test]
    fn fault_plan_parses_the_cli_grammar() {
        let plan = FaultPlan::parse("kill:w2@round=3,delay:w1@round=5,kill:w0@gen=1").unwrap();
        assert_eq!(plan.actions.len(), 3);
        assert_eq!(plan.actions[0].kind, FaultKind::Kill);
        assert_eq!(plan.actions[0].worker, 2);
        assert_eq!(plan.actions[0].site, FaultSite::Round(3));
        assert_eq!(plan.actions[1].kind, FaultKind::Delay);
        assert_eq!(plan.actions[1].site, FaultSite::Round(5));
        assert_eq!(plan.actions[2].site, FaultSite::Gen(1));
        let mine = plan.for_worker(2);
        assert_eq!(mine.len(), 1);
        assert_eq!(mine[0].site, FaultSite::Round(3));
        assert!(plan.for_worker(9).is_empty());
    }

    #[test]
    fn fault_plan_rejects_malformed_specs() {
        for bad in [
            "boom:w1@round=2",  // unknown kind
            "kill:x1@round=2",  // bad worker tag
            "kill:w1@epoch=2",  // unknown site
            "kill:w1@round=0",  // counts are 1-based
            "delay:w1@gen=2",   // delay only at round sites
            "kill:w1",          // missing site
            "",                 // empty action
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn net_config_defaults_are_sane() {
        let cfg = NetConfig::default();
        assert_eq!(cfg.io_timeout, IO_TIMEOUT);
        assert_eq!(cfg.connect_retries, DEFAULT_CONNECT_RETRIES);
        assert_eq!(cfg.respawn_budget, DEFAULT_RESPAWN_BUDGET);
        assert_eq!(cfg.respawn_backoff_ms, DEFAULT_RESPAWN_BACKOFF_MS);
        assert!(cfg.fault_plan.is_none());
        assert!(cfg.checkpoint_dir.is_none());
        assert!(cfg.delta_sync);
        assert_eq!(cfg.worker_threads, 1);
    }

    #[test]
    fn run_checkpoint_survives_a_spill_roundtrip() {
        // the net-layer view of the spill-layer format: what rewire
        // persists, recovery's establish_custody must read back verbatim
        let dir = std::env::temp_dir().join(format!("lcc-net-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(spill::CHECKPOINT_NAME);
        let cp = spill::RunCheckpoint {
            generation: 4,
            machines: 8,
            mirror_hash: Some(0xfeed_beef),
            rng_state: [9, 8, 7, 6],
            rounds: 123,
            custody_dir: "gen-4".into(),
        };
        spill::write_checkpoint(&path, &cp).unwrap();
        assert_eq!(spill::read_checkpoint(&path).unwrap(), cp);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
