//! The MPC(0) round simulator: hash shuffle, key grouping, per-machine
//! reduction, exact communication accounting.
//!
//! One [`Simulator::round`] = one computation-communication round of §2.1:
//! the caller's *map* output (a flat list of key-value messages) is
//! partitioned over `machines` by key hash, each machine's bytes are
//! charged against the space bound, messages are grouped by key, and the
//! caller's *reduce* runs once per group.  Machines execute on the
//! persistent worker pool ([`super::pool`]) so wall-clock measurements
//! (Table 3) reflect parallel per-round cost, while the metrics reflect
//! the model-level quantities.
//!
//! **Engine invariance.**  Model metrics (`messages`, `bytes`,
//! `max_machine_bytes`, `space_violation`) are pure functions of the
//! message multiset, so they are bit-identical across `threads` settings:
//! every parallel path accumulates them as per-chunk `u64` sums merged in
//! chunk order.  The chunked fast paths additionally require the fold `op`
//! to be associative and commutative (the min/max hops are), which makes
//! the *outputs* identical too.  `rust/tests/mpc_accounting.rs` and the
//! tests below enforce both.

use super::metrics::{Metrics, RoundMetrics, WireSize};
use super::pool;
use crate::util::rng::splitmix64;

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct MpcConfig {
    /// Number of simulated machines (`p` in §2.1).
    pub machines: usize,
    /// Optional per-machine receive bound in bytes (`O(N/p)` for ε = 0).
    /// Exceeding it marks `space_violation` on the round rather than
    /// aborting, so experiments can report violations.
    pub space_per_machine: Option<u64>,
    /// Resident-memory budget for the sharded edge store, in bytes: graphs
    /// whose edge set exceeds it run with disk-backed shards
    /// (`crate::graph::spill`) through the same rounds — the out-of-core
    /// counterpart of `space_per_machine`'s *model* bound.  `None` =
    /// unbounded (always resident).  Threaded into every graph the flat
    /// `CcAlgorithm::run` adapter shards, and inherited by all contracted
    /// generations.
    pub spill_budget: Option<u64>,
    /// OS threads used to execute machines (simulation-level parallelism;
    /// does not affect the model metrics).
    pub threads: usize,
}

impl Default for MpcConfig {
    fn default() -> Self {
        MpcConfig {
            machines: 16,
            space_per_machine: None,
            spill_budget: None,
            threads: std::thread::available_parallelism()
                .map(|n| n.get().min(16))
                .unwrap_or(4),
        }
    }
}

/// Machine owning `key` under the stable hash partition.  The single
/// definition of the partition function: the simulator rounds, the
/// chunked fast paths, the fused rounds in `cc::common` (which charge
/// the model directly via [`Simulator::charge_round`]), and the resident
/// [`crate::graph::ShardedGraph`] partition must all agree on it, or
/// charged per-machine loads silently diverge from real rounds.
#[inline]
pub fn machine_of(key: u64, machines: usize) -> usize {
    (splitmix64(key) % machines as u64) as usize
}

/// Exact, pre-computed accounting for one **sharded** round.
///
/// When the resident representation is partitioned by [`machine_of`] (the
/// [`crate::graph::ShardedGraph`] invariant), per-machine loads are pure
/// functions of shard membership: the graph layer derives them from cached
/// shard statistics (`ShardedGraph::hop_charge`, `contract_charges`) and
/// the round engine no longer recomputes `machine_of` per message.  The
/// sharded entry points ([`Simulator::round_fold_sharded`],
/// [`Simulator::round_map_sharded`]) verify in debug builds that the
/// charge's message count matches the stream they actually folded.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardRound {
    /// Messages shuffled this round.
    pub messages: u64,
    /// Total bytes shuffled this round.
    pub bytes: u64,
    /// Bytes received per machine; `len` must equal `MpcConfig::machines`.
    pub machine_bytes: Vec<u64>,
}

/// The MPC execution engine: owns config + accumulated metrics.
#[derive(Debug)]
pub struct Simulator {
    pub cfg: MpcConfig,
    pub metrics: Metrics,
}

impl Simulator {
    pub fn new(cfg: MpcConfig) -> Self {
        Simulator {
            cfg,
            metrics: Metrics::new(),
        }
    }

    /// Partition a key over machines (stable across rounds).
    #[inline]
    pub fn machine_of(&self, key: u64) -> usize {
        machine_of(key, self.cfg.machines)
    }

    /// Execute one MapReduce round.
    ///
    /// * `label` — step name recorded in the metrics.
    /// * `messages` — the map output: `(key, value)` pairs.
    /// * `reduce` — called once per key group (per machine) with the key and
    ///   all values for that key; returns this round's output items.
    ///
    /// Returns the concatenated reduce outputs (order: machine-major,
    /// key-sorted within a machine — deterministic).
    pub fn round<V, R, F>(&mut self, label: &str, messages: Vec<(u64, V)>, reduce: F) -> Vec<R>
    where
        V: WireSize + Send,
        R: Send,
        F: Fn(u64, &mut Vec<V>) -> Vec<R> + Sync,
    {
        let p = self.cfg.machines.max(1);

        // ---- shuffle: partition by key hash --------------------------------
        // Pre-size for the uniform-hash expectation so the buckets do not
        // realloc through millions of pushes (skewed keys still grow
        // amortized; §Perf).
        let bucket_cap = messages.len() / p + 1;
        let mut per_machine: Vec<Vec<(u64, V)>> =
            (0..p).map(|_| Vec::with_capacity(bucket_cap)).collect();
        let mut bytes = 0u64;
        let mut machine_bytes = vec![0u64; p];
        let n_messages = messages.len() as u64;
        for (key, value) in messages {
            let m = self.machine_of(key);
            let sz = 8 + value.wire_size();
            bytes += sz;
            machine_bytes[m] += sz;
            per_machine[m].push((key, value));
        }
        let max_machine_bytes = machine_bytes.iter().copied().max().unwrap_or(0);
        let space_violation = self
            .cfg
            .space_per_machine
            .map(|cap| max_machine_bytes > cap)
            .unwrap_or(false);

        // ---- per-machine: group by key, reduce ------------------------------
        let threads = self.cfg.threads.max(1).min(p);
        let run_machine = |mut local: Vec<(u64, V)>| -> Vec<R> {
            local.sort_unstable_by_key(|(k, _)| *k);
            let mut out = Vec::new();
            let mut group: Vec<V> = Vec::new();
            let mut it = local.into_iter().peekable();
            while let Some((key, v)) = it.next() {
                group.push(v);
                while it.peek().map(|(k, _)| *k == key).unwrap_or(false) {
                    group.push(it.next().unwrap().1);
                }
                out.extend(reduce(key, &mut group));
                group.clear();
            }
            out
        };

        let outputs: Vec<Vec<R>> = if threads <= 1 {
            per_machine.into_iter().map(run_machine).collect()
        } else {
            // `threads` pool jobs over contiguous machine chunks — the
            // knob stays a real wall-clock parallelism bound (Table 3
            // thread sweeps), not just a serial/parallel switch.  Jobs
            // return in chunk order, machines stay in machine order
            // within a chunk, so output order matches the serial path.
            let run = &run_machine;
            let mut machines = per_machine.into_iter();
            let mut jobs = Vec::with_capacity(threads);
            for i in 0..threads {
                let (a, b) = pool::chunk_range(p, threads, i);
                let chunk: Vec<Vec<(u64, V)>> = machines.by_ref().take(b - a).collect();
                jobs.push(move || chunk.into_iter().map(run).collect::<Vec<Vec<R>>>());
            }
            pool::global().run_jobs(jobs).into_iter().flatten().collect()
        };

        self.metrics.record(RoundMetrics {
            label: label.to_string(),
            messages: n_messages,
            bytes,
            max_machine_bytes,
            space_violation,
            ..Default::default()
        });

        outputs.into_iter().flatten().collect()
    }

    /// Fast path for **associative, commutative per-key folds** (the min/max
    /// hops that dominate every contraction phase).  Semantically identical
    /// to [`round`](Self::round) with a folding reducer, but skips the
    /// physical grouping: a real MapReduce sorts/groups inside the shuffle
    /// service, which the model does not observe — the metrics (messages,
    /// bytes, per-machine load) are computed exactly as in `round`.
    /// §Perf: 3–4x on the label-computation rounds (see EXPERIMENTS.md).
    ///
    /// `out[key]` is folded in place; keys receiving no message keep their
    /// prior value (the "own value" semantics of the hops).
    pub fn round_fold<V, I>(&mut self, label: &str, out: &mut [V], messages: I, op: fn(V, V) -> V)
    where
        V: WireSize + Copy,
        I: IntoIterator<Item = (u64, V)>,
    {
        let p = self.cfg.machines.max(1);
        let mut machine_bytes = vec![0u64; p];
        let mut bytes = 0u64;
        let mut n_messages = 0u64;
        let mut touched = vec![false; out.len()];
        for (key, value) in messages {
            let sz = 8 + value.wire_size();
            bytes += sz;
            machine_bytes[machine_of(key, p)] += sz;
            n_messages += 1;
            let k = key as usize;
            out[k] = if touched[k] { op(out[k], value) } else { value };
            touched[k] = true;
        }
        self.finish_round(label, n_messages, bytes, &machine_bytes);
    }

    /// Fast path for **per-message transforms** (endpoint relabeling in the
    /// contraction rounds of Lemma 3.1): every message is mapped
    /// independently by the machine owning its key, so no grouping is
    /// needed.  Accounting is identical to [`round`](Self::round).
    pub fn round_map<V, R, I, F>(&mut self, label: &str, messages: I, f: F) -> Vec<R>
    where
        V: WireSize + Copy,
        I: IntoIterator<Item = (u64, V)>,
        F: Fn(u64, V) -> R,
    {
        let p = self.cfg.machines.max(1);
        let mut machine_bytes = vec![0u64; p];
        let mut bytes = 0u64;
        let mut n_messages = 0u64;
        let messages = messages.into_iter();
        let mut out = Vec::with_capacity(messages.size_hint().0);
        for (key, value) in messages {
            let sz = 8 + value.wire_size();
            bytes += sz;
            machine_bytes[machine_of(key, p)] += sz;
            n_messages += 1;
            out.push(f(key, value));
        }
        self.finish_round(label, n_messages, bytes, &machine_bytes);
        out
    }

    /// Chunked, parallel form of [`round_fold`](Self::round_fold): the
    /// message stream arrives as independent chunks (typically one per
    /// configured thread, produced by slicing the edge list) that workers
    /// fold into per-worker accumulator arrays guarded by `touched`
    /// bitsets; partials are merged into `out` in chunk order by `op`.
    ///
    /// Because `op` must be associative and commutative, the result — and
    /// all model metrics, which are plain sums — is bit-identical to
    /// folding the concatenated chunks serially, for every `threads`
    /// setting.  Keys must be `< out.len()`.
    pub fn round_fold_chunked<V, C>(
        &mut self,
        label: &str,
        out: &mut [V],
        chunks: Vec<C>,
        op: fn(V, V) -> V,
    ) where
        V: WireSize + Copy + Send,
        C: IntoIterator<Item = (u64, V)> + Send,
    {
        let p = self.cfg.machines.max(1);
        if self.cfg.threads.max(1) <= 1 || chunks.len() <= 1 {
            // Serial: exactly `round_fold` over the concatenated chunks.
            return self.round_fold(label, out, chunks.into_iter().flatten(), op);
        }

        let n = out.len();
        let words = n.div_ceil(64);
        // Accumulators need a fill value only so the Vec is materialized;
        // untouched slots are never read (the bitset gates every access).
        let fill = out.first().copied();
        let parts = pool::global().run_jobs(
            chunks
                .into_iter()
                .map(|chunk| {
                    move || {
                        let mut acc: Vec<V> = match fill {
                            Some(f) => vec![f; n],
                            None => Vec::new(),
                        };
                        let mut touched = vec![0u64; words];
                        let mut machine_bytes = vec![0u64; p];
                        let (mut bytes, mut msgs) = (0u64, 0u64);
                        for (key, value) in chunk {
                            let sz = 8 + value.wire_size();
                            bytes += sz;
                            machine_bytes[machine_of(key, p)] += sz;
                            msgs += 1;
                            let k = key as usize;
                            if (touched[k / 64] >> (k % 64)) & 1 == 1 {
                                acc[k] = op(acc[k], value);
                            } else {
                                acc[k] = value;
                                touched[k / 64] |= 1u64 << (k % 64);
                            }
                        }
                        (acc, touched, machine_bytes, bytes, msgs)
                    }
                })
                .collect(),
        );

        let mut machine_bytes = vec![0u64; p];
        let (mut bytes, mut msgs) = (0u64, 0u64);
        let mut touched = vec![0u64; words];
        for (acc, part_touched, part_mb, part_bytes, part_msgs) in parts {
            bytes += part_bytes;
            msgs += part_msgs;
            for (mb, pb) in machine_bytes.iter_mut().zip(&part_mb) {
                *mb += pb;
            }
            for (w, &set_bits) in part_touched.iter().enumerate() {
                let mut set = set_bits;
                while set != 0 {
                    let k = w * 64 + set.trailing_zeros() as usize;
                    set &= set - 1;
                    out[k] = if (touched[w] >> (k % 64)) & 1 == 1 {
                        op(out[k], acc[k])
                    } else {
                        acc[k]
                    };
                    touched[w] |= 1u64 << (k % 64);
                }
            }
        }
        self.finish_round(label, msgs, bytes, &machine_bytes);
    }

    /// Chunked, parallel form of [`round_map`](Self::round_map): workers
    /// transform their chunks independently with per-worker byte/message
    /// accounting, reduced at the end.  Outputs concatenate in chunk order,
    /// so both the output sequence and the model metrics are identical to
    /// the serial path.
    pub fn round_map_chunked<V, R, C, F>(
        &mut self,
        label: &str,
        chunks: Vec<C>,
        f: F,
    ) -> Vec<R>
    where
        V: WireSize + Copy + Send,
        R: Send,
        C: IntoIterator<Item = (u64, V)> + Send,
        F: Fn(u64, V) -> R + Sync,
    {
        let p = self.cfg.machines.max(1);
        if self.cfg.threads.max(1) <= 1 || chunks.len() <= 1 {
            // Serial: exactly `round_map` over the concatenated chunks.
            return self.round_map(label, chunks.into_iter().flatten(), f);
        }

        let f = &f;
        let parts = pool::global().run_jobs(
            chunks
                .into_iter()
                .map(|chunk| {
                    move || {
                        let mut machine_bytes = vec![0u64; p];
                        let (mut bytes, mut msgs) = (0u64, 0u64);
                        let chunk = chunk.into_iter();
                        let mut out = Vec::with_capacity(chunk.size_hint().0);
                        for (key, value) in chunk {
                            let sz = 8 + value.wire_size();
                            bytes += sz;
                            machine_bytes[machine_of(key, p)] += sz;
                            msgs += 1;
                            out.push(f(key, value));
                        }
                        (out, machine_bytes, bytes, msgs)
                    }
                })
                .collect(),
        );

        let mut machine_bytes = vec![0u64; p];
        let (mut bytes, mut msgs) = (0u64, 0u64);
        let mut out = Vec::new();
        for (part_out, part_mb, part_bytes, part_msgs) in parts {
            bytes += part_bytes;
            msgs += part_msgs;
            for (mb, pb) in machine_bytes.iter_mut().zip(&part_mb) {
                *mb += pb;
            }
            out.extend(part_out);
        }
        self.finish_round(label, msgs, bytes, &machine_bytes);
        out
    }

    /// Sharded form of [`round_fold`](Self::round_fold): the message
    /// stream arrives as one chunk **per shard** of the resident
    /// [`crate::graph::ShardedGraph`] (so the chunking is a function of
    /// `machines` — the single source of the shard count — never of
    /// `threads`), and the accounting arrives pre-computed as a
    /// [`ShardRound`] derived from shard membership.  No `machine_of` is
    /// evaluated per message; debug builds verify the charge's message
    /// count against the stream actually folded.
    ///
    /// Shard chunks are folded into per-worker accumulators guarded by
    /// `touched` bitsets and merged into `out` in shard order, so — `op`
    /// being associative and commutative — both the result and the model
    /// metrics are bit-identical for every `threads` setting.  Keys must
    /// be `< out.len()`.
    ///
    /// Known trade-off: a shard is the unit of work, so wall-clock
    /// parallelism is capped at `min(threads, machines)` — with fewer
    /// machines than threads the round under-uses the pool (the default
    /// 16 machines saturates it; sub-shard splitting is a possible later
    /// extension since the merge order, not the split, carries the
    /// determinism).
    pub fn round_fold_sharded<V, C>(
        &mut self,
        label: &str,
        out: &mut [V],
        shards: Vec<C>,
        charge: ShardRound,
        op: fn(V, V) -> V,
    ) where
        V: Copy + Send,
        C: IntoIterator<Item = (u64, V)> + Send,
    {
        assert_eq!(
            charge.machine_bytes.len(),
            self.cfg.machines.max(1),
            "shard charge width != machines"
        );
        let t = self.cfg.threads.max(1).min(shards.len().max(1));
        let mut msgs_seen = 0u64;
        if t <= 1 || shards.len() <= 1 {
            // Serial: exactly `round_fold` over the concatenated shards,
            // minus the per-message accounting the charge already carries.
            let mut touched = vec![false; out.len()];
            for (key, value) in shards.into_iter().flatten() {
                msgs_seen += 1;
                let k = key as usize;
                out[k] = if touched[k] { op(out[k], value) } else { value };
                touched[k] = true;
            }
        } else {
            let n = out.len();
            let words = n.div_ceil(64);
            // Accumulators need a fill value only so the Vec is
            // materialized; untouched slots are never read.
            let fill = out.first().copied();
            let num_shards = shards.len();
            let mut it = shards.into_iter();
            let mut jobs = Vec::with_capacity(t);
            for i in 0..t {
                let (a, b) = pool::chunk_range(num_shards, t, i);
                let group: Vec<C> = it.by_ref().take(b - a).collect();
                jobs.push(move || {
                    let mut acc: Vec<V> = match fill {
                        Some(f) => vec![f; n],
                        None => Vec::new(),
                    };
                    let mut touched = vec![0u64; words];
                    let mut msgs = 0u64;
                    for (key, value) in group.into_iter().flatten() {
                        msgs += 1;
                        let k = key as usize;
                        if (touched[k / 64] >> (k % 64)) & 1 == 1 {
                            acc[k] = op(acc[k], value);
                        } else {
                            acc[k] = value;
                            touched[k / 64] |= 1u64 << (k % 64);
                        }
                    }
                    (acc, touched, msgs)
                });
            }
            let parts = pool::global().run_jobs(jobs);
            let mut touched = vec![0u64; words];
            for (acc, part_touched, m) in parts {
                msgs_seen += m;
                for (w, &set_bits) in part_touched.iter().enumerate() {
                    let mut set = set_bits;
                    while set != 0 {
                        let k = w * 64 + set.trailing_zeros() as usize;
                        set &= set - 1;
                        out[k] = if (touched[w] >> (k % 64)) & 1 == 1 {
                            op(out[k], acc[k])
                        } else {
                            acc[k]
                        };
                        touched[w] |= 1u64 << (k % 64);
                    }
                }
            }
        }
        debug_assert_eq!(
            msgs_seen, charge.messages,
            "shard charge disagrees with the message stream ({label})"
        );
        let _ = msgs_seen;
        self.finish_round(label, charge.messages, charge.bytes, &charge.machine_bytes);
    }

    /// Sharded form of [`round_map`](Self::round_map): one chunk per shard,
    /// accounting pre-computed from shard membership ([`ShardRound`]).
    /// Outputs concatenate in shard order, so the output sequence and the
    /// model metrics are identical for every `threads` setting.
    pub fn round_map_sharded<V, R, C, F>(
        &mut self,
        label: &str,
        shards: Vec<C>,
        charge: ShardRound,
        f: F,
    ) -> Vec<R>
    where
        V: Copy + Send,
        R: Send,
        C: IntoIterator<Item = (u64, V)> + Send,
        F: Fn(u64, V) -> R + Sync,
    {
        assert_eq!(
            charge.machine_bytes.len(),
            self.cfg.machines.max(1),
            "shard charge width != machines"
        );
        let t = self.cfg.threads.max(1).min(shards.len().max(1));
        let mut msgs_seen = 0u64;
        let out: Vec<R> = if t <= 1 || shards.len() <= 1 {
            let mut out = Vec::with_capacity(charge.messages as usize);
            for (key, value) in shards.into_iter().flatten() {
                msgs_seen += 1;
                out.push(f(key, value));
            }
            out
        } else {
            let f = &f;
            let num_shards = shards.len();
            let mut it = shards.into_iter();
            let mut jobs = Vec::with_capacity(t);
            for i in 0..t {
                let (a, b) = pool::chunk_range(num_shards, t, i);
                let group: Vec<C> = it.by_ref().take(b - a).collect();
                jobs.push(move || {
                    let mut out = Vec::new();
                    let mut msgs = 0u64;
                    for (key, value) in group.into_iter().flatten() {
                        msgs += 1;
                        out.push(f(key, value));
                    }
                    (out, msgs)
                });
            }
            let parts = pool::global().run_jobs(jobs);
            let mut out = Vec::with_capacity(parts.iter().map(|(o, _)| o.len()).sum());
            for (part, m) in parts {
                msgs_seen += m;
                out.extend(part);
            }
            out
        };
        debug_assert_eq!(
            msgs_seen, charge.messages,
            "shard charge disagrees with the message stream ({label})"
        );
        let _ = msgs_seen;
        self.finish_round(label, charge.messages, charge.bytes, &charge.machine_bytes);
        out
    }

    /// Record a round whose computation happened outside the engine but
    /// whose accounting replicates exactly the round it replaces (the
    /// fused contraction phases in `cc::common` charge the model this
    /// way).  `machine_bytes` is per machine; `messages`/`bytes` are the
    /// round totals.
    pub fn charge_round(
        &mut self,
        label: &str,
        messages: u64,
        bytes: u64,
        machine_bytes: &[u64],
    ) {
        self.finish_round(label, messages, bytes, machine_bytes);
    }

    fn finish_round(&mut self, label: &str, messages: u64, bytes: u64, machine_bytes: &[u64]) {
        let max_machine_bytes = machine_bytes.iter().copied().max().unwrap_or(0);
        let space_violation = self
            .cfg
            .space_per_machine
            .map(|cap| max_machine_bytes > cap)
            .unwrap_or(false);
        self.metrics.record(RoundMetrics {
            label: label.to_string(),
            messages,
            bytes,
            max_machine_bytes,
            space_violation,
            ..Default::default()
        });
    }

    /// Record DHT traffic against the most recent round (the DHT serves
    /// queries "in the following round", §2.1).
    pub fn charge_dht(&mut self, reads: u64, writes: u64) {
        if let Some(last) = self.metrics.rounds.last_mut() {
            last.dht_reads += reads;
            last.dht_writes += writes;
        } else {
            self.metrics.record(RoundMetrics {
                label: "dht".into(),
                dht_reads: reads,
                dht_writes: writes,
                ..Default::default()
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(machines: usize) -> Simulator {
        Simulator::new(MpcConfig {
            machines,
            space_per_machine: None,
            spill_budget: None,
            threads: 2,
        })
    }

    #[test]
    fn round_groups_by_key() {
        let mut s = sim(4);
        let msgs: Vec<(u64, u32)> = vec![(1, 10), (2, 20), (1, 11), (3, 30), (2, 21)];
        let mut out = s.round("test", msgs, |key, vals| {
            vals.sort_unstable();
            vec![(key, vals.clone())]
        });
        out.sort_by_key(|(k, _)| *k);
        assert_eq!(
            out,
            vec![(1, vec![10, 11]), (2, vec![20, 21]), (3, vec![30])]
        );
    }

    #[test]
    fn metrics_count_bytes_and_messages() {
        let mut s = sim(4);
        let msgs: Vec<(u64, u32)> = (0..10).map(|i| (i, i as u32)).collect();
        let _: Vec<()> = s.round("count", msgs, |_, _| vec![]);
        let r = &s.metrics.rounds[0];
        assert_eq!(r.messages, 10);
        assert_eq!(r.bytes, 10 * 12); // 8 key + 4 value
        assert!(r.max_machine_bytes <= r.bytes);
        assert!(r.max_machine_bytes >= r.bytes / 4);
    }

    #[test]
    fn space_violation_flagged() {
        let mut s = Simulator::new(MpcConfig {
            machines: 1,
            space_per_machine: Some(10),
            spill_budget: None,
            threads: 1,
        });
        let _: Vec<()> = s.round("big", vec![(0u64, 1u32), (1, 2)], |_, _| vec![]);
        assert!(s.metrics.rounds[0].space_violation);
        assert!(s.metrics.any_space_violation());
    }

    #[test]
    fn deterministic_output_order() {
        let run = || {
            let mut s = sim(8);
            let msgs: Vec<(u64, u32)> = (0..100).map(|i| (i * 7 % 13, i as u32)).collect();
            s.round("det", msgs, |k, vals| vec![(k, vals.len())])
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn parallel_matches_serial() {
        let exec = |threads: usize| {
            let mut s = Simulator::new(MpcConfig {
                machines: 8,
                space_per_machine: None,
                spill_budget: None,
                threads,
            });
            let msgs: Vec<(u64, u32)> = (0..1000).map(|i| (i % 37, i as u32)).collect();
            let mut out = s.round("p", msgs, |k, vals| vec![(k, vals.iter().sum::<u32>())]);
            out.sort_unstable();
            (out, s.metrics.rounds[0].clone())
        };
        assert_eq!(exec(1), exec(4));
    }

    /// A deterministic message mix with repeated keys, a hot key, and an
    /// untouched tail of the key space.
    fn fold_messages(n_msgs: usize, key_space: u64) -> Vec<(u64, u32)> {
        (0..n_msgs)
            .map(|i| {
                let key = if i % 7 == 0 {
                    3 // hot key
                } else {
                    (i as u64 * 2654435761) % key_space
                };
                (key, (i as u32).wrapping_mul(2246822519))
            })
            .collect()
    }

    fn chunked<T: Copy>(msgs: &[T], chunks: usize) -> Vec<std::vec::IntoIter<T>> {
        (0..chunks)
            .map(|i| {
                let (a, b) = crate::mpc::pool::chunk_range(msgs.len(), chunks, i);
                msgs[a..b].to_vec().into_iter()
            })
            .collect()
    }

    #[test]
    fn fold_chunked_matches_serial_across_threads() {
        let msgs = fold_messages(10_000, 512);
        let exec = |threads: usize| {
            let mut s = Simulator::new(MpcConfig {
                machines: 16,
                space_per_machine: Some(20_000),
                spill_budget: None,
                threads,
            });
            let mut out: Vec<u32> = (0..600u32).collect();
            s.round_fold_chunked(
                "fold",
                &mut out,
                chunked(&msgs, threads.max(1)),
                u32::min,
            );
            (out, s.metrics.rounds[0].clone())
        };
        let base = exec(1);
        for threads in [4, 8] {
            assert_eq!(exec(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn fold_chunked_matches_single_iterator_fold() {
        let msgs = fold_messages(5_000, 300);
        let mut serial = Simulator::new(MpcConfig {
            machines: 8,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        });
        let mut out_serial: Vec<u32> = vec![u32::MAX; 400];
        serial.round_fold("fold", &mut out_serial, msgs.iter().copied(), u32::min);

        let mut par = Simulator::new(MpcConfig {
            machines: 8,
            space_per_machine: None,
            spill_budget: None,
            threads: 8,
        });
        let mut out_par: Vec<u32> = vec![u32::MAX; 400];
        par.round_fold_chunked("fold", &mut out_par, chunked(&msgs, 8), u32::min);

        assert_eq!(out_serial, out_par);
        assert_eq!(serial.metrics.rounds[0], par.metrics.rounds[0]);
    }

    #[test]
    fn map_chunked_matches_serial_across_threads() {
        let msgs = fold_messages(10_000, 1 << 20);
        let exec = |threads: usize| {
            let mut s = Simulator::new(MpcConfig {
                machines: 16,
                space_per_machine: Some(15_000),
                spill_budget: None,
                threads,
            });
            let out: Vec<(u64, u32)> = s.round_map_chunked(
                "map",
                chunked(&msgs, threads.max(1)),
                |k, v| (k ^ 0xABCD, v.rotate_left(5)),
            );
            (out, s.metrics.rounds[0].clone())
        };
        let base = exec(1);
        for threads in [4, 8] {
            assert_eq!(exec(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn map_chunked_matches_single_iterator_map() {
        let msgs = fold_messages(3_000, 1 << 16);
        let mut serial = Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        });
        let out_serial: Vec<u32> = serial.round_map("map", msgs.iter().copied(), |_, v| v + 1);

        let mut par = Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 4,
        });
        let out_par: Vec<u32> = par.round_map_chunked("map", chunked(&msgs, 4), |_, v| v + 1);

        assert_eq!(out_serial, out_par);
        assert_eq!(serial.metrics.rounds[0], par.metrics.rounds[0]);
    }

    #[test]
    fn fold_chunked_empty_out_and_chunks() {
        let mut s = sim(4);
        let mut out: Vec<u32> = Vec::new();
        let chunks: Vec<std::vec::IntoIter<(u64, u32)>> =
            vec![Vec::new().into_iter(), Vec::new().into_iter()];
        s.round_fold_chunked("empty", &mut out, chunks, u32::min);
        let r = &s.metrics.rounds[0];
        assert_eq!((r.messages, r.bytes, r.max_machine_bytes), (0, 0, 0));
    }

    /// Brute-force a `ShardRound` from a message list (the per-message
    /// accounting the sharded paths are allowed to skip).
    fn brute_charge(msgs: &[(u64, u32)], p: usize) -> ShardRound {
        let mut machine_bytes = vec![0u64; p];
        let mut bytes = 0;
        for &(key, value) in msgs {
            let sz = 8 + crate::mpc::WireSize::wire_size(&value);
            bytes += sz;
            machine_bytes[machine_of(key, p)] += sz;
        }
        ShardRound {
            messages: msgs.len() as u64,
            bytes,
            machine_bytes,
        }
    }

    #[test]
    fn fold_sharded_matches_round_fold_reference() {
        let msgs = fold_messages(8_000, 512);
        let p = 8;
        let mut reference = Simulator::new(MpcConfig {
            machines: p,
            space_per_machine: Some(25_000),
            spill_budget: None,
            threads: 1,
        });
        let mut out_ref: Vec<u32> = (0..600u32).collect();
        reference.round_fold("fold", &mut out_ref, msgs.iter().copied(), u32::min);

        for threads in [1usize, 4, 8] {
            let mut s = Simulator::new(MpcConfig {
                machines: p,
                space_per_machine: Some(25_000),
                spill_budget: None,
                threads,
            });
            let mut out: Vec<u32> = (0..600u32).collect();
            s.round_fold_sharded(
                "fold",
                &mut out,
                chunked(&msgs, p),
                brute_charge(&msgs, p),
                u32::min,
            );
            assert_eq!(out, out_ref, "threads={threads}");
            assert_eq!(s.metrics.rounds[0], reference.metrics.rounds[0], "threads={threads}");
        }
    }

    #[test]
    fn map_sharded_matches_round_map_reference() {
        let msgs = fold_messages(6_000, 1 << 18);
        let p = 4;
        let mut reference = Simulator::new(MpcConfig {
            machines: p,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        });
        let out_ref: Vec<u64> =
            reference.round_map("map", msgs.iter().copied(), |k, v| k ^ v as u64);

        for threads in [1usize, 4, 8] {
            let mut s = Simulator::new(MpcConfig {
                machines: p,
                space_per_machine: None,
                spill_budget: None,
                threads,
            });
            let out: Vec<u64> = s.round_map_sharded(
                "map",
                chunked(&msgs, p),
                brute_charge(&msgs, p),
                |k, v| k ^ v as u64,
            );
            assert_eq!(out, out_ref, "threads={threads}");
            assert_eq!(s.metrics.rounds[0], reference.metrics.rounds[0], "threads={threads}");
        }
    }

    #[test]
    fn sharded_rounds_handle_empty_streams() {
        let mut s = sim(4);
        let mut out: Vec<u32> = vec![7; 10];
        let charge = ShardRound {
            messages: 0,
            bytes: 0,
            machine_bytes: vec![0; 4],
        };
        let chunks: Vec<std::vec::IntoIter<(u64, u32)>> =
            (0..4).map(|_| Vec::new().into_iter()).collect();
        s.round_fold_sharded("empty", &mut out, chunks, charge, u32::min);
        assert_eq!(out, vec![7; 10]);
        let r = &s.metrics.rounds[0];
        assert_eq!((r.messages, r.bytes, r.max_machine_bytes), (0, 0, 0));
    }

    #[test]
    fn single_key_goes_to_one_machine() {
        let mut s = sim(16);
        let msgs: Vec<(u64, u32)> = (0..50).map(|_| (42u64, 1u32)).collect();
        let _: Vec<()> = s.round("hot", msgs, |_, _| vec![]);
        let r = &s.metrics.rounds[0];
        assert_eq!(r.max_machine_bytes, r.bytes, "hot key concentrates load");
    }

    #[test]
    fn charge_dht_attaches_to_last_round() {
        let mut s = sim(2);
        let _: Vec<()> = s.round("r", vec![(0u64, 0u32)], |_, _| vec![]);
        s.charge_dht(5, 3);
        assert_eq!(s.metrics.rounds[0].dht_reads, 5);
        assert_eq!(s.metrics.rounds[0].dht_writes, 3);
    }
}
