//! The MPC(0) round engine: hash shuffle, key grouping, per-machine
//! reduction, exact communication accounting — behind a pluggable
//! [`Exchange`] round transport.
//!
//! One [`Simulator::round`] = one computation-communication round of §2.1:
//! the caller's *map* output (a flat list of key-value messages) is
//! partitioned over `machines` by key hash, each machine's bytes are
//! charged against the space bound, messages are grouped by key, and the
//! caller's *reduce* runs once per group.  Machines execute on the
//! persistent worker pool ([`super::pool`]) so wall-clock measurements
//! (Table 3) reflect parallel per-round cost, while the metrics reflect
//! the model-level quantities.
//!
//! **Engine invariance.**  Model metrics (`messages`, `bytes`,
//! `max_machine_bytes`, `space_violation`) are pure functions of the
//! message multiset, so they are bit-identical across `threads` settings:
//! every parallel path accumulates them as per-chunk `u64` sums merged in
//! chunk order.  The chunked fast paths additionally require the fold `op`
//! to be associative and commutative (the min/max hops are), which makes
//! the *outputs* identical too.  `rust/tests/mpc_accounting.rs` and the
//! tests below enforce both.
//!
//! **Transport invariance.**  Every round completes through the private
//! `complete_round` → [`Exchange::exchange`].  On the
//! in-process backend that call is a pure accounting barrier and the
//! engine runs exactly as above.  On a wire backend
//! ([`Exchange::wants_wire`]) the round takes a serial single pass that
//! additionally serializes each message into its destination machine's
//! byte image (8-byte key + [`WireSize`] value — precisely the bytes the
//! model charges), ships the images, and validates the receiver-counted
//! loads against the charge; fold rounds carrying a [`WireOp`] tag are
//! reduced *by the remote machines* and merged back.  Because the fold
//! ops are associative and commutative and outputs concatenate in a
//! fixed order, both the outputs and the metrics are bit-identical across
//! transports — `rust/tests/transport_equivalence.rs` enforces this for
//! all eight algorithms.  A transport failure unwinds with the typed
//! [`TransportError`] as payload (see [`super::transport`] module docs).

use super::metrics::{Metrics, RoundMetrics, RoundTiming, WireSize};
use super::pool;
use super::transport::{
    Exchange, HopSpec, InProcess, RoundCharge, TransportError, WireFold, WireOp,
};
use crate::graph::spill::Fnv1a;
use crate::graph::{ShardedGraph, Vertex};
use crate::util::rng::splitmix64;
use std::time::Instant;

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct MpcConfig {
    /// Number of simulated machines (`p` in §2.1).
    pub machines: usize,
    /// Optional per-machine receive bound in bytes (`O(N/p)` for ε = 0).
    /// Exceeding it marks `space_violation` on the round rather than
    /// aborting, so experiments can report violations.
    pub space_per_machine: Option<u64>,
    /// Resident-memory budget for the sharded edge store, in bytes: graphs
    /// whose edge set exceeds it run with disk-backed shards
    /// (`crate::graph::spill`) through the same rounds — the out-of-core
    /// counterpart of `space_per_machine`'s *model* bound.  `None` =
    /// unbounded (always resident).  Threaded into every graph the flat
    /// `CcAlgorithm::run` adapter shards, and inherited by all contracted
    /// generations.
    pub spill_budget: Option<u64>,
    /// OS threads used to execute machines (simulation-level parallelism;
    /// does not affect the model metrics).
    pub threads: usize,
}

impl Default for MpcConfig {
    fn default() -> Self {
        MpcConfig {
            machines: 16,
            space_per_machine: None,
            spill_budget: None,
            threads: std::thread::available_parallelism()
                .map(|n| n.get().min(16))
                .unwrap_or(4),
        }
    }
}

/// Machine owning `key` under the stable hash partition.  The single
/// definition of the partition function: the simulator rounds, the
/// chunked fast paths, the fused rounds in `cc::common` (which charge
/// the model directly via [`Simulator::charge_round`]), and the resident
/// [`crate::graph::ShardedGraph`] partition must all agree on it, or
/// charged per-machine loads silently diverge from real rounds.
#[inline]
pub fn machine_of(key: u64, machines: usize) -> usize {
    (splitmix64(key) % machines as u64) as usize
}

/// Exact, pre-computed accounting for one **sharded** round.
///
/// When the resident representation is partitioned by [`machine_of`] (the
/// [`crate::graph::ShardedGraph`] invariant), per-machine loads are pure
/// functions of shard membership: the graph layer derives them from cached
/// shard statistics (`ShardedGraph::hop_charge`, `contract_charges`) and
/// the round engine no longer recomputes `machine_of` per message.  The
/// sharded entry points ([`Simulator::round_fold_sharded`],
/// [`Simulator::round_map_sharded`]) verify in debug builds that the
/// charge's message count matches the stream they actually folded.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardRound {
    /// Messages shuffled this round.
    pub messages: u64,
    /// Total bytes shuffled this round.
    pub bytes: u64,
    /// Bytes received per machine; `len` must equal `MpcConfig::machines`.
    pub machine_bytes: Vec<u64>,
}

/// The MPC execution engine: owns config, accumulated metrics, and the
/// round transport every exchange goes through.
pub struct Simulator {
    pub cfg: MpcConfig,
    pub metrics: Metrics,
    transport: Box<dyn Exchange>,
    /// Per-machine byte scratch, cleared (not dropped) between rounds so
    /// the in-process engine stops re-allocating a `Vec` per round on the
    /// bench path (§Perf).
    scratch_mb: Vec<u64>,
    /// Touched-key bitset scratch (one bit per output slot), same
    /// clear-not-drop lifecycle — this is the O(n) per-round allocation
    /// of the fold rounds.
    scratch_touched: Vec<u64>,
    /// Wall-clock of the current round's generate / fold stages, consumed
    /// into a [`RoundTiming`] row when the round completes.
    pending_gen_ms: f64,
    pending_fold_ms: f64,
    /// Data-plane watermarks (process-global counters as of the previous
    /// timing row) — the per-round `allocs` / mapped / copied deltas in
    /// [`RoundTiming`] are measured against these.
    alloc_mark: u64,
    dp_mark: crate::graph::spill::DataPlaneCounters,
}

impl std::fmt::Debug for Simulator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Simulator")
            .field("cfg", &self.cfg)
            .field("metrics", &self.metrics)
            .field("transport", &self.transport.name())
            .finish()
    }
}

impl Simulator {
    /// Engine on the in-process transport (the default and the reference
    /// semantics).
    pub fn new(cfg: MpcConfig) -> Self {
        Self::with_transport(cfg, Box::new(InProcess))
    }

    /// Engine on an explicit transport.  A transport bound to a machine
    /// count (the multi-process backend) must match `cfg.machines`.
    pub fn with_transport(cfg: MpcConfig, transport: Box<dyn Exchange>) -> Self {
        if let Some(m) = transport.machines() {
            assert_eq!(
                m,
                cfg.machines.max(1),
                "transport is bound to {m} machines, config says {}",
                cfg.machines
            );
        }
        Simulator {
            cfg,
            metrics: Metrics::new(),
            transport,
            scratch_mb: Vec::new(),
            scratch_touched: Vec::new(),
            pending_gen_ms: 0.0,
            pending_fold_ms: 0.0,
            alloc_mark: crate::util::alloc::allocation_count(),
            dp_mark: crate::graph::spill::data_plane_counters(),
        }
    }

    /// Name of the transport this engine shuffles on.
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// Re-arm the engine for another run in a persistent session (`lcc
    /// serve`): (re)establish `g` on the transport — the wire backends
    /// re-ship shard custody to the live fleet; in-process is a no-op —
    /// and reset the accumulated metrics and timing watermarks so every
    /// run's report stands alone, exactly as if the engine were freshly
    /// built.  The scratch buffers survive (that is the point of the
    /// session: no per-run teardown).
    pub fn begin_run(&mut self, g: &ShardedGraph) -> Result<(), TransportError> {
        self.transport.load_graph(g)?;
        self.metrics = Metrics::new();
        self.pending_gen_ms = 0.0;
        self.pending_fold_ms = 0.0;
        self.alloc_mark = crate::util::alloc::allocation_count();
        self.dp_mark = crate::graph::spill::data_plane_counters();
        Ok(())
    }

    /// Does the transport physically move bytes?  The round helpers in
    /// `cc::common` use this to pick shippable round shapes (e.g. two
    /// real hop rounds instead of the shared-memory fused traversal).
    #[inline]
    pub fn wire_mode(&self) -> bool {
        self.transport.wants_wire()
    }

    /// Partition a key over machines (stable across rounds).
    #[inline]
    pub fn machine_of(&self, key: u64) -> usize {
        machine_of(key, self.cfg.machines)
    }

    /// Borrow the per-machine byte scratch, zeroed to `p` slots.  Return
    /// it with [`put_mb`](Self::put_mb) so the allocation survives the
    /// round (cleared, not dropped).
    fn take_mb(&mut self, p: usize) -> Vec<u64> {
        let mut mb = std::mem::take(&mut self.scratch_mb);
        mb.clear();
        mb.resize(p, 0);
        mb
    }

    fn put_mb(&mut self, mb: Vec<u64>) {
        self.scratch_mb = mb;
    }

    /// Borrow the touched-key bitset scratch, zeroed to `words` words.
    fn take_touched(&mut self, words: usize) -> Vec<u64> {
        let mut t = std::mem::take(&mut self.scratch_touched);
        t.clear();
        t.resize(words, 0);
        t
    }

    fn put_touched(&mut self, t: Vec<u64>) {
        self.scratch_touched = t;
    }

    /// Attribute wall time to the current round's generate stage.
    #[inline]
    fn note_gen(&mut self, since: Instant) {
        self.pending_gen_ms += since.elapsed().as_secs_f64() * 1e3;
    }

    /// Attribute wall time to the current round's fold stage (before the
    /// round completes).
    #[inline]
    fn note_fold(&mut self, since: Instant) {
        self.pending_fold_ms += since.elapsed().as_secs_f64() * 1e3;
    }

    /// Attribute post-exchange reduce/merge time to the round that just
    /// completed.
    #[inline]
    fn note_fold_after(&mut self, since: Instant) {
        if let Some(t) = self.metrics.timings.last_mut() {
            t.fold_ms += since.elapsed().as_secs_f64() * 1e3;
        }
    }

    /// Data-plane deltas (allocation count, spilled-shard bytes mapped /
    /// copied) since the previous timing row, advancing the watermarks.
    /// The counters are process-global, so concurrently-running engines
    /// bleed into each other's rows — pure observability, excluded from
    /// every bit-identity comparison exactly like the wall-clock timings.
    fn data_plane_delta(&mut self) -> (u64, u64, u64) {
        let allocs = crate::util::alloc::allocation_count();
        let dp = crate::graph::spill::data_plane_counters();
        let d_allocs = allocs.saturating_sub(self.alloc_mark);
        let d_mapped = dp
            .shard_bytes_mapped
            .saturating_sub(self.dp_mark.shard_bytes_mapped);
        let d_copied = dp
            .shard_bytes_copied
            .saturating_sub(self.dp_mark.shard_bytes_copied);
        self.alloc_mark = allocs;
        self.dp_mark = dp;
        (d_allocs, d_mapped, d_copied)
    }

    /// Execute one MapReduce round.
    ///
    /// * `label` — step name recorded in the metrics.
    /// * `messages` — the map output: `(key, value)` pairs.
    /// * `reduce` — called once per key group (per machine) with the key and
    ///   all values for that key; returns this round's output items.
    ///
    /// Returns the concatenated reduce outputs (order: machine-major,
    /// key-sorted within a machine — deterministic).
    pub fn round<V, R, F>(&mut self, label: &str, messages: Vec<(u64, V)>, reduce: F) -> Vec<R>
    where
        V: WireSize + Send,
        R: Send,
        F: Fn(u64, &mut Vec<V>) -> Vec<R> + Sync,
    {
        let p = self.cfg.machines.max(1);

        // ---- shuffle: partition by key hash --------------------------------
        // Pre-size for the uniform-hash expectation so the buckets do not
        // realloc through millions of pushes (skewed keys still grow
        // amortized; §Perf).
        let t_gen = Instant::now();
        let bucket_cap = messages.len() / p + 1;
        let mut per_machine: Vec<Vec<(u64, V)>> =
            (0..p).map(|_| Vec::with_capacity(bucket_cap)).collect();
        let mut bytes = 0u64;
        let mut machine_bytes = self.take_mb(p);
        let n_messages = messages.len() as u64;
        for (key, value) in messages {
            let m = self.machine_of(key);
            let sz = 8 + value.wire_size();
            bytes += sz;
            machine_bytes[m] += sz;
            per_machine[m].push((key, value));
        }

        // ---- exchange: the transport moves (or barriers) the round ----------
        // On a wire transport each machine's exact byte image ships before
        // any reduce runs; in-process this is the accounting barrier.
        let payloads = if self.wire_mode() {
            encode_buckets(&per_machine)
        } else {
            Vec::new()
        };
        self.note_gen(t_gen);
        self.complete_round(label, n_messages, bytes, &machine_bytes, payloads, None);
        self.put_mb(machine_bytes);
        let t_fold = Instant::now();

        // ---- per-machine: group by key, reduce ------------------------------
        let threads = self.cfg.threads.max(1).min(p);
        let run_machine = |mut local: Vec<(u64, V)>| -> Vec<R> {
            local.sort_unstable_by_key(|(k, _)| *k);
            let mut out = Vec::new();
            let mut group: Vec<V> = Vec::new();
            let mut it = local.into_iter().peekable();
            while let Some((key, v)) = it.next() {
                group.push(v);
                while it.peek().map(|(k, _)| *k == key).unwrap_or(false) {
                    group.push(it.next().unwrap().1);
                }
                out.extend(reduce(key, &mut group));
                group.clear();
            }
            out
        };

        let outputs: Vec<Vec<R>> = if threads <= 1 {
            per_machine.into_iter().map(run_machine).collect()
        } else {
            // `threads` pool jobs over contiguous machine chunks — the
            // knob stays a real wall-clock parallelism bound (Table 3
            // thread sweeps), not just a serial/parallel switch.  Jobs
            // return in chunk order, machines stay in machine order
            // within a chunk, so output order matches the serial path.
            let run = &run_machine;
            let mut machines = per_machine.into_iter();
            let mut jobs = Vec::with_capacity(threads);
            for i in 0..threads {
                let (a, b) = pool::chunk_range(p, threads, i);
                let chunk: Vec<Vec<(u64, V)>> = machines.by_ref().take(b - a).collect();
                jobs.push(move || chunk.into_iter().map(run).collect::<Vec<Vec<R>>>());
            }
            pool::global().run_jobs(jobs).into_iter().flatten().collect()
        };

        let out = outputs.into_iter().flatten().collect();
        self.note_fold_after(t_fold);
        out
    }

    /// Fast path for **associative, commutative per-key folds** (the min/max
    /// hops that dominate every contraction phase).  Semantically identical
    /// to [`round`](Self::round) with a folding reducer, but skips the
    /// physical grouping: a real MapReduce sorts/groups inside the shuffle
    /// service, which the model does not observe — the metrics (messages,
    /// bytes, per-machine load) are computed exactly as in `round`.
    /// §Perf: 3–4x on the label-computation rounds (see EXPERIMENTS.md).
    ///
    /// `out[key]` is folded in place; keys receiving no message keep their
    /// prior value (the "own value" semantics of the hops).
    pub fn round_fold<V, I>(&mut self, label: &str, out: &mut [V], messages: I, op: fn(V, V) -> V)
    where
        V: WireSize + Copy,
        I: IntoIterator<Item = (u64, V)>,
    {
        self.round_fold_tagged(label, out, messages, WireFold::untagged(op));
    }

    /// [`round_fold`](Self::round_fold) with the fold's optional wire
    /// identity: on a wire transport a [`WireOp`]-tagged fold is reduced
    /// **by the remote machines** (each folds the messages for the keys
    /// it owns and returns one pair per key, merged back here); untagged
    /// folds reduce locally while the byte image still ships for
    /// receiver-side accounting.  Either way the single pass below both
    /// accounts and (when needed) serializes, so the charged and shipped
    /// bytes agree by construction.
    pub fn round_fold_tagged<V, I>(
        &mut self,
        label: &str,
        out: &mut [V],
        messages: I,
        fold: WireFold<V>,
    ) where
        V: WireSize + Copy,
        I: IntoIterator<Item = (u64, V)>,
    {
        let p = self.cfg.machines.max(1);
        let wire = self.wire_mode();
        let remote = wire && fold.wire.is_some();
        let t_gen = Instant::now();
        let mut bufs: Vec<Vec<u8>> = if wire {
            (0..p).map(|_| Vec::new()).collect()
        } else {
            Vec::new()
        };
        let mut machine_bytes = self.take_mb(p);
        let words = out.len().div_ceil(64);
        let mut touched = self.take_touched(words);
        let mut bytes = 0u64;
        let mut n_messages = 0u64;
        for (key, value) in messages {
            let sz = 8 + value.wire_size();
            bytes += sz;
            let m = machine_of(key, p);
            machine_bytes[m] += sz;
            n_messages += 1;
            if wire {
                bufs[m].extend_from_slice(&key.to_le_bytes());
                value.encode_wire(&mut bufs[m]);
            }
            if !remote {
                let k = key as usize;
                out[k] = if (touched[k / 64] >> (k % 64)) & 1 == 1 {
                    (fold.f)(out[k], value)
                } else {
                    value
                };
                touched[k / 64] |= 1u64 << (k % 64);
            }
        }
        self.note_gen(t_gen);
        let folded = self.complete_round(
            label,
            n_messages,
            bytes,
            &machine_bytes,
            bufs,
            if remote { fold.wire } else { None },
        );
        self.put_mb(machine_bytes);
        self.put_touched(touched);
        if remote {
            let t_fold = Instant::now();
            apply_folded(out, folded.expect("wire transport returned no fold results"));
            self.note_fold_after(t_fold);
        }
    }

    /// Fast path for **per-message transforms** (endpoint relabeling in the
    /// contraction rounds of Lemma 3.1): every message is mapped
    /// independently by the machine owning its key, so no grouping is
    /// needed.  Accounting is identical to [`round`](Self::round).
    pub fn round_map<V, R, I, F>(&mut self, label: &str, messages: I, f: F) -> Vec<R>
    where
        V: WireSize + Copy,
        I: IntoIterator<Item = (u64, V)>,
        F: Fn(u64, V) -> R,
    {
        let p = self.cfg.machines.max(1);
        let wire = self.wire_mode();
        let t_gen = Instant::now();
        let mut bufs: Vec<Vec<u8>> = if wire {
            (0..p).map(|_| Vec::new()).collect()
        } else {
            Vec::new()
        };
        let mut machine_bytes = self.take_mb(p);
        let mut bytes = 0u64;
        let mut n_messages = 0u64;
        let messages = messages.into_iter();
        let mut out = Vec::with_capacity(messages.size_hint().0);
        for (key, value) in messages {
            let sz = 8 + value.wire_size();
            bytes += sz;
            let m = machine_of(key, p);
            machine_bytes[m] += sz;
            n_messages += 1;
            if wire {
                bufs[m].extend_from_slice(&key.to_le_bytes());
                value.encode_wire(&mut bufs[m]);
            }
            out.push(f(key, value));
        }
        self.note_gen(t_gen);
        self.complete_round(label, n_messages, bytes, &machine_bytes, bufs, None);
        self.put_mb(machine_bytes);
        out
    }

    /// Chunked, parallel form of [`round_fold`](Self::round_fold): the
    /// message stream arrives as independent chunks (typically one per
    /// configured thread, produced by slicing the edge list) that workers
    /// fold into per-worker accumulator arrays guarded by `touched`
    /// bitsets; partials are merged into `out` in chunk order by `op`.
    ///
    /// Because `op` must be associative and commutative, the result — and
    /// all model metrics, which are plain sums — is bit-identical to
    /// folding the concatenated chunks serially, for every `threads`
    /// setting.  Keys must be `< out.len()`.
    pub fn round_fold_chunked<V, C>(
        &mut self,
        label: &str,
        out: &mut [V],
        chunks: Vec<C>,
        op: fn(V, V) -> V,
    ) where
        V: WireSize + Copy + Send,
        C: IntoIterator<Item = (u64, V)> + Send,
    {
        let p = self.cfg.machines.max(1);
        if self.wire_mode() || self.cfg.threads.max(1) <= 1 || chunks.len() <= 1 {
            // Serial: exactly `round_fold` over the concatenated chunks.
            // Wire transports always take it: the pass that folds also
            // serializes each machine's byte image, and chunk-order
            // concatenation keeps the image deterministic.
            return self.round_fold(label, out, chunks.into_iter().flatten(), op);
        }

        let n = out.len();
        let words = n.div_ceil(64);
        // Accumulators need a fill value only so the Vec is materialized;
        // untouched slots are never read (the bitset gates every access).
        let fill = out.first().copied();
        let parts = pool::global().run_jobs(
            chunks
                .into_iter()
                .map(|chunk| {
                    move || {
                        let mut acc: Vec<V> = match fill {
                            Some(f) => vec![f; n],
                            None => Vec::new(),
                        };
                        let mut touched = vec![0u64; words];
                        let mut machine_bytes = vec![0u64; p];
                        let (mut bytes, mut msgs) = (0u64, 0u64);
                        for (key, value) in chunk {
                            let sz = 8 + value.wire_size();
                            bytes += sz;
                            machine_bytes[machine_of(key, p)] += sz;
                            msgs += 1;
                            let k = key as usize;
                            if (touched[k / 64] >> (k % 64)) & 1 == 1 {
                                acc[k] = op(acc[k], value);
                            } else {
                                acc[k] = value;
                                touched[k / 64] |= 1u64 << (k % 64);
                            }
                        }
                        (acc, touched, machine_bytes, bytes, msgs)
                    }
                })
                .collect(),
        );

        let mut machine_bytes = vec![0u64; p];
        let (mut bytes, mut msgs) = (0u64, 0u64);
        let mut touched = vec![0u64; words];
        for (acc, part_touched, part_mb, part_bytes, part_msgs) in parts {
            bytes += part_bytes;
            msgs += part_msgs;
            for (mb, pb) in machine_bytes.iter_mut().zip(&part_mb) {
                *mb += pb;
            }
            for (w, &set_bits) in part_touched.iter().enumerate() {
                let mut set = set_bits;
                while set != 0 {
                    let k = w * 64 + set.trailing_zeros() as usize;
                    set &= set - 1;
                    out[k] = if (touched[w] >> (k % 64)) & 1 == 1 {
                        op(out[k], acc[k])
                    } else {
                        acc[k]
                    };
                    touched[w] |= 1u64 << (k % 64);
                }
            }
        }
        self.complete_round(label, msgs, bytes, &machine_bytes, Vec::new(), None);
    }

    /// Chunked, parallel form of [`round_map`](Self::round_map): workers
    /// transform their chunks independently with per-worker byte/message
    /// accounting, reduced at the end.  Outputs concatenate in chunk order,
    /// so both the output sequence and the model metrics are identical to
    /// the serial path.
    pub fn round_map_chunked<V, R, C, F>(
        &mut self,
        label: &str,
        chunks: Vec<C>,
        f: F,
    ) -> Vec<R>
    where
        V: WireSize + Copy + Send,
        R: Send,
        C: IntoIterator<Item = (u64, V)> + Send,
        F: Fn(u64, V) -> R + Sync,
    {
        let p = self.cfg.machines.max(1);
        if self.wire_mode() || self.cfg.threads.max(1) <= 1 || chunks.len() <= 1 {
            // Serial: exactly `round_map` over the concatenated chunks
            // (wire transports always take it — the serial pass builds
            // each machine's byte image in deterministic chunk order).
            return self.round_map(label, chunks.into_iter().flatten(), f);
        }

        let f = &f;
        let parts = pool::global().run_jobs(
            chunks
                .into_iter()
                .map(|chunk| {
                    move || {
                        let mut machine_bytes = vec![0u64; p];
                        let (mut bytes, mut msgs) = (0u64, 0u64);
                        let chunk = chunk.into_iter();
                        let mut out = Vec::with_capacity(chunk.size_hint().0);
                        for (key, value) in chunk {
                            let sz = 8 + value.wire_size();
                            bytes += sz;
                            machine_bytes[machine_of(key, p)] += sz;
                            msgs += 1;
                            out.push(f(key, value));
                        }
                        (out, machine_bytes, bytes, msgs)
                    }
                })
                .collect(),
        );

        let mut machine_bytes = vec![0u64; p];
        let (mut bytes, mut msgs) = (0u64, 0u64);
        let mut out = Vec::new();
        for (part_out, part_mb, part_bytes, part_msgs) in parts {
            bytes += part_bytes;
            msgs += part_msgs;
            for (mb, pb) in machine_bytes.iter_mut().zip(&part_mb) {
                *mb += pb;
            }
            out.extend(part_out);
        }
        self.complete_round(label, msgs, bytes, &machine_bytes, Vec::new(), None);
        out
    }

    /// Sharded form of [`round_fold`](Self::round_fold): the message
    /// stream arrives as one chunk **per shard** of the resident
    /// [`crate::graph::ShardedGraph`] (so the chunking is a function of
    /// `machines` — the single source of the shard count — never of
    /// `threads`), and the accounting arrives pre-computed as a
    /// [`ShardRound`] derived from shard membership.  No `machine_of` is
    /// evaluated per message; debug builds verify the charge's message
    /// count against the stream actually folded.
    ///
    /// Shard chunks are folded into per-worker accumulators guarded by
    /// `touched` bitsets and merged into `out` in shard order, so — `op`
    /// being associative and commutative — both the result and the model
    /// metrics are bit-identical for every `threads` setting.  Keys must
    /// be `< out.len()`.
    ///
    /// Chunks need not be whole shards: the merge order, not the split,
    /// carries the determinism, so callers with more threads than
    /// machines pass sub-shard row ranges
    /// ([`crate::graph::ShardedGraph::msg_chunks_split`]) — a mapped
    /// spilled shard then feeds every thread from borrowed cursor slices
    /// over one shared image.
    pub fn round_fold_sharded<V, C>(
        &mut self,
        label: &str,
        out: &mut [V],
        shards: Vec<C>,
        charge: ShardRound,
        op: fn(V, V) -> V,
    ) where
        V: WireSize + Copy + Send,
        C: IntoIterator<Item = (u64, V)> + Send,
    {
        self.round_fold_sharded_tagged(label, out, shards, charge, WireFold::untagged(op));
    }

    /// [`round_fold_sharded`](Self::round_fold_sharded) with the fold's
    /// wire identity (see [`round_fold_tagged`](Self::round_fold_tagged)):
    /// the hop helpers in `cc::common` pass tagged min/max folds so a
    /// wire transport reduces them on the remote machines.
    pub fn round_fold_sharded_tagged<V, C>(
        &mut self,
        label: &str,
        out: &mut [V],
        shards: Vec<C>,
        charge: ShardRound,
        fold: WireFold<V>,
    ) where
        V: WireSize + Copy + Send,
        C: IntoIterator<Item = (u64, V)> + Send,
    {
        assert_eq!(
            charge.machine_bytes.len(),
            self.cfg.machines.max(1),
            "shard charge width != machines"
        );
        if self.wire_mode() {
            return self.fold_sharded_wire(label, out, shards, charge, fold);
        }
        let op = fold.f;
        let t = self.cfg.threads.max(1).min(shards.len().max(1));
        let t_fold = Instant::now();
        let mut msgs_seen = 0u64;
        if t <= 1 || shards.len() <= 1 {
            // Serial: exactly `round_fold` over the concatenated shards,
            // minus the per-message accounting the charge already carries.
            let mut touched = self.take_touched(out.len().div_ceil(64));
            for (key, value) in shards.into_iter().flatten() {
                msgs_seen += 1;
                let k = key as usize;
                out[k] = if (touched[k / 64] >> (k % 64)) & 1 == 1 {
                    op(out[k], value)
                } else {
                    value
                };
                touched[k / 64] |= 1u64 << (k % 64);
            }
            self.put_touched(touched);
        } else {
            let n = out.len();
            let words = n.div_ceil(64);
            // Accumulators need a fill value only so the Vec is
            // materialized; untouched slots are never read.
            let fill = out.first().copied();
            let num_shards = shards.len();
            let mut it = shards.into_iter();
            let mut jobs = Vec::with_capacity(t);
            for i in 0..t {
                let (a, b) = pool::chunk_range(num_shards, t, i);
                let group: Vec<C> = it.by_ref().take(b - a).collect();
                jobs.push(move || {
                    let mut acc: Vec<V> = match fill {
                        Some(f) => vec![f; n],
                        None => Vec::new(),
                    };
                    let mut touched = vec![0u64; words];
                    let mut msgs = 0u64;
                    for (key, value) in group.into_iter().flatten() {
                        msgs += 1;
                        let k = key as usize;
                        if (touched[k / 64] >> (k % 64)) & 1 == 1 {
                            acc[k] = op(acc[k], value);
                        } else {
                            acc[k] = value;
                            touched[k / 64] |= 1u64 << (k % 64);
                        }
                    }
                    (acc, touched, msgs)
                });
            }
            let parts = pool::global().run_jobs(jobs);
            let mut touched = vec![0u64; words];
            for (acc, part_touched, m) in parts {
                msgs_seen += m;
                for (w, &set_bits) in part_touched.iter().enumerate() {
                    let mut set = set_bits;
                    while set != 0 {
                        let k = w * 64 + set.trailing_zeros() as usize;
                        set &= set - 1;
                        out[k] = if (touched[w] >> (k % 64)) & 1 == 1 {
                            op(out[k], acc[k])
                        } else {
                            acc[k]
                        };
                        touched[w] |= 1u64 << (k % 64);
                    }
                }
            }
        }
        debug_assert_eq!(
            msgs_seen, charge.messages,
            "shard charge disagrees with the message stream ({label})"
        );
        let _ = msgs_seen;
        self.note_fold(t_fold);
        self.complete_round(
            label,
            charge.messages,
            charge.bytes,
            &charge.machine_bytes,
            Vec::new(),
            None,
        );
    }

    /// The wire form of the sharded fold: one serial pass routes every
    /// message (`machine_of` per message — the price of genuinely moving
    /// bytes; the shard-derived charge is kept and *validated* against
    /// the receiver counts) and serializes it into its machine's image.
    /// Tagged folds come back reduced by the remote machines.
    fn fold_sharded_wire<V, C>(
        &mut self,
        label: &str,
        out: &mut [V],
        shards: Vec<C>,
        charge: ShardRound,
        fold: WireFold<V>,
    ) where
        V: WireSize + Copy,
        C: IntoIterator<Item = (u64, V)>,
    {
        let p = self.cfg.machines.max(1);
        let remote = fold.wire.is_some();
        let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
        let mut msgs_seen = 0u64;
        let mut touched = vec![false; if remote { 0 } else { out.len() }];
        for (key, value) in shards.into_iter().flatten() {
            msgs_seen += 1;
            let m = machine_of(key, p);
            bufs[m].extend_from_slice(&key.to_le_bytes());
            value.encode_wire(&mut bufs[m]);
            if !remote {
                let k = key as usize;
                out[k] = if touched[k] {
                    (fold.f)(out[k], value)
                } else {
                    value
                };
                touched[k] = true;
            }
        }
        debug_assert_eq!(
            msgs_seen, charge.messages,
            "shard charge disagrees with the message stream ({label})"
        );
        let _ = msgs_seen;
        let folded = self.complete_round(
            label,
            charge.messages,
            charge.bytes,
            &charge.machine_bytes,
            bufs,
            fold.wire,
        );
        if remote {
            apply_folded(out, folded.expect("wire transport returned no fold results"));
        }
    }

    /// Sharded form of [`round_map`](Self::round_map): one chunk per shard,
    /// accounting pre-computed from shard membership ([`ShardRound`]).
    /// Outputs concatenate in shard order, so the output sequence and the
    /// model metrics are identical for every `threads` setting.
    pub fn round_map_sharded<V, R, C, F>(
        &mut self,
        label: &str,
        shards: Vec<C>,
        charge: ShardRound,
        f: F,
    ) -> Vec<R>
    where
        V: WireSize + Copy + Send,
        R: Send,
        C: IntoIterator<Item = (u64, V)> + Send,
        F: Fn(u64, V) -> R + Sync,
    {
        assert_eq!(
            charge.machine_bytes.len(),
            self.cfg.machines.max(1),
            "shard charge width != machines"
        );
        let p = self.cfg.machines.max(1);
        if self.wire_mode() {
            // one serial pass: route + serialize each machine's byte
            // image, transform in stream order (identical to the serial
            // path's output sequence)
            let mut bufs: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
            let mut out = Vec::with_capacity(charge.messages as usize);
            let mut msgs_seen = 0u64;
            for (key, value) in shards.into_iter().flatten() {
                msgs_seen += 1;
                let m = machine_of(key, p);
                bufs[m].extend_from_slice(&key.to_le_bytes());
                value.encode_wire(&mut bufs[m]);
                out.push(f(key, value));
            }
            debug_assert_eq!(
                msgs_seen, charge.messages,
                "shard charge disagrees with the message stream ({label})"
            );
            let _ = msgs_seen;
            self.complete_round(
                label,
                charge.messages,
                charge.bytes,
                &charge.machine_bytes,
                bufs,
                None,
            );
            return out;
        }
        let t = self.cfg.threads.max(1).min(shards.len().max(1));
        let mut msgs_seen = 0u64;
        let out: Vec<R> = if t <= 1 || shards.len() <= 1 {
            let mut out = Vec::with_capacity(charge.messages as usize);
            for (key, value) in shards.into_iter().flatten() {
                msgs_seen += 1;
                out.push(f(key, value));
            }
            out
        } else {
            let f = &f;
            let num_shards = shards.len();
            let mut it = shards.into_iter();
            let mut jobs = Vec::with_capacity(t);
            for i in 0..t {
                let (a, b) = pool::chunk_range(num_shards, t, i);
                let group: Vec<C> = it.by_ref().take(b - a).collect();
                jobs.push(move || {
                    let mut out = Vec::new();
                    let mut msgs = 0u64;
                    for (key, value) in group.into_iter().flatten() {
                        msgs += 1;
                        out.push(f(key, value));
                    }
                    (out, msgs)
                });
            }
            let parts = pool::global().run_jobs(jobs);
            let mut out = Vec::with_capacity(parts.iter().map(|(o, _)| o.len()).sum());
            for (part, m) in parts {
                msgs_seen += m;
                out.extend(part);
            }
            out
        };
        debug_assert_eq!(
            msgs_seen, charge.messages,
            "shard charge disagrees with the message stream ({label})"
        );
        let _ = msgs_seen;
        self.complete_round(
            label,
            charge.messages,
            charge.bytes,
            &charge.machine_bytes,
            Vec::new(),
            None,
        );
        out
    }

    /// Record a round whose computation happened outside the engine but
    /// whose accounting replicates exactly the round it replaces (the
    /// fused contraction phases and the graph-layer contraction rewrites
    /// in `cc::common` charge the model this way).  `machine_bytes` is
    /// per machine; `messages`/`bytes` are the round totals.  On a wire
    /// transport this is still a real barrier: every machine acknowledges
    /// the declared load before the next round starts.
    pub fn charge_round(
        &mut self,
        label: &str,
        messages: u64,
        bytes: u64,
        machine_bytes: &[u64],
    ) {
        self.complete_round(label, messages, bytes, machine_bytes, Vec::new(), None);
    }

    /// One **worker-native** hop round on a shuffle-capable transport
    /// ([`super::transport::ShuffleOps`]), or `None` when the transport
    /// has no worker data plane / the fold has no wire identity — the
    /// caller then takes the generic (coordinator-routed) wire path.
    ///
    /// The coordinator side of the round is pure control plane:
    ///
    /// 1. ensure the workers hold custody of `g` (peer-to-peer rewires
    ///    keep it current across contractions; a coordinator re-ship is
    ///    the fallback for graphs rebuilt outside the rewire protocol)
    ///    and a mirror of `vals` (hash-checked; chained hops skip the
    ///    sync because the fold all-gather keeps worker mirrors current);
    /// 2. issue the O(1) hop descriptor — workers generate the messages
    ///    from their shards, shuffle worker↔worker, and fold;
    /// 3. **while they shuffle**, compute the same fold locally (the
    ///    algorithm needs the output here anyway — this is the same
    ///    in-process fold the `inproc` engine runs) and the canonical
    ///    per-machine fold-image checksums;
    /// 4. collect the O(machines) acks and validate: receiver-observed
    ///    loads against the shard-derived charge
    ///    ([`TransportError::AccountingMismatch`]), worker fold images
    ///    against the local fold ([`TransportError::Protocol`]) — the
    ///    bit-identity guarantee, enforced every round.
    ///
    /// Transport failures unwind with the typed error like every round.
    pub fn try_shuffle_hop<V>(
        &mut self,
        label: &str,
        g: &ShardedGraph,
        vals: &[V],
        include_self: bool,
        fold: WireFold<V>,
        charge: &ShardRound,
    ) -> Option<Vec<V>>
    where
        V: WireSize + Copy,
    {
        let op = fold.wire?;
        let n = vals.len();
        if n == 0 || self.transport.shuffle().is_none() {
            return None;
        }
        let vb = op.value_bytes();
        if vals[0].wire_size() as usize != vb {
            return None; // shape mismatch: keep the per-message wire path
        }
        let p = self.cfg.machines.max(1);
        debug_assert_eq!(charge.machine_bytes.len(), p);

        // The mirror hash is computed incrementally (vb-byte tmp buffer);
        // the full O(n·vb) mirror image materializes only when a sync is
        // actually needed — on the steady-state chained-hop path (the
        // all-gather kept worker mirrors current) this is allocation-free.
        let gen = g.generation();
        let hash = {
            let mut h = Fnv1a::new();
            h.update(&[vb as u8]);
            h.update(&((n * vb) as u64).to_le_bytes());
            let mut tmp = Vec::with_capacity(vb);
            for v in vals {
                tmp.clear();
                v.encode_wire(&mut tmp);
                h.update(&tmp);
            }
            h.finish()
        };
        let spec = HopSpec {
            label,
            op,
            include_self,
        };
        let rc = RoundCharge {
            messages: charge.messages,
            bytes: charge.bytes,
            machine_bytes: &charge.machine_bytes,
        };

        // The whole round is one replayable attempt: a recoverable fault
        // anywhere (descriptor write, mid-shuffle crash, barrier read)
        // respawns the fleet and retries from the control plane — the
        // replay lazily re-establishes custody (checkpointed spill files)
        // and the mirror, exactly the lazy paths an undisturbed run uses.
        // The local fold is computed once and cached across replays (it
        // is a pure function of `g` and `vals`); the round is charged
        // once, on the attempt that completes — bit-identical metrics by
        // construction.
        let mut folded: Option<(Vec<V>, Vec<u64>, u64, Vec<u8>)> = None;
        let mut replays = 0usize;
        loop {
            // ---- control plane: custody + mirror, then the descriptor --
            let t_gen = Instant::now();
            let ctrl = {
                let sh = self.transport.shuffle().expect("checked above");
                let mut step = || -> Result<u64, TransportError> {
                    if sh.custody() != Some(gen) {
                        sh.establish_custody(g)?;
                    }
                    if sh.mirror_hash() != Some(hash) {
                        let mut data = Vec::with_capacity(n * vb);
                        for v in vals {
                            v.encode_wire(&mut data);
                        }
                        debug_assert_eq!(
                            crate::mpc::net::mirror_hash_of(vb as u8, &data),
                            hash
                        );
                        sh.sync_mirror(vb as u8, &data, hash)?;
                    }
                    sh.begin_hop(&spec, &rc)
                };
                step()
            };
            self.note_gen(t_gen);
            let seq = match ctrl {
                Ok(seq) => seq,
                Err(e) => {
                    self.recover_or_abort(label, &mut replays, e);
                    continue;
                }
            };

            // ---- the same fold, locally, while the workers shuffle -----
            if folded.is_none() {
                let t_fold = Instant::now();
                folded = Some(self.local_hop_fold(
                    label,
                    g,
                    vals,
                    include_self,
                    fold.f,
                    vb,
                    charge.messages,
                ));
                self.note_fold(t_fold);
            }
            let (_, expected, post_mirror, post_bytes) = folded.as_ref().expect("just computed");

            // ---- the barrier: O(machines) summaries, validated ---------
            let t_shuffle = Instant::now();
            let fin = {
                let sh = self.transport.shuffle().expect("checked above");
                match sh.finish_hop(seq, &spec, &rc, expected) {
                    Ok(()) => {
                        // pin the post-hop mirror bytes: the retained
                        // image is the delta base of the next sync
                        sh.set_mirror(vb as u8, post_bytes, *post_mirror);
                        Ok(())
                    }
                    Err(e) => Err(e),
                }
            };
            match fin {
                Ok(()) => {
                    self.finish_round(
                        label,
                        charge.messages,
                        charge.bytes,
                        &charge.machine_bytes,
                    );
                    let (allocs, shard_bytes_mapped, shard_bytes_copied) =
                        self.data_plane_delta();
                    self.metrics.timings.push(RoundTiming {
                        label: label.to_string(),
                        gen_ms: std::mem::take(&mut self.pending_gen_ms),
                        shuffle_ms: t_shuffle.elapsed().as_secs_f64() * 1e3,
                        fold_ms: std::mem::take(&mut self.pending_fold_ms),
                        allocs,
                        shard_bytes_mapped,
                        shard_bytes_copied,
                    });
                    let (out, _, _, _) = folded.expect("just computed");
                    return Some(out);
                }
                Err(e) => {
                    self.recover_or_abort(label, &mut replays, e);
                    continue;
                }
            }
        }
    }

    /// The in-process fold of one hop round — the computation
    /// [`Self::try_shuffle_hop`] runs locally while the workers
    /// shuffle.  Returns the fold output, the canonical per-machine
    /// fold-image hashes (ascending keys — exactly the worker
    /// encoding), the post-hop mirror hash, and the post-hop mirror
    /// image bytes (retained as the delta base of the next sync).
    fn local_hop_fold<V>(
        &mut self,
        label: &str,
        g: &ShardedGraph,
        vals: &[V],
        include_self: bool,
        opf: fn(V, V) -> V,
        vb: usize,
        expect_messages: u64,
    ) -> (Vec<V>, Vec<u64>, u64, Vec<u8>)
    where
        V: WireSize + Copy,
    {
        let n = vals.len();
        let p = self.cfg.machines.max(1);
        let mut out: Vec<V> = vals.to_vec();
        let words = n.div_ceil(64);
        let mut touched = self.take_touched(words);
        let mut msgs_seen = 0u64;
        {
            let mut fold_in = |k: Vertex, value: V| {
                let k = k as usize;
                out[k] = if (touched[k / 64] >> (k % 64)) & 1 == 1 {
                    opf(out[k], value)
                } else {
                    value
                };
                touched[k / 64] |= 1u64 << (k % 64);
                msgs_seen += 1;
            };
            for s in 0..p {
                let shard = g.shard_data(s);
                for (u, v) in shard.iter() {
                    fold_in(u, vals[v as usize]);
                    fold_in(v, vals[u as usize]);
                }
                if include_self {
                    let (sa, sb) = pool::chunk_range(n, p, s);
                    for v in sa..sb {
                        fold_in(v as Vertex, vals[v]);
                    }
                }
            }
        }
        debug_assert_eq!(
            msgs_seen, expect_messages,
            "shard charge disagrees with the message stream ({label})"
        );
        let _ = msgs_seen;

        // canonical per-machine fold images (ascending keys — exactly
        // the worker encoding) hashed incrementally, plus the post-hop
        // mirror hash + image, in one pass
        let mut fold_hash: Vec<Fnv1a> = (0..p).map(|_| Fnv1a::new()).collect();
        let mut mirror_h = Fnv1a::new();
        mirror_h.update(&[vb as u8]);
        mirror_h.update(&((n * vb) as u64).to_le_bytes());
        let mut image = Vec::with_capacity(n * vb);
        let mut tmp = Vec::with_capacity(vb);
        for (k, v) in out.iter().enumerate() {
            tmp.clear();
            v.encode_wire(&mut tmp);
            mirror_h.update(&tmp);
            image.extend_from_slice(&tmp);
            if (touched[k / 64] >> (k % 64)) & 1 == 1 {
                let h = &mut fold_hash[machine_of(k as u64, p)];
                h.update(&(k as u64).to_le_bytes());
                h.update(&tmp);
            }
        }
        self.put_touched(touched);
        let expected: Vec<u64> = fold_hash.into_iter().map(Fnv1a::finish).collect();
        (out, expected, mirror_h.finish(), image)
    }

    /// One **worker-native pipelined batch** of consecutive hop rounds
    /// ([`RoundPlan`]) on a shuffle-capable transport, or `None` when
    /// the transport has no worker data plane / the fold has no wire
    /// identity — the caller then runs the rounds one at a time.
    ///
    /// The coordinator ships the whole plan as ONE descriptor batch and
    /// reads ONE barrier of O(machines) acks; workers run each round's
    /// generate→shuffle→fold back-to-back without re-synchronizing with
    /// the coordinator in between.  The coordinator computes the same
    /// chained folds locally (round `k+1` folds round `k`'s output) and
    /// validates every round's per-machine fold images from the batch
    /// ack — bit-identity enforced per round, exactly like the
    /// unpipelined path.  A fault anywhere replays the WHOLE batch on a
    /// recovered fleet, and the rounds are charged once, on the attempt
    /// that completes, so `Metrics` stay engine-invariant.
    pub fn try_shuffle_hop_plan<V>(
        &mut self,
        plan: RoundPlan<'_>,
        g: &ShardedGraph,
        vals: &[V],
        fold: WireFold<V>,
        charge: &ShardRound,
    ) -> Option<Vec<V>>
    where
        V: WireSize + Copy,
    {
        let rounds = plan.labels.len();
        if rounds == 0 {
            return None;
        }
        if rounds == 1 {
            // a one-round plan IS the unpipelined round
            return self.try_shuffle_hop(plan.labels[0], g, vals, plan.include_self, fold, charge);
        }
        let op = fold.wire?;
        let n = vals.len();
        if n == 0 || self.transport.shuffle().is_none() {
            return None;
        }
        let vb = op.value_bytes();
        if vals[0].wire_size() as usize != vb {
            return None; // shape mismatch: keep the per-message wire path
        }
        let p = self.cfg.machines.max(1);
        debug_assert_eq!(charge.machine_bytes.len(), p);

        let gen = g.generation();
        let hash = {
            let mut h = Fnv1a::new();
            h.update(&[vb as u8]);
            h.update(&((n * vb) as u64).to_le_bytes());
            let mut tmp = Vec::with_capacity(vb);
            for v in vals {
                tmp.clear();
                v.encode_wire(&mut tmp);
                h.update(&tmp);
            }
            h.finish()
        };
        let specs: Vec<HopSpec<'_>> = plan
            .labels
            .iter()
            .map(|&label| HopSpec {
                label,
                op,
                include_self: plan.include_self,
            })
            .collect();
        let rc = RoundCharge {
            messages: charge.messages,
            bytes: charge.bytes,
            machine_bytes: &charge.machine_bytes,
        };

        let mut folded: Option<(Vec<V>, Vec<Vec<u64>>, u64, Vec<u8>)> = None;
        let mut replays = 0usize;
        loop {
            // ---- control plane: custody + mirror + ONE batch descriptor
            let t_gen = Instant::now();
            let ctrl = {
                let sh = self.transport.shuffle().expect("checked above");
                let mut step = || -> Result<u64, TransportError> {
                    if sh.custody() != Some(gen) {
                        sh.establish_custody(g)?;
                    }
                    if sh.mirror_hash() != Some(hash) {
                        let mut data = Vec::with_capacity(n * vb);
                        for v in vals {
                            v.encode_wire(&mut data);
                        }
                        debug_assert_eq!(
                            crate::mpc::net::mirror_hash_of(vb as u8, &data),
                            hash
                        );
                        sh.sync_mirror(vb as u8, &data, hash)?;
                    }
                    sh.begin_hop_batch(&specs, &rc)
                };
                step()
            };
            self.note_gen(t_gen);
            let base = match ctrl {
                Ok(base) => base,
                Err(e) => {
                    self.recover_or_abort(plan.labels[0], &mut replays, e);
                    continue;
                }
            };

            // ---- the same chained folds, locally, while they pipeline --
            if folded.is_none() {
                let t_fold = Instant::now();
                let mut cur: Vec<V> = vals.to_vec();
                let mut expected_all: Vec<Vec<u64>> = Vec::with_capacity(rounds);
                let mut post = (0u64, Vec::new());
                for &label in plan.labels {
                    let (out, expected, post_hash, post_image) = self.local_hop_fold(
                        label,
                        g,
                        &cur,
                        plan.include_self,
                        fold.f,
                        vb,
                        charge.messages,
                    );
                    cur = out;
                    expected_all.push(expected);
                    post = (post_hash, post_image);
                }
                self.note_fold(t_fold);
                folded = Some((cur, expected_all, post.0, post.1));
            }
            let (_, expected_all, post_mirror, post_bytes) =
                folded.as_ref().expect("just computed");

            // ---- ONE barrier for the whole batch, validated per round --
            let t_shuffle = Instant::now();
            let fin = {
                let sh = self.transport.shuffle().expect("checked above");
                match sh.finish_hop_batch(base, &specs, &rc, expected_all) {
                    Ok(()) => {
                        sh.set_mirror(vb as u8, post_bytes, *post_mirror);
                        Ok(())
                    }
                    Err(e) => Err(e),
                }
            };
            match fin {
                Ok(()) => {
                    let shuffle_ms = t_shuffle.elapsed().as_secs_f64() * 1e3;
                    for (k, &label) in plan.labels.iter().enumerate() {
                        // every round of the batch is charged
                        // individually — `Metrics` can't tell a batch
                        // from the same rounds run one at a time
                        self.finish_round(
                            label,
                            charge.messages,
                            charge.bytes,
                            &charge.machine_bytes,
                        );
                        let (allocs, shard_bytes_mapped, shard_bytes_copied) =
                            self.data_plane_delta();
                        self.metrics.timings.push(RoundTiming {
                            label: label.to_string(),
                            // the batch's one-off wall costs land on its
                            // first round; the later rounds rode along
                            gen_ms: if k == 0 {
                                std::mem::take(&mut self.pending_gen_ms)
                            } else {
                                0.0
                            },
                            shuffle_ms: if k == 0 { shuffle_ms } else { 0.0 },
                            fold_ms: if k == 0 {
                                std::mem::take(&mut self.pending_fold_ms)
                            } else {
                                0.0
                            },
                            allocs,
                            shard_bytes_mapped,
                            shard_bytes_copied,
                        });
                    }
                    let (out, _, _, _) = folded.expect("just computed");
                    return Some(out);
                }
                Err(e) => {
                    self.recover_or_abort(plan.labels[0], &mut replays, e);
                    continue;
                }
            }
        }
    }

    /// One **worker-native** hub rewire (Cracker's
    /// `{(m(v), u) : u ∈ N(v) ∪ {v}}` — see `cc::cracker::rewire`) on a
    /// shuffle transport, or `None` — the caller then takes the
    /// coordinator-routed `round_map` path, which charges identically.
    ///
    /// The coordinator computes the same next generation locally (the
    /// algorithm needs it here anyway) together with the exact
    /// per-message accounting of the hub-keyed round, then ships the
    /// O(1) `GatherRewire` descriptor — the new vertex count plus the
    /// [`WireOp::GatherPairU32`] reduce program, wire-shipped like a
    /// fold op — and validates the shard-by-shard stats + checksums the
    /// workers ack against the local build: the adopted custody is
    /// bit-identical to `from_edges_like` by construction, and the
    /// O(m) hub pairs never touch a coordinator link.
    pub fn try_shuffle_gather_rewire(
        &mut self,
        label: &str,
        g: &ShardedGraph,
        m: &[Vertex],
    ) -> Option<ShardedGraph> {
        let n = g.num_vertices();
        if n == 0 || m.len() != n || self.transport.shuffle().is_none() {
            return None;
        }
        let p = self.cfg.machines.max(1);
        let gen = g.generation();

        let mut built: Option<(ShardedGraph, u64, u64, Vec<u64>)> = None;
        let mut replays = 0usize;
        loop {
            // ---- control plane: custody (lazy re-ship after recovery) --
            let ctrl = {
                let sh = self.transport.shuffle().expect("checked above");
                if sh.custody() != Some(gen) {
                    sh.establish_custody(g)
                } else {
                    Ok(())
                }
            };
            if let Err(e) = ctrl {
                self.recover_or_abort(label, &mut replays, e);
                continue;
            }

            // ---- the same round, locally: edges + exact accounting -----
            // Replicates `round_map` over `cc::cracker::rewire`'s chunk
            // stream message for message: per edge the two hub pairs,
            // per primary-chunk vertex the self pair, each 16 wire
            // bytes charged to the machine owning its hub key.
            if built.is_none() {
                let t_gen = Instant::now();
                let mut machine_bytes = vec![0u64; p];
                let mut messages = 0u64;
                let mut edges: Vec<(Vertex, Vertex)> = Vec::new();
                {
                    let mut push = |key: u64, pair: (Vertex, Vertex)| {
                        machine_bytes[machine_of(key, p)] += 16;
                        messages += 1;
                        edges.push(pair);
                    };
                    for s in 0..p {
                        let shard = g.shard_data(s);
                        for (u, v) in shard.iter() {
                            let (mu, mv) = (m[u as usize], m[v as usize]);
                            push(mu as u64, (mu, v));
                            push(mv as u64, (mv, u));
                        }
                        let (sa, sb) = pool::chunk_range(n, p, s);
                        for v in sa..sb {
                            push(m[v] as u64, (m[v], v as Vertex));
                        }
                    }
                }
                let bytes = messages * 16;
                let new = g.from_edges_like(edges);
                self.note_gen(t_gen);
                built = Some((new, messages, bytes, machine_bytes));
            }
            let (new, messages, bytes, machine_bytes) =
                built.as_ref().expect("just computed");

            // ---- ship the descriptor; workers gather + adopt peer-to-peer
            let t_shuffle = Instant::now();
            let res = {
                let sh = self.transport.shuffle().expect("checked above");
                sh.gather_rewire(m, new)
            };
            match res {
                Ok(()) => {
                    self.finish_round(label, *messages, *bytes, machine_bytes);
                    let (allocs, shard_bytes_mapped, shard_bytes_copied) =
                        self.data_plane_delta();
                    self.metrics.timings.push(RoundTiming {
                        label: label.to_string(),
                        gen_ms: std::mem::take(&mut self.pending_gen_ms),
                        shuffle_ms: t_shuffle.elapsed().as_secs_f64() * 1e3,
                        fold_ms: std::mem::take(&mut self.pending_fold_ms),
                        allocs,
                        shard_bytes_mapped,
                        shard_bytes_copied,
                    });
                    let (new, _, _, _) = built.expect("just computed");
                    return Some(new);
                }
                Err(e) => {
                    self.recover_or_abort(label, &mut replays, e);
                    continue;
                }
            }
        }
    }

    /// Mesh data-plane counters of a shuffle transport, `None` on the
    /// others: the per-run evidence that delta sync and pipelining
    /// moved fewer bytes.  Observability only — never part of the
    /// bit-identity surface the equivalence tests compare.
    pub fn mesh_metrics(&self) -> Option<crate::mpc::metrics::MeshMetrics> {
        self.transport.mesh_stats()
    }

    /// How many times one round may replay through recovery before the
    /// run aborts with [`TransportError::RecoveryExhausted`] — the
    /// backstop that turns "the same round keeps dying on every fresh
    /// fleet" (a genuine bug, not a transient fault) into a typed error
    /// instead of an unbounded respawn loop.
    const MAX_ROUND_REPLAYS: usize = 5;

    /// Heal a recoverable transport fault in place — respawn the worker
    /// fleet ([`super::transport::ShuffleOps::recover`]), record the
    /// [`RecoveryEvent`], count the replay — or unwind with the typed
    /// error: correctness faults (checksum/accounting/protocol
    /// divergence) abort immediately, as does an exhausted or disabled
    /// respawn budget and a round replayed past
    /// [`Self::MAX_ROUND_REPLAYS`].
    fn recover_or_abort(&mut self, label: &str, replays: &mut usize, e: TransportError) {
        if !e.recoverable() {
            std::panic::panic_any(e);
        }
        if *replays >= Self::MAX_ROUND_REPLAYS {
            std::panic::panic_any(TransportError::RecoveryExhausted {
                attempts: *replays,
                detail: format!(
                    "round {label:?} failed on {} consecutive fleets; last fault: {e}",
                    *replays + 1
                ),
            });
        }
        let Some(sh) = self.transport.shuffle() else {
            std::panic::panic_any(e);
        };
        match sh.recover(&e) {
            Ok(info) => {
                *replays += 1;
                self.metrics.recovery.replayed_rounds += 1;
                self.metrics.recovery.record(crate::mpc::metrics::RecoveryEvent {
                    label: label.to_string(),
                    worker: e.worker(),
                    cause: e.to_string(),
                    respawn_attempts: info.respawn_attempts as u64,
                    wall_ms: info.wall_ms,
                });
            }
            Err(re) => std::panic::panic_any(re),
        }
    }

    /// Custody handoff after a graph rewrite (contraction, prune): on a
    /// shuffle transport whose workers hold `old`, broadcast the rewrite
    /// `map` (`u32::MAX` = dropped vertex) and have the workers relabel
    /// their own edges and re-ship them **peer to peer** into the next
    /// generation, validated shard-by-shard against `new` (the
    /// coordinator's locally-computed generation — stats + payload
    /// checksum).  A no-op on other transports, and when the workers hold
    /// some other generation (custody then re-establishes lazily at the
    /// next descriptor round).  The model rounds this realizes are
    /// charged by the caller ([`Simulator::charge_round`]); failures
    /// unwind typed.
    /// Does the transport's worker fleet currently hold custody of `g`?
    /// `false` on non-shuffle transports.  Callers that must *build* a
    /// rewrite map for [`shuffle_rewire`](Self::shuffle_rewire) check
    /// this first so the in-process and proc paths never pay the O(n)
    /// map materialization.
    pub fn has_shuffle_custody(&mut self, g: &ShardedGraph) -> bool {
        let gen = g.generation();
        self.transport
            .shuffle()
            .map(|sh| sh.custody() == Some(gen))
            .unwrap_or(false)
    }

    pub fn shuffle_rewire(&mut self, old: &ShardedGraph, map: &[Vertex], new: &ShardedGraph) {
        let old_gen = old.generation();
        let mut replays = 0usize;
        loop {
            let res = {
                let Some(sh) = self.transport.shuffle() else {
                    return;
                };
                if sh.custody() != Some(old_gen) {
                    // No old-generation custody to relabel — also the
                    // post-recovery state: a respawned fleet re-ships the
                    // *new* generation lazily at its next descriptor
                    // round (from the checkpointed custody files), so
                    // there is nothing left to rewire peer-to-peer.
                    return;
                }
                sh.rewire(map, new)
            };
            match res {
                Ok(()) => return,
                Err(e) => self.recover_or_abort("rewire", &mut replays, e),
            }
        }
    }

    /// Every round ends here: run the exchange on the transport (payload
    /// bytes move and the barrier blocks on a wire backend; pure
    /// accounting in-process), validate the receiver-observed loads
    /// against the model charge, record the metrics.  Transport failures
    /// abort the run by unwinding with the typed [`TransportError`] as
    /// payload — the algorithms' round signatures stay `Result`-free, and
    /// `Driver::try_*` catches and surfaces the error.
    fn complete_round(
        &mut self,
        label: &str,
        messages: u64,
        bytes: u64,
        machine_bytes: &[u64],
        payloads: Vec<Vec<u8>>,
        fold: Option<WireOp>,
    ) -> Option<Vec<Vec<u8>>> {
        let t0 = Instant::now();
        let virtual_round = payloads.is_empty();
        let mut payloads = Some(payloads);
        let mut replays = 0usize;
        let ack = loop {
            let round_payloads = payloads.take().unwrap_or_default();
            match self.transport.exchange(
                label,
                RoundCharge {
                    messages,
                    bytes,
                    machine_bytes,
                },
                round_payloads,
                fold,
            ) {
                Ok(ack) => break ack,
                // Only charge-only barriers replay: their (empty) payload
                // is still intact after a failed attempt, so a recovered
                // fleet re-acks the declared load bit-identically.  A
                // payload round's buffers were consumed by the send —
                // those propagate (the shuffle data plane, where chaos
                // faults land, never routes payloads through here).
                Err(e) if virtual_round => {
                    self.recover_or_abort(label, &mut replays, e);
                }
                Err(e) => std::panic::panic_any(e),
            }
        };
        let (allocs, shard_bytes_mapped, shard_bytes_copied) = self.data_plane_delta();
        self.metrics.timings.push(RoundTiming {
            label: label.to_string(),
            gen_ms: std::mem::take(&mut self.pending_gen_ms),
            shuffle_ms: t0.elapsed().as_secs_f64() * 1e3,
            fold_ms: std::mem::take(&mut self.pending_fold_ms),
            allocs,
            shard_bytes_mapped,
            shard_bytes_copied,
        });
        if ack.machine_bytes.len() != machine_bytes.len() {
            std::panic::panic_any(TransportError::Protocol {
                worker: None,
                detail: format!(
                    "round {label:?}: transport acked {} machines, charge has {}",
                    ack.machine_bytes.len(),
                    machine_bytes.len()
                ),
            });
        }
        for (machine, (&expected, &actual)) in
            machine_bytes.iter().zip(&ack.machine_bytes).enumerate()
        {
            if expected != actual {
                std::panic::panic_any(TransportError::AccountingMismatch {
                    label: label.to_string(),
                    machine,
                    expected,
                    actual,
                });
            }
        }
        self.finish_round(label, messages, bytes, machine_bytes);
        ack.folded
    }

    fn finish_round(&mut self, label: &str, messages: u64, bytes: u64, machine_bytes: &[u64]) {
        let max_machine_bytes = machine_bytes.iter().copied().max().unwrap_or(0);
        let space_violation = self
            .cfg
            .space_per_machine
            .map(|cap| max_machine_bytes > cap)
            .unwrap_or(false);
        self.metrics.record(RoundMetrics {
            label: label.to_string(),
            messages,
            bytes,
            max_machine_bytes,
            space_violation,
            ..Default::default()
        });
    }

    /// Record DHT traffic against the most recent round (the DHT serves
    /// queries "in the following round", §2.1).
    pub fn charge_dht(&mut self, reads: u64, writes: u64) {
        if let Some(last) = self.metrics.rounds.last_mut() {
            last.dht_reads += reads;
            last.dht_writes += writes;
        } else {
            self.metrics.record(RoundMetrics {
                label: "dht".into(),
                dht_reads: reads,
                dht_writes: writes,
                ..Default::default()
            });
        }
    }
}

/// A plan of consecutive hop rounds with no intervening coordinator
/// data dependency: every round folds the previous round's output over
/// the same graph with the same wire fold (the fused two-hop of
/// `cc::common` is the canonical instance).  On a shuffle transport
/// [`Simulator::try_shuffle_hop_plan`] ships the plan as one
/// `HopBatch` descriptor and the workers pipeline the rounds
/// back-to-back, acking once per batch — per-round metrics are still
/// charged individually, so `Metrics` stay engine-invariant.
#[derive(Debug, Clone, Copy)]
pub struct RoundPlan<'a> {
    /// One label per round, in execution order.
    pub labels: &'a [&'a str],
    /// Whether each vertex's own value rides along (applied to every
    /// round of the plan).
    pub include_self: bool,
}

/// Serialize already-partitioned per-machine buckets into their wire
/// images: 8-byte key + [`WireSize`] value per message, concatenated in
/// bucket order (deterministic).
fn encode_buckets<V: WireSize>(per_machine: &[Vec<(u64, V)>]) -> Vec<Vec<u8>> {
    per_machine
        .iter()
        .map(|msgs| {
            let mut buf = Vec::new();
            for (key, value) in msgs {
                buf.extend_from_slice(&key.to_le_bytes());
                value.encode_wire(&mut buf);
            }
            buf
        })
        .collect()
}

/// Merge remotely-folded `(key, value)` pairs into `out`.  Each key is
/// owned by exactly one machine and appears at most once per blob, so
/// plain replacement is the fold's result; keys the remote side never saw
/// keep their prior value.  Malformed blobs are a typed protocol error
/// (unwound like every transport failure).
fn apply_folded<V: WireSize + Copy>(out: &mut [V], blobs: Vec<Vec<u8>>) {
    let malformed = |detail: String| -> ! {
        std::panic::panic_any(TransportError::Protocol {
            worker: None,
            detail,
        })
    };
    for blob in blobs {
        let mut off = 0usize;
        while off < blob.len() {
            let Some(key_bytes) = blob.get(off..off + 8) else {
                malformed("fold result truncated inside a key".into());
            };
            let key = u64::from_le_bytes(key_bytes.try_into().unwrap());
            let Some((value, used)) = V::decode_wire(&blob[off + 8..]) else {
                malformed(format!("fold result truncated inside value of key {key}"));
            };
            off += 8 + used;
            match out.get_mut(key as usize) {
                Some(slot) => *slot = value,
                None => malformed(format!(
                    "fold result key {key} outside the output range {}",
                    out.len()
                )),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(machines: usize) -> Simulator {
        Simulator::new(MpcConfig {
            machines,
            space_per_machine: None,
            spill_budget: None,
            threads: 2,
        })
    }

    #[test]
    fn round_groups_by_key() {
        let mut s = sim(4);
        let msgs: Vec<(u64, u32)> = vec![(1, 10), (2, 20), (1, 11), (3, 30), (2, 21)];
        let mut out = s.round("test", msgs, |key, vals| {
            vals.sort_unstable();
            vec![(key, vals.clone())]
        });
        out.sort_by_key(|(k, _)| *k);
        assert_eq!(
            out,
            vec![(1, vec![10, 11]), (2, vec![20, 21]), (3, vec![30])]
        );
    }

    #[test]
    fn metrics_count_bytes_and_messages() {
        let mut s = sim(4);
        let msgs: Vec<(u64, u32)> = (0..10).map(|i| (i, i as u32)).collect();
        let _: Vec<()> = s.round("count", msgs, |_, _| vec![]);
        let r = &s.metrics.rounds[0];
        assert_eq!(r.messages, 10);
        assert_eq!(r.bytes, 10 * 12); // 8 key + 4 value
        assert!(r.max_machine_bytes <= r.bytes);
        assert!(r.max_machine_bytes >= r.bytes / 4);
    }

    #[test]
    fn space_violation_flagged() {
        let mut s = Simulator::new(MpcConfig {
            machines: 1,
            space_per_machine: Some(10),
            spill_budget: None,
            threads: 1,
        });
        let _: Vec<()> = s.round("big", vec![(0u64, 1u32), (1, 2)], |_, _| vec![]);
        assert!(s.metrics.rounds[0].space_violation);
        assert!(s.metrics.any_space_violation());
    }

    #[test]
    fn deterministic_output_order() {
        let run = || {
            let mut s = sim(8);
            let msgs: Vec<(u64, u32)> = (0..100).map(|i| (i * 7 % 13, i as u32)).collect();
            s.round("det", msgs, |k, vals| vec![(k, vals.len())])
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn parallel_matches_serial() {
        let exec = |threads: usize| {
            let mut s = Simulator::new(MpcConfig {
                machines: 8,
                space_per_machine: None,
                spill_budget: None,
                threads,
            });
            let msgs: Vec<(u64, u32)> = (0..1000).map(|i| (i % 37, i as u32)).collect();
            let mut out = s.round("p", msgs, |k, vals| vec![(k, vals.iter().sum::<u32>())]);
            out.sort_unstable();
            (out, s.metrics.rounds[0].clone())
        };
        assert_eq!(exec(1), exec(4));
    }

    /// A deterministic message mix with repeated keys, a hot key, and an
    /// untouched tail of the key space.
    fn fold_messages(n_msgs: usize, key_space: u64) -> Vec<(u64, u32)> {
        (0..n_msgs)
            .map(|i| {
                let key = if i % 7 == 0 {
                    3 // hot key
                } else {
                    (i as u64 * 2654435761) % key_space
                };
                (key, (i as u32).wrapping_mul(2246822519))
            })
            .collect()
    }

    fn chunked<T: Copy>(msgs: &[T], chunks: usize) -> Vec<std::vec::IntoIter<T>> {
        (0..chunks)
            .map(|i| {
                let (a, b) = crate::mpc::pool::chunk_range(msgs.len(), chunks, i);
                msgs[a..b].to_vec().into_iter()
            })
            .collect()
    }

    #[test]
    fn fold_chunked_matches_serial_across_threads() {
        let msgs = fold_messages(10_000, 512);
        let exec = |threads: usize| {
            let mut s = Simulator::new(MpcConfig {
                machines: 16,
                space_per_machine: Some(20_000),
                spill_budget: None,
                threads,
            });
            let mut out: Vec<u32> = (0..600u32).collect();
            s.round_fold_chunked(
                "fold",
                &mut out,
                chunked(&msgs, threads.max(1)),
                u32::min,
            );
            (out, s.metrics.rounds[0].clone())
        };
        let base = exec(1);
        for threads in [4, 8] {
            assert_eq!(exec(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn fold_chunked_matches_single_iterator_fold() {
        let msgs = fold_messages(5_000, 300);
        let mut serial = Simulator::new(MpcConfig {
            machines: 8,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        });
        let mut out_serial: Vec<u32> = vec![u32::MAX; 400];
        serial.round_fold("fold", &mut out_serial, msgs.iter().copied(), u32::min);

        let mut par = Simulator::new(MpcConfig {
            machines: 8,
            space_per_machine: None,
            spill_budget: None,
            threads: 8,
        });
        let mut out_par: Vec<u32> = vec![u32::MAX; 400];
        par.round_fold_chunked("fold", &mut out_par, chunked(&msgs, 8), u32::min);

        assert_eq!(out_serial, out_par);
        assert_eq!(serial.metrics.rounds[0], par.metrics.rounds[0]);
    }

    #[test]
    fn map_chunked_matches_serial_across_threads() {
        let msgs = fold_messages(10_000, 1 << 20);
        let exec = |threads: usize| {
            let mut s = Simulator::new(MpcConfig {
                machines: 16,
                space_per_machine: Some(15_000),
                spill_budget: None,
                threads,
            });
            let out: Vec<(u64, u32)> = s.round_map_chunked(
                "map",
                chunked(&msgs, threads.max(1)),
                |k, v| (k ^ 0xABCD, v.rotate_left(5)),
            );
            (out, s.metrics.rounds[0].clone())
        };
        let base = exec(1);
        for threads in [4, 8] {
            assert_eq!(exec(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn map_chunked_matches_single_iterator_map() {
        let msgs = fold_messages(3_000, 1 << 16);
        let mut serial = Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        });
        let out_serial: Vec<u32> = serial.round_map("map", msgs.iter().copied(), |_, v| v + 1);

        let mut par = Simulator::new(MpcConfig {
            machines: 4,
            space_per_machine: None,
            spill_budget: None,
            threads: 4,
        });
        let out_par: Vec<u32> = par.round_map_chunked("map", chunked(&msgs, 4), |_, v| v + 1);

        assert_eq!(out_serial, out_par);
        assert_eq!(serial.metrics.rounds[0], par.metrics.rounds[0]);
    }

    #[test]
    fn fold_chunked_empty_out_and_chunks() {
        let mut s = sim(4);
        let mut out: Vec<u32> = Vec::new();
        let chunks: Vec<std::vec::IntoIter<(u64, u32)>> =
            vec![Vec::new().into_iter(), Vec::new().into_iter()];
        s.round_fold_chunked("empty", &mut out, chunks, u32::min);
        let r = &s.metrics.rounds[0];
        assert_eq!((r.messages, r.bytes, r.max_machine_bytes), (0, 0, 0));
    }

    /// Brute-force a `ShardRound` from a message list (the per-message
    /// accounting the sharded paths are allowed to skip).
    fn brute_charge(msgs: &[(u64, u32)], p: usize) -> ShardRound {
        let mut machine_bytes = vec![0u64; p];
        let mut bytes = 0;
        for &(key, value) in msgs {
            let sz = 8 + crate::mpc::WireSize::wire_size(&value);
            bytes += sz;
            machine_bytes[machine_of(key, p)] += sz;
        }
        ShardRound {
            messages: msgs.len() as u64,
            bytes,
            machine_bytes,
        }
    }

    #[test]
    fn fold_sharded_matches_round_fold_reference() {
        let msgs = fold_messages(8_000, 512);
        let p = 8;
        let mut reference = Simulator::new(MpcConfig {
            machines: p,
            space_per_machine: Some(25_000),
            spill_budget: None,
            threads: 1,
        });
        let mut out_ref: Vec<u32> = (0..600u32).collect();
        reference.round_fold("fold", &mut out_ref, msgs.iter().copied(), u32::min);

        for threads in [1usize, 4, 8] {
            let mut s = Simulator::new(MpcConfig {
                machines: p,
                space_per_machine: Some(25_000),
                spill_budget: None,
                threads,
            });
            let mut out: Vec<u32> = (0..600u32).collect();
            s.round_fold_sharded(
                "fold",
                &mut out,
                chunked(&msgs, p),
                brute_charge(&msgs, p),
                u32::min,
            );
            assert_eq!(out, out_ref, "threads={threads}");
            assert_eq!(s.metrics.rounds[0], reference.metrics.rounds[0], "threads={threads}");
        }
    }

    #[test]
    fn map_sharded_matches_round_map_reference() {
        let msgs = fold_messages(6_000, 1 << 18);
        let p = 4;
        let mut reference = Simulator::new(MpcConfig {
            machines: p,
            space_per_machine: None,
            spill_budget: None,
            threads: 1,
        });
        let out_ref: Vec<u64> =
            reference.round_map("map", msgs.iter().copied(), |k, v| k ^ v as u64);

        for threads in [1usize, 4, 8] {
            let mut s = Simulator::new(MpcConfig {
                machines: p,
                space_per_machine: None,
                spill_budget: None,
                threads,
            });
            let out: Vec<u64> = s.round_map_sharded(
                "map",
                chunked(&msgs, p),
                brute_charge(&msgs, p),
                |k, v| k ^ v as u64,
            );
            assert_eq!(out, out_ref, "threads={threads}");
            assert_eq!(s.metrics.rounds[0], reference.metrics.rounds[0], "threads={threads}");
        }
    }

    #[test]
    fn sharded_rounds_handle_empty_streams() {
        let mut s = sim(4);
        let mut out: Vec<u32> = vec![7; 10];
        let charge = ShardRound {
            messages: 0,
            bytes: 0,
            machine_bytes: vec![0; 4],
        };
        let chunks: Vec<std::vec::IntoIter<(u64, u32)>> =
            (0..4).map(|_| Vec::new().into_iter()).collect();
        s.round_fold_sharded("empty", &mut out, chunks, charge, u32::min);
        assert_eq!(out, vec![7; 10]);
        let r = &s.metrics.rounds[0];
        assert_eq!((r.messages, r.bytes, r.max_machine_bytes), (0, 0, 0));
    }

    #[test]
    fn single_key_goes_to_one_machine() {
        let mut s = sim(16);
        let msgs: Vec<(u64, u32)> = (0..50).map(|_| (42u64, 1u32)).collect();
        let _: Vec<()> = s.round("hot", msgs, |_, _| vec![]);
        let r = &s.metrics.rounds[0];
        assert_eq!(r.max_machine_bytes, r.bytes, "hot key concentrates load");
    }

    #[test]
    fn charge_dht_attaches_to_last_round() {
        let mut s = sim(2);
        let _: Vec<()> = s.round("r", vec![(0u64, 0u32)], |_, _| vec![]);
        s.charge_dht(5, 3);
        assert_eq!(s.metrics.rounds[0].dht_reads, 5);
        assert_eq!(s.metrics.rounds[0].dht_writes, 3);
    }

    /// A wire transport without processes: counts the payload bytes it
    /// "received" and folds tagged rounds with the shared worker fold —
    /// the simulator's wire paths exercised without sockets.
    #[derive(Debug, Default)]
    struct LoopbackWire;

    impl crate::mpc::transport::Exchange for LoopbackWire {
        fn name(&self) -> &'static str {
            "loopback"
        }
        fn wants_wire(&self) -> bool {
            true
        }
        fn exchange(
            &mut self,
            _label: &str,
            charge: crate::mpc::transport::RoundCharge<'_>,
            payloads: Vec<Vec<u8>>,
            fold: Option<crate::mpc::transport::WireOp>,
        ) -> Result<crate::mpc::transport::ExchangeAck, crate::mpc::transport::TransportError>
        {
            let machine_bytes: Vec<u64> = if payloads.is_empty() {
                charge.machine_bytes.to_vec() // charge-only barrier
            } else {
                payloads.iter().map(|p| p.len() as u64).collect()
            };
            let folded = match fold {
                None => None,
                Some(op) => Some(
                    payloads
                        .iter()
                        .map(|p| crate::mpc::net::fold_wire_payload(op, p).unwrap())
                        .collect(),
                ),
            };
            Ok(crate::mpc::transport::ExchangeAck {
                machine_bytes,
                folded,
            })
        }
    }

    fn wire_sim(machines: usize) -> Simulator {
        Simulator::with_transport(
            MpcConfig {
                machines,
                space_per_machine: None,
                spill_budget: None,
                threads: 2,
            },
            Box::new(LoopbackWire),
        )
    }

    #[test]
    fn wire_fold_remote_matches_inproc() {
        let msgs = fold_messages(4_000, 300);
        let mut local = sim(8);
        let mut out_local: Vec<u32> = (0..400u32).collect();
        local.round_fold("fold", &mut out_local, msgs.iter().copied(), u32::min);

        let mut wire = wire_sim(8);
        let mut out_wire: Vec<u32> = (0..400u32).collect();
        wire.round_fold_tagged(
            "fold",
            &mut out_wire,
            msgs.iter().copied(),
            WireFold::min_u32(),
        );
        assert_eq!(out_wire, out_local, "remote fold diverges");
        assert_eq!(wire.metrics.rounds[0], local.metrics.rounds[0]);

        // untagged on the wire: local fold + shipped accounting
        let mut wire2 = wire_sim(8);
        let mut out_wire2: Vec<u32> = (0..400u32).collect();
        wire2.round_fold("fold", &mut out_wire2, msgs.iter().copied(), u32::min);
        assert_eq!(out_wire2, out_local);
        assert_eq!(wire2.metrics.rounds[0], local.metrics.rounds[0]);
    }

    #[test]
    fn wire_grouped_round_matches_inproc() {
        let msgs: Vec<(u64, u32)> = (0..500).map(|i| (i % 37, i as u32)).collect();
        let reduce = |k: u64, vals: &mut Vec<u32>| vec![(k, vals.iter().sum::<u32>())];
        let mut local = sim(8);
        let out_local = local.round("g", msgs.clone(), reduce);
        let mut wire = wire_sim(8);
        let out_wire = wire.round("g", msgs, reduce);
        assert_eq!(out_wire, out_local);
        assert_eq!(wire.metrics.rounds[0], local.metrics.rounds[0]);
    }

    #[test]
    fn wire_sharded_paths_match_reference() {
        let msgs = fold_messages(6_000, 512);
        let p = 4;
        let charge = brute_charge(&msgs, p);

        let mut local = sim(p);
        let mut out_local: Vec<u32> = (0..600u32).collect();
        local.round_fold_sharded(
            "fold",
            &mut out_local,
            chunked(&msgs, p),
            charge.clone(),
            u32::min,
        );

        let mut wire = wire_sim(p);
        let mut out_wire: Vec<u32> = (0..600u32).collect();
        wire.round_fold_sharded_tagged(
            "fold",
            &mut out_wire,
            chunked(&msgs, p),
            charge.clone(),
            WireFold::min_u32(),
        );
        assert_eq!(out_wire, out_local);
        assert_eq!(wire.metrics.rounds[0], local.metrics.rounds[0]);

        let mut local2 = sim(p);
        let map_local: Vec<u64> =
            local2.round_map_sharded("map", chunked(&msgs, p), charge.clone(), |k, v| {
                k ^ v as u64
            });
        let mut wire2 = wire_sim(p);
        let map_wire: Vec<u64> =
            wire2.round_map_sharded("map", chunked(&msgs, p), charge, |k, v| k ^ v as u64);
        assert_eq!(map_wire, map_local);
        assert_eq!(wire2.metrics.rounds[0], local2.metrics.rounds[0]);
    }

    #[test]
    fn wire_charge_only_round_barriers() {
        let mut wire = wire_sim(4);
        wire.charge_round("virtual", 10, 120, &[30, 30, 30, 30]);
        let r = &wire.metrics.rounds[0];
        assert_eq!((r.messages, r.bytes, r.max_machine_bytes), (10, 120, 30));
    }

    /// A transport whose receiver counts disagree with the charge: the
    /// engine must abort with the typed accounting error.
    #[derive(Debug)]
    struct LyingWire;

    impl crate::mpc::transport::Exchange for LyingWire {
        fn name(&self) -> &'static str {
            "lying"
        }
        fn wants_wire(&self) -> bool {
            true
        }
        fn exchange(
            &mut self,
            _label: &str,
            charge: crate::mpc::transport::RoundCharge<'_>,
            _payloads: Vec<Vec<u8>>,
            _fold: Option<crate::mpc::transport::WireOp>,
        ) -> Result<crate::mpc::transport::ExchangeAck, crate::mpc::transport::TransportError>
        {
            let mut mb = charge.machine_bytes.to_vec();
            if let Some(first) = mb.first_mut() {
                *first += 1;
            }
            Ok(crate::mpc::transport::ExchangeAck {
                machine_bytes: mb,
                folded: None,
            })
        }
    }

    #[test]
    fn accounting_divergence_is_a_typed_abort() {
        let mut s = Simulator::with_transport(
            MpcConfig {
                machines: 2,
                space_per_machine: None,
                spill_budget: None,
                threads: 1,
            },
            Box::new(LyingWire),
        );
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _: Vec<()> = s.round("r", vec![(0u64, 1u32), (1, 2)], |_, _| vec![]);
        }))
        .expect_err("must abort");
        let e = caught
            .downcast::<crate::mpc::transport::TransportError>()
            .expect("typed payload");
        assert!(matches!(
            *e,
            crate::mpc::transport::TransportError::AccountingMismatch { .. }
        ));
    }
}
