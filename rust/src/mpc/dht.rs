//! Distributed hash table extension of the MPC model (§2.1).
//!
//! "In each round all other machines can send messages of total size O(n)
//! that define the stored key-value pairs.  In the following round, all
//! machines can query the distributed hash table ... and for each query the
//! value corresponding to a key is returned immediately."
//!
//! The simulator models this with a publish/freeze cycle: writes go to a
//! staging map and become visible only after [`Dht::publish`] (the round
//! boundary); reads before the first publish see nothing.  All traffic is
//! counted and charged to the owning [`Simulator`] via
//! [`Dht::take_counters`] / `Simulator::charge_dht`.

use std::collections::HashMap;

/// A u64 -> u64 distributed hash table with round-boundary visibility.
///
/// TreeContraction's labels and Two-Phase's representative lookups only
/// need fixed-width values, so the table is monomorphic; this matches the
/// Bigtable-style store the paper cites [CDG+08].
#[derive(Debug, Default)]
pub struct Dht {
    visible: HashMap<u64, u64>,
    staged: HashMap<u64, u64>,
    reads: u64,
    writes: u64,
}

impl Dht {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stage a write; visible after the next [`publish`](Self::publish).
    pub fn put(&mut self, key: u64, value: u64) {
        self.writes += 1;
        self.staged.insert(key, value);
    }

    /// Query the table (counted).  Returns `None` for absent keys.
    pub fn get(&mut self, key: u64) -> Option<u64> {
        self.reads += 1;
        self.visible.get(&key).copied()
    }

    /// Round boundary: staged writes become visible.
    pub fn publish(&mut self) {
        for (k, v) in self.staged.drain() {
            self.visible.insert(k, v);
        }
    }

    /// Number of visible entries.
    pub fn len(&self) -> usize {
        self.visible.len()
    }

    pub fn is_empty(&self) -> bool {
        self.visible.is_empty()
    }

    /// Drain `(reads, writes)` counters (for `Simulator::charge_dht`).
    pub fn take_counters(&mut self) -> (u64, u64) {
        let out = (self.reads, self.writes);
        self.reads = 0;
        self.writes = 0;
        out
    }

    /// Clear everything (between phases).
    pub fn reset(&mut self) {
        self.visible.clear();
        self.staged.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_invisible_until_publish() {
        let mut d = Dht::new();
        d.put(1, 10);
        assert_eq!(d.get(1), None);
        d.publish();
        assert_eq!(d.get(1), Some(10));
    }

    #[test]
    fn publish_overwrites() {
        let mut d = Dht::new();
        d.put(1, 10);
        d.publish();
        d.put(1, 20);
        assert_eq!(d.get(1), Some(10), "old value until boundary");
        d.publish();
        assert_eq!(d.get(1), Some(20));
    }

    #[test]
    fn counters_drain() {
        let mut d = Dht::new();
        d.put(1, 1);
        d.put(2, 2);
        d.publish();
        d.get(1);
        d.get(9);
        assert_eq!(d.take_counters(), (2, 2));
        assert_eq!(d.take_counters(), (0, 0));
    }

    #[test]
    fn reset_clears() {
        let mut d = Dht::new();
        d.put(1, 1);
        d.publish();
        d.reset();
        assert_eq!(d.get(1), None);
        assert_eq!(d.len(), 0);
    }
}
