//! Round-level accounting for the MPC simulator.
//!
//! The paper's claims are stated in terms the simulator measures exactly:
//! number of **rounds**, per-round **communication** (bytes shuffled), and
//! per-machine **load** (max bytes received by one machine — the MPC(ε)
//! constraint of §2.1).  The `O(m)` communication-per-round observation of
//! §1.1 is checked against these counters by `lcc theory --exp comm`.

/// Counters for a single computation-communication round.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundMetrics {
    /// Human-readable label of the step this round implements.
    pub label: String,
    /// Shuffled key-value messages.
    pub messages: u64,
    /// Total shuffled bytes.
    pub bytes: u64,
    /// Max bytes received by a single machine (load balance / space bound).
    pub max_machine_bytes: u64,
    /// Distributed-hash-table traffic (§2.1 extension).
    pub dht_writes: u64,
    pub dht_reads: u64,
    /// Rounds where a machine exceeded the configured space bound.
    pub space_violation: bool,
}

/// Wall-clock breakdown of one round, split along the data-plane stages:
/// **generate** (building/serializing the message stream), **shuffle**
/// (the transport exchange — socket time on wire backends, a pure barrier
/// in-process), and **fold** (per-key reduction / reduce execution /
/// merging remote fold results).  Pure measurement: *never* part of the
/// model metrics or any equivalence comparison — [`RoundMetrics`] stays
/// bit-identical across transports and thread counts, timings do not.
#[derive(Debug, Clone, Default)]
pub struct RoundTiming {
    pub label: String,
    pub gen_ms: f64,
    pub shuffle_ms: f64,
    pub fold_ms: f64,
    /// Heap allocations performed process-wide during the round (counting
    /// global allocator, [`crate::util::alloc`]).  Steady-state spilled
    /// hop rounds must keep this O(machines), never O(edges): shard
    /// payloads stream through borrowed cursors over mmap'd images.
    pub allocs: u64,
    /// Spilled-shard bytes served zero-copy from mmap'd images during the
    /// round ([`crate::graph::spill::data_plane_counters`]).
    pub shard_bytes_mapped: u64,
    /// Spilled-shard bytes served through the owned-read fallback during
    /// the round — nonzero on the hot path means the zero-copy plane
    /// silently degraded (the CI spill gate checks the run-level total).
    pub shard_bytes_copied: u64,
}

/// One worker-recovery incident: a disconnect-shaped transport fault the
/// engine healed by respawning the fleet and replaying from the last
/// generation barrier.  Pure observability, like [`RoundTiming`]: never
/// part of any bit-identity comparison (an undisturbed run has zero
/// events; a recovered run's [`RoundMetrics`] are still identical).
#[derive(Debug, Clone, Default)]
pub struct RecoveryEvent {
    /// Label of the round the fault interrupted.
    pub label: String,
    /// Worker the fault was attributed to, when known.
    pub worker: Option<usize>,
    /// Human-readable cause (the underlying [`super::TransportError`]).
    pub cause: String,
    /// Respawn attempts consumed before the mesh came back.
    pub respawn_attempts: u64,
    /// Wall-clock of the respawn + mesh rebuild, in milliseconds.
    pub wall_ms: f64,
}

/// Replay accounting of a run's worker recoveries.  Replayed rounds are
/// charged **once** in [`Metrics::rounds`] (only the successful attempt
/// records) — the replay cost is logged here instead.
#[derive(Debug, Clone, Default)]
pub struct RecoveryMetrics {
    /// One entry per healed fault, in occurrence order.
    pub events: Vec<RecoveryEvent>,
    /// Rounds that ran more than once because a fault interrupted them
    /// (each counted once per extra attempt).
    pub replayed_rounds: u64,
    /// Total wall-clock spent in recovery, in milliseconds.
    pub total_ms: f64,
}

impl RecoveryMetrics {
    pub fn record(&mut self, event: RecoveryEvent) {
        self.total_ms += event.wall_ms;
        self.events.push(event);
    }
}

/// Mesh data-plane counters of a shuffle-transport run: what the worker
/// mesh and the coordinator's state channel actually moved, snapshotted
/// from the transport's `ShuffleStats` at run end.  Pure observability,
/// like [`RoundTiming`]: never part of any bit-identity comparison —
/// the same run with delta sync or pipelining disabled produces
/// identical [`RoundMetrics`] and different counters here.  Reported in
/// the `mesh` section of [`crate::coordinator::Report`] / `lcc perf`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MeshMetrics {
    /// Descriptor hop rounds issued (batched rounds count individually).
    pub hops: u64,
    /// Pipelined `HopBatch` descriptors issued (each covers ≥1 hop).
    pub hop_batches: u64,
    /// Mirror synchronizations, full broadcasts and deltas together.
    pub state_syncs: u64,
    /// The subset of `state_syncs` that shipped as `(index, value)`
    /// deltas instead of full broadcasts.
    pub delta_syncs: u64,
    /// Coordinator→worker bytes spent on mirror sync (frame headers
    /// included), summed over all workers.  With delta sync this is
    /// O(changed) after the first generation, not O(n).
    pub sync_bytes: u64,
    /// Worker↔worker mesh bytes (peer messages, fold images, rewired
    /// edges; frame headers included), as reported by the workers in
    /// their acks.
    pub mesh_bytes: u64,
    /// Peer-to-peer generation rewires (map-shipped + gather).
    pub rewires: u64,
    /// Custody establishments that re-shipped shards via the
    /// coordinator (recovery / non-rewire generations).
    pub custody_loads: u64,
    /// Data-plane threads each worker process ran its rounds on, as
    /// reported in the v5 Hello (1 = the serial path).  Observability
    /// only — thread count never changes [`RoundMetrics`] by
    /// construction, and the equivalence suites enforce it.
    pub worker_threads: u64,
}

/// Accumulated metrics for a run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub rounds: Vec<RoundMetrics>,
    /// Per-round wall-clock breakdown, parallel to `rounds` for rounds
    /// recorded through the engine (rounds recorded directly via
    /// [`Metrics::record`] carry no timing row).  Reported by `lcc perf`;
    /// excluded from every bit-identity comparison.
    pub timings: Vec<RoundTiming>,
    /// Worker-recovery log (shuffle transport).  Like `timings`,
    /// excluded from every bit-identity comparison: recovered runs must
    /// still produce `rounds` identical to undisturbed ones.
    pub recovery: RecoveryMetrics,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, round: RoundMetrics) {
        self.rounds.push(round);
    }

    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.bytes).sum()
    }

    pub fn total_messages(&self) -> u64 {
        self.rounds.iter().map(|r| r.messages).sum()
    }

    pub fn total_dht_ops(&self) -> u64 {
        self.rounds.iter().map(|r| r.dht_reads + r.dht_writes).sum()
    }

    pub fn max_round_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.bytes).max().unwrap_or(0)
    }

    pub fn any_space_violation(&self) -> bool {
        self.rounds.iter().any(|r| r.space_violation)
    }

    /// Merge metrics from a sub-computation (e.g. a per-phase job).
    pub fn extend(&mut self, other: Metrics) {
        self.rounds.extend(other.rounds);
        self.timings.extend(other.timings);
        self.recovery.events.extend(other.recovery.events);
        self.recovery.replayed_rounds += other.recovery.replayed_rounds;
        self.recovery.total_ms += other.recovery.total_ms;
    }
}

/// Wire model for shuffled values: size, encoding, decoding.
///
/// The simulator charges `8 (key) + value.wire_size()` bytes per message —
/// the natural encoding a MapReduce shuffle would use.  `encode_wire` IS
/// that encoding (little-endian), so on a wire transport the bytes that
/// physically cross the process boundary are exactly the bytes the model
/// charges: `encode_wire` must append precisely `wire_size()` bytes, and
/// `decode_wire` must invert it.  The round-trip is enforced by the tests
/// below and, at run time, by the receiver-side accounting every proc
/// round validates against the charge.
pub trait WireSize {
    fn wire_size(&self) -> u64;

    /// Append exactly [`wire_size`](WireSize::wire_size) bytes to `out`.
    fn encode_wire(&self, out: &mut Vec<u8>);

    /// Decode one value from the front of `bytes`, returning it and the
    /// bytes consumed; `None` on short or malformed input.
    fn decode_wire(bytes: &[u8]) -> Option<(Self, usize)>
    where
        Self: Sized;
}

impl WireSize for u32 {
    fn wire_size(&self) -> u64 {
        4
    }
    fn encode_wire(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn decode_wire(bytes: &[u8]) -> Option<(u32, usize)> {
        let b = bytes.get(..4)?;
        Some((u32::from_le_bytes(b.try_into().unwrap()), 4))
    }
}
impl WireSize for u64 {
    fn wire_size(&self) -> u64 {
        8
    }
    fn encode_wire(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn decode_wire(bytes: &[u8]) -> Option<(u64, usize)> {
        let b = bytes.get(..8)?;
        Some((u64::from_le_bytes(b.try_into().unwrap()), 8))
    }
}
impl WireSize for i64 {
    fn wire_size(&self) -> u64 {
        8
    }
    fn encode_wire(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn decode_wire(bytes: &[u8]) -> Option<(i64, usize)> {
        let b = bytes.get(..8)?;
        Some((i64::from_le_bytes(b.try_into().unwrap()), 8))
    }
}
impl WireSize for () {
    fn wire_size(&self) -> u64 {
        0
    }
    fn encode_wire(&self, _out: &mut Vec<u8>) {}
    fn decode_wire(_bytes: &[u8]) -> Option<((), usize)> {
        Some(((), 0))
    }
}
impl<A: WireSize, B: WireSize> WireSize for (A, B) {
    fn wire_size(&self) -> u64 {
        self.0.wire_size() + self.1.wire_size()
    }
    fn encode_wire(&self, out: &mut Vec<u8>) {
        self.0.encode_wire(out);
        self.1.encode_wire(out);
    }
    fn decode_wire(bytes: &[u8]) -> Option<((A, B), usize)> {
        let (a, na) = A::decode_wire(bytes)?;
        let (b, nb) = B::decode_wire(&bytes[na..])?;
        Some(((a, b), na + nb))
    }
}
impl<A: WireSize, B: WireSize, C: WireSize> WireSize for (A, B, C) {
    fn wire_size(&self) -> u64 {
        self.0.wire_size() + self.1.wire_size() + self.2.wire_size()
    }
    fn encode_wire(&self, out: &mut Vec<u8>) {
        self.0.encode_wire(out);
        self.1.encode_wire(out);
        self.2.encode_wire(out);
    }
    fn decode_wire(bytes: &[u8]) -> Option<((A, B, C), usize)> {
        let (a, na) = A::decode_wire(bytes)?;
        let (b, nb) = B::decode_wire(&bytes[na..])?;
        let (c, nc) = C::decode_wire(&bytes[na + nb..])?;
        Some(((a, b, c), na + nb + nc))
    }
}
impl<T: WireSize> WireSize for Vec<T> {
    fn wire_size(&self) -> u64 {
        8 + self.iter().map(|x| x.wire_size()).sum::<u64>()
    }
    fn encode_wire(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.len() as u64).to_le_bytes());
        for x in self {
            x.encode_wire(out);
        }
    }
    fn decode_wire(bytes: &[u8]) -> Option<(Vec<T>, usize)> {
        let (len, mut off) = u64::decode_wire(bytes)?;
        // grow as decoded: a garbage length must not pre-allocate — and
        // must not spin either, so every element has to consume bytes
        // (zero-size elements are unrepresentable on the wire: their
        // count would be bounded by nothing but the declared length)
        let mut v = Vec::new();
        for _ in 0..len {
            let (x, n) = T::decode_wire(&bytes[off..])?;
            if n == 0 {
                return None;
            }
            off += n;
            v.push(x);
        }
        Some((v, off))
    }
}
impl<T: WireSize> WireSize for Option<T> {
    fn wire_size(&self) -> u64 {
        1 + self.as_ref().map(|x| x.wire_size()).unwrap_or(0)
    }
    fn encode_wire(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(x) => {
                out.push(1);
                x.encode_wire(out);
            }
        }
    }
    fn decode_wire(bytes: &[u8]) -> Option<(Option<T>, usize)> {
        match *bytes.first()? {
            0 => Some((None, 1)),
            1 => {
                let (x, n) = T::decode_wire(&bytes[1..])?;
                Some((Some(x), 1 + n))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_accumulate() {
        let mut m = Metrics::new();
        m.record(RoundMetrics {
            label: "a".into(),
            messages: 10,
            bytes: 100,
            max_machine_bytes: 30,
            ..Default::default()
        });
        m.record(RoundMetrics {
            label: "b".into(),
            messages: 5,
            bytes: 50,
            dht_reads: 7,
            ..Default::default()
        });
        assert_eq!(m.num_rounds(), 2);
        assert_eq!(m.total_bytes(), 150);
        assert_eq!(m.total_messages(), 15);
        assert_eq!(m.total_dht_ops(), 7);
        assert_eq!(m.max_round_bytes(), 100);
        assert!(!m.any_space_violation());
    }

    #[test]
    fn wire_sizes() {
        assert_eq!(3u32.wire_size(), 4);
        assert_eq!((1u32, 2u32).wire_size(), 8);
        assert_eq!((1u64, 2u32, 3u32).wire_size(), 16);
        assert_eq!(vec![1u32, 2u32].wire_size(), 16);
        assert_eq!(Some(1u32).wire_size(), 5);
        assert_eq!(None::<u32>.wire_size(), 1);
    }

    fn roundtrip<T: WireSize + PartialEq + std::fmt::Debug>(v: T) {
        let mut buf = Vec::new();
        v.encode_wire(&mut buf);
        assert_eq!(buf.len() as u64, v.wire_size(), "{v:?}");
        // a trailing byte must not confuse the consumed count
        buf.push(0xEE);
        let (back, used) = T::decode_wire(&buf).expect("decode");
        assert_eq!(back, v);
        assert_eq!(used as u64, v.wire_size());
    }

    #[test]
    fn wire_encoding_mirrors_wire_size() {
        roundtrip(7u32);
        roundtrip(u64::MAX - 3);
        roundtrip(-9i64);
        roundtrip(());
        roundtrip((1u32, 2u32));
        roundtrip((1u64, 2u32, 3u32));
        roundtrip(vec![5u32, 6, 7]);
        roundtrip(Vec::<u32>::new());
        roundtrip(Some((4u32, 2u32)));
        roundtrip(None::<u32>);
    }

    #[test]
    fn decode_rejects_short_and_garbage_input() {
        assert!(u32::decode_wire(&[1, 2, 3]).is_none());
        assert!(<(u32, u32)>::decode_wire(&[0; 7]).is_none());
        // Vec with a declared length far beyond the buffer: no
        // pre-allocation, clean None
        let mut buf = Vec::new();
        (u64::MAX).encode_wire(&mut buf);
        assert!(Vec::<u32>::decode_wire(&buf).is_none());
        // zero-size elements would make the declared length the only
        // bound — the decoder must refuse rather than spin
        assert!(Vec::<()>::decode_wire(&buf).is_none());
        let mut one = Vec::new();
        1u64.encode_wire(&mut one);
        assert!(Vec::<()>::decode_wire(&one).is_none());
        assert!(Option::<u32>::decode_wire(&[9]).is_none());
    }

    #[test]
    fn extend_merges() {
        let mut a = Metrics::new();
        a.record(RoundMetrics::default());
        let mut b = Metrics::new();
        b.record(RoundMetrics::default());
        b.record(RoundMetrics::default());
        b.recovery.record(RecoveryEvent {
            label: "hop".into(),
            worker: Some(1),
            cause: "worker 1 crashed".into(),
            respawn_attempts: 1,
            wall_ms: 12.5,
        });
        b.recovery.replayed_rounds = 2;
        a.extend(b);
        assert_eq!(a.num_rounds(), 3);
        assert_eq!(a.recovery.events.len(), 1);
        assert_eq!(a.recovery.replayed_rounds, 2);
        assert!((a.recovery.total_ms - 12.5).abs() < 1e-9);
    }
}
