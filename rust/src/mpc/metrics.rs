//! Round-level accounting for the MPC simulator.
//!
//! The paper's claims are stated in terms the simulator measures exactly:
//! number of **rounds**, per-round **communication** (bytes shuffled), and
//! per-machine **load** (max bytes received by one machine — the MPC(ε)
//! constraint of §2.1).  The `O(m)` communication-per-round observation of
//! §1.1 is checked against these counters by `lcc theory --exp comm`.

/// Counters for a single computation-communication round.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundMetrics {
    /// Human-readable label of the step this round implements.
    pub label: String,
    /// Shuffled key-value messages.
    pub messages: u64,
    /// Total shuffled bytes.
    pub bytes: u64,
    /// Max bytes received by a single machine (load balance / space bound).
    pub max_machine_bytes: u64,
    /// Distributed-hash-table traffic (§2.1 extension).
    pub dht_writes: u64,
    pub dht_reads: u64,
    /// Rounds where a machine exceeded the configured space bound.
    pub space_violation: bool,
}

/// Accumulated metrics for a run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub rounds: Vec<RoundMetrics>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, round: RoundMetrics) {
        self.rounds.push(round);
    }

    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.bytes).sum()
    }

    pub fn total_messages(&self) -> u64 {
        self.rounds.iter().map(|r| r.messages).sum()
    }

    pub fn total_dht_ops(&self) -> u64 {
        self.rounds.iter().map(|r| r.dht_reads + r.dht_writes).sum()
    }

    pub fn max_round_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.bytes).max().unwrap_or(0)
    }

    pub fn any_space_violation(&self) -> bool {
        self.rounds.iter().any(|r| r.space_violation)
    }

    /// Merge metrics from a sub-computation (e.g. a per-phase job).
    pub fn extend(&mut self, other: Metrics) {
        self.rounds.extend(other.rounds);
    }
}

/// Wire-size model for shuffled values.
///
/// The simulator charges `8 (key) + value.wire_size()` bytes per message —
/// the natural encoding a MapReduce shuffle would use.
pub trait WireSize {
    fn wire_size(&self) -> u64;
}

impl WireSize for u32 {
    fn wire_size(&self) -> u64 {
        4
    }
}
impl WireSize for u64 {
    fn wire_size(&self) -> u64 {
        8
    }
}
impl WireSize for i64 {
    fn wire_size(&self) -> u64 {
        8
    }
}
impl WireSize for () {
    fn wire_size(&self) -> u64 {
        0
    }
}
impl<A: WireSize, B: WireSize> WireSize for (A, B) {
    fn wire_size(&self) -> u64 {
        self.0.wire_size() + self.1.wire_size()
    }
}
impl<A: WireSize, B: WireSize, C: WireSize> WireSize for (A, B, C) {
    fn wire_size(&self) -> u64 {
        self.0.wire_size() + self.1.wire_size() + self.2.wire_size()
    }
}
impl<T: WireSize> WireSize for Vec<T> {
    fn wire_size(&self) -> u64 {
        8 + self.iter().map(|x| x.wire_size()).sum::<u64>()
    }
}
impl<T: WireSize> WireSize for Option<T> {
    fn wire_size(&self) -> u64 {
        1 + self.as_ref().map(|x| x.wire_size()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_accumulate() {
        let mut m = Metrics::new();
        m.record(RoundMetrics {
            label: "a".into(),
            messages: 10,
            bytes: 100,
            max_machine_bytes: 30,
            ..Default::default()
        });
        m.record(RoundMetrics {
            label: "b".into(),
            messages: 5,
            bytes: 50,
            dht_reads: 7,
            ..Default::default()
        });
        assert_eq!(m.num_rounds(), 2);
        assert_eq!(m.total_bytes(), 150);
        assert_eq!(m.total_messages(), 15);
        assert_eq!(m.total_dht_ops(), 7);
        assert_eq!(m.max_round_bytes(), 100);
        assert!(!m.any_space_violation());
    }

    #[test]
    fn wire_sizes() {
        assert_eq!(3u32.wire_size(), 4);
        assert_eq!((1u32, 2u32).wire_size(), 8);
        assert_eq!((1u64, 2u32, 3u32).wire_size(), 16);
        assert_eq!(vec![1u32, 2u32].wire_size(), 16);
        assert_eq!(Some(1u32).wire_size(), 5);
        assert_eq!(None::<u32>.wire_size(), 1);
    }

    #[test]
    fn extend_merges() {
        let mut a = Metrics::new();
        a.record(RoundMetrics::default());
        let mut b = Metrics::new();
        b.record(RoundMetrics::default());
        b.record(RoundMetrics::default());
        a.extend(b);
        assert_eq!(a.num_rounds(), 3);
    }
}
