//! MPC(ε = 0) execution substrate (§2.1 of the paper).
//!
//! The simulator gives the algorithms the exact interface the paper's model
//! defines — rounds of local computation + key-shuffled communication, an
//! optional distributed hash table — while measuring the model-level
//! quantities every claim is stated in: rounds, shuffled bytes, per-machine
//! load.

pub mod dht;
pub mod metrics;
pub mod pool;
pub mod simulator;

pub use dht::Dht;
pub use metrics::{Metrics, RoundMetrics, WireSize};
pub use pool::WorkerPool;
pub use simulator::{MpcConfig, Simulator};
