//! MPC(ε = 0) execution substrate (§2.1 of the paper).
//!
//! The round engine gives the algorithms the exact interface the paper's
//! model defines — rounds of local computation + key-shuffled
//! communication, an optional distributed hash table — while measuring
//! the model-level quantities every claim is stated in: rounds, shuffled
//! bytes, per-machine load.
//!
//! **The transport boundary.**  *How a round shuffles* is a trait, not a
//! hard-coded simulator: every round completes through
//! [`transport::Exchange`], which owns message routing, per-machine load
//! accounting, and barrier semantics.  [`Simulator`] is the engine over
//! that boundary; two backends implement it:
//!
//! * [`transport::InProcess`] (default) — machines share the address
//!   space; messages never serialize; the exchange is a pure accounting
//!   barrier.  All the parallel fast paths below run on this backend.
//! * [`net::ProcTransport`] — one worker **process** per machine
//!   (`lcc worker`), each owning its [`crate::graph::EdgeShard`] (shipped
//!   in the spill file framing — a spilled shard goes on the wire as its
//!   raw file bytes), exchanging length-prefixed checksummed frames per
//!   round.  The hop folds are reduced *by the workers* ([`WireOp`]
//!   tags); every other round ships its exact charged byte image for
//!   receiver-side accounting.  Worker crash, frame truncation, and
//!   payload corruption are typed [`TransportError`]s.
//! * [`net::ShuffleTransport`] — the same worker processes promoted to
//!   the **data plane**: each generates the hop and rewire rounds from
//!   its owned shard and a synchronized value mirror, shuffles the
//!   messages worker↔worker over a localhost TCP mesh, folds what it
//!   receives, and reports only O(machines) load/checksum summaries;
//!   the coordinator shrinks to a control plane issuing round
//!   descriptors ([`transport::ShuffleOps`]) and validating the
//!   summaries against its locally-computed fold.  Rounds with no
//!   descriptor shape fall back to coordinator routing, proc-style.
//!
//! The eight algorithms and the contraction loop never see the backend:
//! labels, per-round [`Metrics`], and derived graphs are bit-identical
//! across transports (`rust/tests/transport_equivalence.rs`).
//!
//! **Shard-ownership invariant.**  [`MpcConfig::machines`] is the single
//! source of the shard count: the resident [`crate::graph::ShardedGraph`]
//! partitions its edges by `machine_of(min_endpoint, machines)` (the same
//! [`simulator::machine_of`] hash the shuffle rounds use), so per-machine
//! load metrics are **pure functions of shard membership**.  The sharded
//! round entry points ([`Simulator::round_fold_sharded`],
//! [`Simulator::round_map_sharded`]) consume one message chunk per shard
//! and a pre-computed [`ShardRound`] charge derived from cached shard
//! statistics — no `machine_of` recomputation per message in-process (the
//! wire backend does route per message: it genuinely moves the bytes, and
//! the receiver counts *validate* the shard-derived charges).  The legacy
//! per-message-accounted rounds (`round`, `round_fold`, `round_map` and
//! their chunked forms) remain the reference semantics the sharded paths
//! are tested against.
//!
//! **Out-of-core.**  [`MpcConfig::spill_budget`] bounds *resident* edge
//! bytes: graphs over the budget keep their shards on disk
//! (`crate::graph::spill`) and the sharded rounds consume lazily-loaded
//! per-shard chunks — the charges above need only the cached statistics,
//! so model metrics are bit-identical either way
//! (`rust/tests/spill_equivalence.rs`).  The budget bounds the graph
//! representation and the streaming contraction-loop algorithms; the
//! cluster-growing baselines still materialize O(m) round state of their
//! own (see `crate::graph::spill` module docs).

pub mod dht;
pub mod metrics;
pub mod net;
pub mod pool;
pub mod simulator;
pub mod transport;

pub use dht::Dht;
pub use metrics::{
    MeshMetrics, Metrics, RecoveryEvent, RecoveryMetrics, RoundMetrics, RoundTiming, WireSize,
};
pub use pool::WorkerPool;
pub use simulator::{MpcConfig, RoundPlan, ShardRound, Simulator};
pub use transport::{
    Exchange, ExchangeAck, HopSpec, InProcess, RecoveryInfo, RoundCharge, ShuffleOps,
    TransportError, TransportMode, WireFold, WireOp,
};
