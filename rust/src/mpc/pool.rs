//! Persistent worker pool for simulation-level parallelism.
//!
//! The simulator previously spawned fresh `thread::scope` threads in every
//! round; at four rounds per phase and `O(log n)` phases, the spawn/join
//! overhead and cold stacks dominated the cheap rounds (see EXPERIMENTS.md
//! §Perf).  This pool spawns its workers once — lazily, on first use — and
//! keeps them parked on a shared job queue; a round submits its chunk jobs
//! and blocks until exactly those jobs drain.
//!
//! **Scoped borrows.**  Jobs may borrow from the caller's stack (message
//! slices, value arrays, reducer closures).  [`WorkerPool::run_jobs`]
//! erases those lifetimes to ship the jobs across the queue, and restores
//! soundness by blocking on a completion latch before returning — on the
//! happy path explicitly, and on every unwind path via a drop guard
//! ([`SubmitGuard`]): no job can outlive the borrows it closes over.
//! This is the classic `scoped_threadpool` design on std primitives (the
//! offline crate set has no `rayon`).
//!
//! **Determinism.**  `run_jobs` returns results in job order regardless of
//! which worker ran what, so callers that merge partial results in job
//! order are bit-deterministic across pool sizes — the property the
//! simulator's "model metrics are engine-invariant" contract relies on.

use std::cell::Cell;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Set for the lifetime of every pool worker thread.  `run_jobs`
    /// checks it to run nested submissions inline: with all workers busy,
    /// a job that submitted and blocked on the pool it is running on
    /// (e.g. `Graph::normalize` → `par_sort_u64` from inside a round
    /// closure) would deadlock.
    static IN_POOL_WORKER: Cell<bool> = Cell::new(false);
}

/// Counts outstanding jobs of one [`WorkerPool::run_jobs`] call; `wait`
/// parks the caller until every job has completed.  Panicking jobs are
/// counted too (so the latch cannot deadlock) and re-raised caller-side.
struct Latch {
    state: Mutex<(usize, bool)>, // (pending, panicked)
    done: Condvar,
}

impl Latch {
    fn new(pending: usize) -> Latch {
        Latch {
            state: Mutex::new((pending, false)),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        st.1 |= panicked;
        if st.0 == 0 {
            self.done.notify_all();
        }
    }

    /// Block until all jobs completed; returns whether any panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.done.wait(st).unwrap();
        }
        st.1
    }
}

/// SAFETY: the borrows captured by `task` (result slots, the latch, and
/// the caller's `'a` data) are kept alive by the caller blocking on the
/// latch until the task has run; the erased lifetime is never exceeded.
unsafe fn erase<'a>(task: Box<dyn FnOnce() + Send + 'a>) -> Job {
    std::mem::transmute(task)
}

/// Unwind guard for the submit-then-wait span of [`WorkerPool::run_jobs`].
///
/// Once the first lifetime-erased job is on the queue, the caller **must**
/// block on the latch before its stack frame (holding `results` and the
/// latch itself) unwinds — otherwise workers race a use-after-free.  The
/// happy path waits explicitly; this guard makes the panic paths (a failed
/// `send`, a poisoned submit lock) do the same: its `Drop` retires the
/// jobs that never reached the queue (they can no longer complete
/// themselves) and then blocks until every submitted job has drained.
struct SubmitGuard<'a> {
    latch: &'a Latch,
    unsent: usize,
}

impl Drop for SubmitGuard<'_> {
    fn drop(&mut self) {
        for _ in 0..self.unsent {
            self.latch.complete(false);
        }
        self.latch.wait();
    }
}

/// A fixed set of parked worker threads fed from one shared queue.
///
/// The sender sits behind a mutex so the pool is `Sync` on every
/// supported toolchain (`mpsc::Sender` only became `Sync` in recent std);
/// submissions are a few cheap sends per round, so the lock is not a
/// bottleneck.
pub struct WorkerPool {
    tx: Option<Mutex<Sender<Job>>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers.  `threads == 0` is allowed: every
    /// `run_jobs` call then executes inline on the caller.
    pub fn new(threads: usize) -> WorkerPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("lcc-worker-{i}"))
                    .spawn(move || {
                        IN_POOL_WORKER.with(|f| f.set(true));
                        loop {
                            // Hold the lock only for the dequeue; blocking
                            // in `recv` under the lock is fine because the
                            // lock is released the moment a job (or
                            // disconnect) arrives.
                            let job = match rx.lock().unwrap().recv() {
                                Ok(job) => job,
                                Err(_) => return, // pool dropped: queue closed
                            };
                            job();
                        }
                    })
                    .expect("spawn lcc pool worker")
            })
            .collect();
        WorkerPool {
            tx: Some(Mutex::new(tx)),
            workers,
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Run `jobs` on the pool and return their results **in job order**.
    ///
    /// Jobs may borrow from the caller: the call blocks until every job has
    /// finished — even if submission unwinds partway (see [`SubmitGuard`])
    /// — so no borrow is outlived.  Panics (after all jobs drain) if any
    /// job panicked.  Calls from inside a pool worker (nested submission,
    /// e.g. a round closure reaching `Graph::normalize`'s parallel sort)
    /// execute inline on the worker instead of enqueueing: with every
    /// worker busy, submit-and-block would deadlock the pool.
    pub fn run_jobs<'env, T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'env,
        F: FnOnce() -> T + Send + 'env,
    {
        if self.workers.is_empty() || jobs.len() <= 1 || IN_POOL_WORKER.with(|f| f.get()) {
            return jobs.into_iter().map(|j| j()).collect();
        }
        let n = jobs.len();
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let latch = Latch::new(n);
        let mut guard = SubmitGuard { latch: &latch, unsent: n };
        {
            let tx = self.tx.as_ref().expect("pool queue alive").lock().unwrap();
            for (job, slot) in jobs.into_iter().zip(results.iter_mut()) {
                let latch = &latch;
                let task = Box::new(move || {
                    // Count completion even on panic so `wait` cannot hang;
                    // the panic flag re-raises below, on the caller's thread.
                    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        *slot = Some(job());
                    }));
                    latch.complete(caught.is_err());
                });
                tx.send(unsafe { erase(task) }).expect("pool queue closed");
                guard.unsent -= 1;
            }
        } // release the submit lock before blocking on the latch
        let panicked = latch.wait();
        drop(guard); // latch already drained: the guard's wait is a no-op
        if panicked {
            panic!("worker pool job panicked");
        }
        results
            .into_iter()
            .map(|r| r.expect("completed job left no result"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue: workers see Err and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Default simulation-level parallelism (mirrors `MpcConfig::default`).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().min(16))
        .unwrap_or(4)
}

/// The process-wide pool.  All simulators (and the graph layer's parallel
/// sorts) share it: a `Simulator` with `cfg.threads = t` submits `t` chunk
/// jobs per round, and the pool executes them at whatever parallelism the
/// hardware offers — chunking, and therefore every result and metric, is a
/// function of `t` alone, never of the worker count.
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(default_threads()))
}

/// Balanced contiguous split: the `i`-th of `chunks` ranges over `len`
/// items.  The first `len % chunks` ranges get one extra item, ranges
/// concatenate to `0..len` in order, and out-of-range `i` yields an empty
/// range.
pub fn chunk_range(len: usize, chunks: usize, i: usize) -> (usize, usize) {
    let chunks = chunks.max(1);
    let base = len / chunks;
    let rem = len % chunks;
    let start = i * base + i.min(rem);
    let end = start + base + usize::from(i < rem);
    (start.min(len), end.min(len))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_job_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<_> = (0..32u64).map(|i| move || i * i).collect();
        let out = pool.run_jobs(jobs);
        assert_eq!(out, (0..32u64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_can_borrow_caller_data() {
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let jobs: Vec<_> = (0..8)
            .map(|i| {
                let data = &data;
                move || {
                    let (a, b) = chunk_range(data.len(), 8, i);
                    data[a..b].iter().sum::<u64>()
                }
            })
            .collect();
        let total: u64 = pool.run_jobs(jobs).into_iter().sum();
        assert_eq!(total, 1000 * 999 / 2);
    }

    #[test]
    fn pool_is_reusable_across_calls() {
        let pool = WorkerPool::new(2);
        for round in 0..10u64 {
            let out = pool.run_jobs((0..4).map(|i| move || round + i).collect::<Vec<_>>());
            assert_eq!(out, vec![round, round + 1, round + 2, round + 3]);
        }
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        let out = pool.run_jobs(vec![(|| 1u32) as fn() -> u32, || 2u32]);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "worker pool job panicked")]
    fn job_panic_propagates_to_caller() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
            Box::new(|| 2),
        ];
        let _ = pool.run_jobs(jobs);
    }

    #[test]
    fn pool_survives_a_panicked_job() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> =
            vec![Box::new(|| panic!("boom")), Box::new(|| 7)];
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_jobs(jobs);
        }));
        assert!(caught.is_err());
        // workers are still alive and serving
        let out = pool.run_jobs((0..4u32).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn nested_submission_runs_inline_instead_of_deadlocking() {
        // More outer jobs than workers, and every outer job submits to the
        // same pool it runs on.  Without the in-worker inline fallback both
        // workers block in the inner `run_jobs` with nobody left to serve
        // the queue — a deadlock; with it, the inner calls execute inline.
        let pool = WorkerPool::new(2);
        let pool_ref = &pool;
        let out = pool.run_jobs(
            (0..4u64)
                .map(|i| {
                    move || {
                        let inner = pool_ref
                            .run_jobs((0..4u64).map(|j| move || i * 10 + j).collect::<Vec<_>>());
                        inner.into_iter().sum::<u64>()
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(out, vec![6, 46, 86, 126]);
    }

    #[test]
    fn chunk_ranges_partition_exactly() {
        for len in [0usize, 1, 7, 64, 100, 1023] {
            for chunks in [1usize, 2, 3, 8, 16] {
                let mut expected = 0;
                for i in 0..chunks {
                    let (a, b) = chunk_range(len, chunks, i);
                    assert_eq!(a, expected, "len={len} chunks={chunks} i={i}");
                    assert!(b >= a);
                    expected = b;
                }
                assert_eq!(expected, len);
                // out-of-range chunk index is empty
                let (a, b) = chunk_range(len, chunks, chunks + 3);
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn global_pool_initializes_once() {
        let a = global() as *const WorkerPool;
        let b = global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(global().threads() >= 1);
    }
}
