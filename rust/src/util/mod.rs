//! Hand-rolled substrate utilities.
//!
//! The offline crate set for this build contains only the `xla` 0.1.6
//! dependency closure (+`anyhow`), so the usual ecosystem crates (`rand`,
//! `serde`, `clap`, `proptest`, `criterion`) are unavailable; these modules
//! provide the small slices of them the system needs (see DESIGN.md §5).

pub mod alloc;
pub mod cli;
pub mod dsu;
pub mod json;
pub mod quickcheck;
pub mod radix;
pub mod rng;
pub mod stats;
