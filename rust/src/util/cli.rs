//! Tiny CLI argument parser (no `clap` in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Typed getters with defaults; unknown-flag detection so the
//! binary can fail fast on typos.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags that were consumed by a getter — for unknown-flag reporting.
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclude argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(body.to_string(), v);
                } else {
                    args.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.typed_or(key, default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.typed_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.typed_or(key, default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.mark(key);
        match self.flags.get(key).map(|s| s.as_str()) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key}: expected bool, got {v:?}"),
        }
    }

    fn typed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        self.typed_opt(key).unwrap_or(default)
    }

    fn typed_opt<T: std::str::FromStr>(&self, key: &str) -> Option<T>
    where
        T::Err: std::fmt::Display,
    {
        self.str_opt(key).map(|v| {
            v.parse()
                .unwrap_or_else(|e| panic!("--{key}: cannot parse {v:?}: {e}"))
        })
    }

    /// Optional count flag: `None` when absent (callers fall back to an
    /// environment variable or a compiled-in default).
    pub fn usize_opt(&self, key: &str) -> Option<usize> {
        self.typed_opt(key)
    }

    /// Optional count flag where 0 is invalid (timeouts, retry budgets):
    /// `None` when absent, fails at the flag on 0 — a zero timeout would
    /// otherwise surface downstream as an instantly-dead socket.
    pub fn nonzero_u64_opt(&self, key: &str) -> Option<u64> {
        let v: u64 = self.typed_opt(key)?;
        if v == 0 {
            panic!("--{key}: must be >= 1 (got 0)");
        }
        Some(v)
    }

    /// [`nonzero_u64_opt`](Args::nonzero_u64_opt) for `usize` flags.
    pub fn nonzero_usize_opt(&self, key: &str) -> Option<usize> {
        let v: usize = self.typed_opt(key)?;
        if v == 0 {
            panic!("--{key}: must be >= 1 (got 0)");
        }
        Some(v)
    }

    /// Count flag where 0 is invalid (machine/thread/worker counts): a
    /// zero would otherwise surface far downstream as a division, an
    /// empty pool, or a hung transport — fail at the flag instead.
    pub fn nonzero_usize_or(&self, key: &str, default: usize) -> usize {
        let v = self.usize_or(key, default);
        if v == 0 {
            panic!("--{key}: must be >= 1 (got 0)");
        }
        v
    }

    /// Byte-size flag: plain bytes or a binary `K`/`M`/`G` suffix
    /// (`--spill-budget 64M`).  Unparseable values fail with a clear
    /// error naming the flag instead of a panic deep in a run.
    pub fn byte_size_opt(&self, key: &str) -> Option<u64> {
        self.str_opt(key).map(|v| {
            parse_byte_size(v).unwrap_or_else(|| {
                panic!("--{key}: cannot parse {v:?} as a byte size (use N, NK, NM, or NG)")
            })
        })
    }

    /// Comma-separated list getter, e.g. `--sizes 10,20,30`.
    pub fn u64_list_or(&self, key: &str, default: &[u64]) -> Vec<u64> {
        match self.str_opt(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|e| panic!("--{key}: bad item {s:?}: {e}"))
                })
                .collect(),
        }
    }

    /// Print the unused-flag warning now.  Long-running commands
    /// (`lcc serve`) never return to `main`'s post-dispatch check, so
    /// they call this once all flags are consumed, before blocking.
    pub fn warn_unknown(&self, cmd: &str) {
        let unknown = self.unknown_flags();
        if !unknown.is_empty() {
            eprintln!("warning: {cmd}: unused flags: {unknown:?}");
        }
    }

    /// Flags present on the command line but never consumed by a getter.
    pub fn unknown_flags(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.flags
            .keys()
            .filter(|k| !seen.iter().any(|s| s == *k))
            .cloned()
            .collect()
    }
}

/// Parse `N`, `NK`, `NM`, or `NG` (binary multiples) into bytes.
fn parse_byte_size(s: &str) -> Option<u64> {
    let t = s.trim();
    for (suffix, mult) in [
        ("k", 1u64 << 10),
        ("K", 1 << 10),
        ("m", 1 << 20),
        ("M", 1 << 20),
        ("g", 1 << 30),
        ("G", 1 << 30),
    ] {
        if let Some(num) = t.strip_suffix(suffix) {
            return num.trim().parse::<u64>().ok()?.checked_mul(mult);
        }
    }
    t.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["run", "--n", "100", "--p=0.5", "--verbose"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.u64_or("n", 0), 100);
        assert_eq!(a.f64_or("p", 0.0), 0.5);
        assert!(a.bool_or("verbose", false));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.u64_or("n", 7), 7);
        assert_eq!(a.str_or("algo", "lc"), "lc");
        assert!(!a.bool_or("x", false));
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--dx", "-5"]);
        assert_eq!(a.typed_or::<i64>("dx", 0), -5);
    }

    #[test]
    fn list_flag() {
        let a = parse(&["--sizes", "1,2,3"]);
        assert_eq!(a.u64_list_or("sizes", &[9]), vec![1, 2, 3]);
        assert_eq!(a.u64_list_or("other", &[9]), vec![9]);
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse(&["--good", "1", "--oops", "2"]);
        let _ = a.u64_or("good", 0);
        assert_eq!(a.unknown_flags(), vec!["oops".to_string()]);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_parse_panics() {
        let a = parse(&["--n", "xyz"]);
        let _ = a.u64_or("n", 0);
    }

    #[test]
    fn nonzero_counts_pass_through() {
        let a = parse(&["--machines", "4"]);
        assert_eq!(a.nonzero_usize_or("machines", 16), 4);
        assert_eq!(a.nonzero_usize_or("threads", 8), 8); // default
    }

    #[test]
    #[should_panic(expected = "--machines: must be >= 1")]
    fn zero_machines_is_rejected() {
        let a = parse(&["--machines", "0"]);
        let _ = a.nonzero_usize_or("machines", 16);
    }

    #[test]
    #[should_panic(expected = "--threads: must be >= 1")]
    fn zero_threads_is_rejected() {
        let a = parse(&["--threads", "0"]);
        let _ = a.nonzero_usize_or("threads", 8);
    }

    #[test]
    fn optional_counts_pass_through_or_stay_none() {
        let a = parse(&["--io-timeout", "30", "--connect-retries", "4"]);
        assert_eq!(a.nonzero_u64_opt("io-timeout"), Some(30));
        assert_eq!(a.nonzero_usize_opt("connect-retries"), Some(4));
        assert_eq!(a.nonzero_u64_opt("absent"), None);
        assert_eq!(a.usize_opt("respawn-budget"), None);
        let b = parse(&["--respawn-budget", "0"]);
        assert_eq!(b.usize_opt("respawn-budget"), Some(0)); // 0 = disable, valid
    }

    #[test]
    #[should_panic(expected = "--io-timeout: must be >= 1")]
    fn zero_io_timeout_is_rejected() {
        let a = parse(&["--io-timeout", "0"]);
        let _ = a.nonzero_u64_opt("io-timeout");
    }

    #[test]
    #[should_panic(expected = "--connect-retries: must be >= 1")]
    fn zero_connect_retries_is_rejected() {
        let a = parse(&["--connect-retries", "0"]);
        let _ = a.nonzero_usize_opt("connect-retries");
    }

    #[test]
    fn byte_sizes_parse_with_suffixes() {
        let a = parse(&["--spill-budget", "64M"]);
        assert_eq!(a.byte_size_opt("spill-budget"), Some(64 << 20));
        assert_eq!(a.byte_size_opt("absent"), None);
        assert_eq!(parse_byte_size("123"), Some(123));
        assert_eq!(parse_byte_size(" 2k "), Some(2048));
        assert_eq!(parse_byte_size("1G"), Some(1 << 30));
        assert_eq!(parse_byte_size("4 M"), Some(4 << 20));
        assert_eq!(parse_byte_size("-3"), None);
        assert_eq!(parse_byte_size("64MB"), None);
        assert_eq!(parse_byte_size("lots"), None);
        // overflow is a parse failure, not a wrapped number
        assert_eq!(parse_byte_size("99999999999999999999G"), None);
    }

    #[test]
    #[should_panic(expected = "--spill-budget: cannot parse")]
    fn bad_spill_budget_is_rejected_at_the_flag() {
        let a = parse(&["--spill-budget", "lots"]);
        let _ = a.byte_size_opt("spill-budget");
    }
}
