//! Minimal property-testing runner (no `proptest` in the offline crate set).
//!
//! The proptest-shaped invariant suites (`rust/tests/prop_*.rs`) run each
//! property over many generated inputs with a deterministic, reportable seed
//! and a size-based shrink: when a sized case fails, the runner retries
//! smaller sizes with the same per-case stream to report the smallest
//! failing size.  Override the base seed with `LCC_PROP_SEED=<u64>` and
//! scale every suite's case count with `LCC_PROP_CASES=<u64>` (a
//! multiplier numerator over 100: `LCC_PROP_CASES=300` triples the cases —
//! how the CI spill job deepens the property sweeps without code changes).

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Prop {
    pub cases: u64,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        let seed = std::env::var("LCC_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Prop { cases: 64, seed }
    }
}

/// Percentage multiplier applied to every suite's case count
/// (`LCC_PROP_CASES`, default 100 = as written).
fn case_scale() -> u64 {
    std::env::var("LCC_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100)
}

impl Prop {
    pub fn new(cases: u64) -> Self {
        Prop {
            cases: (cases * case_scale() / 100).max(1),
            ..Prop::default()
        }
    }

    /// Check `prop` over `cases` generated inputs; panics with the seed and
    /// case index on the first failure.
    pub fn check<T, G, P>(&self, name: &str, mut generate: G, mut prop: P)
    where
        G: FnMut(&mut Rng) -> T,
        P: FnMut(&T) -> Result<(), String>,
        T: std::fmt::Debug,
    {
        for case in 0..self.cases {
            let mut rng = Rng::new(self.seed ^ case.wrapping_mul(0x9E37_79B9));
            let input = generate(&mut rng);
            if let Err(msg) = prop(&input) {
                panic!(
                    "property {name:?} failed at case {case} \
                     (LCC_PROP_SEED={}): {msg}\ninput: {input:#?}",
                    self.seed
                );
            }
        }
    }

    /// Sized variant with shrink-by-size: `generate(rng, size)` receives a
    /// size that ramps up over cases; on failure the runner re-runs the same
    /// case stream at smaller sizes and reports the smallest failure.
    pub fn check_sized<T, G, P>(&self, name: &str, max_size: usize, mut generate: G, mut prop: P)
    where
        G: FnMut(&mut Rng, usize) -> T,
        P: FnMut(&T) -> Result<(), String>,
        T: std::fmt::Debug,
    {
        for case in 0..self.cases {
            let size = 1 + (max_size - 1) * case as usize / (self.cases.max(2) - 1) as usize;
            let mk_rng = |c: u64| Rng::new(self.seed ^ c.wrapping_mul(0x9E37_79B9));
            let input = generate(&mut mk_rng(case), size);
            if let Err(first_msg) = prop(&input) {
                // shrink: binary-search-ish descent over sizes
                let mut best = (size, first_msg);
                let mut s = size / 2;
                while s >= 1 {
                    let small = generate(&mut mk_rng(case), s);
                    match prop(&small) {
                        Err(m) => {
                            best = (s, m);
                            s /= 2;
                        }
                        Ok(()) => break,
                    }
                }
                panic!(
                    "property {name:?} failed at case {case} size {} \
                     (shrunk from {size}; LCC_PROP_SEED={}): {}",
                    best.0, self.seed, best.1
                );
            }
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Equality assertion for property bodies: on mismatch, fails the case
/// with both values rendered (the `assert_eq!` of the `Result<_, String>`
/// world, so the shrinker still gets to run).
#[macro_export]
macro_rules! prop_assert_eq {
    ($left:expr, $right:expr, $($fmt:tt)+) => {{
        let (l, r) = (&$left, &$right);
        if l != r {
            return Err(format!(
                "{}: left = {:?}, right = {:?}",
                format!($($fmt)+),
                l,
                r
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        Prop::new(16).check(
            "sum-commutes",
            |rng| (rng.gen_range(100), rng.gen_range(100)),
            |&(a, b)| {
                ran += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(ran, 16);
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics_with_name() {
        Prop::new(4).check("always-fails", |rng| rng.gen_range(10), |_| Err("always-fails".into()));
    }

    #[test]
    fn sized_cases_ramp_up() {
        let mut sizes = Vec::new();
        Prop::new(8).check_sized(
            "sizes",
            100,
            |_rng, size| size,
            |&s| {
                sizes.push(s);
                Ok(())
            },
        );
        assert!(sizes.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*sizes.last().unwrap(), 100);
        assert_eq!(sizes[0], 1);
    }

    #[test]
    #[should_panic(expected = "size 1")]
    fn shrink_reports_minimal_size() {
        // Fails for every size, so the shrinker must land on 1.
        Prop::new(4).check_sized("shrinks", 64, |_rng, size| size, |_| Err("nope".into()));
    }

    #[test]
    fn prop_assert_eq_reports_both_sides() {
        fn body(a: u32, b: u32) -> Result<(), String> {
            crate::prop_assert_eq!(a, b, "values differ");
            Ok(())
        }
        assert!(body(3, 3).is_ok());
        let msg = body(3, 4).unwrap_err();
        assert!(msg.contains("left = 3") && msg.contains("right = 4"), "{msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let p = Prop { cases: 8, seed };
            let mut xs = Vec::new();
            p.check("gen", |rng| rng.next_u64(), |&x| {
                xs.push(x);
                Ok(())
            });
            xs
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }
}
