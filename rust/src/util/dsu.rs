//! Disjoint-set union (union-find) with path halving + union by size.
//!
//! Used as (a) the sequential correctness oracle every distributed algorithm
//! is checked against, (b) the single-machine streaming finisher the paper
//! applies once the contracted graph is small (§6: "we use union-find ... as
//! it can process incoming edges in a streaming fashion and only use space
//! proportional to the number of vertices").

/// Union-find over `0..n`.
#[derive(Debug, Clone)]
pub struct DisjointSet {
    parent: Vec<u32>,
    size: Vec<u32>,
    components: usize,
}

impl DisjointSet {
    pub fn new(n: usize) -> Self {
        assert!(n <= u32::MAX as usize, "DisjointSet limited to u32 ids");
        DisjointSet {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
            components: n,
        }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Number of disjoint sets.
    pub fn components(&self) -> usize {
        self.components
    }

    /// Find with path halving (iterative, streaming-friendly).
    pub fn find(&mut self, mut x: u32) -> u32 {
        loop {
            let p = self.parent[x as usize];
            if p == x {
                return x;
            }
            let gp = self.parent[p as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
    }

    /// Union by size; returns true if the edge merged two sets.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra as usize] < self.size[rb as usize] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb as usize] = ra;
        self.size[ra as usize] += self.size[rb as usize];
        self.components -= 1;
        true
    }

    /// Size of the set containing `x`.
    pub fn set_size(&mut self, x: u32) -> u32 {
        let r = self.find(x);
        self.size[r as usize]
    }

    /// Canonical labels: `label[v] = min vertex id in v's set`.
    ///
    /// Using the *minimum* member (not the DSU root) makes labels
    /// implementation-independent, so oracle and distributed outputs can be
    /// compared with plain equality.
    pub fn canonical_labels(&mut self) -> Vec<u32> {
        let n = self.parent.len();
        let mut min_of_root: Vec<u32> = (0..n as u32).collect();
        for v in 0..n as u32 {
            let r = self.find(v) as usize;
            if v < min_of_root[r] {
                min_of_root[r] = v;
            }
        }
        (0..n as u32)
            .map(|v| {
                let r = self.find(v) as usize;
                min_of_root[r]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_sets_are_singletons() {
        let mut d = DisjointSet::new(5);
        assert_eq!(d.components(), 5);
        for v in 0..5 {
            assert_eq!(d.find(v), v);
            assert_eq!(d.set_size(v), 1);
        }
    }

    #[test]
    fn union_merges_and_counts() {
        let mut d = DisjointSet::new(6);
        assert!(d.union(0, 1));
        assert!(d.union(2, 3));
        assert!(!d.union(1, 0), "already merged");
        assert!(d.union(1, 2));
        assert_eq!(d.components(), 3); // {0,1,2,3} {4} {5}
        assert_eq!(d.set_size(3), 4);
        assert_eq!(d.find(0), d.find(3));
        assert_ne!(d.find(0), d.find(4));
    }

    #[test]
    fn canonical_labels_are_min_member() {
        let mut d = DisjointSet::new(5);
        d.union(4, 2);
        d.union(2, 3);
        let labels = d.canonical_labels();
        assert_eq!(labels, vec![0, 1, 2, 2, 2]);
    }

    #[test]
    fn long_chain_is_flattened() {
        let n = 10_000;
        let mut d = DisjointSet::new(n);
        for v in 1..n as u32 {
            d.union(v - 1, v);
        }
        assert_eq!(d.components(), 1);
        assert_eq!(d.set_size(0), n as u32);
        let labels = d.canonical_labels();
        assert!(labels.iter().all(|&l| l == 0));
    }
}
