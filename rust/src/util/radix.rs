//! Parallel LSD radix sort for `u64` keys.
//!
//! §Perf: replaces the comparison sort in `Graph::normalize` — the edge
//! list is re-sorted after *every* contraction phase, making the sort one
//! of the hottest non-engine loops in the system.  Edges pack into `u64`
//! (`u << 32 | v`, preserving lexicographic order), which radix-sorts in
//! O(m) per 8-bit digit instead of O(m log m) comparisons.
//!
//! Each pass over one digit: per-chunk histograms (parallel) → exclusive
//! per-chunk bucket offsets (serial over `256·t` counters) → stable
//! parallel scatter into disjoint target ranges.  An initial scan computes
//! all eight digit histograms at once so constant digits are skipped
//! entirely; with dense vertex ids (`u, v < n`) the top bytes are constant
//! and the sort does ~half the passes.

use crate::mpc::pool::{self, chunk_range};

const DIGITS: usize = 8;
const BUCKETS: usize = 256;

#[inline]
fn digit(key: u64, d: usize) -> usize {
    ((key >> (8 * d)) & 0xFF) as usize
}

/// Raw destination pointer shipped to scatter jobs.  Writes are disjoint
/// by construction (each chunk owns exclusive cursor ranges per bucket).
#[derive(Clone, Copy)]
struct SendPtr(*mut u64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Sort `keys` ascending, stable within equal keys, using the global
/// worker pool.  Falls back to the comparison sort for small inputs where
/// the pass overhead would dominate.
pub fn par_sort_u64(keys: &mut Vec<u64>) {
    let len = keys.len();
    if len < (1 << 12) {
        keys.sort_unstable();
        return;
    }
    let pool = pool::global();
    // Don't over-chunk small arrays: each chunk should carry real work.
    let t = pool.threads().clamp(1, len.div_ceil(1 << 12).max(1));
    let chunks: Vec<(usize, usize)> = (0..t).map(|i| chunk_range(len, t, i)).collect();

    // One parallel scan: all 8 digit histograms per chunk.
    let keys_ro: &[u64] = keys;
    let all_hists: Vec<Vec<[u64; BUCKETS]>> = pool.run_jobs(
        chunks
            .iter()
            .map(|&(a, b)| {
                let part = &keys_ro[a..b];
                move || {
                    let mut h = vec![[0u64; BUCKETS]; DIGITS];
                    for &k in part {
                        for (d, hd) in h.iter_mut().enumerate() {
                            hd[digit(k, d)] += 1;
                        }
                    }
                    h
                }
            })
            .collect(),
    );
    let mut global_hist = vec![[0u64; BUCKETS]; DIGITS];
    for h in &all_hists {
        for d in 0..DIGITS {
            for b in 0..BUCKETS {
                global_hist[d][b] += h[d][b];
            }
        }
    }
    // A digit where every key falls in one bucket needs no pass.
    let needed: Vec<usize> = (0..DIGITS)
        .filter(|&d| !global_hist[d].iter().any(|&c| c == len as u64))
        .collect();
    if needed.is_empty() {
        return; // all keys identical
    }

    let mut src: Vec<u64> = std::mem::take(keys);
    let mut dst: Vec<u64> = vec![0u64; len];
    for (pass_idx, &d) in needed.iter().enumerate() {
        // Per-chunk histograms of this digit over the *current* order.
        // The first pass reuses the initial scan (order untouched so far).
        let src_ro: &[u64] = &src;
        let hists: Vec<Vec<u64>> = if pass_idx == 0 {
            all_hists.iter().map(|h| h[d].to_vec()).collect()
        } else {
            pool.run_jobs(
                chunks
                    .iter()
                    .map(|&(a, b)| {
                        let part = &src_ro[a..b];
                        move || {
                            let mut h = vec![0u64; BUCKETS];
                            for &k in part {
                                h[digit(k, d)] += 1;
                            }
                            h
                        }
                    })
                    .collect(),
            )
        };

        // Exclusive global bucket starts, then per-chunk cursors: chunk
        // c's bucket b begins at start[b] + Σ_{c'<c} hists[c'][b].
        // Chunks scatter in original order, so the sort stays stable.
        let mut start = [0u64; BUCKETS];
        let mut sum = 0u64;
        for b in 0..BUCKETS {
            start[b] = sum;
            sum += global_hist[d][b];
        }
        let mut cursors: Vec<Vec<u64>> = Vec::with_capacity(t);
        let mut running = start;
        for h in &hists {
            cursors.push(running.to_vec());
            for b in 0..BUCKETS {
                running[b] += h[b];
            }
        }

        let dst_ptr = SendPtr(dst.as_mut_ptr());
        let _: Vec<()> = pool.run_jobs(
            chunks
                .iter()
                .zip(cursors)
                .map(|(&(a, b), mut cur)| {
                    let part = &src_ro[a..b];
                    move || {
                        for &k in part {
                            let bkt = digit(k, d);
                            // SAFETY: cursor ranges of distinct (chunk,
                            // bucket) pairs are disjoint and within bounds
                            // (they partition 0..len by construction).
                            unsafe { *dst_ptr.0.add(cur[bkt] as usize) = k };
                            cur[bkt] += 1;
                        }
                    }
                })
                .collect(),
        );
        std::mem::swap(&mut src, &mut dst);
    }
    *keys = src;
}

/// Sort canonical edge pairs ascending (optionally deduping): packs each
/// pair into a `u64` (`u << 32 | v`, which preserves lexicographic pair
/// order) and runs [`par_sort_u64`]; small lists keep the comparison
/// sort.  The one edge-sort idiom shared by `Graph::normalize` and
/// `ShardedGraph::to_graph` — keeping their results bit-identical by
/// construction.
pub fn par_sort_edge_pairs(edges: &mut Vec<(u32, u32)>, dedup: bool) {
    if edges.len() < (1 << 12) {
        edges.sort_unstable();
        if dedup {
            edges.dedup();
        }
        return;
    }
    let mut keys: Vec<u64> = edges
        .iter()
        .map(|&(u, v)| ((u as u64) << 32) | v as u64)
        .collect();
    par_sort_u64(&mut keys);
    if dedup {
        keys.dedup();
    }
    edges.clear();
    edges.extend(keys.into_iter().map(|k| ((k >> 32) as u32, k as u32)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check(mut keys: Vec<u64>) {
        let mut expect = keys.clone();
        expect.sort_unstable();
        par_sort_u64(&mut keys);
        assert_eq!(keys, expect);
    }

    #[test]
    fn sorts_small_inputs_via_fallback() {
        check(Vec::new());
        check(vec![5]);
        check(vec![3, 1, 2]);
        check((0..1000u64).rev().collect());
    }

    #[test]
    fn sorts_large_random_inputs() {
        let mut rng = Rng::new(7);
        check((0..100_000).map(|_| rng.next_u64()).collect());
    }

    #[test]
    fn sorts_packed_edge_shaped_keys() {
        // dense ids < n: top bytes constant -> exercises digit skipping
        let mut rng = Rng::new(8);
        let n = 50_000u64;
        check(
            (0..120_000)
                .map(|_| (rng.gen_range(n) << 32) | rng.gen_range(n))
                .collect(),
        );
    }

    #[test]
    fn sorts_with_heavy_duplicates() {
        let mut rng = Rng::new(9);
        check((0..60_000).map(|_| rng.gen_range(17)).collect());
    }

    #[test]
    fn all_equal_keys_short_circuit() {
        check(vec![42u64; 20_000]);
    }

    #[test]
    fn already_sorted_and_reverse_sorted() {
        check((0..50_000u64).collect());
        check((0..50_000u64).rev().collect());
        check((0..50_000u64).map(|i| i ^ (i >> 3)).collect());
    }

    #[test]
    fn edge_pairs_sort_and_dedup_both_size_regimes() {
        let mut rng = Rng::new(11);
        for m in [100usize, 20_000] {
            let raw: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.gen_range(300) as u32, rng.gen_range(300) as u32))
                .collect();
            for dedup in [false, true] {
                let mut got = raw.clone();
                par_sort_edge_pairs(&mut got, dedup);
                let mut want = raw.clone();
                want.sort_unstable();
                if dedup {
                    want.dedup();
                }
                assert_eq!(got, want, "m={m} dedup={dedup}");
            }
        }
    }

    #[test]
    fn high_bits_exercised() {
        let mut rng = Rng::new(10);
        check(
            (0..30_000)
                .map(|i| rng.next_u64() | ((i as u64 % 3) << 62))
                .collect(),
        );
    }
}
