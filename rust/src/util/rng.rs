//! Deterministic PRNGs.
//!
//! The offline crate set has no `rand`, so we carry our own: SplitMix64 for
//! seeding/hashing and Xoshiro256++ as the workhorse generator.  Both are
//! public-domain algorithms (Vigna); determinism across runs is load-bearing
//! for the experiment harness (median-of-3-seeds protocol, §6 of the paper).

/// SplitMix64 step: also used as the stateless vertex-hash in the algorithms
/// (the paper assigns each vertex "a random hash chosen uniformly"; we hash
/// `(seed, vertex)` so machines can evaluate priorities without a broadcast).
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Stateless uniform hash of a vertex under a per-phase seed.
///
/// Collision-free in practice for our scales (64-bit); the algorithms only
/// compare hashes, matching the paper's "we can only compare the priorities"
/// observation (§3).
#[inline]
pub fn vertex_hash(seed: u64, v: u64) -> u64 {
    splitmix64(seed ^ splitmix64(v.wrapping_add(0x517cc1b727220a95)))
}

/// Xoshiro256++ PRNG (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64, as recommended by the Xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            *slot = splitmix64(x);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, n)`; unbiased via Lemire rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Geometric-like sample: number of failures before a success with
    /// probability `p` (used by the G(n,p) skip-sampling generator).
    #[inline]
    pub fn skip_geometric(&mut self, p: f64) -> u64 {
        if p >= 1.0 {
            return 0;
        }
        let u = self.next_f64().max(f64::MIN_POSITIVE);
        (u.ln() / (1.0 - p).ln()).floor() as u64
    }

    /// Split off an independent stream (for per-thread determinism).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ splitmix64(stream))
    }

    /// The raw state words — the stream position a checkpoint records.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Resume a stream from checkpointed state words ([`Rng::state`]).
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(4);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut rng = Rng::new(5);
        let p = rng.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100u32).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut rng = Rng::new(6);
        let mut xs: Vec<u32> = (0..50).map(|i| i % 7).collect();
        let mut want = xs.clone();
        rng.shuffle(&mut xs);
        want.sort_unstable();
        xs.sort_unstable();
        assert_eq!(xs, want);
    }

    #[test]
    fn vertex_hash_stable_and_spread() {
        let h1 = vertex_hash(42, 7);
        assert_eq!(h1, vertex_hash(42, 7));
        assert_ne!(h1, vertex_hash(42, 8));
        assert_ne!(h1, vertex_hash(43, 7));
        // rough uniformity: high bit set about half the time
        let hi = (0..10_000)
            .filter(|&v| vertex_hash(9, v) >> 63 == 1)
            .count();
        assert!((4_000..6_000).contains(&hi), "hi-bit count {hi}");
    }

    #[test]
    fn skip_geometric_mean_close_to_inverse_p() {
        let mut rng = Rng::new(8);
        let p = 0.01;
        let n = 20_000;
        let total: u64 = (0..n).map(|_| rng.skip_geometric(p)).sum();
        let mean = total as f64 / n as f64;
        // E[failures before success] = (1-p)/p = 99
        assert!((mean - 99.0).abs() < 5.0, "mean {mean}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(11);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Rng::new(13);
        for _ in 0..37 {
            a.next_u64();
        }
        let saved = a.state();
        let tail: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(saved);
        let replay: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        assert_eq!(tail, replay, "resumed stream must continue bit-identically");
    }

    #[test]
    fn gen_bool_extremes() {
        let mut rng = Rng::new(12);
        assert!(!(0..100).any(|_| rng.gen_bool(0.0)));
        assert!((0..100).all(|_| rng.gen_bool(1.0)));
    }
}
