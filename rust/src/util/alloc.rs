//! Counting global allocator: [`System`] plus one relaxed atomic
//! increment per allocation.
//!
//! The zero-copy data plane's contract is *counted*, not assumed: a
//! steady-state spilled hop round must perform no per-edge heap
//! allocation (shard payloads stream through borrowed cursors over
//! mmap'd images — see `graph::spill`).  The per-round `allocs` delta in
//! [`crate::mpc::RoundTiming`] and the run totals in the `lcc perf` JSON
//! come from this counter, and the CI spill gate fails when a round's
//! allocation count scales with the edge count again.
//!
//! Only allocation *events* are counted (alloc / realloc / zeroed-alloc;
//! frees are not): the gate cares about churn on the hot path, and an
//! event count is cheaper and less ambiguous than tracking live bytes
//! under realloc.  The counter is process-global and monotone; readers
//! take deltas between two [`allocation_count`] snapshots.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// The crate's `#[global_allocator]` (registered in `lib.rs`).
pub struct CountingAlloc;

// SAFETY: defers every operation to `System`, which upholds the
// GlobalAlloc contract; the counter is a side effect with no aliasing.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Allocation events since process start (monotone; take deltas).
pub fn allocation_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocations_are_counted() {
        let before = allocation_count();
        let v: Vec<u64> = Vec::with_capacity(1024);
        let after = allocation_count();
        assert!(after > before, "Vec allocation was not counted");
        drop(v);
    }

    #[test]
    fn count_is_monotone() {
        let a = allocation_count();
        let _s = format!("{a}");
        let b = allocation_count();
        assert!(b >= a);
    }
}
