//! Minimal JSON reader/writer (no `serde` in the offline crate set).
//!
//! A `Json` value tree with a stable (insertion-ordered) object
//! representation, compact/pretty serializers, and a full
//! recursive-descent [`parse`] — enough for the run reports, the bench
//! result files, and the artifact manifest (`runtime::artifact`).

use std::fmt::Write as _;

/// A JSON value with insertion-ordered objects.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert / overwrite a key (object variant only).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kvs) = self {
            if let Some(kv) = kvs.iter_mut().find(|(k, _)| k == key) {
                kv.1 = value.into();
            } else {
                kvs.push((key.to_string(), value.into()));
            }
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn dumps(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    x.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(kvs) => {
                if kvs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (full recursive-descent; numbers become `Int` when
/// they round-trip as i64, `Num` otherwise).  Used for the artifact
/// manifest and report re-loading.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut kvs = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.ws();
            xs.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or("short \\u escape")?,
                            )
                            .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s =
                        std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        if let Ok(i) = s.parse::<i64>() {
            Ok(Json::Int(i))
        } else {
            s.parse::<f64>()
                .map(Json::Num)
                .map_err(|e| format!("bad number {s:?}: {e}"))
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<i64> for Json {
    fn from(i: i64) -> Json {
        Json::Int(i)
    }
}
impl From<u64> for Json {
    fn from(i: u64) -> Json {
        Json::Int(i as i64)
    }
}
impl From<usize> for Json {
    fn from(i: usize) -> Json {
        Json::Int(i as i64)
    }
}
impl From<u32> for Json {
    fn from(i: u32) -> Json {
        Json::Int(i as i64)
    }
}
impl From<f64> for Json {
    fn from(f: f64) -> Json {
        Json::Num(f)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Int(3).dumps(), "3");
        assert_eq!(Json::Bool(true).dumps(), "true");
        assert_eq!(Json::Null.dumps(), "null");
        assert_eq!(Json::Num(1.5).dumps(), "1.5");
        assert_eq!(Json::Num(f64::NAN).dumps(), "null");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").dumps(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::from("\u{1}").dumps(), "\"\\u0001\"");
    }

    #[test]
    fn object_roundtrip_shape() {
        let j = Json::obj()
            .set("name", "lcc")
            .set("n", 42u64)
            .set("xs", vec![1i64, 2, 3]);
        assert_eq!(j.dumps(), r#"{"name":"lcc","n":42,"xs":[1,2,3]}"#);
        assert_eq!(j.get("n").unwrap().as_i64(), Some(42));
        assert_eq!(j.get("name").unwrap().as_str(), Some("lcc"));
        assert_eq!(j.get("xs").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn set_overwrites() {
        let j = Json::obj().set("k", 1i64).set("k", 2i64);
        assert_eq!(j.dumps(), r#"{"k":2}"#);
    }

    #[test]
    fn pretty_is_indented() {
        let j = Json::obj().set("a", vec![1i64]);
        let p = j.pretty();
        assert!(p.contains("\n  \"a\": [\n"), "{p}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).pretty(), "[]");
        assert_eq!(Json::obj().pretty(), "{}");
    }
}

#[cfg(test)]
mod parse_tests {
    use super::*;

    #[test]
    fn parse_roundtrip_compact_and_pretty() {
        let j = Json::obj()
            .set("name", "lcc")
            .set("n", 42u64)
            .set("pi", 3.25f64)
            .set("ok", true)
            .set("xs", vec![1i64, 2, 3])
            .set("nested", Json::obj().set("k", Json::Null));
        assert_eq!(parse(&j.dumps()).unwrap(), j);
        assert_eq!(parse(&j.pretty()).unwrap(), j);
    }

    #[test]
    fn parse_escapes() {
        let j = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(parse("-17").unwrap().as_i64(), Some(-17));
        assert_eq!(parse("2.5").unwrap().as_f64(), Some(2.5));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("true false").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
