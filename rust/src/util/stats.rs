//! Summary statistics and histograms for the experiment harness.

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`), `None` where the kernel interface is absent
/// (non-Linux).  Recorded in perf artifacts so the out-of-core and
/// distributed protocols' memory behavior is visible in CI.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Mean of a sample (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted sample; `q` in [0,1].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "percentile q={q}");
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Log-2 bucketed histogram (bucket k counts values in [2^k, 2^(k+1))),
/// used for degree distributions and pointer-chain depth profiles.
#[derive(Debug, Clone, Default)]
pub struct Log2Histogram {
    pub buckets: Vec<u64>,
    pub zeros: u64,
    pub count: u64,
    pub max: u64,
}

impl Log2Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: u64) {
        self.count += 1;
        self.max = self.max.max(v);
        if v == 0 {
            self.zeros += 1;
            return;
        }
        let b = 63 - v.leading_zeros() as usize;
        if self.buckets.len() <= b {
            self.buckets.resize(b + 1, 0);
        }
        self.buckets[b] += 1;
    }

    /// Render as `(bucket_floor, count)` rows.
    pub fn rows(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        if self.zeros > 0 {
            out.push((0, self.zeros));
        }
        for (b, &c) in self.buckets.iter().enumerate() {
            if c > 0 {
                out.push((1u64 << b, c));
            }
        }
        out
    }
}

/// Fixed-width ASCII table writer for harness output (the "same rows the
/// paper reports" formatting used by `lcc table2` etc.).
pub struct AsciiTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl AsciiTable {
    pub fn new(header: &[&str]) -> Self {
        AsciiTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert!((percentile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn log2_histogram_buckets() {
        let mut h = Log2Histogram::new();
        for v in [0, 1, 1, 2, 3, 4, 7, 8, 1024] {
            h.add(v);
        }
        assert_eq!(h.count, 9);
        assert_eq!(h.zeros, 1);
        assert_eq!(h.max, 1024);
        let rows = h.rows();
        assert!(rows.contains(&(0, 1)));
        assert!(rows.contains(&(1, 2))); // 1,1
        assert!(rows.contains(&(2, 2))); // 2,3
        assert!(rows.contains(&(4, 2))); // 4,7
        assert!(rows.contains(&(8, 1)));
        assert!(rows.contains(&(1024, 1)));
    }

    #[test]
    fn ascii_table_renders_aligned() {
        let mut t = AsciiTable::new(&["name", "value"]);
        t.row(vec!["orkut".into(), "2".into()]);
        t.row(vec!["friendster".into(), "3".into()]);
        let s = t.render();
        assert!(s.contains("orkut"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // value column aligned
        assert_eq!(
            lines[2].find('2'),
            lines[3].find('3'),
        );
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn ascii_table_rejects_bad_row() {
        let mut t = AsciiTable::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
