//! Artifact registry: the `manifest.json` written by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Metadata for one compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub shard_size: usize,
    pub outputs: usize,
}

/// The artifact manifest (one per `artifacts/` directory).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let root = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        if root.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("unsupported artifact format in {}", path.display());
        }
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest: missing artifacts array")?
        {
            artifacts.push(ArtifactMeta {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .context("artifact: missing name")?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .context("artifact: missing file")?
                    .to_string(),
                shard_size: a
                    .get("shard_size")
                    .and_then(Json::as_i64)
                    .context("artifact: missing shard_size")? as usize,
                outputs: a
                    .get("outputs")
                    .and_then(Json::as_i64)
                    .unwrap_or(1) as usize,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Find an artifact by exact name.
    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Full path of an artifact's HLO text file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Shard sizes available for a given artifact family (e.g.
    /// `"local_labels"` -> `[256, 1024]`), ascending.
    pub fn shard_sizes(&self, family: &str) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| {
                a.name
                    .strip_prefix(family)
                    .map(|rest| rest.starts_with('_'))
                    .unwrap_or(false)
            })
            .map(|a| a.shard_size)
            .collect();
        sizes.sort_unstable();
        sizes.dedup();
        sizes
    }
}

/// Default artifacts directory: `$LCC_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("LCC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn load_and_query() {
        let dir = std::env::temp_dir().join("lcc_manifest_test");
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","artifacts":[
                {"name":"local_labels_256","file":"local_labels_256.hlo.txt","shard_size":256,"inputs":[],"outputs":1},
                {"name":"local_labels_1024","file":"local_labels_1024.hlo.txt","shard_size":1024,"inputs":[],"outputs":1},
                {"name":"tree_roots_256","file":"tree_roots_256.hlo.txt","shard_size":256,"inputs":[],"outputs":1}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.find("local_labels_256").unwrap().shard_size, 256);
        assert!(m.find("nope").is_none());
        assert_eq!(m.shard_sizes("local_labels"), vec![256, 1024]);
        assert_eq!(m.shard_sizes("tree_roots"), vec![256]);
        assert!(m
            .path_of(m.find("tree_roots_256").unwrap())
            .ends_with("tree_roots_256.hlo.txt"));
    }

    #[test]
    fn rejects_bad_format() {
        let dir = std::env::temp_dir().join("lcc_manifest_bad");
        write_manifest(&dir, r#"{"format":"protobuf","artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(Manifest::load("/nonexistent/dir").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // best-effort check against the actual artifacts dir when present
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.shard_sizes("local_labels").is_empty());
        }
    }
}
