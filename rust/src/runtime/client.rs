//! PJRT client wrapper: load HLO-text artifacts and compile them once.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): the
//! xla_extension 0.5.1 bundled with the `xla` 0.1.6 crate rejects jax≥0.5's
//! serialized protos (64-bit instruction ids), while the text parser
//! reassigns ids and round-trips cleanly.  See `python/compile/aot.py` and
//! /opt/xla-example/README.md.

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT CPU client owning compiled executables.
pub struct XlaClient {
    client: xla::PjRtClient,
}

impl XlaClient {
    /// Create the CPU client (the only PJRT plugin available in this image;
    /// TPU lowering is compile-only — see DESIGN.md §Hardware-Adaptation).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(XlaClient { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it for this client.
    pub fn compile_hlo_text<P: AsRef<Path>>(
        &self,
        path: P,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .with_context(|| format!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))
    }
}

/// Execute a compiled single-output-tuple artifact on int32 inputs and
/// return the first tuple element as an `i32` vector.
pub fn run_i32(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<i32>> {
    let result = exe
        .execute::<xla::Literal>(inputs)
        .context("execute artifact")?[0][0]
        .to_literal_sync()
        .context("fetch result")?;
    // aot.py lowers with return_tuple=True
    let out = result.to_tuple1().context("unwrap result tuple")?;
    out.to_vec::<i32>().context("read i32 result")
}

/// Build an `[n] i32` literal.
pub fn lit_vec_i32(xs: &[i32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// Build an `[n, n] i32` literal from a row-major buffer.
pub fn lit_mat_i32(xs: &[i32], n: usize) -> Result<xla::Literal> {
    anyhow::ensure!(xs.len() == n * n, "matrix buffer size mismatch");
    xla::Literal::vec1(xs)
        .reshape(&[n as i64, n as i64])
        .context("reshape mask literal")
}
