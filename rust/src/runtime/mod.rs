//! PJRT runtime: load the AOT HLO-text artifacts built by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! `make artifacts` runs Python once; afterwards the `lcc` binary is
//! self-contained — this module never shells out to Python.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{default_dir, ArtifactMeta, Manifest};
pub use client::XlaClient;
pub use executor::ShardExecutor;

/// Convenience: load the best shard executor from the default artifacts
/// directory, or an error string when artifacts are not built.
pub fn try_default_executor() -> Result<ShardExecutor, String> {
    let dir = default_dir();
    let manifest = Manifest::load(&dir).map_err(|e| format!("{e:#}"))?;
    ShardExecutor::load_largest(&manifest).map_err(|e| format!("{e:#}"))
}
