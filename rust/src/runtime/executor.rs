//! Dense shard executor: the Layer-3 ↔ Layer-1 bridge.
//!
//! Packs a (sub)graph into the dense shard form the AOT artifacts expect —
//! `[n, n]` int32 adjacency mask with the diagonal set on live slots,
//! `[n]` int32 priorities with `INF` padding — executes the compiled
//! executables, and unpacks the labels.  Implements
//! [`crate::cc::backend::DenseBackend`], so LocalContraction's phase labels
//! transparently run on the compiled Pallas kernel whenever the current
//! graph fits a shard (the "dense finisher" of DESIGN.md).

use anyhow::{Context, Result};

use super::artifact::Manifest;
use super::client::{lit_mat_i32, lit_vec_i32, run_i32, XlaClient};
use crate::cc::backend::{DenseBackend, INF};
use crate::graph::Graph;

/// Compiled executables for one shard size.
pub struct ShardExecutor {
    client: XlaClient,
    n: usize,
    local_labels: xla::PjRtLoadedExecutable,
    hash_min_step: xla::PjRtLoadedExecutable,
    tree_roots: xla::PjRtLoadedExecutable,
    phase_shrink: Option<xla::PjRtLoadedExecutable>,
    /// Executions performed (for perf reporting).
    pub calls: std::cell::Cell<u64>,
}

impl ShardExecutor {
    /// Load + compile the artifacts for shard size `n` from `manifest`.
    pub fn load(manifest: &Manifest, n: usize) -> Result<ShardExecutor> {
        let client = XlaClient::cpu()?;
        let get = |family: &str| -> Result<xla::PjRtLoadedExecutable> {
            let name = format!("{family}_{n}");
            let meta = manifest
                .find(&name)
                .with_context(|| format!("artifact {name} not in manifest"))?;
            client.compile_hlo_text(manifest.path_of(meta))
        };
        Ok(ShardExecutor {
            n,
            local_labels: get("local_labels")?,
            hash_min_step: get("hash_min_step")?,
            tree_roots: get("tree_roots")?,
            phase_shrink: get("phase_shrink_stats").ok(),
            client,
            calls: std::cell::Cell::new(0),
        })
    }

    /// Load using the largest shard size available in `manifest`.
    pub fn load_largest(manifest: &Manifest) -> Result<ShardExecutor> {
        let sizes = manifest.shard_sizes("local_labels");
        let n = *sizes
            .last()
            .context("no local_labels artifacts in manifest")?;
        Self::load(manifest, n)
    }

    pub fn shard_size(&self) -> usize {
        self.n
    }

    pub fn platform(&self) -> String {
        self.client.platform()
    }

    /// Pack a graph into the dense `[n, n]` mask (diag set on live slots).
    pub fn pack_mask(&self, g: &Graph) -> Result<Vec<i32>> {
        let live = g.num_vertices();
        anyhow::ensure!(
            live <= self.n,
            "graph ({live} vertices) exceeds shard size {}",
            self.n
        );
        let n = self.n;
        let mut mask = vec![0i32; n * n];
        for v in 0..live {
            mask[v * n + v] = 1;
        }
        for &(u, v) in g.edges() {
            mask[u as usize * n + v as usize] = 1;
            mask[v as usize * n + u as usize] = 1;
        }
        Ok(mask)
    }

    /// Pad live priorities with INF up to the shard size.
    fn pack_prio(&self, prio: &[i32]) -> Vec<i32> {
        let mut p = vec![INF; self.n];
        p[..prio.len()].copy_from_slice(prio);
        p
    }

    fn run_mask_prio(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        g: &Graph,
        prio: &[i32],
    ) -> Result<Vec<i32>> {
        anyhow::ensure!(
            prio.len() == g.num_vertices(),
            "prio length {} != vertices {}",
            prio.len(),
            g.num_vertices()
        );
        let mask = lit_mat_i32(&self.pack_mask(g)?, self.n)?;
        let prio_l = lit_vec_i32(&self.pack_prio(prio));
        self.calls.set(self.calls.get() + 1);
        let mut out = run_i32(exe, &[mask, prio_l])?;
        out.truncate(g.num_vertices());
        Ok(out)
    }

    /// Labels + distinct-label count (Lemma 4.1 diagnostics artifact).
    /// Requires priorities forming a permutation of `[0, live)`.
    pub fn phase_shrink_stats(&self, g: &Graph, prio: &[i32]) -> Result<(Vec<i32>, i32)> {
        let exe = self
            .phase_shrink
            .as_ref()
            .context("phase_shrink_stats artifact not loaded")?;
        let mask = lit_mat_i32(&self.pack_mask(g)?, self.n)?;
        let prio_l = lit_vec_i32(&self.pack_prio(prio));
        self.calls.set(self.calls.get() + 1);
        let result = exe
            .execute::<xla::Literal>(&[mask, prio_l])
            .context("execute phase_shrink_stats")?[0][0]
            .to_literal_sync()?;
        let (labels_l, count_l) = result.to_tuple2().context("unwrap 2-tuple")?;
        let mut labels = labels_l.to_vec::<i32>()?;
        labels.truncate(g.num_vertices());
        let count = count_l.get_first_element::<i32>()?;
        Ok((labels, count))
    }
}

impl DenseBackend for ShardExecutor {
    fn max_vertices(&self) -> usize {
        self.n
    }

    fn local_labels(&self, g: &Graph, prio: &[i32]) -> Result<Vec<i32>> {
        self.run_mask_prio(&self.local_labels, g, prio)
    }

    fn hash_min_step(&self, g: &Graph, prio: &[i32]) -> Result<Vec<i32>> {
        self.run_mask_prio(&self.hash_min_step, g, prio)
    }

    fn tree_roots(&self, f: &[i32]) -> Result<Vec<i32>> {
        anyhow::ensure!(f.len() <= self.n, "pointer array exceeds shard");
        // pad with identity pointers (fixed points stay put)
        let mut padded: Vec<i32> = (0..self.n as i32).collect();
        padded[..f.len()].copy_from_slice(f);
        self.calls.set(self.calls.get() + 1);
        let mut out = run_i32(&self.tree_roots, &[lit_vec_i32(&padded)])?;
        out.truncate(f.len());
        Ok(out)
    }
}
