//! # lcc — Connected Components at Scale via Local Contractions
//!
//! A three-layer reproduction of Łącki, Mirrokni & Włodarczyk (2018):
//!
//! * **Layer 3 (this crate)** — an MPC(0) execution engine with explicit
//!   machines, shuffles, and communication accounting ([`mpc`]); the paper's
//!   contraction algorithms and the published baselines ([`cc`]); a
//!   streaming coordinator with sharding, backpressure, and run reports
//!   ([`coordinator`]); and the benchmark harness regenerating every table
//!   and figure of the paper's evaluation ([`bench`]).
//! * **Layer 2/1 (build time)** — `python/compile/` lowers the per-phase
//!   label computation (a Pallas tropical-SpMV kernel inside a JAX graph)
//!   to HLO-text artifacts; [`runtime`] loads and executes them via PJRT.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `lcc` binary is self-contained.

/// Allocation-counting [`System`](std::alloc::System) wrapper: the
/// per-round `allocs` metric and the CI zero-copy gate read its counter
/// (see [`util::alloc`]).
#[global_allocator]
static GLOBAL_ALLOC: util::alloc::CountingAlloc = util::alloc::CountingAlloc;

pub mod bench;
pub mod cc;
pub mod coordinator;
pub mod graph;
pub mod mpc;
pub mod runtime;
pub mod serve;
pub mod util;
