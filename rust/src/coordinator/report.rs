//! Run reports: the JSON/text record every harness run emits.

use crate::cc::CcResult;
use crate::mpc::{MeshMetrics, RecoveryMetrics};
use crate::util::json::Json;

/// Everything a single algorithm run produced.
#[derive(Debug, Clone)]
pub struct Report {
    pub algorithm: String,
    pub dataset: String,
    pub n: usize,
    pub m: usize,
    pub phases: u32,
    pub rounds: usize,
    pub completed: bool,
    pub num_components: usize,
    pub largest_component: usize,
    pub edges_per_phase: Vec<u64>,
    pub nodes_per_phase: Vec<u64>,
    pub total_shuffle_bytes: u64,
    pub max_round_bytes: u64,
    pub dht_ops: u64,
    pub wall_ms: f64,
    /// Some(true/false) when the oracle check ran.
    pub verified: Option<bool>,
    /// Dense-backend executions (XLA artifact calls), if used.
    pub xla_calls: u64,
    /// Round transport the run shuffled on (`"inproc"` / `"proc"`).
    pub transport: String,
    /// Worker-recovery log (shuffle transport; empty for undisturbed
    /// runs).  Observability only — never part of bit-identity.
    pub recovery: RecoveryMetrics,
    /// Mesh data-plane counters (shuffle transport only): sync vs mesh
    /// bytes, delta-sync and pipelined-batch adoption.  Observability
    /// only, like `recovery`.
    pub mesh: Option<MeshMetrics>,
}

impl Report {
    pub fn from_result(
        algorithm: &str,
        dataset: &str,
        n: usize,
        m: usize,
        res: &CcResult,
        wall_ms: f64,
    ) -> Report {
        let mut labels = res.labels.clone();
        labels.sort_unstable();
        let mut largest = 0usize;
        let mut run = 0usize;
        let mut prev = None;
        for &l in &labels {
            if Some(l) == prev {
                run += 1;
            } else {
                run = 1;
                prev = Some(l);
            }
            largest = largest.max(run);
        }
        Report {
            algorithm: algorithm.to_string(),
            dataset: dataset.to_string(),
            n,
            m,
            phases: res.phases,
            rounds: res.metrics.num_rounds(),
            completed: res.completed,
            num_components: res.num_components(),
            largest_component: largest,
            edges_per_phase: res.edges_per_phase.clone(),
            nodes_per_phase: res.nodes_per_phase.clone(),
            total_shuffle_bytes: res.metrics.total_bytes(),
            max_round_bytes: res.metrics.max_round_bytes(),
            dht_ops: res.metrics.total_dht_ops(),
            wall_ms,
            verified: None,
            xla_calls: 0,
            transport: "inproc".to_string(),
            recovery: res.metrics.recovery.clone(),
            mesh: None,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("algorithm", self.algorithm.as_str())
            .set("dataset", self.dataset.as_str())
            .set("n", self.n)
            .set("m", self.m)
            .set("phases", u64::from(self.phases))
            .set("rounds", self.rounds)
            .set("completed", self.completed)
            .set("num_components", self.num_components)
            .set("largest_component", self.largest_component)
            .set("edges_per_phase", self.edges_per_phase.clone())
            .set("nodes_per_phase", self.nodes_per_phase.clone())
            .set("total_shuffle_bytes", self.total_shuffle_bytes)
            .set("max_round_bytes", self.max_round_bytes)
            .set("dht_ops", self.dht_ops)
            .set("wall_ms", self.wall_ms)
            .set(
                "verified",
                match self.verified {
                    None => Json::Null,
                    Some(b) => Json::Bool(b),
                },
            )
            .set("xla_calls", self.xla_calls)
            .set("transport", self.transport.as_str())
            .set(
                "recovery",
                Json::obj()
                    .set("replayed_rounds", self.recovery.replayed_rounds)
                    .set("total_ms", self.recovery.total_ms)
                    .set(
                        "events",
                        Json::Arr(
                            self.recovery
                                .events
                                .iter()
                                .map(|e| {
                                    Json::obj()
                                        .set("label", e.label.as_str())
                                        .set(
                                            "worker",
                                            match e.worker {
                                                None => Json::Null,
                                                Some(w) => Json::from(w),
                                            },
                                        )
                                        .set("cause", e.cause.as_str())
                                        .set("respawn_attempts", e.respawn_attempts)
                                        .set("wall_ms", e.wall_ms)
                                })
                                .collect(),
                        ),
                    ),
            )
            .set(
                "mesh",
                match &self.mesh {
                    None => Json::Null,
                    Some(m) => Json::obj()
                        .set("hops", m.hops)
                        .set("hop_batches", m.hop_batches)
                        .set("state_syncs", m.state_syncs)
                        .set("delta_syncs", m.delta_syncs)
                        .set("sync_bytes", m.sync_bytes)
                        .set("mesh_bytes", m.mesh_bytes)
                        .set("rewires", m.rewires)
                        .set("custody_loads", m.custody_loads)
                        .set("worker_threads", m.worker_threads),
                },
            )
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<22} {:>9} comps  {:>3} phases  {:>4} rounds  {:>12} shuffle-B  {:>9.1} ms{}{}{}",
            format!("{}/{}", self.algorithm, self.dataset),
            self.num_components,
            self.phases,
            self.rounds,
            self.total_shuffle_bytes,
            self.wall_ms,
            if self.completed { "" } else { "  [INCOMPLETE]" },
            match self.verified {
                Some(true) => "  [verified]",
                Some(false) => "  [VERIFY-FAILED]",
                None => "",
            },
            if self.recovery.events.is_empty() {
                String::new()
            } else {
                format!("  [recovered x{}]", self.recovery.events.len())
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::Metrics;

    fn dummy_result() -> CcResult {
        CcResult {
            labels: vec![0, 0, 0, 3, 3],
            phases: 2,
            completed: true,
            edges_per_phase: vec![10, 1, 0],
            nodes_per_phase: vec![5, 2, 2],
            metrics: Metrics::new(),
        }
    }

    #[test]
    fn report_aggregates() {
        let r = Report::from_result("lc", "test", 5, 10, &dummy_result(), 1.5);
        assert_eq!(r.num_components, 2);
        assert_eq!(r.largest_component, 3);
        assert_eq!(r.phases, 2);
        assert!(r.completed);
    }

    #[test]
    fn json_roundtrip() {
        let r = Report::from_result("lc", "test", 5, 10, &dummy_result(), 1.5);
        let j = r.to_json();
        let parsed = crate::util::json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.get("phases").unwrap().as_i64(), Some(2));
        assert_eq!(parsed.get("algorithm").unwrap().as_str(), Some("lc"));
        assert_eq!(
            parsed.get("edges_per_phase").unwrap().as_arr().unwrap().len(),
            3
        );
    }

    #[test]
    fn summary_flags_incomplete() {
        let mut res = dummy_result();
        res.completed = false;
        let r = Report::from_result("htm", "big", 5, 10, &res, 0.1);
        assert!(r.summary().contains("INCOMPLETE"));
    }
}
