//! The worker process main loop (`lcc worker --connect HOST:PORT`) — one
//! MPC machine, and on the shuffle transport one node of the
//! worker↔worker **data plane**.
//!
//! The process splits cleanly along the control-plane/data-plane
//! boundary of [`crate::mpc::net`]:
//!
//! * **Control plane** (the coordinator link): handshake
//!   (`Hello`/`Assign` — the coordinator assigns the machine index; the
//!   Hello carries this worker's mesh listener port), shard custody
//!   (`LoadShard`, validated and re-derived independently), the mesh
//!   roster (`Peers`), value-mirror broadcasts (`StateSync`), round
//!   descriptors (`HopRound`, `Rewire`), and O(1) acks — load counts,
//!   fold/shard checksums.  Nothing O(m) crosses this link after custody
//!   is established.
//! * **Data plane** (the peer mesh, shuffle transport only): this worker
//!   **generates** each described hop round's messages from its owned
//!   shard and its value mirror, ships each bucket straight to the peer
//!   worker owning the keys (`PeerMsgs`), folds what it receives, and
//!   all-gathers the fold images (`PeerFold`) so every mirror stays
//!   current; after a `Rewire` it relabels its own edges through the map
//!   mirror and ships them to their next-generation owners (`PeerEdges`)
//!   — custody survives contraction without touching the coordinator.
//!
//! Proc-transport rounds (`Round` frames with coordinator-routed byte
//! images) are served as before: count the received bytes, fold when
//! tagged, ack — the receiver-side accounting the coordinator validates
//! against the model charge.
//!
//! **Parallel data plane.**  `LCC_WORKER_THREADS` (the
//! `--worker-threads` flag, shipped through the spawn environment and
//! echoed back in the Hello) sizes a per-process [`WorkerPool`] that
//! every worker-native round runs on, **bit-identically by
//! construction**: generation splits the custody cursor into contiguous
//! per-thread row ranges ([`chunk_range`]) bucketed into thread-local
//! per-peer buffers that are shipped in chunk order — every bucket's
//! byte stream equals the serial cursor-order stream; the fold
//! partitions the received payloads by key range, folds sub-ranges on
//! the pool, and concatenates the partial images in key order — the
//! exact bytes of the serial ascending-key fold (see
//! [`net::fold_wire_payload_in_range`]).  Sends are staggered
//! `(my + j) % p` so the fleet doesn't convoy on worker 0, and inbound
//! `PeerMsgs`/`PeerFold` frames are drained opportunistically between
//! sends instead of strictly after them.  `worker_threads == 1` keeps
//! the serial hot path (the pool runs jobs inline).
//!
//! Protocol violations the worker detects are answered with a
//! `WorkerErr` frame (surfaced as typed [`TransportError::Protocol`]);
//! I/O failures end the process.  A dead peer is an immediate typed
//! error, not a hang: every mesh socket has a dedicated reader thread
//! (EOF/corruption surfaces the moment it happens), writes carry the
//! shared I/O timeout ([`net::IO_TIMEOUT`] unless the coordinator
//! shipped `LCC_IO_TIMEOUT_MS`, the `--io-timeout` flag), and mesh
//! waits are bounded by the same timeout.  EOF at a coordinator frame
//! boundary means the coordinator is gone: exit cleanly.  A *panic*
//! anywhere in the serve loop is caught, answered as a `WorkerErr`
//! carrying the panic message, and exits the process nonzero — the
//! coordinator sees the cause, never an opaque short read.
//!
//! **Deterministic fault injection.**  `LCC_FAULT_PLAN` (the
//! `--fault-plan` flag, shipped through the spawn environment) names
//! kill/delay actions per worker at exact protocol sites
//! ([`net::FaultPlan`]); this worker enacts its own actions — exit
//! before serving its n-th round frame, or immediately after acking its
//! n-th `Rewire` (the generation boundary).  The chaos suite drives
//! recovery through these, bit-identically reproducible.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::graph::spill::{self, Fnv1a, ShardStats, SpillError};
use crate::graph::Vertex;
use crate::mpc::net::{
    self, BodyReader, Frame, FrameKind, PROTO_VERSION,
};
use crate::mpc::pool::{chunk_range, WorkerPool};
use crate::mpc::simulator::machine_of;
use crate::mpc::transport::{TransportError, WireOp};

/// Per-peer connect attempt budget (covers the race where a peer has
/// not yet processed `Peers`; its listener is bound since startup, so
/// real refusals persist through the backoff).  `LCC_CONNECT_RETRIES`
/// (the `--connect-retries` flag) overrides — fault tests shrink it so
/// a refused connect surfaces in milliseconds.  Backoff doubles from
/// [`net::CONNECT_BACKOFF_MS`] per attempt.
fn connect_retries() -> usize {
    std::env::var("LCC_CONNECT_RETRIES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(net::DEFAULT_CONNECT_RETRIES)
        .max(1)
}

/// The worker-side I/O timeout: [`net::IO_TIMEOUT`] unless the
/// coordinator shipped `LCC_IO_TIMEOUT_MS` (the `--io-timeout` flag).
fn io_timeout() -> Duration {
    std::env::var("LCC_IO_TIMEOUT_MS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(Duration::from_millis)
        .unwrap_or(net::IO_TIMEOUT)
}
/// How long a worker waits for all inbound peer connections.
const MESH_ACCEPT_DEADLINE: Duration = Duration::from_secs(20);

/// One frame (or terminal error) read off a peer connection by its
/// dedicated reader thread.
struct PeerEvent {
    from: usize,
    frame: Result<Frame, TransportError>,
}

/// The established worker↔worker mesh: one full-duplex connection per
/// peer — writes go through `links`, reads arrive on `rx` from the
/// per-peer reader threads (which also make a dead peer an immediate
/// event instead of a blocked read).
struct Mesh {
    /// Writer half per peer; `None` at this worker's own index.
    links: Vec<Option<BufWriter<TcpStream>>>,
    rx: mpsc::Receiver<PeerEvent>,
    /// The effective I/O timeout, captured once at mesh setup.
    timeout: Duration,
}

impl Mesh {
    /// Wait for the next peer event, bounding the wait by the shared I/O
    /// timeout so a wedged mesh is a typed error, not a hang.
    fn recv(&self) -> Result<PeerEvent, TransportError> {
        match self.rx.recv_timeout(self.timeout) {
            Ok(ev) => Ok(ev),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(TransportError::Io {
                worker: None,
                op: "await peer frame",
                source: std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "no peer frame within the I/O timeout",
                ),
            }),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(TransportError::Protocol {
                worker: None,
                detail: "all peer connections closed mid-round".into(),
            }),
        }
    }

    /// Take one peer event if one is already queued, without blocking —
    /// the opportunistic drain the send loops run between frame writes
    /// so receive processing overlaps generation and shipping.
    fn try_recv(&self) -> Option<PeerEvent> {
        self.rx.try_recv().ok()
    }
}

/// Custody of one shard generation, held as its **framed file image**:
/// the `LoadShard` body (or the image re-encoded from peer-shipped
/// rewire edges) is kept verbatim — exactly the bytes a spill file of
/// this shard holds — and every described round walks it in place
/// through a borrowed [`spill::ShardCursor`].  The checksum is verified
/// once when custody is taken; per-round reads re-parse only the cheap
/// header.  No rehydrated `Vec<(u32, u32)>` copy of the shard exists for
/// the lifetime of a generation.
struct ShardCustody {
    /// The full shard-file image (columnar layout; see `graph::spill`).
    image: Vec<u8>,
    shard: u32,
    machines: u32,
    /// Statistics re-derived independently from the image — the
    /// coordinator cross-checks these against its own cache.
    stats: ShardStats,
    /// Logical row-major payload checksum ([`spill::checksum_edges`]).
    checksum: u64,
}

impl ShardCustody {
    /// Borrowed cursor over the retained image.  Header-only re-parse:
    /// the image was fully validated (checksum + index) at custody.
    fn cursor(&self) -> spill::ShardCursor<'_> {
        let (cursor, checksum) =
            spill::parse_shard_header(&self.image, self.shard, self.machines, Path::new("<custody>"))
                .expect("custody image was validated when custody was taken");
        debug_assert_eq!(checksum, self.checksum);
        debug_assert_eq!(cursor.len() as u64, self.stats.len);
        cursor
    }
}

/// One worker's custody state.
struct WorkerState {
    worker_id: u32,
    machines: u32,
    /// The shard this machine owns, once the coordinator shipped it.  On
    /// the shuffle transport the image is the generation source of every
    /// described round; after a `Rewire` the slot advances to the next
    /// generation peer-to-peer.
    shard: Option<ShardCustody>,
    /// Mesh listener, bound at startup (its port travels in the Hello),
    /// consumed when the `Peers` roster arrives.
    mesh_listener: Option<TcpListener>,
    /// The peer mesh, once `Peers` established it.
    mesh: Option<Mesh>,
    /// Wire-encoded per-vertex values (the hop inputs / rewire map),
    /// maintained by `StateSync` broadcasts, `StateDelta` patches, and
    /// hop fold all-gathers.
    mirror: Vec<u8>,
    /// Wire width of one mirror value (0 = no mirror yet).
    mirror_vb: usize,
    /// Data-plane parallelism: how many contiguous chunks every
    /// worker-native round splits its generate/fold work into
    /// (`LCC_WORKER_THREADS`, clamped ≥ 1).  Chunk-order merges keep the
    /// output bytes identical for every value.
    threads: usize,
    /// The round pool the chunks run on; zero workers (inline execution)
    /// when `threads == 1`, so the single-threaded hot path stays free
    /// of queue traffic.
    pool: WorkerPool,
    /// Retained write buffers of the round shuffles, flat across chunk
    /// sets (clear-don't-drop, capacity-capped like the spill layer's
    /// `READ_BUF`): bucketing a round reuses last round's allocations
    /// instead of growing `threads × p` fresh vectors per round.
    bucket_bufs: Vec<Vec<u8>>,
}

/// Retained-capacity cap of one reusable write buffer — the same bound
/// as the spill layer's `READ_BUF_RETAIN`: one pathological round must
/// not pin its peak allocation for the process lifetime.
const WRITE_BUF_RETAIN: usize = 8 << 20;

/// Retained-capacity cap across the **whole** bucket pool.  The pool
/// holds up to `2 · threads · p` buffers; capping each one alone still
/// lets a skewed round pin `O(threads · p · WRITE_BUF_RETAIN)` RAM for
/// the process lifetime, so the put-back walks a shared budget and
/// shrinks everything past it to zero retained capacity.
const WRITE_BUF_RETAIN_TOTAL: usize = 32 << 20;

/// Take `chunks` cleared bucket sets of `p` buffers each out of the
/// flat retained pool (reusing capacity; missing buffers start fresh).
fn take_bucket_sets(pool: &mut Vec<Vec<u8>>, chunks: usize, p: usize) -> Vec<Vec<Vec<u8>>> {
    let mut flat = std::mem::take(pool);
    flat.resize_with(chunks * p, Vec::new);
    let mut sets = Vec::with_capacity(chunks);
    let mut rest = flat;
    for _ in 0..chunks {
        let mut set = rest.split_off(p);
        std::mem::swap(&mut set, &mut rest);
        for b in &mut set {
            b.clear();
        }
        sets.push(set);
    }
    sets
}

/// Return bucket sets to the flat pool, clearing every buffer and
/// capping retained capacity per buffer **and** in total.  Error paths
/// may skip the put-back — the next take simply starts fresh.
fn put_bucket_sets(pool: &mut Vec<Vec<u8>>, sets: Vec<Vec<Vec<u8>>>) {
    let mut flat: Vec<Vec<u8>> = sets.into_iter().flatten().collect();
    let mut budget = WRITE_BUF_RETAIN_TOTAL;
    for b in &mut flat {
        b.clear();
        let keep = b.capacity().min(WRITE_BUF_RETAIN).min(budget);
        if b.capacity() > keep {
            b.shrink_to(keep);
        }
        budget = budget.saturating_sub(b.capacity());
    }
    *pool = flat;
}

/// Connect to the coordinator and serve until shutdown (the `lcc worker`
/// subcommand).
pub fn run_worker(connect: &str) -> Result<(), TransportError> {
    let stream = TcpStream::connect(connect).map_err(|e| TransportError::Io {
        worker: None,
        op: "connect to coordinator",
        source: e,
    })?;
    serve(stream)
}

/// Serve the worker protocol over an established stream (exposed so
/// tests can run a worker against an in-test coordinator).  The
/// data-plane thread count comes from `LCC_WORKER_THREADS` (shipped by
/// the coordinator's spawn environment; absent = serial).
pub fn serve(stream: TcpStream) -> Result<(), TransportError> {
    let threads = std::env::var("LCC_WORKER_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&t| t > 0)
        .unwrap_or(1);
    serve_with_threads(stream, threads)
}

/// [`serve`] with an explicit data-plane thread count (tests drive the
/// parallel rounds without touching process environment).
pub fn serve_with_threads(stream: TcpStream, threads: usize) -> Result<(), TransportError> {
    let threads = threads.max(1);
    stream.set_nodelay(true).map_err(|e| TransportError::Io {
        worker: None,
        op: "set nodelay",
        source: e,
    })?;
    // a coordinator that stops draining must not block an ack write
    // forever; reads stay untimed — idling between rounds is normal
    stream
        .set_write_timeout(Some(io_timeout()))
        .map_err(|e| TransportError::Io {
            worker: None,
            op: "set write timeout",
            source: e,
        })?;
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| TransportError::Io {
        worker: None,
        op: "clone stream",
        source: e,
    })?);
    let mut writer = BufWriter::new(stream);

    // the mesh listener exists from the start (shuffle coordinators need
    // its port in the Hello; proc coordinators simply never use it)
    let mesh_listener = TcpListener::bind(("127.0.0.1", 0)).map_err(|e| TransportError::Io {
        worker: None,
        op: "bind mesh listener",
        source: e,
    })?;
    let mesh_port = mesh_listener
        .local_addr()
        .map_err(|e| TransportError::Io {
            worker: None,
            op: "mesh listener addr",
            source: e,
        })?
        .port();

    // handshake: version + our pid (the coordinator aligns its spawned
    // children to worker ids by it) + our mesh port + the data-plane
    // thread count this process will actually run (v5)
    let mut hello = Vec::with_capacity(14);
    hello.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    hello.extend_from_slice(&std::process::id().to_le_bytes());
    hello.extend_from_slice(&mesh_port.to_le_bytes());
    hello.extend_from_slice(&(threads as u32).to_le_bytes());
    net::write_frame(&mut writer, FrameKind::Hello, 0, &hello)?;
    let assign = net::read_frame(&mut reader)?;
    if assign.kind != FrameKind::Assign {
        return Err(TransportError::Protocol {
            worker: None,
            detail: format!("expected Assign, got {:?}", assign.kind),
        });
    }
    let mut r = BodyReader::new(&assign.body);
    let version = r.u32("assign version")?;
    if version != PROTO_VERSION {
        return Err(TransportError::Protocol {
            worker: None,
            detail: format!("coordinator speaks protocol {version}, worker {PROTO_VERSION}"),
        });
    }
    let worker_id = r.u32("worker id")?;
    let machines = r.u32("machine count")?;
    let mut state = WorkerState {
        worker_id,
        machines,
        shard: None,
        mesh_listener: Some(mesh_listener),
        mesh: None,
        mirror: Vec::new(),
        mirror_vb: 0,
        threads,
        // threads == 1 keeps a zero-worker pool: run_jobs executes
        // inline, so the serial path never pays queue traffic
        pool: WorkerPool::new(if threads <= 1 { 0 } else { threads }),
        bucket_bufs: Vec::new(),
    };
    // this worker's slice of the deterministic fault plan (the id is
    // only known post-Assign, so the plan parses here)
    let faults = match std::env::var("LCC_FAULT_PLAN") {
        Ok(s) if !s.is_empty() => match net::FaultPlan::parse(&s) {
            Ok(plan) => plan.for_worker(worker_id as usize),
            Err(detail) => {
                let msg = format!("bad LCC_FAULT_PLAN: {detail}");
                worker_err(&mut writer, 0, &msg)?;
                return Err(TransportError::Protocol {
                    worker: None,
                    detail: msg,
                });
            }
        },
        _ => Vec::new(),
    };

    // A panic anywhere in the serve loop must reach the coordinator as
    // its message, not as an opaque ShortRead when the process dies with
    // the socket: catch it, answer WorkerErr, exit nonzero via Err.
    let served = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        serve_loop(&mut state, &faults, &mut reader, &mut writer)
    }));
    match served {
        Ok(result) => result,
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            let detail = format!("worker panicked: {msg}");
            let _ = worker_err(&mut writer, 0, &detail);
            Err(TransportError::Protocol {
                worker: None,
                detail,
            })
        }
    }
}

fn serve_loop(
    state: &mut WorkerState,
    faults: &[net::FaultAction],
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
) -> Result<(), TransportError> {
    // 1-based fault-site counters: round frames served, rewires acked
    let mut rounds_served = 0u64;
    let mut gens_acked = 0u64;
    loop {
        let frame = match net::read_frame(reader) {
            Ok(f) => f,
            // EOF at a frame boundary: the coordinator dropped the
            // connection (its transport was dropped) — clean exit.
            Err(TransportError::ShortRead { got: 0, .. }) => return Ok(()),
            Err(e) => return Err(e),
        };
        if matches!(
            frame.kind,
            FrameKind::Round | FrameKind::HopRound | FrameKind::Rewire | FrameKind::GatherRewire
        ) {
            rounds_served += 1;
            enact_faults(faults, net::FaultSite::Round(rounds_served));
        }
        match frame.kind {
            FrameKind::LoadShard => handle_load(state, &frame, writer)?,
            FrameKind::Round => handle_round(state, &frame, writer)?,
            FrameKind::Peers => handle_peers(state, &frame, writer)?,
            FrameKind::StateSync => handle_state_sync(state, &frame, writer)?,
            FrameKind::StateDelta => handle_state_delta(state, &frame, writer)?,
            FrameKind::HopRound => handle_hop(state, &frame, writer)?,
            // a pipelined batch counts its rounds one by one inside the
            // handler, so round-site faults land mid-batch exactly where
            // they would in an unpipelined run
            FrameKind::HopBatch => {
                handle_hop_batch(state, &frame, writer, faults, &mut rounds_served)?
            }
            FrameKind::Rewire => {
                handle_rewire(state, &frame, writer)?;
                // the generation boundary: custody advanced and the ack
                // is flushed — a gen-site kill dies exactly here
                gens_acked += 1;
                enact_faults(faults, net::FaultSite::Gen(gens_acked));
            }
            FrameKind::GatherRewire => {
                handle_gather_rewire(state, &frame, writer)?;
                gens_acked += 1;
                enact_faults(faults, net::FaultSite::Gen(gens_acked));
            }
            FrameKind::Ping => {
                net::write_frame(writer, FrameKind::Pong, frame.seq, &[])?;
            }
            FrameKind::Shutdown => {
                net::write_frame(writer, FrameKind::Bye, frame.seq, &[])?;
                return Ok(());
            }
            other => {
                worker_err(
                    writer,
                    frame.seq,
                    &format!("unexpected frame kind {other:?}"),
                )?;
            }
        }
    }
}

/// Enact this worker's fault-plan actions matching `site`: `kill` exits
/// the process on the spot (sockets drop mid-protocol — the coordinator
/// sees a crash); `delay` sleeps 100 ms, exercising the timeout/backoff
/// paths without a casualty.
fn enact_faults(faults: &[net::FaultAction], site: net::FaultSite) {
    for f in faults {
        if f.site != site {
            continue;
        }
        match f.kind {
            net::FaultKind::Kill => std::process::exit(17),
            net::FaultKind::Delay => std::thread::sleep(Duration::from_millis(100)),
        }
    }
}

fn worker_err<W: std::io::Write>(
    writer: &mut W,
    seq: u64,
    detail: &str,
) -> Result<(), TransportError> {
    net::write_frame(writer, FrameKind::WorkerErr, seq, detail.as_bytes())
}

/// Take custody of this machine's shard: validate the spill framing
/// (magic, identity, length, payload checksum), enforce the
/// shard-ownership invariant edge by edge, and re-derive the statistics
/// the coordinator will cross-check.
fn handle_load<W: std::io::Write>(
    state: &mut WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let mut r = BodyReader::new(&frame.body);
    let parsed = (|| -> Result<(u32, &[u8]), SpillError> {
        let shard = r
            .u32("load shard index")
            .map_err(|e| SpillError::Corrupt {
                path: "<frame>".into(),
                detail: e.to_string(),
            })?;
        let image_len = r.u64("load image length").map_err(|e| SpillError::Corrupt {
            path: "<frame>".into(),
            detail: e.to_string(),
        })? as usize;
        let image = r
            .bytes(image_len, "load image")
            .map_err(|e| SpillError::Corrupt {
                path: "<frame>".into(),
                detail: e.to_string(),
            })?;
        Ok((shard, image))
    })();
    let (shard, image) = match parsed {
        Ok(v) => v,
        Err(e) => return worker_err(writer, frame.seq, &format!("shard image rejected: {e}")),
    };
    // Full validation — checksum walk + range index — happens exactly
    // once, here at the custody boundary; the image is then kept as the
    // working representation and only header-parsed per round.
    let (cursor, checksum) =
        match spill::parse_shard_image(image, shard, state.machines, Path::new("<frame>")) {
            Ok(v) => v,
            Err(e) => return worker_err(writer, frame.seq, &format!("shard image rejected: {e}")),
        };
    if shard != state.worker_id {
        return worker_err(
            writer,
            frame.seq,
            &format!("received shard {shard}, this machine is {}", state.worker_id),
        );
    }
    // shard-ownership invariant, validated on the machine taking custody
    let p = state.machines as usize;
    for (u, v) in cursor.iter() {
        if u >= v || machine_of(u as u64, p) != state.worker_id as usize {
            return worker_err(
                writer,
                frame.seq,
                &format!("edge ({u},{v}) violates the shard-ownership invariant"),
            );
        }
    }
    let stats = ShardStats::from_pairs(cursor.iter(), p, state.worker_id as usize);
    let mut body = Vec::with_capacity(4 + 8 + 8 + 4 + 8 * p);
    body.extend_from_slice(&shard.to_le_bytes());
    body.extend_from_slice(&stats.len.to_le_bytes());
    body.extend_from_slice(&checksum.to_le_bytes());
    body.extend_from_slice(&(p as u32).to_le_bytes());
    for &c in &stats.peer_counts {
        body.extend_from_slice(&c.to_le_bytes());
    }
    net::write_frame(writer, FrameKind::LoadAck, frame.seq, &body)?;
    state.shard = Some(ShardCustody {
        image: image.to_vec(),
        shard,
        machines: state.machines,
        stats,
        checksum,
    });
    Ok(())
}

/// Serve one coordinator-routed round: account the received bytes (or
/// acknowledge the declared load of a charge-only round), fold when
/// asked, ack.
fn handle_round<W: std::io::Write>(
    _state: &WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let msg = match net::decode_round_body(&frame.body) {
        Ok(m) => m,
        Err(e) => return worker_err(writer, frame.seq, &format!("bad round body: {e}")),
    };
    let accounted = if msg.virtual_round {
        msg.declared_bytes
    } else {
        msg.payload.len() as u64
    };
    let folded = match msg.fold {
        None => Vec::new(),
        Some(op) => match net::fold_wire_payload(op, msg.payload) {
            Ok(f) => f,
            Err(detail) => {
                return worker_err(
                    writer,
                    frame.seq,
                    &format!("round {:?}: {detail}", msg.label),
                )
            }
        },
    };
    let mut body = Vec::with_capacity(8 + 8 + folded.len());
    body.extend_from_slice(&accounted.to_le_bytes());
    body.extend_from_slice(&(folded.len() as u64).to_le_bytes());
    body.extend_from_slice(&folded);
    net::write_frame(writer, FrameKind::RoundAck, frame.seq, &body)
}

// ---------------------------------------------------------------------------
// the shuffle data plane

/// Register one established peer connection: tune the socket, spawn its
/// reader thread, store the writer half.
fn register_peer(
    links: &mut [Option<BufWriter<TcpStream>>],
    tx: &mpsc::Sender<PeerEvent>,
    from: usize,
    sock: TcpStream,
    timeout: Duration,
) -> Result<(), TransportError> {
    let io = |op: &'static str| {
        move |e: std::io::Error| TransportError::Io {
            worker: None,
            op,
            source: e,
        }
    };
    sock.set_nodelay(true).map_err(io("peer nodelay"))?;
    // peer writes carry the same timeout as coordinator links: a peer
    // that stops draining is a typed error, not a hang
    sock.set_write_timeout(Some(timeout))
        .map_err(io("peer write timeout"))?;
    // reads have no socket timeout: the dedicated reader thread blocks
    // legitimately between rounds; round waits are bounded by Mesh::recv
    sock.set_read_timeout(None).map_err(io("peer read timeout"))?;
    let mut reader = BufReader::new(sock.try_clone().map_err(io("clone peer stream"))?);
    let tx = tx.clone();
    std::thread::spawn(move || loop {
        match net::read_frame(&mut reader) {
            Ok(frame) => {
                if tx.send(PeerEvent { from, frame: Ok(frame) }).is_err() {
                    return;
                }
            }
            Err(e) => {
                // EOF or corruption: surface once and stop (a clean
                // shutdown races here harmlessly — nobody is listening)
                let _ = tx.send(PeerEvent { from, frame: Err(e) });
                return;
            }
        }
    });
    links[from] = Some(BufWriter::new(sock));
    Ok(())
}

/// Bring up the full mesh from the roster: connect to every lower id,
/// accept from every higher id, `PeerHello` identifying each link.
fn setup_mesh(
    my: usize,
    p: usize,
    ports: &[u16],
    listener: TcpListener,
) -> Result<Mesh, TransportError> {
    let (tx, rx) = mpsc::channel();
    let mut links: Vec<Option<BufWriter<TcpStream>>> = (0..p).map(|_| None).collect();
    let timeout = io_timeout();
    let retries = connect_retries();

    // outbound: worker `my` initiates to every j < my, retrying with
    // exponential backoff up to the configured attempt budget
    for (j, &port) in ports.iter().enumerate().take(my) {
        let mut attempt = 0usize;
        let sock = loop {
            match TcpStream::connect(("127.0.0.1", port)) {
                Ok(s) => break s,
                Err(e) if attempt + 1 < retries => {
                    let _ = e;
                    let shift = attempt.min(16) as u32;
                    std::thread::sleep(Duration::from_millis(
                        net::CONNECT_BACKOFF_MS << shift,
                    ));
                    attempt += 1;
                }
                Err(e) => {
                    return Err(TransportError::Io {
                        worker: Some(j),
                        op: "connect to peer",
                        source: e,
                    })
                }
            }
        };
        sock.set_write_timeout(Some(timeout))
            .map_err(|e| TransportError::Io {
                worker: Some(j),
                op: "peer write timeout",
                source: e,
            })?;
        {
            let mut w = sock.try_clone().map_err(|e| TransportError::Io {
                worker: Some(j),
                op: "clone peer stream",
                source: e,
            })?;
            net::write_frame(&mut w, FrameKind::PeerHello, 0, &(my as u32).to_le_bytes())?;
        }
        register_peer(&mut links, &tx, j, sock, timeout)?;
    }

    // inbound: every j > my connects to us
    listener
        .set_nonblocking(true)
        .map_err(|e| TransportError::Io {
            worker: None,
            op: "mesh listener nonblocking",
            source: e,
        })?;
    let deadline = Instant::now() + MESH_ACCEPT_DEADLINE;
    let mut pending = p - 1 - my;
    while pending > 0 {
        match listener.accept() {
            Ok((sock, _peer)) => {
                sock.set_nonblocking(false).map_err(|e| TransportError::Io {
                    worker: None,
                    op: "peer blocking mode",
                    source: e,
                })?;
                // bound the hello read; cleared again by register_peer
                sock.set_read_timeout(Some(timeout))
                    .map_err(|e| TransportError::Io {
                        worker: None,
                        op: "peer hello timeout",
                        source: e,
                    })?;
                let hello = {
                    let mut r = sock.try_clone().map_err(|e| TransportError::Io {
                        worker: None,
                        op: "clone peer stream",
                        source: e,
                    })?;
                    net::read_frame(&mut r)?
                };
                if hello.kind != FrameKind::PeerHello {
                    return Err(TransportError::Protocol {
                        worker: None,
                        detail: format!("expected PeerHello, got {:?}", hello.kind),
                    });
                }
                let mut r = BodyReader::new(&hello.body);
                let from = r.u32("peer hello id")? as usize;
                r.expect_end("peer hello")?;
                if from <= my || from >= p || links[from].is_some() {
                    return Err(TransportError::Protocol {
                        worker: Some(from),
                        detail: format!("peer {from} must not initiate to worker {my}"),
                    });
                }
                register_peer(&mut links, &tx, from, sock, timeout)?;
                pending -= 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(TransportError::Protocol {
                        worker: None,
                        detail: format!(
                            "{pending} peers never connected before the mesh deadline"
                        ),
                    });
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                return Err(TransportError::Io {
                    worker: None,
                    op: "accept peer",
                    source: e,
                })
            }
        }
    }
    Ok(Mesh { links, rx, timeout })
}

/// `Peers`: establish the worker↔worker mesh from the roster.
fn handle_peers<W: std::io::Write>(
    state: &mut WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let p = state.machines as usize;
    let my = state.worker_id as usize;
    let parsed = (|| -> Result<Vec<u16>, TransportError> {
        let mut r = BodyReader::new(&frame.body);
        let count = r.u32("peer count")? as usize;
        if count != p {
            return Err(TransportError::Protocol {
                worker: None,
                detail: format!("roster lists {count} workers, machine count is {p}"),
            });
        }
        let mut ports = vec![0u16; p];
        for _ in 0..count {
            let id = r.u32("roster worker id")? as usize;
            let port = r.u16("roster port")?;
            if id >= p {
                return Err(TransportError::Protocol {
                    worker: None,
                    detail: format!("roster id {id} out of range {p}"),
                });
            }
            ports[id] = port;
        }
        r.expect_end("peers roster")?;
        Ok(ports)
    })();
    let ports = match parsed {
        Ok(v) => v,
        Err(e) => return worker_err(writer, frame.seq, &format!("bad roster: {e}")),
    };
    let Some(listener) = state.mesh_listener.take() else {
        return worker_err(writer, frame.seq, "mesh already established");
    };
    match setup_mesh(my, p, &ports, listener) {
        Ok(mesh) => {
            state.mesh = Some(mesh);
            net::write_frame(writer, FrameKind::PeersAck, frame.seq, &[])
        }
        Err(e) => worker_err(writer, frame.seq, &format!("mesh setup failed: {e}")),
    }
}

/// Parse a `StateSync` body into (value width, mirror data).
fn parse_state_sync(body: &[u8]) -> Result<(u8, &[u8]), TransportError> {
    let mut r = BodyReader::new(body);
    let vb = r.u8("mirror value width")?;
    let len = r.u64("mirror length")? as usize;
    let data = r.bytes(len, "mirror data")?;
    r.expect_end("state sync")?;
    if vb == 0 || len % vb as usize != 0 {
        return Err(TransportError::Protocol {
            worker: None,
            detail: format!("mirror of {len} bytes is not a multiple of width {vb}"),
        });
    }
    Ok((vb, data))
}

/// `StateSync`: replace the value mirror, ack its content hash.
fn handle_state_sync<W: std::io::Write>(
    state: &mut WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let (vb, data) = match parse_state_sync(&frame.body) {
        Ok(v) => v,
        Err(e) => return worker_err(writer, frame.seq, &format!("bad mirror: {e}")),
    };
    let hash = net::mirror_hash_of(vb, data);
    state.mirror.clear();
    state.mirror.extend_from_slice(data);
    state.mirror_vb = vb as usize;
    net::write_frame(writer, FrameKind::StateAck, frame.seq, &hash.to_le_bytes())
}

/// `StateDelta`: patch the existing mirror in place with `(index, value)`
/// pairs.  The receipt hashes the **full** resulting mirror — exactly
/// like a full `StateSync` ack — so a patch applied over a base the
/// coordinator did not expect diverges at the cross-check instead of
/// corrupting later rounds silently.
fn handle_state_delta<W: std::io::Write>(
    state: &mut WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let applied = (|| -> Result<(), TransportError> {
        let mut r = BodyReader::new(&frame.body);
        let vb = r.u8("delta value width")? as usize;
        let total = r.u64("delta mirror length")? as usize;
        let count = r.u64("delta entry count")? as usize;
        if vb == 0 || total % vb != 0 {
            return Err(proto(format!(
                "delta mirror of {total} bytes is not a multiple of width {vb}"
            )));
        }
        if state.mirror_vb != vb || state.mirror.len() != total {
            return Err(proto(format!(
                "delta targets a {total}-byte width-{vb} mirror; holding {} bytes width {}",
                state.mirror.len(),
                state.mirror_vb
            )));
        }
        let n = total / vb;
        for _ in 0..count {
            let idx = r.u32("delta entry index")? as usize;
            let value = r.bytes(vb, "delta entry value")?;
            if idx >= n {
                return Err(proto(format!("delta index {idx} outside mirror of {n}")));
            }
            state.mirror[idx * vb..(idx + 1) * vb].copy_from_slice(value);
        }
        r.expect_end("state delta")?;
        Ok(())
    })();
    if let Err(e) = applied {
        return worker_err(writer, frame.seq, &format!("bad mirror delta: {e}"));
    }
    let hash = net::mirror_hash_of(state.mirror_vb as u8, &state.mirror);
    net::write_frame(writer, FrameKind::StateAck, frame.seq, &hash.to_le_bytes())
}

/// Collect `PeerMsgs` then `PeerFold` frames of the round `seq` from
/// every peer, tolerating arrival interleaving (a fast peer's fold can
/// land before a slow peer's messages).
struct RoundInbox {
    msgs: Vec<Option<Vec<u8>>>,
    folds: Vec<Option<Vec<u8>>>,
    want_msgs: usize,
    want_folds: usize,
}

impl RoundInbox {
    fn new(p: usize, my: usize) -> RoundInbox {
        let mut msgs = Vec::with_capacity(p);
        let mut folds = Vec::with_capacity(p);
        for j in 0..p {
            // own slots are pre-filled locally, never via the mesh
            msgs.push(if j == my { Some(Vec::new()) } else { None });
            folds.push(if j == my { Some(Vec::new()) } else { None });
        }
        RoundInbox {
            msgs,
            folds,
            want_msgs: p - 1,
            want_folds: p - 1,
        }
    }

    /// File one event; errors on duplicates, stale seqs, wrong kinds.
    fn file(&mut self, seq: u64, ev: PeerEvent) -> Result<(), TransportError> {
        let frame = ev.frame.map_err(|e| e.for_worker(ev.from))?;
        if frame.seq != seq {
            return Err(TransportError::Protocol {
                worker: Some(ev.from),
                detail: format!("peer frame seq {} != round seq {seq}", frame.seq),
            });
        }
        let (slot, pending) = match frame.kind {
            FrameKind::PeerMsgs => (&mut self.msgs[ev.from], &mut self.want_msgs),
            FrameKind::PeerFold => (&mut self.folds[ev.from], &mut self.want_folds),
            other => {
                return Err(TransportError::Protocol {
                    worker: Some(ev.from),
                    detail: format!("unexpected mesh frame {other:?}"),
                })
            }
        };
        if slot.is_some() {
            return Err(TransportError::Protocol {
                worker: Some(ev.from),
                detail: format!("duplicate {:?} in one round", frame.kind),
            });
        }
        *slot = Some(frame.body);
        *pending -= 1;
        Ok(())
    }
}

/// Which mesh frames of the current round this worker already shipped,
/// per phase and **per link** — a failure mid-send-loop must poison only
/// the links that never got the real frame (a duplicate would make
/// healthy peers fail too and steal the error attribution).
#[derive(Default)]
struct HopProgress {
    /// `msgs[j]` = the real `PeerMsgs` went out to link `j`.
    msgs: Vec<bool>,
    /// `fold[j]` = the real `PeerFold` went out to link `j`.
    fold: Vec<bool>,
}

/// Best-effort empty `kind` frames to every link the round never
/// reached (`sent[j] == false`): peers waiting on this worker then
/// complete immediately (their accounting/checksum validation flags the
/// damage) instead of stalling out the I/O timeout, and the coordinator
/// attributes the failure to this worker's `WorkerErr`, not a symptom
/// on a peer.
fn poison_peers(state: &mut WorkerState, seq: u64, kind: FrameKind, sent: &[bool]) {
    let Some(mesh) = state.mesh.as_mut() else {
        return;
    };
    for (j, link) in mesh.links.iter_mut().enumerate() {
        if let Some(link) = link {
            if !sent.get(j).copied().unwrap_or(false) {
                let _ = net::write_frame(link, kind, seq, &[]);
            }
        }
    }
}

/// `HopRound`: generate this round's messages from the owned shard and
/// the value mirror, shuffle them peer-to-peer, fold the received keys,
/// all-gather the fold images, ack the load + fold checksum + mesh
/// bytes shipped.  Every failure — descriptor, mesh I/O, corrupted peer
/// frame, malformed fold — is answered as a `WorkerErr` (a typed
/// protocol error at the coordinator), never a silent worker death,
/// with the unreached mesh sends poisoned so no peer stalls on this
/// worker.
fn handle_hop<W: std::io::Write>(
    state: &mut WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let desc = match parse_hop_desc(&frame.body) {
        Ok(desc) => desc,
        Err(e) => return worker_err(writer, frame.seq, &format!("hop failed: {e}")),
    };
    let mut sent = HopProgress::default();
    let mut stash = Vec::new();
    match hop_core(state, frame.seq, frame.seq, &desc, &mut stash, &mut sent) {
        Ok((received, checksum, mesh_sent)) => {
            let mut body = Vec::with_capacity(24);
            body.extend_from_slice(&received.to_le_bytes());
            body.extend_from_slice(&checksum.to_le_bytes());
            body.extend_from_slice(&mesh_sent.to_le_bytes());
            net::write_frame(writer, FrameKind::HopAck, frame.seq, &body)
        }
        Err(e) => {
            poison_peers(state, frame.seq, FrameKind::PeerMsgs, &sent.msgs);
            poison_peers(state, frame.seq, FrameKind::PeerFold, &sent.fold);
            worker_err(writer, frame.seq, &format!("hop failed: {e}"))
        }
    }
}

fn proto(detail: String) -> TransportError {
    TransportError::Protocol {
        worker: None,
        detail,
    }
}

/// One hop round's shipped program: which fold to run and whether the
/// primary chunk self-messages ride along.  The label travels for the
/// coordinator's error attribution only; workers discard it.
struct HopDesc {
    op: WireOp,
    include_self: bool,
}

fn parse_hop_desc(body: &[u8]) -> Result<HopDesc, TransportError> {
    let mut r = BodyReader::new(body);
    let desc = read_hop_desc(&mut r)?;
    r.expect_end("hop round")?;
    Ok(desc)
}

fn read_hop_desc(r: &mut BodyReader<'_>) -> Result<HopDesc, TransportError> {
    let op = WireOp::from_code(r.u8("hop op")?)
        .ok_or_else(|| proto("unknown hop wire op".into()))?;
    let include_self = r.u8("hop include_self")? != 0;
    let label_len = r.u16("hop label length")? as usize;
    let _label = r.bytes(label_len, "hop label")?;
    Ok(HopDesc { op, include_self })
}

/// `HopBatch` body: `count u16 | descriptor×count` — the descriptors of
/// `count` consecutive hop rounds with no coordinator data dependency
/// between them.
fn parse_hop_batch(body: &[u8]) -> Result<Vec<HopDesc>, TransportError> {
    let mut r = BodyReader::new(body);
    let count = r.u16("hop batch count")? as usize;
    if count == 0 {
        return Err(proto("empty hop batch".into()));
    }
    let mut descs = Vec::with_capacity(count);
    for _ in 0..count {
        descs.push(read_hop_desc(&mut r)?);
    }
    r.expect_end("hop batch")?;
    Ok(descs)
}

/// Receive one mesh event for round `seq`, stashing frames of *later*
/// rounds of the same batch (`seq < f.seq <= max_seq`) instead of
/// failing on them.  Inside a pipelined batch a faster peer legally
/// runs ahead — its `PeerMsgs` for round `k+1` can land while this
/// worker is still folding round `k` — and the stash replays those
/// frames when their round starts.  Outside a batch `max_seq == seq`,
/// so nothing stashes and any out-of-round frame surfaces as the
/// protocol error it is (via `RoundInbox::file`).
fn recv_for(
    mesh: &Mesh,
    stash: &mut Vec<(usize, Frame)>,
    seq: u64,
    max_seq: u64,
) -> Result<PeerEvent, TransportError> {
    if let Some(pos) = stash.iter().position(|(_, f)| f.seq == seq) {
        let (from, frame) = stash.remove(pos);
        return Ok(PeerEvent {
            from,
            frame: Ok(frame),
        });
    }
    loop {
        let ev = mesh.recv()?;
        if let Ok(frame) = &ev.frame {
            if frame.seq > seq && frame.seq <= max_seq {
                let frame = ev.frame.expect("checked Ok");
                stash.push((ev.from, frame));
                continue;
            }
        }
        return Ok(ev);
    }
}

/// File every already-arrived mesh event of round `seq` into `inbox`
/// without blocking — the compute/comms overlap: the send loops call
/// this between frame writes (and `hop_core` before generation), so a
/// fast peer's `PeerMsgs`/`PeerFold` is absorbed while this worker is
/// still producing its own, instead of queueing until the tail wait.
/// Later-round frames of a pipelined batch stash exactly as in
/// [`recv_for`]; peer errors surface immediately.
fn drain_ready(
    mesh: &Mesh,
    stash: &mut Vec<(usize, Frame)>,
    inbox: &mut RoundInbox,
    seq: u64,
    max_seq: u64,
) -> Result<(), TransportError> {
    while let Some(pos) = stash.iter().position(|(_, f)| f.seq == seq) {
        let (from, frame) = stash.remove(pos);
        inbox.file(seq, PeerEvent { from, frame: Ok(frame) })?;
    }
    while let Some(ev) = mesh.try_recv() {
        if let Ok(frame) = &ev.frame {
            if frame.seq > seq && frame.seq <= max_seq {
                let frame = ev.frame.expect("checked Ok");
                stash.push((ev.from, frame));
                continue;
            }
        }
        inbox.file(seq, ev)?;
    }
    Ok(())
}

/// One parallel-generate chunk of a hop round: a contiguous sub-cursor
/// of the custody shard, or a contiguous sub-range of the primary-chunk
/// self-messages.  Jobs are submitted edge chunks first, self chunks
/// after, each in range order — so per-bucket concatenation in job
/// order reproduces the serial cursor-then-self byte stream exactly.
enum GenSpan<'a> {
    Edges(spill::ShardCursor<'a>),
    Selfs(usize, usize),
}

/// The body of one hop round at mesh sequence `seq`; `max_seq` bounds
/// the stash window for pipelined batches.  Returns
/// `(received_bytes, fold_checksum, mesh_bytes_sent)`.
fn hop_core(
    state: &mut WorkerState,
    seq: u64,
    max_seq: u64,
    desc: &HopDesc,
    stash: &mut Vec<(usize, Frame)>,
    sent: &mut HopProgress,
) -> Result<(u64, u64, u64), TransportError> {
    let (op, include_self) = (desc.op, desc.include_self);
    let p = state.machines as usize;
    let my = state.worker_id as usize;
    let vb = op.value_bytes();
    if state.mirror_vb != vb {
        return Err(proto(format!(
            "hop needs a {vb}-byte mirror, holding {} bytes/value",
            state.mirror_vb
        )));
    }
    let n = state.mirror.len() / vb;
    let Some(custody) = state.shard.as_ref() else {
        return Err(proto("hop before shard custody".into()));
    };
    if state.mesh.is_none() && p > 1 {
        return Err(proto("hop before the peer mesh is up".into()));
    }

    // ---- generate: the owned shard × the mirror, chunked ---------------
    // The custody image is walked in place — no row materialization.
    // Each pool job buckets one contiguous row range (then one self
    // sub-range) into its own per-peer buffer set; buffer sets come from
    // the retained pool, so round-over-round the write buffers keep
    // their (total-capped) capacity instead of reallocating.  Per-bucket
    // concatenation in job order reproduces the serial byte stream for
    // every thread count.
    let t = state.threads.max(1);
    let sets_needed = if include_self { 2 * t } else { t };
    let taken = take_bucket_sets(&mut state.bucket_bufs, sets_needed, p);
    let cursor = custody.cursor();
    let rows = cursor.len();
    let mut specs: Vec<(GenSpan<'_>, Vec<Vec<u8>>)> = Vec::with_capacity(sets_needed);
    {
        let mut taken = taken.into_iter();
        for i in 0..t {
            let (lo, hi) = chunk_range(rows, t, i);
            specs.push((
                GenSpan::Edges(cursor.slice(lo, hi)),
                taken.next().expect("one set per chunk"),
            ));
        }
        if include_self {
            let (sa, sb) = chunk_range(n, p, my);
            for i in 0..t {
                let (lo, hi) = chunk_range(sb - sa, t, i);
                specs.push((
                    GenSpan::Selfs(sa + lo, sa + hi),
                    taken.next().expect("one set per chunk"),
                ));
            }
        }
    }
    let mirror = &state.mirror;
    let jobs: Vec<_> = specs
        .into_iter()
        .map(|(span, mut set)| {
            move || -> Result<Vec<Vec<u8>>, String> {
                let mut push = |set: &mut Vec<Vec<u8>>, key: Vertex, value_of: Vertex| {
                    let b = &mut set[machine_of(key as u64, p)];
                    b.extend_from_slice(&(key as u64).to_le_bytes());
                    b.extend_from_slice(
                        &mirror[value_of as usize * vb..(value_of as usize + 1) * vb],
                    );
                };
                match span {
                    GenSpan::Edges(sub) => {
                        for (u, v) in sub.iter() {
                            if (u as usize) >= n || (v as usize) >= n {
                                return Err(format!(
                                    "edge ({u},{v}) outside the {n}-vertex mirror"
                                ));
                            }
                            push(&mut set, u, v);
                            push(&mut set, v, u);
                        }
                    }
                    GenSpan::Selfs(lo, hi) => {
                        for v in lo..hi {
                            push(&mut set, v as Vertex, v as Vertex);
                        }
                    }
                }
                Ok(set)
            }
        })
        .collect();
    // results come back in job order = range order; the first error in
    // that order is exactly the error the serial walk would hit first
    let mut sets: Vec<Vec<Vec<u8>>> = Vec::with_capacity(sets_needed);
    for r in state.pool.run_jobs(jobs) {
        sets.push(r.map_err(proto)?);
    }

    // ---- shuffle: every bucket straight to its owner -------------------
    // Buckets ship as chunk-slice lists (`write_frame_slices` — wire
    // bytes equal the serial single-buffer frame), staggered
    // `(my + jj) % p` so the fleet doesn't convoy on worker 0, with an
    // opportunistic inbox drain between writes.  The own bucket never
    // moves: its chunk slices feed the fold in place.
    let mut mesh_sent = 0u64;
    let mut inbox = RoundInbox::new(p, my);
    sent.msgs.resize(p, false);
    sent.fold.resize(p, false);
    if let Some(mesh) = state.mesh.as_mut() {
        drain_ready(mesh, stash, &mut inbox, seq, max_seq)?;
        for jj in 1..p {
            let j = (my + jj) % p;
            if let Some(link) = mesh.links[j].as_mut() {
                let parts: Vec<&[u8]> = sets.iter().map(|s| s[j].as_slice()).collect();
                let len: u64 = parts.iter().map(|b| b.len() as u64).sum();
                net::write_frame_slices(link, FrameKind::PeerMsgs, seq, &parts)
                    .map_err(|e| e.for_worker(j))?;
                sent.msgs[j] = true;
                mesh_sent += net::FRAME_HEADER_BYTES + len;
            }
            drain_ready(mesh, stash, &mut inbox, seq, max_seq)?;
        }
        while inbox.want_msgs > 0 {
            let ev = recv_for(mesh, stash, seq, max_seq)?;
            inbox.file(seq, ev)?;
        }
    }

    // ---- fold the keys this machine owns -------------------------------
    // Zero staging: the receive volume is folded in place — own chunk
    // buckets plus peer frame bodies as one multi-slice part list.
    // `threads > 1` folds disjoint key ranges on the pool and
    // concatenates the partial images in key order — byte-identical to
    // the serial ascending-key fold; the last range runs unbounded so
    // any garbage key (≥ n, caught at mirror apply) folds exactly once.
    let mut parts: Vec<&[u8]> = Vec::with_capacity(sets.len() + p);
    for s in &sets {
        parts.push(s[my].as_slice());
    }
    for (j, m) in inbox.msgs.iter().enumerate() {
        if j != my {
            parts.push(m.as_deref().expect("msgs complete"));
        }
    }
    let received: u64 = parts.iter().map(|b| b.len() as u64).sum();
    net::validate_fold_parts(op, &parts)
        .map_err(|detail| proto(format!("hop fold: {detail}")))?;
    let folded = if t <= 1 {
        net::fold_wire_payload_in_range(op, &parts, 0, None)
    } else {
        let parts_ref = &parts;
        let jobs: Vec<_> = (0..t)
            .map(|i| {
                let (lo, hi) = chunk_range(n, t, i);
                let hi = if i + 1 == t { None } else { Some(hi as u64) };
                move || net::fold_wire_payload_in_range(op, parts_ref, lo as u64, hi)
            })
            .collect();
        let folds = state.pool.run_jobs(jobs);
        let mut folded = Vec::with_capacity(folds.iter().map(Vec::len).sum());
        for f in &folds {
            folded.extend_from_slice(f);
        }
        folded
    };
    put_bucket_sets(&mut state.bucket_bufs, sets);
    let mut h = Fnv1a::new();
    h.update(&folded);
    let checksum = h.finish();

    // ---- all-gather the fold images: every mirror stays current --------
    if let Some(mesh) = state.mesh.as_mut() {
        for jj in 1..p {
            let j = (my + jj) % p;
            if let Some(link) = mesh.links[j].as_mut() {
                net::write_frame(link, FrameKind::PeerFold, seq, &folded)
                    .map_err(|e| e.for_worker(j))?;
                sent.fold[j] = true;
                mesh_sent += net::FRAME_HEADER_BYTES + folded.len() as u64;
            }
            drain_ready(mesh, stash, &mut inbox, seq, max_seq)?;
        }
        while inbox.want_folds > 0 {
            let ev = recv_for(mesh, stash, seq, max_seq)?;
            inbox.file(seq, ev)?;
        }
    }
    inbox.folds[my] = Some(folded);
    let rec = 8 + vb;
    for blob in inbox.folds.iter().flatten() {
        if blob.len() % rec != 0 {
            return Err(proto("ragged peer fold image".into()));
        }
        for pair in blob.chunks_exact(rec) {
            let key = u64::from_le_bytes(pair[..8].try_into().unwrap()) as usize;
            if key >= n {
                return Err(proto(format!("fold key {key} outside mirror {n}")));
            }
            state.mirror[key * vb..(key + 1) * vb].copy_from_slice(&pair[8..]);
        }
    }

    Ok((received, checksum, mesh_sent))
}

/// `HopBatch`: run `count` consecutive hop rounds back-to-back without
/// returning to the coordinator between them, then ack the whole batch
/// once.  Round `k` of the batch runs at mesh sequence `base + k`, so
/// peer frames stay unambiguous; faults are enacted and `rounds_served`
/// advances per round, exactly as if the rounds had been shipped
/// individually.  On a failure in round `k` the current round's
/// unreached sends are poisoned with the per-link `sent` map and every
/// *later* round of the batch is poisoned outright — peers that raced
/// ahead complete instantly and the coordinator replays the whole batch
/// against this worker's `WorkerErr`.
fn handle_hop_batch<W: std::io::Write>(
    state: &mut WorkerState,
    frame: &Frame,
    writer: &mut W,
    faults: &[net::FaultAction],
    rounds_served: &mut u64,
) -> Result<(), TransportError> {
    let descs = match parse_hop_batch(&frame.body) {
        Ok(descs) => descs,
        Err(e) => return worker_err(writer, frame.seq, &format!("hop batch failed: {e}")),
    };
    let base = frame.seq;
    let last = base + descs.len() as u64 - 1;
    let mut stash = Vec::new();
    let mut acks: Vec<(u64, u64, u64)> = Vec::with_capacity(descs.len());
    for (k, desc) in descs.iter().enumerate() {
        *rounds_served += 1;
        enact_faults(faults, net::FaultSite::Round(*rounds_served));
        let seq = base + k as u64;
        let mut sent = HopProgress::default();
        match hop_core(state, seq, last, desc, &mut stash, &mut sent) {
            Ok(triple) => acks.push(triple),
            Err(e) => {
                poison_peers(state, seq, FrameKind::PeerMsgs, &sent.msgs);
                poison_peers(state, seq, FrameKind::PeerFold, &sent.fold);
                for later in seq + 1..=last {
                    poison_peers(state, later, FrameKind::PeerMsgs, &[]);
                    poison_peers(state, later, FrameKind::PeerFold, &[]);
                }
                return worker_err(
                    writer,
                    base,
                    &format!("hop batch round {k} failed: {e}"),
                );
            }
        }
    }
    let mut body = Vec::with_capacity(2 + acks.len() * 24);
    body.extend_from_slice(&(acks.len() as u16).to_le_bytes());
    for (received, checksum, mesh_sent) in acks {
        body.extend_from_slice(&received.to_le_bytes());
        body.extend_from_slice(&checksum.to_le_bytes());
        body.extend_from_slice(&mesh_sent.to_le_bytes());
    }
    net::write_frame(writer, FrameKind::HopBatchAck, base, &body)
}

/// `Rewire`: relabel the owned edges through the map mirror, ship each
/// to its next-generation owner, adopt the merged result as the new
/// custody, ack its statistics + checksum.  Failures answer as
/// `WorkerErr` like the hop rounds.
fn handle_rewire<W: std::io::Write>(
    state: &mut WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let mut edges_sent = Vec::new();
    match rewire_inner(state, frame, &mut edges_sent) {
        Ok((body, next)) => {
            net::write_frame(writer, FrameKind::RewireAck, frame.seq, &body)?;
            state.shard = Some(next);
            Ok(())
        }
        Err(e) => {
            poison_peers(state, frame.seq, FrameKind::PeerEdges, &edges_sent);
            worker_err(writer, frame.seq, &format!("rewire failed: {e}"))
        }
    }
}

fn rewire_inner(
    state: &mut WorkerState,
    frame: &Frame,
    edges_sent: &mut Vec<bool>,
) -> Result<(Vec<u8>, ShardCustody), TransportError> {
    let seq = frame.seq;
    let new_n = {
        let mut r = BodyReader::new(&frame.body);
        let new_n = r.u64("rewire new n")?;
        r.expect_end("rewire")?;
        new_n
    };
    let p = state.machines as usize;
    if state.mirror_vb != 4 {
        return Err(proto("rewire needs a u32 map mirror".into()));
    }
    let map_len = state.mirror.len() / 4;
    let Some(custody) = state.shard.as_ref() else {
        return Err(proto("rewire before shard custody".into()));
    };
    if state.mesh.is_none() && p > 1 {
        return Err(proto("rewire before the peer mesh is up".into()));
    }

    // ---- relabel + re-bucket by the next generation's ownership --------
    // Chunked like hop generation: each pool job relabels one contiguous
    // row range into its own bucket set.  Order never matters past this
    // point — the adopting side sorts + dedups — but chunk-order merges
    // keep the shipped bytes identical across thread counts anyway.
    let t = state.threads.max(1);
    let taken = take_bucket_sets(&mut state.bucket_bufs, t, p);
    let cursor = custody.cursor();
    let rows = cursor.len();
    let jobs: Vec<_> = taken
        .into_iter()
        .enumerate()
        .map(|(i, mut set)| {
            let (lo, hi) = chunk_range(rows, t, i);
            let sub = cursor.slice(lo, hi);
            let mirror = &state.mirror;
            move || -> Result<Vec<Vec<u8>>, String> {
                let map_at = |v: usize| -> u32 {
                    u32::from_le_bytes(mirror[v * 4..v * 4 + 4].try_into().unwrap())
                };
                for (u, v) in sub.iter() {
                    if (u as usize) >= map_len || (v as usize) >= map_len {
                        return Err(format!("edge ({u},{v}) outside the map"));
                    }
                    let (nu, nv) = (map_at(u as usize), map_at(v as usize));
                    if nu == u32::MAX || nv == u32::MAX {
                        return Err(format!("map drops endpoint of live edge ({u},{v})"));
                    }
                    if nu == nv {
                        continue; // self-loop vanishes
                    }
                    let (a, b) = if nu < nv { (nu, nv) } else { (nv, nu) };
                    let bucket = &mut set[machine_of(a as u64, p)];
                    bucket.extend_from_slice(&a.to_le_bytes());
                    bucket.extend_from_slice(&b.to_le_bytes());
                }
                Ok(set)
            }
        })
        .collect();
    let mut sets: Vec<Vec<Vec<u8>>> = Vec::with_capacity(t);
    for r in state.pool.run_jobs(jobs) {
        sets.push(r.map_err(proto)?);
    }

    ship_and_adopt(state, seq, sets, new_n, edges_sent)
}

/// Ship normalized `(a, b)` edge buckets peer-to-peer, merge what this
/// machine owns in the next generation, adopt the canonical result as
/// the new custody, and build the `RewireAck` body
/// (`len | checksum | p | peer_counts | mesh_sent`).  Shared by the
/// map-shipped `Rewire` and the worker-native `GatherRewire` — the two
/// differ only in how the bucket sets are generated.  Buckets arrive as
/// chunk sets; each peer's frame ships the chunk slices in order
/// (serial-identical bytes), and the own edges decode straight from
/// their chunk buffers plus the received frame bodies — no merge-buffer
/// staging copy on either side of the wire.
fn ship_and_adopt(
    state: &mut WorkerState,
    seq: u64,
    sets: Vec<Vec<Vec<u8>>>,
    new_n: u64,
    edges_sent: &mut Vec<bool>,
) -> Result<(Vec<u8>, ShardCustody), TransportError> {
    let p = state.machines as usize;
    let my = state.worker_id as usize;

    // decode one normalized-edge payload slice straight into the merge
    // vector, enforcing the next-generation invariant per edge; the
    // canonicalizing sort + dedup below makes decode order irrelevant
    let mut new_edges: Vec<(Vertex, Vertex)> = Vec::new();
    let decode_into =
        |new_edges: &mut Vec<(Vertex, Vertex)>, body: &[u8]| -> Result<(), TransportError> {
            if body.len() % 8 != 0 {
                return Err(proto("ragged rewired-edge payload".into()));
            }
            new_edges.reserve(body.len() / 8);
            for pair in body.chunks_exact(8) {
                let a = u32::from_le_bytes(pair[..4].try_into().unwrap());
                let b = u32::from_le_bytes(pair[4..].try_into().unwrap());
                if a >= b || (b as u64) >= new_n || machine_of(a as u64, p) != my {
                    return Err(proto(format!(
                        "rewired edge ({a},{b}) violates the next-generation invariant"
                    )));
                }
                new_edges.push((a, b));
            }
            Ok(())
        };

    // ---- ship: custody moves peer-to-peer, never via the coordinator ---
    let mut mesh_sent = 0u64;
    edges_sent.resize(p, false);
    if let Some(mesh) = state.mesh.as_mut() {
        for jj in 1..p {
            let j = (my + jj) % p;
            if let Some(link) = mesh.links[j].as_mut() {
                let parts: Vec<&[u8]> = sets.iter().map(|s| s[j].as_slice()).collect();
                let len: u64 = parts.iter().map(|b| b.len() as u64).sum();
                net::write_frame_slices(link, FrameKind::PeerEdges, seq, &parts)
                    .map_err(|e| e.for_worker(j))?;
                edges_sent[j] = true;
                mesh_sent += net::FRAME_HEADER_BYTES + len;
            }
        }
        // own edges decode while the peers are still shipping theirs
        for s in &sets {
            decode_into(&mut new_edges, &s[my])?;
        }
        let mut pending = p - 1;
        while pending > 0 {
            let ev = mesh.recv()?;
            let peer_frame = ev.frame.map_err(|e| e.for_worker(ev.from))?;
            if peer_frame.kind != FrameKind::PeerEdges || peer_frame.seq != seq {
                return Err(proto(format!(
                    "expected PeerEdges seq {seq}, got {:?} seq {}",
                    peer_frame.kind, peer_frame.seq
                )));
            }
            decode_into(&mut new_edges, &peer_frame.body)?;
            pending -= 1;
        }
    } else {
        for s in &sets {
            decode_into(&mut new_edges, &s[my])?;
        }
    }
    put_bucket_sets(&mut state.bucket_bufs, sets);

    // ---- adopt the next generation (canonical order = global dedup) ----
    new_edges.sort_unstable();
    new_edges.dedup();
    let stats = ShardStats::from_edges(&new_edges, p, my);
    // Re-frame the next generation once, at the custody boundary — the
    // encode returns the same logical row-major checksum the coordinator
    // pins, and the image is what every later round (and any onward
    // custody transfer) walks directly.
    let (image, checksum) = spill::encode_shard_bytes(my as u32, p as u32, &new_edges);
    let mut body = Vec::with_capacity(8 + 8 + 4 + 8 * p + 8);
    body.extend_from_slice(&stats.len.to_le_bytes());
    body.extend_from_slice(&checksum.to_le_bytes());
    body.extend_from_slice(&(p as u32).to_le_bytes());
    for &c in &stats.peer_counts {
        body.extend_from_slice(&c.to_le_bytes());
    }
    body.extend_from_slice(&mesh_sent.to_le_bytes());
    Ok((
        body,
        ShardCustody {
            image,
            shard: my as u32,
            machines: p as u32,
            stats,
            checksum,
        },
    ))
}

/// `GatherRewire`: the worker-native Cracker hub rewire.  Instead of
/// the coordinator gathering every `(hub, spoke)` pair and shipping the
/// rebuilt shards back out (two O(m) traversals of the coordinator
/// links), each worker derives the next generation's edges directly
/// from the map mirror it already holds: per owned edge `(u, v)` the
/// hub pairs `(m[u], v)` and `(m[v], u)`, plus `(m[v], v)` for every
/// `v` in this shard's primary chunk — the same message set Cracker's
/// `rewire` emits through `round_map_chunked` — then normalizes,
/// ships, and adopts through the shared `ship_and_adopt` path.  The
/// ack's stats + checksum are pinned against the coordinator's locally
/// built graph, so the shard is bit-identical by construction.
fn handle_gather_rewire<W: std::io::Write>(
    state: &mut WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let mut edges_sent = Vec::new();
    match gather_rewire_inner(state, frame, &mut edges_sent) {
        Ok((body, next)) => {
            net::write_frame(writer, FrameKind::RewireAck, frame.seq, &body)?;
            state.shard = Some(next);
            Ok(())
        }
        Err(e) => {
            poison_peers(state, frame.seq, FrameKind::PeerEdges, &edges_sent);
            worker_err(writer, frame.seq, &format!("gather rewire failed: {e}"))
        }
    }
}

fn gather_rewire_inner(
    state: &mut WorkerState,
    frame: &Frame,
    edges_sent: &mut Vec<bool>,
) -> Result<(Vec<u8>, ShardCustody), TransportError> {
    let seq = frame.seq;
    let (new_n, program) = {
        let mut r = BodyReader::new(&frame.body);
        let new_n = r.u64("gather rewire new n")?;
        let program = WireOp::from_code(r.u8("gather rewire program")?)
            .ok_or_else(|| proto("unknown gather rewire program".into()))?;
        r.expect_end("gather rewire")?;
        (new_n, program)
    };
    if program != WireOp::GatherPairU32 {
        return Err(proto(format!(
            "gather rewire only runs {:?}, got {program:?}",
            WireOp::GatherPairU32
        )));
    }
    let p = state.machines as usize;
    let my = state.worker_id as usize;
    if state.mirror_vb != 4 {
        return Err(proto("gather rewire needs a u32 map mirror".into()));
    }
    let map_len = state.mirror.len() / 4;
    let Some(custody) = state.shard.as_ref() else {
        return Err(proto("gather rewire before shard custody".into()));
    };
    if state.mesh.is_none() && p > 1 {
        return Err(proto("gather rewire before the peer mesh is up".into()));
    }

    // ---- generate the hub pairs from the owned shard + the map ---------
    // Chunked like hop generation: edge-row chunks first, primary-chunk
    // self-pair sub-ranges after, each job into its own bucket set.
    let t = state.threads.max(1);
    let taken = take_bucket_sets(&mut state.bucket_bufs, 2 * t, p);
    let cursor = custody.cursor();
    let rows = cursor.len();
    let mut specs: Vec<(GenSpan<'_>, Vec<Vec<u8>>)> = Vec::with_capacity(2 * t);
    {
        let mut taken = taken.into_iter();
        for i in 0..t {
            let (lo, hi) = chunk_range(rows, t, i);
            specs.push((
                GenSpan::Edges(cursor.slice(lo, hi)),
                taken.next().expect("one set per chunk"),
            ));
        }
        let (sa, sb) = chunk_range(map_len, p, my);
        for i in 0..t {
            let (lo, hi) = chunk_range(sb - sa, t, i);
            specs.push((
                GenSpan::Selfs(sa + lo, sa + hi),
                taken.next().expect("one set per chunk"),
            ));
        }
    }
    let mirror = &state.mirror;
    let jobs: Vec<_> = specs
        .into_iter()
        .map(|(span, mut set)| {
            move || -> Result<Vec<Vec<u8>>, String> {
                let map_at = |v: usize| -> u32 {
                    u32::from_le_bytes(mirror[v * 4..v * 4 + 4].try_into().unwrap())
                };
                let mut push = |set: &mut Vec<Vec<u8>>, hub: u32, spoke: u32| {
                    if hub == spoke {
                        return; // self-loop vanishes under normalization
                    }
                    let (a, b) = if hub < spoke { (hub, spoke) } else { (spoke, hub) };
                    let bucket = &mut set[machine_of(a as u64, p)];
                    bucket.extend_from_slice(&a.to_le_bytes());
                    bucket.extend_from_slice(&b.to_le_bytes());
                };
                match span {
                    GenSpan::Edges(sub) => {
                        for (u, v) in sub.iter() {
                            if (u as usize) >= map_len || (v as usize) >= map_len {
                                return Err(format!("edge ({u},{v}) outside the map"));
                            }
                            let (mu, mv) = (map_at(u as usize), map_at(v as usize));
                            if mu == u32::MAX || mv == u32::MAX {
                                return Err(format!(
                                    "map drops endpoint of live edge ({u},{v})"
                                ));
                            }
                            push(&mut set, mu, v);
                            push(&mut set, mv, u);
                        }
                    }
                    GenSpan::Selfs(lo, hi) => {
                        for v in lo..hi {
                            let mv = map_at(v);
                            if mv == u32::MAX {
                                return Err(format!("map drops live vertex {v}"));
                            }
                            push(&mut set, mv, v as u32);
                        }
                    }
                }
                Ok(set)
            }
        })
        .collect();
    let mut sets: Vec<Vec<Vec<u8>>> = Vec::with_capacity(2 * t);
    for r in state.pool.run_jobs(jobs) {
        sets.push(r.map_err(proto)?);
    }

    ship_and_adopt(state, seq, sets, new_n, edges_sent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Drive a full worker session from an in-test coordinator thread:
    /// handshake, shard custody, a data round, a fold round, a virtual
    /// round, shutdown.
    #[test]
    fn worker_serves_the_protocol_end_to_end() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            serve(stream)
        });
        let (coord, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(coord.try_clone().unwrap());
        let mut writer = BufWriter::new(coord);

        // handshake
        let hello = net::read_frame(&mut reader).unwrap();
        assert_eq!(hello.kind, FrameKind::Hello);
        {
            let mut r = BodyReader::new(&hello.body);
            assert_eq!(r.u32("version").unwrap(), PROTO_VERSION);
            let _pid = r.u32("pid").unwrap();
            let port = r.u16("mesh port").unwrap();
            assert!(port != 0, "worker must advertise a mesh port");
            let threads = r.u32("worker threads").unwrap();
            assert!(threads >= 1, "worker must advertise its thread count");
            r.expect_end("hello").unwrap();
        }
        let p = 2u32;
        let mut body = Vec::new();
        body.extend_from_slice(&PROTO_VERSION.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes()); // worker_id = 1
        body.extend_from_slice(&p.to_le_bytes());
        net::write_frame(&mut writer, FrameKind::Assign, 0, &body).unwrap();

        // shard custody: edges owned by machine 1 of 2
        let edges: Vec<(u32, u32)> = (0u32..50)
            .filter(|&u| machine_of(u as u64, 2) == 1)
            .map(|u| (u, u + 3))
            .collect();
        let (image, checksum) = spill::encode_shard_bytes(1, 2, &edges);
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(image.len() as u64).to_le_bytes());
        body.extend_from_slice(&image);
        net::write_frame(&mut writer, FrameKind::LoadShard, 1, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::LoadAck);
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u32("shard").unwrap(), 1);
        assert_eq!(r.u64("len").unwrap(), edges.len() as u64);
        assert_eq!(r.u64("checksum").unwrap(), checksum);
        let ack_p = r.u32("p").unwrap();
        assert_eq!(ack_p, 2);
        let want = ShardStats::from_edges(&edges, 2, 1);
        for j in 0..2 {
            assert_eq!(r.u64("peer").unwrap(), want.peer_counts[j]);
        }

        // a data round: 2 records of (key u64, u32), no fold
        let mut payload = Vec::new();
        for (k, v) in [(4u64, 9u32), (6, 2)] {
            payload.extend_from_slice(&k.to_le_bytes());
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let body = net::encode_round_body(false, None, payload.len() as u64, "t", &payload);
        net::write_frame(&mut writer, FrameKind::Round, 2, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::RoundAck);
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u64("accounted").unwrap(), payload.len() as u64);
        assert_eq!(r.u64("fold len").unwrap(), 0);

        // a fold round: min over two values of one key
        let mut payload = Vec::new();
        for (k, v) in [(4u64, 9u32), (4, 2)] {
            payload.extend_from_slice(&k.to_le_bytes());
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let body = net::encode_round_body(
            false,
            Some(crate::mpc::transport::WireOp::MinU32),
            payload.len() as u64,
            "hop",
            &payload,
        );
        net::write_frame(&mut writer, FrameKind::Round, 3, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u64("accounted").unwrap(), payload.len() as u64);
        let fl = r.u64("fold len").unwrap();
        assert_eq!(fl, 12); // one (key u64, u32) pair
        let pairs = r.bytes(fl as usize, "fold").unwrap();
        assert_eq!(u64::from_le_bytes(pairs[..8].try_into().unwrap()), 4);
        assert_eq!(u32::from_le_bytes(pairs[8..12].try_into().unwrap()), 2);

        // a virtual (charge-only) round acks the declared load
        let body = net::encode_round_body(true, None, 4242, "contract/left", &[]);
        net::write_frame(&mut writer, FrameKind::Round, 4, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u64("accounted").unwrap(), 4242);

        // shutdown
        net::write_frame(&mut writer, FrameKind::Shutdown, 5, &[]).unwrap();
        let bye = net::read_frame(&mut reader).unwrap();
        assert_eq!(bye.kind, FrameKind::Bye);
        worker.join().unwrap().unwrap();
    }

    #[test]
    fn worker_rejects_a_foreign_shard_with_worker_err() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            serve(stream)
        });
        let (coord, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(coord.try_clone().unwrap());
        let mut writer = BufWriter::new(coord);
        let _hello = net::read_frame(&mut reader).unwrap();
        let mut body = Vec::new();
        body.extend_from_slice(&PROTO_VERSION.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&2u32.to_le_bytes());
        net::write_frame(&mut writer, FrameKind::Assign, 0, &body).unwrap();

        // ship shard 1's image to worker 0: custody violation
        let edges: Vec<(u32, u32)> = (0u32..50)
            .filter(|&u| machine_of(u as u64, 2) == 1)
            .map(|u| (u, u + 3))
            .collect();
        let (image, _) = spill::encode_shard_bytes(1, 2, &edges);
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(image.len() as u64).to_le_bytes());
        body.extend_from_slice(&image);
        net::write_frame(&mut writer, FrameKind::LoadShard, 1, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::WorkerErr);
        let detail = String::from_utf8_lossy(&ack.body).into_owned();
        assert!(detail.contains("shard 1"), "{detail}");

        net::write_frame(&mut writer, FrameKind::Shutdown, 2, &[]).unwrap();
        let bye = net::read_frame(&mut reader).unwrap();
        assert_eq!(bye.kind, FrameKind::Bye);
        worker.join().unwrap().unwrap();
    }

    /// A single-machine shuffle session end to end: roster (empty mesh),
    /// mirror sync, a descriptor hop (generated from the shard, folded
    /// locally, mirror updated), and a rewire that contracts the shard —
    /// all without one payload byte crossing the coordinator link.  The
    /// session pins exact received byte counts and fold checksums, so
    /// running it at several thread counts is the bit-identity assertion
    /// for the chunked generate / key-range fold paths.
    fn drive_descriptor_session(threads: usize) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            serve_with_threads(stream, threads)
        });
        let (coord, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(coord.try_clone().unwrap());
        let mut writer = BufWriter::new(coord);
        let _hello = net::read_frame(&mut reader).unwrap();
        let mut body = Vec::new();
        body.extend_from_slice(&PROTO_VERSION.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes()); // worker 0 of 1
        body.extend_from_slice(&1u32.to_le_bytes());
        net::write_frame(&mut writer, FrameKind::Assign, 0, &body).unwrap();

        // custody: a 4-vertex path, machines = 1 owns everything
        let edges: Vec<(u32, u32)> = vec![(0, 1), (1, 2), (2, 3)];
        let (image, _) = spill::encode_shard_bytes(0, 1, &edges);
        let mut body = Vec::new();
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&(image.len() as u64).to_le_bytes());
        body.extend_from_slice(&image);
        net::write_frame(&mut writer, FrameKind::LoadShard, 1, &body).unwrap();
        assert_eq!(net::read_frame(&mut reader).unwrap().kind, FrameKind::LoadAck);

        // roster: one worker, no peers
        let mut roster = Vec::new();
        roster.extend_from_slice(&1u32.to_le_bytes());
        roster.extend_from_slice(&0u32.to_le_bytes());
        roster.extend_from_slice(&0u16.to_le_bytes());
        net::write_frame(&mut writer, FrameKind::Peers, 2, &roster).unwrap();
        assert_eq!(net::read_frame(&mut reader).unwrap().kind, FrameKind::PeersAck);

        // mirror: vals = [3, 0, 2, 1] (u32)
        let vals: [u32; 4] = [3, 0, 2, 1];
        let mut data = Vec::new();
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        let hash = net::mirror_hash_of(4, &data);
        let mut body = vec![4u8];
        body.extend_from_slice(&(data.len() as u64).to_le_bytes());
        body.extend_from_slice(&data);
        net::write_frame(&mut writer, FrameKind::StateSync, 3, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::StateAck);
        assert_eq!(
            u64::from_le_bytes(ack.body[..8].try_into().unwrap()),
            hash
        );

        // hop: min over closed neighborhoods of the path
        let mut body = vec![WireOp::MinU32.code(), 1u8];
        body.extend_from_slice(&3u16.to_le_bytes());
        body.extend_from_slice(b"hop");
        net::write_frame(&mut writer, FrameKind::HopRound, 4, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::HopAck, "{:?}", ack.body);
        let mut r = BodyReader::new(&ack.body);
        // 2 msgs/edge × 3 edges + 4 self = 10 messages × 12 bytes
        assert_eq!(r.u64("received").unwrap(), 120);
        // expected fold: min over N(v) ∪ {v} of [3,0,2,1] = [0,0,0,1]
        let mut expect = Vec::new();
        for (k, m) in [(0u64, 0u32), (1, 0), (2, 0), (3, 1)] {
            expect.extend_from_slice(&k.to_le_bytes());
            expect.extend_from_slice(&m.to_le_bytes());
        }
        let mut h = Fnv1a::new();
        h.update(&expect);
        assert_eq!(r.u64("fold checksum").unwrap(), h.finish());

        // rewire through map [0,0,1,1]: path contracts to one edge (0,1)
        let map: [u32; 4] = [0, 0, 1, 1];
        let mut data = Vec::new();
        for m in map {
            data.extend_from_slice(&m.to_le_bytes());
        }
        let mut body = vec![4u8];
        body.extend_from_slice(&(data.len() as u64).to_le_bytes());
        body.extend_from_slice(&data);
        net::write_frame(&mut writer, FrameKind::StateSync, 5, &body).unwrap();
        assert_eq!(net::read_frame(&mut reader).unwrap().kind, FrameKind::StateAck);
        net::write_frame(&mut writer, FrameKind::Rewire, 6, &2u64.to_le_bytes()).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::RewireAck, "{:?}", ack.body);
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u64("len").unwrap(), 1);
        assert_eq!(
            r.u64("checksum").unwrap(),
            spill::checksum_edges(&[(0u32, 1u32)])
        );

        // delta sync over the contracted generation: full base [5, 7],
        // then a one-entry patch — the ack must hash the FULL mirror
        let base: [u32; 2] = [5, 7];
        let mut data = Vec::new();
        for v in base {
            data.extend_from_slice(&v.to_le_bytes());
        }
        let mut body = vec![4u8];
        body.extend_from_slice(&(data.len() as u64).to_le_bytes());
        body.extend_from_slice(&data);
        net::write_frame(&mut writer, FrameKind::StateSync, 7, &body).unwrap();
        assert_eq!(net::read_frame(&mut reader).unwrap().kind, FrameKind::StateAck);
        let mut body = vec![4u8];
        body.extend_from_slice(&8u64.to_le_bytes()); // mirror total bytes
        body.extend_from_slice(&1u64.to_le_bytes()); // one changed entry
        body.extend_from_slice(&1u32.to_le_bytes()); // index 1
        body.extend_from_slice(&9u32.to_le_bytes()); // new value
        net::write_frame(&mut writer, FrameKind::StateDelta, 8, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::StateAck, "{:?}", ack.body);
        let mut patched = Vec::new();
        for v in [5u32, 9] {
            patched.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(
            u64::from_le_bytes(ack.body[..8].try_into().unwrap()),
            net::mirror_hash_of(4, &patched)
        );

        // a pipelined batch of two min hops over the contracted edge
        // (0,1) with mirror [5, 9]: round one folds the mirror to
        // [5, 5], round two is a fixed point — one ack for both
        let mut body = 2u16.to_le_bytes().to_vec();
        for label in ["h1", "h2"] {
            body.push(WireOp::MinU32.code());
            body.push(1u8);
            body.extend_from_slice(&(label.len() as u16).to_le_bytes());
            body.extend_from_slice(label.as_bytes());
        }
        net::write_frame(&mut writer, FrameKind::HopBatch, 9, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::HopBatchAck, "{:?}", ack.body);
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u16("count").unwrap(), 2);
        let fold_hash = |vals: [(u64, u32); 2]| {
            let mut img = Vec::new();
            for (k, v) in vals {
                img.extend_from_slice(&k.to_le_bytes());
                img.extend_from_slice(&v.to_le_bytes());
            }
            let mut h = Fnv1a::new();
            h.update(&img);
            h.finish()
        };
        for round in 0..2u32 {
            // edge msgs both ways + 2 self msgs = 4 × 12 bytes
            assert_eq!(r.u64("received").unwrap(), 48, "round {round}");
            assert_eq!(
                r.u64("fold checksum").unwrap(),
                fold_hash([(0, 5), (1, 5)]),
                "round {round}"
            );
            // one machine: nothing crossed the mesh
            assert_eq!(r.u64("mesh sent").unwrap(), 0, "round {round}");
        }

        // worker-native gather rewire through map [0, 0]: both hub pairs
        // of edge (0,1) plus the chunk self-pairs normalize to (0,1)
        let map: [u32; 2] = [0, 0];
        let mut data = Vec::new();
        for m in map {
            data.extend_from_slice(&m.to_le_bytes());
        }
        let mut body = vec![4u8];
        body.extend_from_slice(&(data.len() as u64).to_le_bytes());
        body.extend_from_slice(&data);
        net::write_frame(&mut writer, FrameKind::StateSync, 11, &body).unwrap();
        assert_eq!(net::read_frame(&mut reader).unwrap().kind, FrameKind::StateAck);
        let mut body = 2u64.to_le_bytes().to_vec();
        body.push(WireOp::GatherPairU32.code());
        net::write_frame(&mut writer, FrameKind::GatherRewire, 12, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::RewireAck, "{:?}", ack.body);
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u64("len").unwrap(), 1);
        assert_eq!(
            r.u64("checksum").unwrap(),
            spill::checksum_edges(&[(0u32, 1u32)])
        );

        net::write_frame(&mut writer, FrameKind::Shutdown, 13, &[]).unwrap();
        assert_eq!(net::read_frame(&mut reader).unwrap().kind, FrameKind::Bye);
        worker.join().unwrap().unwrap();
    }

    #[test]
    fn worker_serves_descriptor_rounds_on_one_machine() {
        drive_descriptor_session(1);
    }

    /// The same session, every ack pinned to the same bytes, with the
    /// data plane running chunked on a 4-thread pool.
    #[test]
    fn descriptor_rounds_are_bit_identical_on_a_thread_pool() {
        drive_descriptor_session(4);
    }
}
