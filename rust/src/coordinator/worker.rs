//! The worker process main loop (`lcc worker --connect HOST:PORT`).
//!
//! One worker process is one MPC machine of the multi-process transport
//! ([`crate::mpc::net`]): it connects back to the coordinator, handshakes
//! (`Hello`/`Assign` — the coordinator assigns the machine index), takes
//! **custody of its edge shard** (validating the spill framing and
//! independently re-deriving the shard statistics the coordinator's round
//! charges are computed from — custody divergence is caught before any
//! round runs), and then serves rounds until shutdown:
//!
//! * every round it counts the bytes it actually received (the
//!   receiver-side load accounting the coordinator validates against the
//!   model charge — for charge-only rounds the declared load is
//!   acknowledged instead, the barrier half of a round whose bytes never
//!   materialize);
//! * fold rounds ([`crate::mpc::transport::WireOp`]-tagged hops) are
//!   **reduced here**: the
//!   worker folds its received `(key, value)` messages with the tagged
//!   op and returns one folded pair per key it owns.
//!
//! Protocol violations the worker detects are answered with a
//! `WorkerErr` frame (the coordinator surfaces them as typed
//! [`TransportError::Protocol`]); I/O failures end the process.  EOF at a
//! frame boundary means the coordinator is gone: exit cleanly.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::path::Path;

use crate::graph::spill::{self, ShardStats, SpillError};
use crate::graph::Vertex;
use crate::mpc::net::{
    self, BodyReader, Frame, FrameKind, PROTO_VERSION,
};
use crate::mpc::simulator::machine_of;
use crate::mpc::transport::TransportError;

/// One worker's custody state.
struct WorkerState {
    worker_id: u32,
    machines: u32,
    /// The shard this machine owns (edges + independently derived stats),
    /// once the coordinator shipped it.  Custody is load-bearing at load
    /// time (framing + ownership validation, stats cross-check); the
    /// edges themselves are held for the worker-side message-generation
    /// step on the roadmap (today the coordinator still routes).
    #[allow(dead_code)]
    shard: Option<(Vec<(Vertex, Vertex)>, ShardStats)>,
}

/// Connect to the coordinator and serve until shutdown (the `lcc worker`
/// subcommand).
pub fn run_worker(connect: &str) -> Result<(), TransportError> {
    let stream = TcpStream::connect(connect).map_err(|e| TransportError::Io {
        worker: None,
        op: "connect to coordinator",
        source: e,
    })?;
    serve(stream)
}

/// Serve the worker protocol over an established stream (exposed so
/// tests can run a worker against an in-test coordinator).
pub fn serve(stream: TcpStream) -> Result<(), TransportError> {
    stream.set_nodelay(true).map_err(|e| TransportError::Io {
        worker: None,
        op: "set nodelay",
        source: e,
    })?;
    // a coordinator that stops draining must not block an ack write
    // forever; reads stay untimed — idling between rounds is normal
    stream
        .set_write_timeout(Some(net::IO_TIMEOUT))
        .map_err(|e| TransportError::Io {
            worker: None,
            op: "set write timeout",
            source: e,
        })?;
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| TransportError::Io {
        worker: None,
        op: "clone stream",
        source: e,
    })?);
    let mut writer = BufWriter::new(stream);

    // handshake: version + our pid (the coordinator aligns its spawned
    // children to worker ids by it)
    let mut hello = Vec::with_capacity(8);
    hello.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    hello.extend_from_slice(&std::process::id().to_le_bytes());
    net::write_frame(&mut writer, FrameKind::Hello, 0, &hello)?;
    let assign = net::read_frame(&mut reader)?;
    if assign.kind != FrameKind::Assign {
        return Err(TransportError::Protocol {
            worker: None,
            detail: format!("expected Assign, got {:?}", assign.kind),
        });
    }
    let mut r = BodyReader::new(&assign.body);
    let version = r.u32("assign version")?;
    if version != PROTO_VERSION {
        return Err(TransportError::Protocol {
            worker: None,
            detail: format!("coordinator speaks protocol {version}, worker {PROTO_VERSION}"),
        });
    }
    let worker_id = r.u32("worker id")?;
    let machines = r.u32("machine count")?;
    let mut state = WorkerState {
        worker_id,
        machines,
        shard: None,
    };

    loop {
        let frame = match net::read_frame(&mut reader) {
            Ok(f) => f,
            // EOF at a frame boundary: the coordinator dropped the
            // connection (its transport was dropped) — clean exit.
            Err(TransportError::ShortRead { got: 0, .. }) => return Ok(()),
            Err(e) => return Err(e),
        };
        match frame.kind {
            FrameKind::LoadShard => handle_load(&mut state, &frame, &mut writer)?,
            FrameKind::Round => handle_round(&state, &frame, &mut writer)?,
            FrameKind::Shutdown => {
                net::write_frame(&mut writer, FrameKind::Bye, frame.seq, &[])?;
                return Ok(());
            }
            other => {
                worker_err(
                    &mut writer,
                    frame.seq,
                    &format!("unexpected frame kind {other:?}"),
                )?;
            }
        }
    }
}

fn worker_err<W: std::io::Write>(
    writer: &mut W,
    seq: u64,
    detail: &str,
) -> Result<(), TransportError> {
    net::write_frame(writer, FrameKind::WorkerErr, seq, detail.as_bytes())
}

/// Take custody of this machine's shard: validate the spill framing
/// (magic, identity, length, payload checksum), enforce the
/// shard-ownership invariant edge by edge, and re-derive the statistics
/// the coordinator will cross-check.
fn handle_load<W: std::io::Write>(
    state: &mut WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let mut r = BodyReader::new(&frame.body);
    let parsed = (|| -> Result<(u32, Vec<(Vertex, Vertex)>, u64), SpillError> {
        let shard = r
            .u32("load shard index")
            .map_err(|e| SpillError::Corrupt {
                path: "<frame>".into(),
                detail: e.to_string(),
            })?;
        let image_len = r.u64("load image length").map_err(|e| SpillError::Corrupt {
            path: "<frame>".into(),
            detail: e.to_string(),
        })? as usize;
        let image = r
            .bytes(image_len, "load image")
            .map_err(|e| SpillError::Corrupt {
                path: "<frame>".into(),
                detail: e.to_string(),
            })?;
        let (edges, checksum) =
            spill::read_shard_bytes(image, shard, state.machines, Path::new("<frame>"))?;
        Ok((shard, edges, checksum))
    })();
    let (shard, edges, checksum) = match parsed {
        Ok(v) => v,
        Err(e) => return worker_err(writer, frame.seq, &format!("shard image rejected: {e}")),
    };
    if shard != state.worker_id {
        return worker_err(
            writer,
            frame.seq,
            &format!("received shard {shard}, this machine is {}", state.worker_id),
        );
    }
    // shard-ownership invariant, validated on the machine taking custody
    let p = state.machines as usize;
    for &(u, v) in &edges {
        if u >= v || machine_of(u as u64, p) != state.worker_id as usize {
            return worker_err(
                writer,
                frame.seq,
                &format!("edge ({u},{v}) violates the shard-ownership invariant"),
            );
        }
    }
    let stats = ShardStats::from_edges(&edges, p, state.worker_id as usize);
    let mut body = Vec::with_capacity(4 + 8 + 8 + 4 + 8 * p);
    body.extend_from_slice(&shard.to_le_bytes());
    body.extend_from_slice(&stats.len.to_le_bytes());
    body.extend_from_slice(&checksum.to_le_bytes());
    body.extend_from_slice(&(p as u32).to_le_bytes());
    for &c in &stats.peer_counts {
        body.extend_from_slice(&c.to_le_bytes());
    }
    net::write_frame(writer, FrameKind::LoadAck, frame.seq, &body)?;
    state.shard = Some((edges, stats));
    Ok(())
}

/// Serve one round: account the received bytes (or acknowledge the
/// declared load of a charge-only round), fold when asked, ack.
fn handle_round<W: std::io::Write>(
    _state: &WorkerState,
    frame: &Frame,
    writer: &mut W,
) -> Result<(), TransportError> {
    let msg = match net::decode_round_body(&frame.body) {
        Ok(m) => m,
        Err(e) => return worker_err(writer, frame.seq, &format!("bad round body: {e}")),
    };
    let accounted = if msg.virtual_round {
        msg.declared_bytes
    } else {
        msg.payload.len() as u64
    };
    let folded = match msg.fold {
        None => Vec::new(),
        Some(op) => match net::fold_wire_payload(op, msg.payload) {
            Ok(f) => f,
            Err(detail) => {
                return worker_err(
                    writer,
                    frame.seq,
                    &format!("round {:?}: {detail}", msg.label),
                )
            }
        },
    };
    let mut body = Vec::with_capacity(8 + 8 + folded.len());
    body.extend_from_slice(&accounted.to_le_bytes());
    body.extend_from_slice(&(folded.len() as u64).to_le_bytes());
    body.extend_from_slice(&folded);
    net::write_frame(writer, FrameKind::RoundAck, frame.seq, &body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Drive a full worker session from an in-test coordinator thread:
    /// handshake, shard custody, a data round, a fold round, a virtual
    /// round, shutdown.
    #[test]
    fn worker_serves_the_protocol_end_to_end() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            serve(stream)
        });
        let (coord, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(coord.try_clone().unwrap());
        let mut writer = BufWriter::new(coord);

        // handshake
        let hello = net::read_frame(&mut reader).unwrap();
        assert_eq!(hello.kind, FrameKind::Hello);
        let p = 2u32;
        let mut body = Vec::new();
        body.extend_from_slice(&PROTO_VERSION.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes()); // worker_id = 1
        body.extend_from_slice(&p.to_le_bytes());
        net::write_frame(&mut writer, FrameKind::Assign, 0, &body).unwrap();

        // shard custody: edges owned by machine 1 of 2
        let edges: Vec<(u32, u32)> = (0u32..50)
            .filter(|&u| machine_of(u as u64, 2) == 1)
            .map(|u| (u, u + 3))
            .collect();
        let (image, checksum) = spill::encode_shard_bytes(1, 2, &edges);
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(image.len() as u64).to_le_bytes());
        body.extend_from_slice(&image);
        net::write_frame(&mut writer, FrameKind::LoadShard, 1, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::LoadAck);
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u32("shard").unwrap(), 1);
        assert_eq!(r.u64("len").unwrap(), edges.len() as u64);
        assert_eq!(r.u64("checksum").unwrap(), checksum);
        let ack_p = r.u32("p").unwrap();
        assert_eq!(ack_p, 2);
        let want = ShardStats::from_edges(&edges, 2, 1);
        for j in 0..2 {
            assert_eq!(r.u64("peer").unwrap(), want.peer_counts[j]);
        }

        // a data round: 2 records of (key u64, u32), no fold
        let mut payload = Vec::new();
        for (k, v) in [(4u64, 9u32), (6, 2)] {
            payload.extend_from_slice(&k.to_le_bytes());
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let body = net::encode_round_body(false, None, payload.len() as u64, "t", &payload);
        net::write_frame(&mut writer, FrameKind::Round, 2, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::RoundAck);
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u64("accounted").unwrap(), payload.len() as u64);
        assert_eq!(r.u64("fold len").unwrap(), 0);

        // a fold round: min over two values of one key
        let mut payload = Vec::new();
        for (k, v) in [(4u64, 9u32), (4, 2)] {
            payload.extend_from_slice(&k.to_le_bytes());
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let body = net::encode_round_body(
            false,
            Some(crate::mpc::transport::WireOp::MinU32),
            payload.len() as u64,
            "hop",
            &payload,
        );
        net::write_frame(&mut writer, FrameKind::Round, 3, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u64("accounted").unwrap(), payload.len() as u64);
        let fl = r.u64("fold len").unwrap();
        assert_eq!(fl, 12); // one (key u64, u32) pair
        let pairs = r.bytes(fl as usize, "fold").unwrap();
        assert_eq!(u64::from_le_bytes(pairs[..8].try_into().unwrap()), 4);
        assert_eq!(u32::from_le_bytes(pairs[8..12].try_into().unwrap()), 2);

        // a virtual (charge-only) round acks the declared load
        let body = net::encode_round_body(true, None, 4242, "contract/left", &[]);
        net::write_frame(&mut writer, FrameKind::Round, 4, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        let mut r = BodyReader::new(&ack.body);
        assert_eq!(r.u64("accounted").unwrap(), 4242);

        // shutdown
        net::write_frame(&mut writer, FrameKind::Shutdown, 5, &[]).unwrap();
        let bye = net::read_frame(&mut reader).unwrap();
        assert_eq!(bye.kind, FrameKind::Bye);
        worker.join().unwrap().unwrap();
    }

    #[test]
    fn worker_rejects_a_foreign_shard_with_worker_err() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            serve(stream)
        });
        let (coord, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(coord.try_clone().unwrap());
        let mut writer = BufWriter::new(coord);
        let _hello = net::read_frame(&mut reader).unwrap();
        let mut body = Vec::new();
        body.extend_from_slice(&PROTO_VERSION.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&2u32.to_le_bytes());
        net::write_frame(&mut writer, FrameKind::Assign, 0, &body).unwrap();

        // ship shard 1's image to worker 0: custody violation
        let edges: Vec<(u32, u32)> = (0u32..50)
            .filter(|&u| machine_of(u as u64, 2) == 1)
            .map(|u| (u, u + 3))
            .collect();
        let (image, _) = spill::encode_shard_bytes(1, 2, &edges);
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(image.len() as u64).to_le_bytes());
        body.extend_from_slice(&image);
        net::write_frame(&mut writer, FrameKind::LoadShard, 1, &body).unwrap();
        let ack = net::read_frame(&mut reader).unwrap();
        assert_eq!(ack.kind, FrameKind::WorkerErr);
        let detail = String::from_utf8_lossy(&ack.body).into_owned();
        assert!(detail.contains("shard 1"), "{detail}");

        net::write_frame(&mut writer, FrameKind::Shutdown, 2, &[]).unwrap();
        let bye = net::read_frame(&mut reader).unwrap();
        assert_eq!(bye.kind, FrameKind::Bye);
        worker.join().unwrap().unwrap();
    }
}
